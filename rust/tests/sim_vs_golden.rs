//! Integration: the cycle-accurate simulators against the golden direct
//! convolution, over randomised geometries, plus the paper's measured
//! invariants at full (224×224) scale.

use trim_sa::arch::control::plan_layer;
use trim_sa::arch::{ArchConfig, EngineSim, SliceSim};
use trim_sa::golden::{conv2d_i32, conv3d_i32, Tensor3};
use trim_sa::model::ConvLayer;
use trim_sa::util::SplitMix64;

/// 40 random slice geometries, bit-exact.
#[test]
fn randomized_slice_vs_golden() {
    let mut rng = SplitMix64::new(0xA11CE);
    for round in 0..40 {
        let k = [2, 3, 3, 3, 5][rng.range(0, 5)];
        let pad = rng.range(0, k.min(3));
        let stride = [1, 1, 1, 2][rng.range(0, 4)];
        let h = rng.range(k + stride + 2, 24);
        let w = rng.range(k.max(4) + 2, 24); // keep W_O ≥ K
        let ifmap = rng.vec_i32(h * w, 0, 256);
        let weights = rng.vec_i32(k * k, -128, 128);

        let golden = conv2d_i32(&ifmap, h, w, &weights, k, stride, pad);
        let r = SliceSim::new(k, w + 2 * pad).run_conv(&ifmap, h, w, &weights, pad, stride);
        assert_eq!(r.output, golden, "round {round}: {h}x{w} k{k} p{pad} s{stride}");
        // input port invariant: padded ifmap read exactly once
        assert_eq!(r.stats.ext_input_reads, ((h + 2 * pad) * (w + 2 * pad)) as u64, "round {round}");
        // eq. (4) peak
        assert_eq!(r.stats.peak_ext_inputs_per_cycle, (2 * k - 1) as u64, "round {round}");
    }
}

/// 12 random engine configurations/layers (native + tiled), bit-exact.
#[test]
fn randomized_engine_vs_golden() {
    let mut rng = SplitMix64::new(0xB0B);
    for round in 0..12 {
        let k = [3, 3, 5][rng.range(0, 3)];
        let pad = rng.range(0, 2);
        let hw = rng.range(k + 6, 16);
        let m = rng.range(1, 6);
        let n = rng.range(1, 6);
        let p_m = rng.range(1, 4);
        let p_n = rng.range(1, 4);
        let layer = ConvLayer::new(&format!("r{round}"), hw, k, m, n, 1, pad);
        let input = Tensor3::from_fn(m, hw, hw, |c, y, x| {
            ((c * 131 + y * 31 + x * 7 + round) % 256) as i32
        });
        let mut wrng = SplitMix64::new(round as u64 + 99);
        let weights = wrng.vec_i32(n * m * k * k, -16, 16);
        let sim = EngineSim::new(ArchConfig::small(3, p_m, p_n));
        let r = sim.run_layer(&layer, &input, &weights);
        assert_eq!(
            r.ofmaps,
            conv3d_i32(&input, &weights, n, k, 1, pad),
            "round {round}: hw{hw} k{k} m{m} n{n} P_M{p_m} P_N{p_n}"
        );
    }
}

/// §II claim at full scale: a 3×3 convolution over 224×224 exhibits a
/// ~1.8 % input-read overhead (ours: exactly 226²/224² − 1 = 1.79 %).
#[test]
fn full_scale_224_overhead_claim() {
    let hw = 224;
    let ifmap: Vec<i32> = (0..hw * hw).map(|i| i as i32 % 256).collect();
    let weights = vec![1i32, 2, 3, 4, 5, 6, 7, 8, 9];
    let r = SliceSim::new(3, 226).run_conv(&ifmap, hw, hw, &weights, 1, 1);
    let overhead = r.stats.input_read_overhead((hw * hw) as u64);
    assert!((overhead - 0.0179).abs() < 0.001, "overhead = {:.4}", overhead);
    // and the numerics still match golden at this scale
    let golden = conv2d_i32(&ifmap, hw, hw, &weights, 3, 1, 1);
    assert_eq!(r.output, golden);
    // RSRBs hold at most one padded row
    assert!(r.stats.max_rsrb_occupancy <= 226);
}

/// Engine cycle accounting equals eq. (2) for random native layers.
#[test]
fn engine_cycles_track_eq2() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..6 {
        let hw = rng.range(8, 14);
        let m = rng.range(1, 7);
        let n = rng.range(1, 7);
        let layer = ConvLayer::new("t", hw, 3, m, n, 1, 1);
        let cfg = ArchConfig::small(3, 2, 2);
        let input = Tensor3::zeros(m, hw, hw);
        let weights = vec![0i32; n * m * 9];
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        assert!(r.stats.cycles >= plan.total_cycles);
        // per-step pipeline fill is the only divergence allowed
        let slack = plan.steps * 16 + 32;
        assert!(r.stats.cycles <= plan.total_cycles + slack, "{} vs {}", r.stats.cycles, plan.total_cycles);
    }
}

/// The engine's psum-buffer traffic matches the analytical expression
/// `(2·m_steps − 1)·|ofmap|` used by Tables I–II.
#[test]
fn psum_buffer_traffic_matches_model() {
    let layer = ConvLayer::new("t", 10, 3, 5, 3, 1, 1);
    let cfg = ArchConfig::small(3, 2, 4); // m_steps = ⌈5/2⌉ = 3
    let input = Tensor3::from_fn(5, 10, 10, |c, y, x| (c + y + x) as i32);
    let weights = vec![1i32; 3 * 5 * 9];
    let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
    let ofmap = (3 * 10 * 10) as u64;
    let m_steps = 3u64;
    assert_eq!(r.stats.psum_buf_writes + r.stats.psum_buf_reads, ofmap * (2 * m_steps - 1));
}
