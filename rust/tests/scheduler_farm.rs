//! Scheduler/engine-farm integration: property tests (randomised with the
//! in-tree SplitMix64 driver, like tests/proptest_invariants.rs) plus the
//! acceptance workloads — farm output must be bit-exact against both the
//! golden convolution oracle and a single-engine `EngineSim` run, for any
//! engine count, in every sharding mode (filter / spatial / hybrid grid /
//! auto / pipeline, all dispatched by work stealing), including the tiled
//! K > 3 path and full-size VGG-16 / AlexNet layers; and the coordinator
//! must serve a ≥ 96-request batched workload from the sim backend with
//! no artifacts.

use std::sync::Arc;
use trim_sa::analytics::EnergyModel;
use trim_sa::arch::{ArchConfig, EngineSim, ExecFidelity, SimStats};
use trim_sa::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::model::quant::Requant;
use trim_sa::model::{alexnet::alexnet, vgg16::vgg16, ConvLayer};
use trim_sa::scheduler::{
    plan_filter_shards, plan_hybrid_shards, plan_row_shards, plan_shards, EngineFarm, FarmConfig,
    PipelineStage, ShardAxis, ShardMode, SimBackend, SimNetSpec,
};
use trim_sa::util::SplitMix64;

fn rand_tensor(rng: &mut SplitMix64, c: usize, h: usize, w: usize) -> Tensor3 {
    Tensor3 { c, h, w, data: rng.vec_i32(c * h * w, -96, 96) }
}

/// Closed-form off-chip input reads of one shard: `n_filters` filters of
/// `layer` over the output-row band `rows` (the slab the band reads, halo
/// rows included) — the "halo accounting" the row- and hybrid-shard stats
/// must follow. Mirrors `fastsim::analytic_stats` applied to the filter
/// sub-layer's slab layer: native layers broadcast the slab once per
/// filter group; tiled layers read the shifted slab view once per filter
/// pass. The full-row "band" is a whole-(sub-)layer run and reads the
/// whole padded ifmap (strided layers pay their decimation leftover rows
/// there).
fn expected_band_reads(
    arch: &ArchConfig,
    layer: &ConvLayer,
    n_filters: usize,
    rows: &std::ops::Range<usize>,
) -> u64 {
    let wp = layer.w_i + 2 * layer.pad;
    let slab_rows = if *rows == (0..layer.h_o()) {
        layer.h_i + 2 * layer.pad
    } else {
        layer.band_input_rows(rows).len()
    };
    if layer.k <= arch.k {
        let n_groups = n_filters.div_ceil(arch.p_n) as u64;
        n_groups * (layer.m * slab_rows * wp) as u64
    } else {
        let (hs, ws) = (slab_rows - layer.k + arch.k, wp - layer.k + arch.k);
        n_filters as u64 * (hs * ws) as u64
    }
}

/// Property: for random layer shapes (native 3×3 and tiled 5×5/7×7 paths,
/// strided and padded) and any engine count, the farm's reassembled ofmaps
/// are bit-exact against the golden conv AND a single-engine run, and its
/// summed access counters partition the single-engine counters exactly
/// (cycles take the max, so they may only shrink).
#[test]
fn prop_farm_bit_exact_any_engine_count() {
    let mut rng = SplitMix64::new(0xFA51);
    for seed in 0..14u64 {
        let k = [3usize, 3, 5, 7][rng.range(0, 4)];
        let hw = rng.range(k + 3, k + 12);
        let m = rng.range(1, 5);
        let n = rng.range(1, 10);
        let stride = rng.range(1, 3);
        let pad = rng.range(0, 2);
        let layer = ConvLayer::new("prop", hw, k, m, n, stride, pad);
        let input = rand_tensor(&mut rng, m, hw, hw);
        let weights = rng.vec_i32(n * m * k * k, -9, 9);
        let engines = rng.range(1, 6);
        let arch = ArchConfig::small(3, 2, rng.range(1, 4));

        let golden = conv3d_i32(&input, &weights, n, k, stride, pad);
        let single = EngineSim::new(arch).run_layer(&layer, &input, &weights);
        let farm = EngineFarm::new(FarmConfig::new(engines, arch));
        let r = farm.run_layer(&layer, &input, &weights).unwrap();

        let ctx = format!("seed {seed}: k={k} hw={hw} m={m} n={n} s={stride} p={pad} e={engines}");
        assert_eq!(r.ofmaps, golden, "{ctx}: farm vs golden");
        assert_eq!(r.ofmaps, single.ofmaps, "{ctx}: farm vs single engine");
        assert_eq!(r.stats.macs, single.stats.macs, "{ctx}: MACs conserved");
        assert_eq!(r.stats.ext_input_reads, single.stats.ext_input_reads, "{ctx}: reads conserved");
        assert_eq!(r.stats.output_writes, single.stats.output_writes, "{ctx}: writes conserved");
        assert_eq!(
            r.stats.psum_buf_reads + r.stats.psum_buf_writes,
            single.stats.psum_buf_reads + single.stats.psum_buf_writes,
            "{ctx}: on-chip accesses conserved"
        );
        assert!(r.stats.cycles <= single.stats.cycles, "{ctx}: parallel cycles must not grow");
        assert_eq!(
            r.stats.cycles,
            r.per_shard.iter().map(|s| s.cycles).max().unwrap(),
            "{ctx}: cycles = max over shards"
        );
    }
}

/// Property: the layer-pipeline mode produces bit-identical activations to
/// a serial golden chain (conv + requant per stage) for any engine count
/// and batch size, with outputs in input order.
#[test]
fn prop_pipeline_bit_exact_any_engine_count() {
    let mut rng = SplitMix64::new(0xBEEF);
    for seed in 0..8u64 {
        let depth = rng.range(2, 4);
        let hw0 = rng.range(10, 15);
        let mut chans = vec![rng.range(1, 4)];
        for _ in 0..depth {
            chans.push(rng.range(1, 5));
        }
        // Build a chain of pad-1 layers (3×3 keeps H, 5×5 shrinks by 2).
        let mut layers = Vec::new();
        let mut hw = hw0;
        for d in 0..depth {
            let k = if rng.range(0, 3) == 0 { 5 } else { 3 };
            let l = ConvLayer::new("pl", hw, k, chans[d], chans[d + 1], 1, 1);
            hw = l.h_o();
            layers.push(l);
        }
        let q = Requant::new(5, 8);
        let stages: Vec<PipelineStage> = layers
            .iter()
            .map(|l| PipelineStage {
                layer: l.clone(),
                weights: Arc::new(rng.vec_i32(l.n * l.m * l.k * l.k, -7, 7)),
                requant: Some(q),
            })
            .collect();
        let batch = rng.range(1, 5);
        let images: Vec<Tensor3> =
            (0..batch).map(|_| rand_tensor(&mut rng, chans[0], hw0, hw0)).collect();
        let engines = rng.range(1, 4);
        let farm = EngineFarm::new(FarmConfig::new(engines, ArchConfig::small(3, 2, 2)));
        let r = farm.run_pipeline(&stages, images.clone()).unwrap();

        for (img_idx, (img, out)) in images.iter().zip(&r.outputs).enumerate() {
            let mut act = img.clone();
            for s in &stages {
                let mut next = conv3d_i32(&act, &s.weights, s.layer.n, s.layer.k, s.layer.stride, s.layer.pad);
                for v in next.data.iter_mut() {
                    *v = q.apply(*v as i64) as i32;
                }
                act = next;
            }
            assert_eq!(out, &act, "seed {seed} image {img_idx}: depth={depth} e={engines}");
        }
    }
}

/// Property: the shard planner's structural invariants hold for arbitrary
/// (P_N, N, engines) — full cover, disjoint contiguous ranges, group
/// alignment, balance within one group, shard count = min(engines, groups).
#[test]
fn prop_shard_planner_invariants() {
    let mut rng = SplitMix64::new(0x51AD);
    for _ in 0..200 {
        let p_n = rng.range(1, 9);
        let n = rng.range(1, 120);
        let engines = rng.range(1, 10);
        let arch = ArchConfig { p_n, ..ArchConfig::paper_engine() };
        let layer = ConvLayer::new("p", 8, 3, 2, n, 1, 1);
        let plan = plan_filter_shards(&arch, &layer, engines);
        assert_eq!(plan.filter_groups, n.div_ceil(p_n));
        assert_eq!(plan.shards.len(), engines.min(plan.filter_groups));
        let mut next = 0usize;
        for s in &plan.shards {
            assert_eq!(s.filters.start, next);
            assert!(s.filters.start < s.filters.end);
            if s.filters.end != n {
                assert_eq!(s.filters.end % p_n, 0, "p_n={p_n} n={n} e={engines}");
            }
            next = s.filters.end;
        }
        assert_eq!(next, n);
        let gmin = plan.shards.iter().map(|s| s.groups).min().unwrap();
        let gmax = plan.shards.iter().map(|s| s.groups).max().unwrap();
        assert!(gmax - gmin <= 1);
        assert!(plan.speedup_bound() >= 1.0);
    }
}

/// Property: row-, hybrid- and auto-shard farm runs are **bit-identical**
/// to a single-engine run (and the golden conv) on BOTH fidelity tiers,
/// and their `SimStats` partition exactly: merged cycles = max over
/// shards, counters = sum; every per-shard entry equals an independent
/// single-engine `run_shard` of that (filters × rows) tile;
/// ofmap-proportional counters (output writes, psum traffic) partition
/// the single-engine counters exactly; off-chip input reads follow the
/// closed-form slab-with-halo accounting per shard (the PR-4 band
/// formulas extended to the grid: the halo depends only on the row-split
/// count `grid.1`, never on the filter splits); and on stride-1 layers
/// MACs and the full halo formula are exact. Sweeps strided, tiled-K>3,
/// multi-group and padded geometries.
#[test]
fn prop_row_and_auto_shards_bit_exact_both_fidelities() {
    let mut rng = SplitMix64::new(0x0551);
    for seed in 0..10u64 {
        let k = [3usize, 3, 5, 7][rng.range(0, 4)];
        let hw = rng.range(k + 3, k + 12);
        let m = rng.range(1, 5);
        let n = rng.range(1, 10);
        let stride = rng.range(1, 3);
        let pad = rng.range(0, 2);
        let layer = ConvLayer::new("rprop", hw, k, m, n, stride, pad);
        let input = rand_tensor(&mut rng, m, hw, hw);
        let weights = rng.vec_i32(n * m * k * k, -9, 9);
        let engines = rng.range(2, 6);
        let arch = ArchConfig::small(3, 2, rng.range(1, 4));
        let golden = conv3d_i32(&input, &weights, n, k, stride, pad);

        for fidelity in [ExecFidelity::Fast, ExecFidelity::Register] {
            let farm = EngineFarm::new(FarmConfig::with_fidelity(engines, arch, fidelity));
            let single = EngineSim::with_fidelity(arch, fidelity);
            let whole = single.run_layer(&layer, &input, &weights);
            for mode in [ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto] {
                let r = farm.run_layer_mode(&layer, &input, &weights, mode).unwrap();
                let ctx = format!(
                    "seed {seed} {fidelity} {mode}: k={k} hw={hw} m={m} n={n} s={stride} p={pad} \
                     e={engines} P_N={} axis={:?} grid={:?}",
                    arch.p_n, r.plan.axis, r.plan.grid
                );
                assert_eq!(r.ofmaps, golden, "{ctx}: farm vs golden");
                assert_eq!(r.ofmaps, whole.ofmaps, "{ctx}: farm vs single engine");
                assert_eq!(r.plan.shards.len(), r.plan.grid.0 * r.plan.grid.1, "{ctx}: grid dims");

                // merged = fold of the per-shard stats
                assert_eq!(
                    r.stats.cycles,
                    r.per_shard.iter().map(|s| s.cycles).max().unwrap(),
                    "{ctx}: cycles = max over shards"
                );
                assert_eq!(
                    r.stats.macs,
                    r.per_shard.iter().map(|s| s.macs).sum::<u64>(),
                    "{ctx}: MACs sum over shards"
                );
                assert_eq!(
                    r.stats.ext_input_reads,
                    r.per_shard.iter().map(|s| s.ext_input_reads).sum::<u64>(),
                    "{ctx}: reads sum over shards"
                );
                assert!(r.stats.cycles <= whole.stats.cycles, "{ctx}: sharding must not slow down");

                // ofmap-proportional counters partition the single run
                assert_eq!(r.stats.output_writes, whole.stats.output_writes, "{ctx}: writes");
                assert_eq!(
                    r.stats.psum_buf_reads + r.stats.psum_buf_writes,
                    whole.stats.psum_buf_reads + whole.stats.psum_buf_writes,
                    "{ctx}: on-chip accesses"
                );

                // every shard equals an independent single-engine run of
                // exactly that (filters × rows) tile
                for (shard, st) in r.plan.shards.iter().zip(&r.per_shard) {
                    let solo = single.run_shard(
                        &layer,
                        &input,
                        &weights,
                        shard.filters.clone(),
                        shard.rows.clone(),
                    );
                    assert_eq!(*st, solo.stats, "{ctx}: shard {} stats", shard.index);
                }

                // halo accounting: every shard reads its whole slab (for
                // its own filter count) — holds on all three axes
                let expect: u64 = r
                    .plan
                    .shards
                    .iter()
                    .map(|s| expected_band_reads(&arch, &layer, s.filters.len(), &s.rows))
                    .sum();
                assert_eq!(r.stats.ext_input_reads, expect, "{ctx}: slab+halo reads");
                let g_r = r.plan.grid.1 as u64;
                if stride == 1 && g_r > 1 {
                    // exact halo formula vs the single engine: each of the
                    // g_r−1 interior row boundaries duplicates K−1 slab
                    // rows — read per filter group × channel on the native
                    // path; the tiled path reads the *shifted view*
                    // (`hs = slab − K + K_nat`), where the same boundary
                    // overlaps as K_nat−1 view rows per filter pass.
                    // Filter splits duplicate nothing (each group's
                    // broadcast is counted once wherever it runs), so the
                    // grid halo is the PR-4 row formula with B = grid.1.
                    let wp = (layer.w_i + 2 * layer.pad) as u64;
                    let halo = if k <= arch.k {
                        layer.n.div_ceil(arch.p_n) as u64
                            * layer.m as u64
                            * wp
                            * (g_r - 1)
                            * (k as u64 - 1)
                    } else {
                        layer.n as u64
                            * (wp - k as u64 + arch.k as u64)
                            * (g_r - 1)
                            * (arch.k as u64 - 1)
                    };
                    assert_eq!(
                        r.stats.ext_input_reads,
                        whole.stats.ext_input_reads + halo,
                        "{ctx}: halo formula"
                    );
                    assert_eq!(r.stats.macs, whole.stats.macs, "{ctx}: stride-1 MACs partition");
                }

                // Auto must never pick a worse bound than any pure axis.
                if mode == ShardMode::Auto {
                    let bf = plan_filter_shards(&arch, &layer, engines).speedup_bound();
                    let br = plan_row_shards(&arch, &layer, engines).speedup_bound();
                    let bh = plan_hybrid_shards(&arch, &layer, engines).speedup_bound();
                    assert!(
                        r.plan.speedup_bound() >= bf.max(br).max(bh) - 1e-9,
                        "{ctx}: auto bound {} < max({bf}, {br}, {bh})",
                        r.plan.speedup_bound()
                    );
                }
            }
        }
    }
}

/// Property: the row-shard planner's structural invariants hold for
/// arbitrary (H, stride, engines) — full cover of `0..H_O`, disjoint
/// contiguous non-empty bands, balance within one row, shard count =
/// min(engines, H_O), and the row-axis speedup bound is whole rows over
/// the largest band.
#[test]
fn prop_row_planner_invariants() {
    let mut rng = SplitMix64::new(0x2075);
    for _ in 0..200 {
        let k = [3usize, 5][rng.range(0, 2)];
        let hw = rng.range(k, k + 40);
        let stride = rng.range(1, 4);
        let engines = rng.range(1, 12);
        let layer = ConvLayer::new("rp", hw, k, 2, rng.range(1, 9), stride, 1);
        let arch = ArchConfig { p_n: rng.range(1, 5), ..ArchConfig::paper_engine() };
        let plan = plan_row_shards(&arch, &layer, engines);
        let h_o = layer.h_o();
        assert_eq!(plan.axis, ShardAxis::Rows);
        assert_eq!(plan.rows, h_o);
        assert_eq!(plan.shards.len(), engines.min(h_o));
        let mut next = 0usize;
        for s in &plan.shards {
            assert_eq!(s.rows.start, next);
            assert!(!s.rows.is_empty());
            assert_eq!(s.filters, 0..layer.n);
            next = s.rows.end;
        }
        assert_eq!(next, h_o);
        let bmin = plan.shards.iter().map(|s| s.rows.len()).min().unwrap();
        let bmax = plan.shards.iter().map(|s| s.rows.len()).max().unwrap();
        assert!(bmax - bmin <= 1);
        assert!((plan.speedup_bound() - h_o as f64 / bmax as f64).abs() < 1e-12);
        // Auto returns one of the two pure plans, never something else.
        let auto = plan_shards(&arch, &layer, engines, ShardMode::Auto);
        let bf = plan_filter_shards(&arch, &layer, engines).speedup_bound();
        assert!(auto.speedup_bound() >= bf.max(plan.speedup_bound()) - 1e-12);
    }
}

/// Acceptance: a farm with N ≥ 2 engines is byte-identical to the
/// single-engine `EngineSim` and to the golden conv on a full-size VGG-16
/// layer (CL1: 3→64 filters over 224×224). Runs on the fast tier (the
/// farm default) so the full-size acceptance suite stays quick; the
/// `#[ignore]`d test below is the same workload on the register oracle.
#[test]
fn vgg16_cl1_full_size_farm_bit_exact() {
    let net = vgg16();
    let layer = net.layers[0].clone();
    assert_eq!((layer.h_i, layer.m, layer.n), (224, 3, 64));
    let mut rng = SplitMix64::new(16);
    let input = Tensor3 { c: 3, h: 224, w: 224, data: rng.vec_i32(3 * 224 * 224, 0, 256) };
    let weights = rng.vec_i32(64 * 3 * 9, -8, 8);
    let arch = ArchConfig::small(3, 2, 4);
    let arch = ArchConfig { w_im: 226, psum_buf_depth: 224 * 224, ..arch };
    let golden = conv3d_i32(&input, &weights, 64, 3, 1, 1);
    let single = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
    let farm = EngineFarm::new(FarmConfig::new(4, arch));
    assert_eq!(farm.fidelity(), ExecFidelity::Fast, "fast is the farm default");
    let r = farm.run_layer(&layer, &input, &weights).unwrap();
    assert_eq!(r.plan.shards.len(), 4);
    assert_eq!(r.ofmaps, golden, "farm vs golden on VGG-16 CL1");
    assert_eq!(r.ofmaps, single.ofmaps, "farm vs single engine on VGG-16 CL1");
    assert_eq!(r.stats.ext_input_reads, single.stats.ext_input_reads);
    assert!(r.stats.cycles < single.stats.cycles, "4-way sharding must cut wall-clock cycles");
}

/// The slow oracle: the same full-size VGG-16 CL1 workload on the
/// register tier, checked against both the golden conv and the fast tier
/// (ofmaps AND stats). Ignored by default — run with
/// `cargo test -- --ignored vgg16_cl1_full_size_register_oracle`.
#[test]
#[ignore = "register-tier full-size run: minutes in debug; the fast-tier test above is the default gate"]
fn vgg16_cl1_full_size_register_oracle() {
    let net = vgg16();
    let layer = net.layers[0].clone();
    let mut rng = SplitMix64::new(16);
    let input = Tensor3 { c: 3, h: 224, w: 224, data: rng.vec_i32(3 * 224 * 224, 0, 256) };
    let weights = rng.vec_i32(64 * 3 * 9, -8, 8);
    let arch = ArchConfig::small(3, 2, 4);
    let arch = ArchConfig { w_im: 226, psum_buf_depth: 224 * 224, ..arch };
    let golden = conv3d_i32(&input, &weights, 64, 3, 1, 1);
    let register = EngineSim::new(arch).run_layer(&layer, &input, &weights);
    let fast = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
    assert_eq!(register.ofmaps, golden, "register oracle vs golden on VGG-16 CL1");
    assert_eq!(fast.ofmaps, register.ofmaps, "fast tier vs register oracle: ofmaps");
    assert_eq!(fast.stats, register.stats, "fast tier vs register oracle: stats");
}

/// Acceptance: the spatial axis is what saturates an 8-engine farm on the
/// paper's own starved layer — full-size VGG-16 CL1 (3→64 over 224², only
/// 10 filter groups on the paper engine's P_N = 7). Filter sharding is
/// bounded at 10/2 = 5×; row sharding splits 224 rows 8 ways (bound 8×).
/// `Auto` must pick rows, serve bit-identical ofmaps, and cut simulated
/// wall-clock cycles strictly below the filter-shard run. Fast tier.
#[test]
fn vgg16_cl1_full_size_auto_beats_filter_sharding() {
    let net = vgg16();
    let layer = net.layers[0].clone();
    let mut rng = SplitMix64::new(81);
    let input = Tensor3 { c: 3, h: 224, w: 224, data: rng.vec_i32(3 * 224 * 224, 0, 256) };
    let weights = rng.vec_i32(64 * 3 * 9, -8, 8);
    let arch = ArchConfig::paper_engine(); // P_N = 7 → 10 filter groups
    let farm = EngineFarm::new(FarmConfig::new(8, arch));
    let filt = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
    let rows = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Spatial).unwrap();
    let auto = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
    assert_eq!(filt.plan.axis, ShardAxis::Filters);
    assert_eq!(rows.plan.axis, ShardAxis::Rows);
    assert_eq!(auto.plan.axis, ShardAxis::Rows, "auto must pick the spatial axis on CL1");
    assert!((filt.plan.speedup_bound() - 5.0).abs() < 1e-9);
    assert!((auto.plan.speedup_bound() - 8.0).abs() < 1e-9);
    assert_eq!(rows.ofmaps, filt.ofmaps, "row shards vs filter shards");
    assert_eq!(auto.ofmaps, filt.ofmaps, "auto vs filter shards");
    assert_eq!(auto.ofmaps, conv3d_i32(&input, &weights, 64, 3, 1, 1), "vs golden");
    assert!(
        auto.stats.cycles < filt.stats.cycles,
        "spatial sharding must cut CL1 wall-clock: auto {} vs filter {} cycles",
        auto.stats.cycles,
        filt.stats.cycles
    );
    assert_eq!(auto.stats.output_writes, filt.stats.output_writes, "same ofmap either way");
}

/// Property (PR 5): work-stealing dispatch is invisible in the results.
/// For random geometries, engine counts and every per-layer shard mode,
/// the farm's `FarmRunResult` — ofmaps, merged stats AND every per-shard
/// entry — is bit-identical to a static serial baseline that runs each
/// planned shard on one engine in plan order and merges by hand. Which
/// worker stole which shard can therefore never leak into the output.
#[test]
fn prop_work_stealing_bit_identical_to_static_baseline() {
    let mut rng = SplitMix64::new(0x57EA);
    for seed in 0..10u64 {
        let k = [3usize, 3, 5][rng.range(0, 3)];
        let hw = rng.range(k + 3, k + 11);
        let m = rng.range(1, 4);
        let n = rng.range(1, 9);
        let stride = rng.range(1, 3);
        let pad = rng.range(0, 2);
        let layer = ConvLayer::new("steal", hw, k, m, n, stride, pad);
        let input = rand_tensor(&mut rng, m, hw, hw);
        let weights = rng.vec_i32(n * m * k * k, -9, 9);
        let engines = rng.range(2, 9);
        let arch = ArchConfig::small(3, 2, rng.range(1, 4));
        let golden = conv3d_i32(&input, &weights, n, k, stride, pad);
        let farm = EngineFarm::new(FarmConfig::new(engines, arch));
        let single = EngineSim::fast(arch);
        let (h_o, w_o) = (layer.h_o(), layer.w_o());

        for mode in
            [ShardMode::FilterShards, ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto]
        {
            let ctx = format!("seed {seed} {mode}: k={k} hw={hw} m={m} n={n} s={stride} e={engines}");
            let r = farm.run_layer_mode(&layer, &input, &weights, mode).unwrap();
            // Static baseline: the same deterministic plan, every shard on
            // one engine, merged in plan order.
            let plan = plan_shards(&arch, &layer, engines, mode);
            assert_eq!(plan.axis, r.plan.axis, "{ctx}: plan is deterministic");
            let mut ofmaps = Tensor3::zeros(n, h_o, w_o);
            let mut stats = SimStats::default();
            for (i, shard) in plan.shards.iter().enumerate() {
                let solo = single.run_shard(
                    &layer,
                    &input,
                    &weights,
                    shard.filters.clone(),
                    shard.rows.clone(),
                );
                assert_eq!(r.per_shard[i], solo.stats, "{ctx}: per-shard stats, shard {i}");
                stats.merge(&solo.stats);
                let b_h = shard.rows.len();
                for (df, f) in shard.filters.clone().enumerate() {
                    let src = &solo.ofmaps.data[df * b_h * w_o..(df + 1) * b_h * w_o];
                    let at = (f * h_o + shard.rows.start) * w_o;
                    ofmaps.data[at..at + b_h * w_o].copy_from_slice(src);
                }
            }
            assert_eq!(r.ofmaps, ofmaps, "{ctx}: ofmaps == static baseline");
            assert_eq!(r.stats, stats, "{ctx}: merged stats == static baseline");
            assert_eq!(r.ofmaps, golden, "{ctx}: vs golden");
        }
    }
}

/// Property (PR 10): hedged re-execution is a pure latency mechanism — a
/// farm with an aggressive hedge budget (2.0× analytic, quarantine
/// disabled so organic hedges can't shrink the fleet) produces ofmaps,
/// merged stats and per-shard stats **bit-identical** to the unhedged
/// baseline farm, across every shard mode and both fidelity tiers. The
/// first-wins rendezvous guarantees duplicates are either dropped unrun
/// or discarded at merge; either way nothing double-merges. On the Fast
/// tier shards beat the budget floor so hedges rarely fire; the Register
/// tier is orders of magnitude slower per shard, which makes organic
/// hedges likely and exercises the duplicate-discard path for real.
#[test]
fn prop_hedged_farm_bit_identical_to_baseline() {
    let mut rng = SplitMix64::new(0x8ED6ED);
    for seed in 0..4u64 {
        let k = 3usize;
        let hw = rng.range(k + 3, k + 9);
        let m = rng.range(1, 3);
        let n = rng.range(2, 7);
        let stride = rng.range(1, 3);
        let pad = rng.range(0, 2);
        let layer = ConvLayer::new("hedge", hw, k, m, n, stride, pad);
        let input = rand_tensor(&mut rng, m, hw, hw);
        let weights = rng.vec_i32(n * m * k * k, -9, 9);
        let engines = rng.range(2, 6);
        let arch = ArchConfig::small(3, 2, rng.range(1, 3));
        let golden = conv3d_i32(&input, &weights, n, k, stride, pad);

        for fidelity in [ExecFidelity::Fast, ExecFidelity::Register] {
            let baseline = EngineFarm::new(FarmConfig::with_fidelity(engines, arch, fidelity));
            let hedged = EngineFarm::new(
                FarmConfig::with_fidelity(engines, arch, fidelity).with_hedge(2.0, u32::MAX),
            );
            for mode in
                [ShardMode::FilterShards, ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto]
            {
                let ctx = format!(
                    "seed {seed} {fidelity} {mode}: hw={hw} m={m} n={n} s={stride} e={engines}"
                );
                let b = baseline.run_layer_mode(&layer, &input, &weights, mode).unwrap();
                let h = hedged.run_layer_mode(&layer, &input, &weights, mode).unwrap();
                assert_eq!(h.ofmaps, b.ofmaps, "{ctx}: hedged ofmaps == baseline");
                assert_eq!(h.ofmaps, golden, "{ctx}: vs golden");
                assert_eq!(
                    h.stats, b.stats,
                    "{ctx}: merged stats identical — a won hedge must not double-merge"
                );
                assert_eq!(h.per_shard, b.per_shard, "{ctx}: per-shard stats identical");
            }
            let rep = hedged.fault_report();
            assert_eq!(rep.injected, 0, "hedging injects no faults");
            assert_eq!(rep.timing_quarantined, 0, "quarantine disabled: threshold is maxed");
        }
    }
}

/// Acceptance (PR 5): at 16 engines the CL1-class serving layer
/// (10 filter groups × 120 output rows on narrow `P_N = 1` engines)
/// out-scales both single axes only on the 2-D grid — filters bound 10×,
/// rows 120/8 = 15×, the 2×8 hybrid grid 1200/(5·15) = 16×. `Auto` must
/// select the hybrid plan with a strictly higher bound than either axis
/// and land at-or-below the spatial-only wall-clock, bit-exactly.
#[test]
fn cl1_class_16_engines_auto_selects_hybrid() {
    let spec = SimNetSpec::cl1_class();
    let layer = spec.layers[0].clone();
    assert_eq!((layer.h_o(), layer.n), (120, 10));
    let arch = ArchConfig::small(3, 2, 1); // the farm_scaling bench arch
    let bf = plan_filter_shards(&arch, &layer, 16).speedup_bound();
    let br = plan_row_shards(&arch, &layer, 16).speedup_bound();
    assert!((bf - 10.0).abs() < 1e-9, "filter bound {bf}");
    assert!((br - 15.0).abs() < 1e-9, "row bound {br}");
    let plan = plan_shards(&arch, &layer, 16, ShardMode::Auto);
    assert_eq!(plan.axis, ShardAxis::Hybrid, "auto must pick the grid at 16 engines");
    assert_eq!(plan.grid, (2, 8));
    assert!((plan.speedup_bound() - 16.0).abs() < 1e-9);
    assert!(plan.speedup_bound() > bf.max(br), "strictly higher than either single axis");

    // And on the farm: the hybrid pick cuts simulated wall-clock below
    // the spatial-only run of the same 16 engines (largest tile 5 groups
    // × 15 rows vs 10 groups × 8 rows), serving bit-identical ofmaps.
    let mut rng = SplitMix64::new(0x16E);
    let input = Tensor3 { c: 3, h: 120, w: 120, data: rng.vec_i32(3 * 120 * 120, 0, 256) };
    let weights = rng.vec_i32(10 * 3 * 9, -8, 8);
    let farm = EngineFarm::new(FarmConfig::new(16, arch));
    let auto = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
    let rows = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Spatial).unwrap();
    assert_eq!(auto.plan.axis, ShardAxis::Hybrid);
    assert_eq!(auto.ofmaps, rows.ofmaps, "hybrid vs spatial ofmaps");
    assert_eq!(auto.ofmaps, conv3d_i32(&input, &weights, 10, 3, 1, 1), "vs golden");
    assert!(
        auto.stats.cycles < rows.stats.cycles,
        "hybrid must cut CL1-class wall-clock at 16 engines: {} vs {}",
        auto.stats.cycles,
        rows.stats.cycles
    );
}

/// Acceptance: same bit-exactness on a full-size AlexNet layer (CL5:
/// 192→256 filters over 13×13), fast tier.
#[test]
fn alexnet_cl5_full_size_farm_bit_exact() {
    let net = alexnet();
    let layer = net.layers[4].clone();
    assert_eq!((layer.h_i, layer.m, layer.n, layer.k), (13, 192, 256, 3));
    let mut rng = SplitMix64::new(5);
    let input = Tensor3 { c: 192, h: 13, w: 13, data: rng.vec_i32(192 * 13 * 13, 0, 256) };
    let weights = rng.vec_i32(256 * 192 * 9, -6, 6);
    let arch = ArchConfig::small(3, 8, 4);
    let golden = conv3d_i32(&input, &weights, 256, 3, 1, 1);
    let single = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
    let farm = EngineFarm::new(FarmConfig::new(3, arch));
    let r = farm.run_layer(&layer, &input, &weights).unwrap();
    assert_eq!(r.ofmaps, golden, "farm vs golden on AlexNet CL5");
    assert_eq!(r.ofmaps, single.ofmaps, "farm vs single engine on AlexNet CL5");
    assert!(r.stats.cycles < single.stats.cycles);
}

/// Acceptance: the tiled K > 3 path shards bit-exactly too — AlexNet CL2
/// geometry (5×5 kernels, pad 2) at reduced channel counts.
#[test]
fn alexnet_cl2_geometry_tiled_farm_bit_exact() {
    let layer = ConvLayer::new("CL2s", 27, 5, 6, 10, 1, 2);
    let mut rng = SplitMix64::new(52);
    let input = Tensor3 { c: 6, h: 27, w: 27, data: rng.vec_i32(6 * 27 * 27, 0, 256) };
    let weights = rng.vec_i32(10 * 6 * 25, -6, 6);
    let arch = ArchConfig::small(3, 2, 2);
    let golden = conv3d_i32(&input, &weights, 10, 5, 1, 2);
    let single = EngineSim::new(arch).run_layer(&layer, &input, &weights);
    let farm = EngineFarm::new(FarmConfig::new(3, arch));
    let r = farm.run_layer(&layer, &input, &weights).unwrap();
    assert_eq!(r.ofmaps, golden, "tiled farm vs golden");
    assert_eq!(r.ofmaps, single.ofmaps, "tiled farm vs single engine");
}

/// Acceptance: the [`trim_sa::coordinator::BatchCost`] a served
/// `SimNetSpec::tiny()` batch reports is pinned to the **register-tier
/// oracle** — a layer-serial chain of cycle-accurate `EngineSim` runs on
/// the same deterministic weights — and its joules/GOPS follow the
/// paper-calibrated energy model exactly.
#[test]
fn batch_cost_pinned_to_register_oracle() {
    let spec = SimNetSpec::tiny();
    let arch = ArchConfig::small(3, 2, 1);
    let mut backend =
        SimBackend::with_fidelity(1, arch, spec.clone(), ShardMode::FilterShards, ExecFidelity::Fast);
    let len = backend.input_len();
    let img = SplitMix64::new(0x07AC).vec_i32(len, 0, 256);
    let report = backend.infer_batch(&[&img]).unwrap();
    let cost = report.cost.expect("sim backend must report a batch cost");

    // The oracle: every layer stepped register by register, stats merged
    // the way the serving path promises (layers run sequentially).
    let oracle = EngineSim::new(arch);
    let q = Requant::new(spec.requant_shift, 8);
    let (c, h, w) = spec.input;
    let mut act = Tensor3 { c, h, w, data: img.clone() };
    let mut expect = SimStats::default();
    for (i, layer) in spec.layers.iter().enumerate() {
        let weights = spec.layer_weights(i);
        let r = oracle.run_layer(layer, &act, &weights);
        expect.merge_sequential(&r.stats);
        act = r.ofmaps;
        for v in act.data.iter_mut() {
            *v = q.apply(*v as i64) as i32;
        }
    }
    assert_eq!(cost.stats, expect, "served batch stats == register-tier oracle");
    assert!(cost.stats.cycles > 0);
    assert!(cost.stats.off_chip_accesses() > 0 && cost.stats.on_chip_accesses() > 0);
    let e = EnergyModel::paper();
    let joules = e
        .memory_energy_j(expect.off_chip_accesses() as f64, expect.on_chip_accesses() as f64)
        + e.compute_energy_j(expect.macs as f64);
    assert!(cost.joules > 0.0 && (cost.joules - joules).abs() < 1e-15);
    let gops = expect.ops_per_s(arch.f_clk) / 1e9;
    assert!(cost.gops > 0.0 && (cost.gops - gops).abs() < 1e-9);
}

/// A served batch's `BatchCost` obeys the farm's own aggregation
/// invariants: per layer, cycles = **max** over the shard plan while
/// accesses/MACs = **sum** over shards; across the layer-serial chain and
/// the images of the batch, cycles add. Reconstructed shard for shard
/// with an identical farm.
#[test]
fn served_batch_cost_matches_farm_aggregation() {
    let spec = SimNetSpec::tiny();
    let arch = ArchConfig::small(3, 2, 1);
    let engines = 3;
    let mut backend = SimBackend::with_spec(engines, arch, spec.clone(), ShardMode::FilterShards);
    let len = backend.input_len();
    let imgs: Vec<Vec<i32>> =
        (0..3).map(|i| SplitMix64::new(0xBA7C + i as u64).vec_i32(len, 0, 256)).collect();
    let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let cost = backend.infer_batch(&refs).unwrap().cost.unwrap();

    let farm = EngineFarm::new(FarmConfig::new(engines, arch));
    let q = Requant::new(spec.requant_shift, 8);
    let mut expect = SimStats::default();
    for img in &imgs {
        let (c, h, w) = spec.input;
        let mut act = Tensor3 { c, h, w, data: img.clone() };
        for (i, layer) in spec.layers.iter().enumerate() {
            let weights = spec.layer_weights(i);
            let r = farm.run_layer(layer, &act, &weights).unwrap();
            // the per-layer reduction the farm promises
            assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
            assert_eq!(r.stats.macs, r.per_shard.iter().map(|s| s.macs).sum::<u64>());
            assert_eq!(
                r.stats.off_chip_accesses(),
                r.per_shard.iter().map(|s| s.off_chip_accesses()).sum::<u64>()
            );
            expect.merge_sequential(&r.stats);
            act = r.ofmaps;
            for v in act.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
        }
    }
    assert_eq!(cost.stats, expect, "served BatchCost == farm aggregation, shard for shard");
}

fn serve_workload(mode: ShardMode) {
    let n_req = 96usize;
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: std::time::Duration::from_millis(20) },
        ..Default::default()
    };
    let probe = SimBackend::with_spec(1, ArchConfig::small(3, 2, 1), SimNetSpec::tiny(), mode);
    let c = Coordinator::start_with(
        move || {
            Ok(Box::new(SimBackend::with_spec(3, ArchConfig::small(3, 2, 1), SimNetSpec::tiny(), mode))
                as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap();
    assert!(c.backend_description().starts_with("sim["));
    let len = c.input_len();
    let images: Vec<Vec<i32>> = (0..n_req)
        .map(|i| SplitMix64::new(1000 + i as u64).vec_i32(len, 0, 256))
        .collect();
    let pending: Vec<_> = images.iter().map(|img| c.submit(img.clone()).unwrap()).collect();
    let mut max_batch_seen = 0usize;
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits, probe.reference_logits(img), "{mode:?}: wrong logits");
        let cost = resp.cost.expect("sim-served responses carry attributed cost");
        assert!(cost.batch_cycles > 0 && cost.joules > 0.0 && cost.gops > 0.0, "{mode:?}");
        max_batch_seen = max_batch_seen.max(resp.batch_size);
    }
    let m = c.metrics();
    assert_eq!(m.requests, n_req as u64);
    assert!(max_batch_seen > 1, "{mode:?}: expected batched execution under load");
    assert!(m.batches < n_req as u64, "{mode:?}: batches = {}", m.batches);
    assert_eq!(m.sim_batches, m.batches, "{mode:?}: every sim batch carries cost");
    assert!(m.sim_cycles > 0 && m.sim_off_chip_accesses > 0, "{mode:?}");
    assert!(m.sim_joules > 0.0 && m.sim_gops > 0.0, "{mode:?}");
}

/// Acceptance: `trim serve --backend sim` semantics — the coordinator
/// completes a 96-request workload with real batching, zero artifacts, and
/// every logit pinned to the golden reference (filter-shard mode).
#[test]
fn coordinator_serves_96_requests_sim_filter_shards() {
    serve_workload(ShardMode::FilterShards);
}

/// Same workload through the layer-pipeline mode.
#[test]
fn coordinator_serves_96_requests_sim_layer_pipeline() {
    serve_workload(ShardMode::LayerPipeline);
}

/// Same workload through the spatial (output-row) shard axis.
#[test]
fn coordinator_serves_96_requests_sim_spatial() {
    serve_workload(ShardMode::Spatial);
}

/// Same workload through the 2-D hybrid (filter × row) grid.
#[test]
fn coordinator_serves_96_requests_sim_hybrid() {
    serve_workload(ShardMode::Hybrid);
}

/// Same workload with the per-layer auto axis pick.
#[test]
fn coordinator_serves_96_requests_sim_auto() {
    serve_workload(ShardMode::Auto);
}
