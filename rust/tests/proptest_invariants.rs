//! Property-based invariants (randomised with the in-tree SplitMix64
//! driver — the crate builds offline, so no proptest dependency; each
//! property runs across a seeded sweep and prints the failing seed).

use trim_sa::arch::control::plan_layer;
use trim_sa::arch::{ArchConfig, EngineSim};
use trim_sa::golden::{conv2d_i32, conv3d_i32, Tensor3};
use trim_sa::model::quant::{DatapathBits, Requant};
use trim_sa::model::{ConvLayer, KernelTiling};
use trim_sa::util::SplitMix64;

/// Property: kernel tiling decomposition is exact for any (K, K_nat).
#[test]
fn prop_tiling_decomposition_exact() {
    let mut rng = SplitMix64::new(1);
    for seed in 0..60u64 {
        let k = rng.range(2, 12);
        let k_nat = rng.range(2, 6);
        let h = rng.range(k + 1, k + 10);
        let w = rng.range(k + 1, k + 10);
        let input = rng.vec_i32(h * w, -64, 64);
        let weights = rng.vec_i32(k * k, -16, 16);

        let full = conv2d_i32(&input, h, w, &weights, k, 1, 0);
        let (h_o, w_o) = (h - k + 1, w - k + 1);
        let tiling = KernelTiling::new(k, k_nat);
        let mut acc = vec![0i32; h_o * w_o];
        for tile in &tiling.tiles {
            let tw = tiling.extract_tile_weights(&weights, tile);
            for oy in 0..h_o {
                for ox in 0..w_o {
                    let mut s = 0i32;
                    for r in 0..k_nat {
                        for c in 0..k_nat {
                            let (iy, ix) = (oy + tile.row0 + r, ox + tile.col0 + c);
                            if iy < h && ix < w {
                                s += input[iy * w + ix] * tw[r * k_nat + c];
                            }
                        }
                    }
                    acc[oy * w_o + ox] += s;
                }
            }
        }
        assert_eq!(acc, full, "seed {seed}: k={k} k_nat={k_nat}");
    }
}

/// Property: every tile holds every kernel weight exactly once.
#[test]
fn prop_tiling_partitions_weights() {
    let mut rng = SplitMix64::new(2);
    for _ in 0..40 {
        let k = rng.range(2, 14);
        let k_nat = rng.range(2, 6);
        let t = KernelTiling::new(k, k_nat);
        let real: usize = t.tiles.iter().map(|tl| tl.rows * tl.cols).sum();
        assert_eq!(real, k * k, "k={k} k_nat={k_nat}");
        assert_eq!(t.num_tiles(), t.grid * t.grid);
        assert!(t.fill_ratio() <= 1.0 && t.fill_ratio() > 0.0);
    }
}

/// Property: eq. (2) structure — the plan's total cycles always decompose
/// into L_I + steps·(P_N·K + sweep), and more parallelism never needs
/// more steps.
#[test]
fn prop_plan_structure_and_monotonicity() {
    let mut rng = SplitMix64::new(3);
    for seed in 0..60u64 {
        let hw = rng.range(6, 64);
        let m = rng.range(1, 600);
        let n = rng.range(1, 600);
        let layer = ConvLayer::new("p", hw, 3, m, n, 1, 1);
        let small = ArchConfig { p_m: 4, p_n: 2, ..ArchConfig::paper_engine() };
        let big = ArchConfig { p_m: 24, p_n: 7, ..ArchConfig::paper_engine() };
        let ps = plan_layer(&small, &layer);
        let pb = plan_layer(&big, &layer);
        assert_eq!(
            ps.total_cycles,
            small.pipeline_latency() + ps.steps * (ps.weight_load_cycles + ps.sweep_cycles),
            "seed {seed}"
        );
        assert!(pb.steps <= ps.steps, "seed {seed}: parallelism must not add steps");
        assert!(ps.utilization > 0.0 && ps.utilization <= 1.0);
        assert!(pb.utilization > 0.0 && pb.utilization <= 1.0);
    }
}

/// Property: the fast execution tier ([`trim_sa::arch::ExecFidelity`])
/// equals the register tier on randomized (layer, ArchConfig) — ofmaps
/// bit-exact and **every** [`trim_sa::arch::SimStats`] counter equal —
/// across multi-group (M > P_M, N > P_N), tiled K > 3, stride > 1 and
/// padded geometries, plus `run_filter_range` shards on both tiers.
#[test]
fn prop_fast_tier_bit_and_counter_exact_vs_register() {
    let mut rng = SplitMix64::new(0xFA57);
    for seed in 0..24u64 {
        let k = [3usize, 3, 3, 5, 7, 11][rng.range(0, 6)];
        // keep the stride-1 sweep grid wide enough for the slice schedule
        // (w_o1 ≥ K_nat) at pad 0
        let hw = rng.range(k + 6, k + 14);
        let m = rng.range(1, 6);
        let n = rng.range(1, 10);
        let stride = [1usize, 1, 2, 4][rng.range(0, 4)];
        let pad = rng.range(0, 3);
        let arch = ArchConfig::small(3, rng.range(1, 5), rng.range(1, 4));
        let layer = ConvLayer::new("fastprop", hw, k, m, n, stride, pad);
        let input = Tensor3 { c: m, h: hw, w: hw, data: rng.vec_i32(m * hw * hw, -96, 96) };
        let weights = rng.vec_i32(n * m * k * k, -9, 9);
        let ctx = format!(
            "seed {seed}: k={k} hw={hw} m={m} n={n} s={stride} p={pad} P_M={} P_N={}",
            arch.p_m, arch.p_n
        );

        let reg = EngineSim::new(arch).run_layer(&layer, &input, &weights);
        let fast = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
        assert_eq!(fast.ofmaps, conv3d_i32(&input, &weights, n, k, stride, pad), "{ctx}: vs golden");
        assert_eq!(fast.ofmaps, reg.ofmaps, "{ctx}: ofmaps fast vs register");
        assert_eq!(fast.stats, reg.stats, "{ctx}: stats fast vs register");

        // Sharded entry point: both tiers, a P_N-aligned split.
        let groups = n.div_ceil(arch.p_n);
        if groups > 1 {
            let cut = arch.p_n * rng.range(1, groups);
            for range in [0..cut, cut..n] {
                let rs = EngineSim::new(arch).run_filter_range(&layer, &input, &weights, range.clone());
                let fs = EngineSim::fast(arch).run_filter_range(&layer, &input, &weights, range.clone());
                assert_eq!(fs.ofmaps, rs.ofmaps, "{ctx}: shard {range:?} ofmaps");
                assert_eq!(fs.stats, rs.stats, "{ctx}: shard {range:?} stats");
            }
        }

        // Row-band entry point (spatial shard axis): both tiers agree on a
        // random interior band, and the band matches the whole-layer rows.
        let h_o = layer.h_o();
        if h_o > 1 {
            let oy0 = rng.range(0, h_o - 1);
            let oy1 = rng.range(oy0 + 1, h_o + 1);
            let band = oy0..oy1;
            let rb = EngineSim::new(arch).run_row_range(&layer, &input, &weights, band.clone());
            let fb = EngineSim::fast(arch).run_row_range(&layer, &input, &weights, band.clone());
            assert_eq!(fb.ofmaps, rb.ofmaps, "{ctx}: band {band:?} ofmaps fast vs register");
            assert_eq!(fb.stats, rb.stats, "{ctx}: band {band:?} stats fast vs register");
            let w_o = layer.w_o();
            for f in 0..n {
                assert_eq!(
                    fb.ofmaps.channel(f),
                    &reg.ofmaps.channel(f)[band.start * w_o..band.end * w_o],
                    "{ctx}: band {band:?} filter {f} vs whole-layer rows"
                );
            }

            // Hybrid tile (the 2-D shard unit): a P_N-aligned filter
            // split × the same row band, both tiers, against the matching
            // block of the whole-layer register run.
            if groups > 1 {
                let cut = arch.p_n * rng.range(1, groups);
                let filters = 0..cut.min(n);
                let rt = EngineSim::new(arch).run_shard(
                    &layer, &input, &weights, filters.clone(), band.clone(),
                );
                let ft = EngineSim::fast(arch).run_shard(
                    &layer, &input, &weights, filters.clone(), band.clone(),
                );
                assert_eq!(ft.ofmaps, rt.ofmaps, "{ctx}: tile ofmaps fast vs register");
                assert_eq!(ft.stats, rt.stats, "{ctx}: tile stats fast vs register");
                for (df, f) in filters.enumerate() {
                    assert_eq!(
                        ft.ofmaps.channel(df),
                        &reg.ofmaps.channel(f)[band.start * w_o..band.end * w_o],
                        "{ctx}: tile {band:?} filter {f} vs whole-layer block"
                    );
                }
            }
        }
    }
}

/// Property: requantisation is monotone, clamped and shift-consistent.
#[test]
fn prop_requant_monotone_and_clamped() {
    let mut rng = SplitMix64::new(4);
    for _ in 0..200 {
        let shift = rng.range(0, 12) as u32;
        let q = Requant::new(shift, 8);
        let a = rng.range_i64(-(1 << 20), 1 << 20);
        let b = rng.range_i64(-(1 << 20), 1 << 20);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(q.apply(lo) <= q.apply(hi), "monotone: {lo} {hi} shift {shift}");
        assert!(q.apply(a) <= 255);
    }
}

/// Property: datapath bit-widths grow monotonically up the hierarchy and
/// stay within the 32-bit psum-buffer word for every paper-scale config.
#[test]
fn prop_datapath_widths_fit_32bit() {
    for b in [4usize, 8] {
        for k in [2usize, 3, 5, 7] {
            let d = DatapathBits::new(b, k);
            assert!(d.psum_bits() < d.slice_out_bits());
            for p_m in [1usize, 4, 24] {
                assert!(d.slice_out_bits() <= d.core_out_bits(p_m));
            }
            for m in [3usize, 64, 512] {
                // the paper's 32-bit psum-buffer sizing (eq. (3)) holds for
                // its native K=3 at B=8 (and everything smaller); larger K
                // on a B=8 datapath would need wider buffers — which is
                // exactly why the engine tiles large kernels to 3×3.
                if b <= 8 && k <= 3 {
                    assert!(d.engine_acc_bits(m) <= 32, "B={b} K={k} M={m}: {}", d.engine_acc_bits(m));
                }
            }
        }
    }
}

/// Property: eq. (3)/(4) scale linearly in P_N and P_M respectively.
#[test]
fn prop_buffer_and_bandwidth_scaling() {
    let base = ArchConfig::paper_engine();
    let mut rng = SplitMix64::new(5);
    for _ in 0..40 {
        let p_n = rng.range(1, 32);
        let p_m = rng.range(1, 32);
        let c = ArchConfig { p_n, p_m, ..base };
        assert_eq!(c.psum_buffer_bits(), (p_n * base.psum_buf_depth * 32) as u64);
        assert_eq!(c.io_bandwidth_bits(), ((p_m * 5 + p_n) * 8) as u64); // K=3
        assert_eq!(c.total_pes(), p_n * p_m * 9);
    }
}
