//! Integration: the production front door under overload (ISSUE 7).
//!
//! Floods a single-farm router far past its admission budget from
//! concurrent submitters and checks the three robustness guarantees:
//!
//! 1. the ingress queue is **bounded** — the queue-wait p99 stays within
//!    a small multiple of `queue_cap × per-image service time` instead of
//!    growing with the offered load;
//! 2. admission **sheds** — the merged snapshot reports a nonzero
//!    `shed` count and shed submits carry a typed
//!    [`ServeError::Overloaded`] with a `retry_after` hint;
//! 3. **everything resolves** — every submitted request ends in logits or
//!    a typed [`ServeError`]; no hangs, no empty-logits sentinels.
//!
//! Plus deadline rejection, the cost-budget admission axis, and graceful
//! drain semantics at the router surface.

use std::sync::Arc;
use std::time::{Duration, Instant};
use trim_sa::coordinator::{
    AdmissionConfig, BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend, MockBackend,
    Router, ServeError, SimBackend,
};

/// A slow mock farm behind a tightly bounded ingress.
fn bounded_mock_router(queue_cap: usize, delay_us: u64) -> Router {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        admission: AdmissionConfig { queue_cap, budget_cycles: None, client_rps: None },
    };
    let c = Coordinator::start_with(
        move || {
            let mut b = MockBackend::new(8, 4);
            b.delay = Duration::from_micros(delay_us);
            Ok(Box::new(b) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap();
    Router::new(vec![c]).unwrap()
}

#[test]
fn flood_past_admission_budget_sheds_bounds_waits_and_resolves_everything() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 50;
    const QUEUE_CAP: usize = 8;
    const DELAY_US: u64 = 2_000; // per image → per-batch service ≈ 8 ms

    let router = Arc::new(bounded_mock_router(QUEUE_CAP, DELAY_US));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let router = Arc::clone(&router);
        handles.push(std::thread::spawn(move || {
            // Submit the whole burst first (that is what floods the
            // bounded queue), then settle every reply.
            let mut replies = Vec::new();
            let (mut served, mut shed, mut other_typed) = (0usize, 0usize, 0usize);
            for i in 0..PER_THREAD {
                let img = vec![(t * PER_THREAD + i) as i32; 8];
                match router.submit(img) {
                    Ok(r) => replies.push(r),
                    Err(e) => match e.downcast_ref::<ServeError>() {
                        Some(ServeError::Overloaded { retry_after }) => {
                            assert!(*retry_after > Duration::ZERO, "shed carries a retry hint");
                            shed += 1;
                        }
                        Some(_) => other_typed += 1,
                        None => panic!("untyped submit error: {e:#}"),
                    },
                }
            }
            for mut r in replies {
                match r.recv() {
                    Ok(resp) => {
                        assert!(!resp.logits.is_empty(), "no empty-logits sentinels");
                        served += 1;
                    }
                    Err(e) => {
                        assert!(
                            e.downcast_ref::<ServeError>().is_some(),
                            "reply failures must be typed: {e:#}"
                        );
                        other_typed += 1;
                    }
                }
            }
            (served, shed, other_typed)
        }));
    }
    let (mut served, mut shed, mut other) = (0usize, 0usize, 0usize);
    for h in handles {
        let (s, sh, o) = h.join().unwrap();
        served += s;
        shed += sh;
        other += o;
    }
    // (3) everything resolved, one way or another.
    assert_eq!(served + shed + other, THREADS * PER_THREAD);
    assert!(served > 0, "the farm must still serve while shedding");
    assert!(shed > 0, "a {}-deep burst must overflow a cap of {QUEUE_CAP}", THREADS * PER_THREAD);

    let m = router.drain(Duration::from_secs(10));
    // (2) the shed count flows into the merged snapshot.
    assert_eq!(m.shed as usize, shed, "snapshot shed == typed Overloaded rejections");
    assert_eq!(m.requests as usize, served, "snapshot requests == successfully served");
    // (1) bounded ingress ⇒ bounded queue wait. An unbounded queue under
    // this burst would see waits up to ≈ offered × 2 ms ≈ 400 ms; the cap
    // holds the p99 estimate (log₂ bucket upper bound) well under that.
    let p99_wait_us = m.queue_wait.quantile(0.99);
    assert!(
        p99_wait_us < 200_000,
        "queue-wait p99 must stay bounded by the admission cap, got {p99_wait_us} µs"
    );
}

#[test]
fn hopeless_deadlines_reject_with_a_typed_error() {
    let router = bounded_mock_router(64, 5_000);
    // A deadline already in the past cannot be met: the batcher screens
    // the request out and the reply is a typed DeadlineExceeded.
    let mut r = router.submit_with(vec![0; 8], Some(Instant::now())).unwrap();
    let err = r.recv().unwrap_err();
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::DeadlineExceeded { .. }) => {}
        other => panic!("expected typed DeadlineExceeded, got {other:?}"),
    }
    // A generous deadline is met and reports nonnegative slack.
    let mut ok = router.submit_with(vec![0; 8], Some(Instant::now() + Duration::from_secs(30))).unwrap();
    let resp = ok.recv().unwrap();
    assert!(resp.deadline_slack.is_some(), "deadline requests report their slack");
    let m = router.metrics();
    assert_eq!(m.deadline_expired, 1);
    assert_eq!(m.requests, 1);
}

#[test]
fn cost_budget_sheds_once_the_ewma_is_warm() {
    // Budget of 1 simulated cycle: the first request is admitted (no cost
    // observed yet — the controller cannot price what it has not seen),
    // and once the sim backend's per-request cycles are in the EWMA every
    // later submit breaches `(depth + 1) × cost > budget` immediately.
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        admission: AdmissionConfig { queue_cap: 1024, budget_cycles: Some(1.0), client_rps: None },
    };
    let c = Coordinator::start_with(
        || Ok(Box::new(SimBackend::new(2)) as Box<dyn InferenceBackend>),
        cfg,
    )
    .unwrap();
    let router = Router::new(vec![c]).unwrap();
    let len = router.input_len();
    router.infer(vec![1; len]).expect("cold admission lets the probe through");
    let err = router.submit(vec![1; len]).unwrap_err();
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::Overloaded { retry_after }) => {
            assert!(*retry_after > Duration::ZERO);
        }
        other => panic!("expected typed Overloaded, got {other:?}"),
    }
    assert_eq!(router.metrics().shed, 1);
}

#[test]
fn drain_stops_admission_resolves_in_flight_and_joins() {
    let router = bounded_mock_router(64, 1_000);
    let mut pending: Vec<_> = (0..16).map(|i| router.submit(vec![i; 8]).unwrap()).collect();
    assert!(!router.is_draining());
    let snap = router.drain(Duration::from_secs(10));
    assert!(router.is_draining());
    // Every in-flight request resolved before drain returned.
    for p in pending.iter_mut() {
        match p.recv() {
            Ok(resp) => assert!(!resp.logits.is_empty()),
            Err(e) => assert!(e.downcast_ref::<ServeError>().is_some(), "typed: {e:#}"),
        }
    }
    assert_eq!(
        snap.requests + snap.drain_rejected,
        16,
        "served + drain-rejected covers the backlog"
    );
    // Post-drain ingress is closed with a typed Shutdown.
    let err = router.submit(vec![0; 8]).unwrap_err();
    assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Shutdown));
    // Draining again is idempotent and still returns a snapshot.
    let again = router.drain(Duration::from_secs(1));
    assert_eq!(again.requests, snap.requests);
}
