//! Loom model checks over the concurrency kernel of the serving stack.
//!
//! Compiled only under `--cfg loom` (see `src/util/sync.rs` — the facade
//! swaps std's `Mutex`/`Condvar`/atomics for loom's model-checked
//! versions). The offline build never sets the cfg, so this file is
//! empty there and `loom` itself is **not** a Cargo dependency of the
//! crate; the CI job adds it on the runner:
//!
//! ```text
//! cargo add loom
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models --release
//! ```
//!
//! What is exhaustively explored:
//!
//! * the work-stealing [`Injector`]: every push/pop/shutdown
//!   interleaving preserves the job multiset (no lost job, no double
//!   pop) and drains the queue before shutdown takes effect;
//! * [`AdmissionControl`]: the depth counter never admits more than
//!   `queue_cap` requests concurrently despite the fetch-add/rollback
//!   window, and release never underflows;
//! * drain vs submit: once `begin_drain` has returned, every later
//!   `try_admit` observes the drain flag and sheds with `Shutdown`;
//! * the [`FirstWins`] hedge rendezvous: across every interleaving of
//!   racing twins exactly one claims the merge (no lost result, no
//!   double-merge) and every loser subsequently observes the cancel.

#![cfg(loom)]

use loom::sync::Arc;
use loom::thread;
use std::time::Instant;

use trim_sa::coordinator::{AdmissionConfig, AdmissionControl, ServeError};
use trim_sa::obs::Registry;
use trim_sa::scheduler::{FirstWins, Injector};

/// Build an injector wired to a fresh registry gauge (same construction
/// the farm uses — the gauge is a plain std atomic the models don't
/// branch on).
fn injector() -> Injector<usize> {
    let registry = Registry::new();
    Injector::new(registry.gauge("injector.depth"))
}

/// Two stealing consumers race one producer: every interleaving must
/// deliver each job exactly once (no lost job, no double pop).
#[test]
fn injector_no_lost_or_duplicated_jobs() {
    let mut model = loom::model::Builder::new();
    // Condvar + 3 threads explodes without a preemption bound; 3 is
    // loom's recommended bound and still catches realistic races.
    model.preemption_bound = Some(3);
    model.check(|| {
        let inj = Arc::new(injector());
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let inj = Arc::clone(&inj);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some((job, _stolen)) = inj.next_job() {
                        got.push(job);
                    }
                    got
                })
            })
            .collect();

        inj.push([1usize]);
        inj.push([2usize, 3usize]);
        inj.shutdown();

        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().expect("consumer panicked"))
            .collect();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3], "jobs lost or double-popped");
    });
}

/// Shutdown racing a single consumer: jobs pushed *before* shutdown are
/// always drained — `next_job` returns `None` only on an empty queue.
#[test]
fn injector_drains_queue_before_shutdown() {
    loom::model(|| {
        let inj = Arc::new(injector());
        inj.push([10usize, 11usize]);

        let consumer = {
            let inj = Arc::clone(&inj);
            thread::spawn(move || {
                let mut n = 0usize;
                while inj.next_job().is_some() {
                    n += 1;
                }
                n
            })
        };

        inj.shutdown();
        let drained = consumer.join().expect("consumer panicked");
        assert_eq!(drained, 2, "shutdown dropped queued jobs");
    });
}

/// Two submitters race for one queue slot: the transient
/// fetch-add-then-rollback in `try_admit` must never let both through,
/// and the rollbacks/releases must return the depth to exactly zero.
#[test]
fn admission_never_exceeds_queue_cap() {
    loom::model(|| {
        let ac = Arc::new(AdmissionControl::new(AdmissionConfig {
            queue_cap: 1,
            budget_cycles: None,
            client_rps: None,
        }));
        // Our own tracking of *successful* admissions — `depth()` itself
        // may transiently read cap+1 mid-rollback, which is fine; the
        // invariant is about admitted requests, not the raw counter.
        let inflight = Arc::new(loom::sync::atomic::AtomicUsize::new(0));

        let threads: Vec<_> = (0..2)
            .map(|_| {
                let ac = Arc::clone(&ac);
                let inflight = Arc::clone(&inflight);
                thread::spawn(move || {
                    if ac.try_admit().is_ok() {
                        let now = inflight.fetch_add(1, loom::sync::atomic::Ordering::AcqRel) + 1;
                        assert!(now <= 1, "two requests admitted into a cap-1 queue");
                        inflight.fetch_sub(1, loom::sync::atomic::Ordering::AcqRel);
                        ac.release(1);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("submitter panicked");
        }
        assert_eq!(ac.depth(), 0, "depth leaked after admit/release");
    });
}

/// `begin_drain` racing a submitter: the racing admit may win or lose,
/// but once drain has returned, admission is closed for good — every
/// subsequent `try_admit` sheds with `Shutdown`, never `Overloaded`.
#[test]
fn drain_closes_admission_for_later_submits() {
    loom::model(|| {
        let ac = Arc::new(AdmissionControl::new(AdmissionConfig {
            queue_cap: 4,
            budget_cycles: None,
            client_rps: None,
        }));

        let submitter = {
            let ac = Arc::clone(&ac);
            thread::spawn(move || {
                // May land before or after the drain flag — both legal.
                let admitted = ac.try_admit().is_ok();
                if admitted {
                    ac.release(1);
                }
                admitted
            })
        };
        ac.begin_drain(Instant::now());
        let _ = submitter.join().expect("submitter panicked");

        assert!(ac.is_draining());
        match ac.try_admit() {
            Err(ServeError::Shutdown) => {}
            other => panic!("post-drain admit must shed with Shutdown, got {other:?}"),
        }
    });
}

/// What each twin of a hedged shard did with the rendezvous.
#[derive(Debug, PartialEq)]
enum TwinOutcome {
    /// Observed the cancel at pickup and dropped the duplicate unrun.
    Dropped,
    /// Won the claim and merged its result.
    Merged,
    /// Ran to completion but lost the claim; its result was discarded.
    Wasted,
}

/// Three twins of one hedged shard race the [`FirstWins`] rendezvous —
/// the original, a hedge, and a re-hedge. In every interleaving exactly
/// one twin merges (no lost result when at least one twin runs, no
/// double-merge ever), and after the winner's claim every other twin
/// either dropped unrun or observed the cancel on its failed claim.
#[test]
fn first_wins_rendezvous_no_lost_result_no_double_merge() {
    let mut model = loom::model::Builder::new();
    // Three threads over one atomic: bounded like the injector model.
    model.preemption_bound = Some(3);
    model.check(|| {
        let fw = Arc::new(FirstWins::new());
        let twins: Vec<_> = (0..3)
            .map(|_| {
                let fw = Arc::clone(&fw);
                thread::spawn(move || {
                    // Pickup check: a cancelled duplicate is dropped
                    // before any work happens (the worker-loop path).
                    if fw.is_cancelled() {
                        return TwinOutcome::Dropped;
                    }
                    // ... deterministic shard execution here ...
                    if fw.claim() {
                        TwinOutcome::Merged
                    } else {
                        // The loser's failed claim IS its cancel
                        // observation — same bit, no window.
                        assert!(fw.is_cancelled(), "loser must observe the winner's claim");
                        TwinOutcome::Wasted
                    }
                })
            })
            .collect();

        let outcomes: Vec<TwinOutcome> =
            twins.into_iter().map(|t| t.join().expect("twin panicked")).collect();
        let merged = outcomes.iter().filter(|o| **o == TwinOutcome::Merged).count();
        assert_eq!(merged, 1, "exactly one twin merges: {outcomes:?}");
        assert!(fw.is_cancelled(), "a settled rendezvous reads cancelled forever");
        assert!(!fw.claim(), "late twins can never re-claim a settled shard");
    });
}
