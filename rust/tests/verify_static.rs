//! Property tests over the static invariant checker (`src/verify/`).
//!
//! Two directions, both required for the checker to be trustworthy:
//!
//! * **soundness of the planners** — every fuzzed
//!   `(geometry, arch, engines)` point, planned by the *real*
//!   `plan_row_shards` / `plan_hybrid_shards` / `Auto` planners and
//!   priced by the *real* fast-tier model, passes every law
//!   (`check_plan`, `check_stats`, `check_point`) with zero violations;
//! * **sensitivity of the checker** — seeded corruptions of those same
//!   plans (a dropped row band, a band extended into its neighbour, an
//!   inflated halo read count) are rejected with the *named* law, not
//!   just "some error". A checker that cannot fail proves nothing.
//!
//! Geometries are drawn with the repo's deterministic [`SplitMix64`], so
//! a failure reproduces from the printed case description alone.

use trim_sa::arch::ArchConfig;
use trim_sa::model::ConvLayer;
use trim_sa::scheduler::{plan_hybrid_shards, plan_row_shards, ShardMode, ShardPlan};
use trim_sa::util::SplitMix64;
use trim_sa::verify::{
    analytic_shard_stats, check_plan, check_point, check_stats, corrupt_drop_shard,
    corrupt_overlap_rows, Law,
};

/// One fuzzed design point: native and tiled kernels, unit and stride-2
/// sweeps, padded and unpadded borders, on a spread of engine fabrics.
fn fuzz_case(rng: &mut SplitMix64, i: usize) -> (ArchConfig, ConvLayer, usize) {
    let k = [3usize, 5, 7][rng.range(0, 3)];
    let h_w = rng.range(k + 1, k + 21);
    let stride = [1usize, 2][rng.range(0, 2)];
    let pad = rng.range(0, 3.min(k / 2 + 1));
    let m = rng.range(1, 6);
    let n = rng.range(1, 20);
    let layer = ConvLayer::new(&format!("fuzz{i}"), h_w, k, m, n, stride, pad);
    // K_nat stays 3 (the paper fabric): k ∈ {5, 7} exercises the tiled
    // decomposition laws, k = 3 the native ones.
    let p_m = [2usize, 4, 8][rng.range(0, 3)];
    let p_n = [2usize, 3, 7][rng.range(0, 3)];
    let arch = ArchConfig::small(3, p_m, p_n);
    let engines = rng.range(1, 9);
    (arch, layer, engines)
}

fn describe(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> String {
    format!(
        "{} {}x{} k{} s{} p{} m{} n{} | P_N={} P_M={} engines={engines}",
        layer.name, layer.h_i, layer.w_i, layer.k, layer.stride, layer.pad, layer.m, layer.n,
        arch.p_n, arch.p_m
    )
}

/// Every fuzzed point, planned for real and priced by the real model,
/// satisfies every law — structural coverage, halo conservation,
/// counter conservation and the cycle bound — on all three axes.
#[test]
fn fuzzed_plans_pass_every_law() {
    let mut rng = SplitMix64::new(0x5747_71C0_DE00_0001);
    for i in 0..150 {
        let (arch, layer, engines) = fuzz_case(&mut rng, i);
        let case = describe(&arch, &layer, engines);

        for (name, plan) in [
            ("rows", plan_row_shards(&arch, &layer, engines)),
            ("hybrid", plan_hybrid_shards(&arch, &layer, engines)),
        ] {
            let pv = check_plan(&arch, &layer, engines, &plan);
            assert!(pv.is_empty(), "[{case}] {name} plan violates: {}", pv[0]);
            let per_shard: Vec<_> =
                plan.shards.iter().map(|s| analytic_shard_stats(&arch, &layer, s)).collect();
            let sv = check_stats(&arch, &layer, &plan, &per_shard);
            assert!(sv.is_empty(), "[{case}] {name} stats violate: {}", sv[0]);
        }

        // The full four-family point check on the planner's own pick.
        let report = check_point(&arch, &layer, engines, ShardMode::Auto);
        assert!(
            report.violations.is_empty(),
            "[{case}] Auto point violates: {}",
            report.violations[0]
        );
        assert!(report.checks > 0, "[{case}] point evaluated no laws");
    }
}

/// Seeded corruptions of fuzzed *valid* plans are rejected with the
/// named Coverage law: a dropped band leaves orphaned output cells, an
/// extended band double-counts (or escapes) them.
#[test]
fn fuzzed_corrupted_plans_are_rejected_by_name() {
    let mut rng = SplitMix64::new(0x5747_71C0_DE00_0002);
    let mut exercised = 0usize;
    for i in 0..150 {
        let (arch, layer, engines) = fuzz_case(&mut rng, i);
        let case = describe(&arch, &layer, engines);
        let plan = plan_row_shards(&arch, &layer, engines);
        if plan.shards.len() < 2 {
            continue; // single-shard plans have nothing to drop/overlap
        }
        exercised += 1;

        let reject = |tag: &str, corrupted: &ShardPlan| {
            let v = check_plan(&arch, &layer, engines, corrupted);
            assert!(
                v.iter().any(|x| x.law == Law::Coverage),
                "[{case}] {tag}: corruption passed the checker (violations: {:?})",
                v.iter().map(|x| x.law).collect::<Vec<_>>()
            );
        };

        let mut dropped = plan.clone();
        corrupt_drop_shard(&mut dropped);
        reject("dropped row band", &dropped);

        let mut overlapped = plan.clone();
        corrupt_overlap_rows(&mut overlapped);
        reject("overlapping bands", &overlapped);
    }
    assert!(exercised >= 20, "fuzz ranges too narrow: only {exercised} multi-shard plans");
}

/// Corrupted *stats* (the farm-merge side) are rejected with the named
/// conservation law: an extra off-chip read breaks HaloConservation, a
/// skewed MAC count breaks CounterConservation.
#[test]
fn fuzzed_corrupted_stats_are_rejected_by_name() {
    let mut rng = SplitMix64::new(0x5747_71C0_DE00_0003);
    let mut exercised = 0usize;
    for i in 0..60 {
        let (arch, layer, engines) = fuzz_case(&mut rng, i);
        if layer.stride != 1 {
            continue; // the exact halo identity is a stride-1 law
        }
        let case = describe(&arch, &layer, engines);
        let plan = plan_row_shards(&arch, &layer, engines);
        let stats: Vec<_> =
            plan.shards.iter().map(|s| analytic_shard_stats(&arch, &layer, s)).collect();
        exercised += 1;

        let mut inflated = stats.clone();
        inflated[0].ext_input_reads += 1;
        let v = check_stats(&arch, &layer, &plan, &inflated);
        assert!(
            v.iter().any(|x| x.law == Law::HaloConservation),
            "[{case}] inflated halo read passed: {:?}",
            v.iter().map(|x| x.law).collect::<Vec<_>>()
        );

        let mut skewed = stats.clone();
        let last = skewed.len() - 1;
        skewed[last].macs = skewed[last].macs.wrapping_add(1);
        let v = check_stats(&arch, &layer, &plan, &skewed);
        assert!(
            v.iter().any(|x| x.law == Law::CounterConservation),
            "[{case}] skewed MAC counter passed: {:?}",
            v.iter().map(|x| x.law).collect::<Vec<_>>()
        );
    }
    assert!(exercised >= 20, "fuzz ranges too narrow: only {exercised} stride-1 cases");
}
