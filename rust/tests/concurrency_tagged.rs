//! Tagged concurrency subset for Miri and ThreadSanitizer.
//!
//! These are plain std-thread stress tests over the same structures the
//! loom models check exhaustively (tests/loom_models.rs): the
//! work-stealing [`Injector`], [`AdmissionControl`] and [`Ewma`]. Loom
//! proves every interleaving of the small models; this file lets the
//! dynamic checkers (Miri's data-race detector, TSan) watch the *real*
//! std primitives under load, including paths loom cannot take (poisoned
//! locks are impossible here, but timing-dependent steal/park ratios
//! are). CI runs it twice:
//!
//! ```text
//! MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --test concurrency_tagged
//! RUSTFLAGS=-Zsanitizer=thread cargo +nightly test -Zbuild-std \
//!     --target x86_64-unknown-linux-gnu --test concurrency_tagged --release
//! ```
//!
//! Thread and iteration counts are deliberately small: Miri interprets
//! every instruction (~100× slowdown), so the point is coverage of the
//! synchronisation edges, not throughput.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use trim_sa::coordinator::{AdmissionConfig, AdmissionControl, ServeError};
use trim_sa::obs::Registry;
use trim_sa::scheduler::Injector;

fn injector() -> Arc<Injector<usize>> {
    let registry = Registry::new();
    Arc::new(Injector::new(registry.gauge("injector.depth")))
}

/// Two producers race two stealing consumers; every job arrives exactly
/// once and the depth gauge settles at zero.
#[test]
fn injector_concurrent_push_and_steal() {
    const PRODUCERS: usize = 2;
    const PER_PRODUCER: usize = 25;
    let inj = injector();

    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let inj = Arc::clone(&inj);
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((job, _stolen)) = inj.next_job() {
                    got.push(job);
                }
                got
            })
        })
        .collect();

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let inj = Arc::clone(&inj);
            thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    inj.push([p * PER_PRODUCER + i]);
                }
            })
        })
        .collect();
    for p in producers {
        p.join().expect("producer panicked");
    }
    inj.shutdown();

    let mut all: Vec<usize> = consumers
        .into_iter()
        .flat_map(|c| c.join().expect("consumer panicked"))
        .collect();
    all.sort_unstable();
    let expect: Vec<usize> = (0..PRODUCERS * PER_PRODUCER).collect();
    assert_eq!(all, expect, "jobs lost or double-popped under contention");
}

/// Jobs queued before shutdown always drain; `next_job` only returns
/// `None` on an empty queue.
#[test]
fn injector_shutdown_still_drains_backlog() {
    let inj = injector();
    inj.push(0..10usize);

    let consumer = {
        let inj = Arc::clone(&inj);
        thread::spawn(move || {
            let mut n = 0usize;
            while inj.next_job().is_some() {
                n += 1;
            }
            n
        })
    };
    inj.shutdown();
    assert_eq!(consumer.join().expect("consumer panicked"), 10);
}

/// Hammer `try_admit`/`release` from several threads: the number of
/// concurrently admitted requests never exceeds `queue_cap`, and every
/// slot is returned (final depth zero).
#[test]
fn admission_cap_holds_under_contention() {
    const CAP: usize = 3;
    const THREADS: usize = 4;
    const ITERS: usize = 25;
    let ac = Arc::new(AdmissionControl::new(AdmissionConfig {
        queue_cap: CAP,
        budget_cycles: None,
        client_rps: None,
    }));
    let inflight = Arc::new(AtomicUsize::new(0));

    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let ac = Arc::clone(&ac);
            let inflight = Arc::clone(&inflight);
            thread::spawn(move || {
                let mut admitted = 0usize;
                for _ in 0..ITERS {
                    match ac.try_admit() {
                        Ok(()) => {
                            let now = inflight.fetch_add(1, Ordering::AcqRel) + 1;
                            assert!(now <= CAP, "{now} admitted into a cap-{CAP} queue");
                            inflight.fetch_sub(1, Ordering::AcqRel);
                            ac.release(1);
                            admitted += 1;
                        }
                        Err(ServeError::Overloaded { .. }) => {}
                        Err(other) => panic!("unexpected shed reason: {other:?}"),
                    }
                }
                admitted
            })
        })
        .collect();

    let total: usize = workers.into_iter().map(|w| w.join().expect("worker panicked")).sum();
    assert!(total >= 1, "at least one admit must succeed without contention on drain");
    assert_eq!(ac.depth(), 0, "queue slots leaked");
    // Release on an empty queue saturates instead of underflowing.
    ac.release(usize::MAX);
    assert_eq!(ac.depth(), 0);
}

/// Concurrent EWMA observers: the packed-atomic update loop must stay
/// race-free and land on a finite, clamped estimate.
#[test]
fn ewma_estimators_survive_concurrent_observers() {
    let ac = Arc::new(AdmissionControl::new(AdmissionConfig::default()));
    let workers: Vec<_> = (0..3u64)
        .map(|t| {
            let ac = Arc::clone(&ac);
            thread::spawn(move || {
                for i in 0..20u64 {
                    ac.observe_batch(4, Some(1_000 + t * 100 + i), Duration::from_micros(250));
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("observer panicked");
    }
    let cost = ac.cost_estimate().expect("cost EWMA never primed");
    assert!(cost.is_finite() && cost >= 1.0, "cost estimate {cost} out of range");
    assert!(ac.service_estimate() >= Duration::from_micros(1));
}

/// `begin_drain` racing live submitters: whatever the interleaving,
/// admission is closed once drain returns and later submits shed with
/// `Shutdown`.
#[test]
fn drain_racing_submitters_closes_admission() {
    let ac = Arc::new(AdmissionControl::new(AdmissionConfig {
        queue_cap: 8,
        budget_cycles: None,
        client_rps: None,
    }));
    let submitters: Vec<_> = (0..2)
        .map(|_| {
            let ac = Arc::clone(&ac);
            thread::spawn(move || {
                for _ in 0..10 {
                    if ac.try_admit().is_ok() {
                        ac.release(1);
                    }
                }
            })
        })
        .collect();
    ac.begin_drain(Instant::now());
    for s in submitters {
        s.join().expect("submitter panicked");
    }

    assert!(ac.is_draining());
    match ac.try_admit() {
        Err(ServeError::Shutdown) => {}
        other => panic!("post-drain admit must shed with Shutdown, got {other:?}"),
    }
}
