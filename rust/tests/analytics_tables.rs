//! Integration: every table/figure renderer against the paper's published
//! numbers (the row-by-row reproduction contract of DESIGN.md §5).

use trim_sa::analytics::design_space::{evaluate, sweep};
use trim_sa::analytics::eyeriss::{PUBLISHED_ALEXNET_TOTAL, PUBLISHED_VGG16_TOTAL};
use trim_sa::analytics::ops::profile_network;
use trim_sa::analytics::trim_model::analyze_network;
use trim_sa::arch::ArchConfig;
use trim_sa::model::{alexnet::alexnet, vgg16::vgg16};
use trim_sa::report::{render_fig1, render_fig7, render_table1_or_2, render_table3};

fn cfg() -> ArchConfig {
    ArchConfig::paper_engine()
}

/// Table I full-row regression: GOPs/s within 1 %, accesses within 7 %.
#[test]
fn table1_rows_regression() {
    let paper_gops = [51.8, 368.0, 387.0, 387.0, 396.0, 432.0, 432.0, 422.0, 422.0, 422.0, 389.0, 389.0, 389.0];
    let paper_total = [13.57, 103.36, 50.23, 96.01, 48.84, 95.38, 95.38, 52.77, 104.42, 104.42, 33.23, 33.23, 33.23];
    let m = analyze_network(&cfg(), &vgg16());
    for ((l, &g), &t) in m.layers.iter().zip(&paper_gops).zip(&paper_total) {
        assert!((l.gops - g).abs() / g < 0.01, "{} gops {:.1} vs {}", l.name, l.gops, g);
        assert!((l.total_m() - t).abs() / t < 0.07, "{} total {:.2} vs {}", l.name, l.total_m(), t);
    }
}

/// The paper's two headline memory ratios.
#[test]
fn headline_access_ratios() {
    let vgg = analyze_network(&cfg(), &vgg16());
    let r_vgg = PUBLISHED_VGG16_TOTAL.total_m() / vgg.total_m();
    assert!(r_vgg > 2.7 && r_vgg < 3.3, "VGG-16 ratio = {r_vgg:.2} (paper ~3x)");

    let alex = analyze_network(&cfg(), &alexnet());
    let r_alex = PUBLISHED_ALEXNET_TOTAL.total_m() / alex.total_m();
    assert!(r_alex > 1.3 && r_alex < 2.4, "AlexNet ratio = {r_alex:.2} (paper ~1.8x)");
}

/// §V: TrIM outperforms Eyeriss up to ~7× on AlexNet's native layers.
#[test]
fn alexnet_up_to_7x_throughput() {
    use trim_sa::analytics::eyeriss::PUBLISHED_ALEXNET;
    let m = analyze_network(&cfg(), &alexnet());
    let best = m
        .layers
        .iter()
        .zip(&PUBLISHED_ALEXNET)
        .map(|(l, e)| l.gops / e.gops)
        .fold(0.0, f64::max);
    assert!(best > 6.0 && best < 8.0, "best TrIM/Eyeriss = {best:.1}x (paper: up to ~7x)");
}

/// Fig. 7 anchors from §IV.
#[test]
fn fig7_anchor_points() {
    let net = vgg16();
    let best = evaluate(&cfg(), &net, 24, 24);
    assert!((best.gops - 1243.0).abs() / 1243.0 < 0.03, "{}", best.gops);
    let paper_point = evaluate(&cfg(), &net, 7, 24);
    assert!((paper_point.gops - 391.0).abs() < 5.0, "{}", paper_point.gops);
    // eq. (4) at the paper's design point, "rounded to the closest power
    // of 2" = 1024 bits/cycle
    assert_eq!(paper_point.io_bandwidth_bits, 1016);
    // full sweep is monotone in each axis at fixed other axis
    let pts = sweep(&cfg(), &net);
    for group in pts.chunks(5) {
        for w in group.windows(2) {
            assert!(w[1].gops >= w[0].gops * 0.999, "throughput monotone in P_M");
        }
    }
}

/// Fig. 1 anchors from §I.
#[test]
fn fig1_anchor_points() {
    let p = profile_network(&vgg16(), 8);
    let total_ops: f64 = p.iter().map(|l| l.gops).sum();
    assert!((total_ops - 30.7).abs() < 0.3);
    // CL1+CL2 dominate ifmap memory; CL11-13 dominate weights
    assert!(p[0].ifmap_mb + p[1].ifmap_mb > 3.0);
    assert!(p[10].weight_mb > 2.0);
}

/// Renderers include the key published values verbatim.
#[test]
fn renderers_are_complete() {
    let c = cfg();
    let t1 = render_table1_or_2(&c, &vgg16());
    assert!(t1.lines().count() > 17);
    assert!(t1.contains("2427.63") || t1.contains("2427.6"), "published Eyeriss total");
    let t2 = render_table1_or_2(&c, &alexnet());
    assert!(t2.contains("CL5"));
    let t3 = render_table3(&c);
    assert!(t3.contains("XCZU7EV") && t3.contains("104.78"));
    assert!(render_fig1(&vgg16(), 8).contains("CL13"));
    assert!(render_fig7(&c, &vgg16()).contains("P_N=24"));
}

/// Table III: the cost model tracks the reported implementation.
#[test]
fn table3_cost_model_tracks_reported() {
    use trim_sa::analytics::fpga::{estimate, CostCoefficients, PUBLISHED_TABLE3};
    let m = estimate(&cfg(), &CostCoefficients::default());
    let r = &PUBLISHED_TABLE3[3];
    assert!((m.luts / r.luts - 1.0).abs() < 0.10);
    assert!((m.power_w / r.power_w - 1.0).abs() < 0.05);
    assert!((m.efficiency_gops_per_w() / r.efficiency_gops_per_w() - 1.0).abs() < 0.06);
}
