//! Integration: the coordinator under concurrent load (mock backend —
//! PJRT-backed serving is covered by tests/runtime_artifacts.rs and the
//! serve_cnn example).

use std::sync::Arc;
use std::time::Duration;
use trim_sa::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend, MockBackend,
};

fn start(max_batch: usize, wait_ms: u64, delay_us: u64) -> Coordinator {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
    };
    Coordinator::start_with(
        move || {
            let mut b = MockBackend::new(16, 10);
            b.delay = Duration::from_micros(delay_us);
            Ok(Box::new(b) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap()
}

#[test]
fn concurrent_submitters_get_their_own_answers() {
    let c = Arc::new(start(8, 2, 0));
    let probe = MockBackend::new(16, 10);
    let mut handles = vec![];
    for t in 0..8u64 {
        let c = c.clone();
        let expected = probe.expected_logits(&vec![t as i32; 16]);
        handles.push(std::thread::spawn(move || {
            for _ in 0..25 {
                let resp = c.infer(vec![t as i32; 16]).unwrap();
                assert_eq!(resp.logits, expected, "thread {t} got someone else's logits");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(c.metrics().requests, 200);
}

#[test]
fn throughput_improves_with_batching_when_backend_amortises() {
    // The mock charges per-image latency, so batching can't help latency —
    // but batch formation must not *hurt* throughput by more than the
    // wait bound, and batches must actually form under load.
    let c = start(16, 20, 100);
    let pending: Vec<_> = (0..64).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    let mut seen_batched = false;
    for rx in pending {
        if rx.recv().unwrap().batch_size > 1 {
            seen_batched = true;
        }
    }
    assert!(seen_batched);
    let m = c.metrics();
    assert!(m.batches < 64, "batches = {}", m.batches);
    assert!(m.mean_batch > 1.0);
}

#[test]
fn latency_percentiles_are_ordered() {
    let c = start(4, 1, 50);
    let pending: Vec<_> = (0..40).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    for rx in pending {
        rx.recv().unwrap();
    }
    let m = c.metrics();
    assert!(m.p50_latency <= m.p95_latency);
    assert!(m.p95_latency <= m.max_latency);
    assert!(m.p50_latency > Duration::ZERO);
}

#[test]
fn startup_failure_is_propagated() {
    let r = Coordinator::start_with(
        || Err(anyhow::anyhow!("no artifacts here")),
        CoordinatorConfig::default(),
    );
    assert!(r.is_err());
    assert!(format!("{:#}", r.err().unwrap()).contains("no artifacts"));
}

#[test]
fn responses_preserve_request_identity() {
    let c = start(8, 5, 0);
    let rxs: Vec<_> = (0..30).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    let probe = MockBackend::new(16, 10);
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&vec![i as i32; 16]));
    }
}
