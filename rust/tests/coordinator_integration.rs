//! Integration: the coordinator under concurrent load (mock backend —
//! PJRT-backed serving is covered by tests/runtime_artifacts.rs and the
//! serve_cnn example), the cost-telemetry plumbing of sim-backed serving,
//! and the multi-farm Router front door.

use std::sync::Arc;
use std::time::Duration;
use trim_sa::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend, MockBackend, Router,
    SimBackend,
};
use trim_sa::util::SplitMix64;

fn start(max_batch: usize, wait_ms: u64, delay_us: u64) -> Coordinator {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
        ..Default::default()
    };
    Coordinator::start_with(
        move || {
            let mut b = MockBackend::new(16, 10);
            b.delay = Duration::from_micros(delay_us);
            Ok(Box::new(b) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap()
}

#[test]
fn concurrent_submitters_get_their_own_answers() {
    let c = Arc::new(start(8, 2, 0));
    let probe = MockBackend::new(16, 10);
    let mut handles = vec![];
    for t in 0..8u64 {
        let c = c.clone();
        let expected = probe.expected_logits(&vec![t as i32; 16]);
        handles.push(std::thread::spawn(move || {
            for _ in 0..25 {
                let resp = c.infer(vec![t as i32; 16]).unwrap();
                assert_eq!(resp.logits, expected, "thread {t} got someone else's logits");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(c.metrics().requests, 200);
}

#[test]
fn throughput_improves_with_batching_when_backend_amortises() {
    // The mock charges per-image latency, so batching can't help latency —
    // but batch formation must not *hurt* throughput by more than the
    // wait bound, and batches must actually form under load.
    let c = start(16, 20, 100);
    let pending: Vec<_> = (0..64).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    let mut seen_batched = false;
    for rx in pending {
        if rx.recv().unwrap().unwrap().batch_size > 1 {
            seen_batched = true;
        }
    }
    assert!(seen_batched);
    let m = c.metrics();
    assert!(m.batches < 64, "batches = {}", m.batches);
    assert!(m.mean_batch > 1.0);
}

#[test]
fn latency_percentiles_are_ordered() {
    let c = start(4, 1, 50);
    let pending: Vec<_> = (0..40).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let m = c.metrics();
    assert!(m.p50_latency <= m.p95_latency);
    assert!(m.p95_latency <= m.max_latency);
    assert!(m.p50_latency > Duration::ZERO);
}

#[test]
fn startup_failure_is_propagated() {
    let r = Coordinator::start_with(
        || Err(anyhow::anyhow!("no artifacts here")),
        CoordinatorConfig::default(),
    );
    assert!(r.is_err());
    assert!(format!("{:#}", r.err().unwrap()).contains("no artifacts"));
}

#[test]
fn responses_preserve_request_identity() {
    let c = start(8, 5, 0);
    let rxs: Vec<_> = (0..30).map(|i| c.submit(vec![i; 16]).unwrap()).collect();
    let probe = MockBackend::new(16, 10);
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&vec![i as i32; 16]));
    }
}

fn sim_coordinator(engines: usize, max_batch: usize) -> Coordinator {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(5) },
        ..Default::default()
    };
    Coordinator::start_with(
        move || Ok(Box::new(SimBackend::new(engines)) as Box<dyn InferenceBackend>),
        cfg,
    )
    .unwrap()
}

/// Sim-backed serving surfaces the execution cost end to end: every
/// response carries an attributed `SimCost`, the metrics snapshot
/// accumulates nonzero cycles/accesses/joules/GOPS, and the per-request
/// shares of joules add back up to the snapshot's cumulative total.
#[test]
fn sim_backed_serving_reports_cost_telemetry() {
    let c = sim_coordinator(2, 8);
    let len = c.input_len();
    let pending: Vec<_> = (0..12)
        .map(|i| c.submit(SplitMix64::new(0x7E1 + i as u64).vec_i32(len, 0, 256)).unwrap())
        .collect();
    let mut joules_sum = 0.0f64;
    for rx in pending {
        let resp = rx.recv().unwrap().unwrap();
        let cost = resp.cost.expect("sim responses carry an attributed cost");
        assert!(cost.batch_cycles > 0);
        assert!(cost.off_chip_accesses > 0.0 && cost.on_chip_accesses > 0.0);
        assert!(cost.macs > 0.0 && cost.joules > 0.0 && cost.gops > 0.0);
        assert!(resp.class.is_some(), "real logits must classify");
        joules_sum += cost.joules;
    }
    let m = c.metrics();
    assert_eq!(m.requests, 12);
    assert!(m.sim_batches > 0 && m.sim_batches == m.batches);
    assert!(m.sim_cycles > 0 && m.sim_off_chip_accesses > 0 && m.sim_on_chip_accesses > 0);
    assert!(m.sim_macs > 0 && m.sim_joules > 0.0 && m.sim_gops > 0.0);
    assert!((m.sim_f_clk - 150.0e6).abs() < 1.0, "priced at the engines' clock");
    // attribution conserves energy: per-request shares sum to the total
    assert!(
        (joules_sum - m.sim_joules).abs() < 1e-9 * m.sim_joules,
        "Σ per-request joules {joules_sum} != cumulative {}",
        m.sim_joules
    );
}

/// Backends with no cost model leave every `sim_*` field zero and every
/// response's cost `None` — telemetry never lies about measuring.
#[test]
fn mock_backend_reports_no_cost() {
    let c = start(4, 1, 0);
    let resp = c.infer(vec![0; 16]).unwrap();
    assert!(resp.cost.is_none());
    let m = c.metrics();
    assert_eq!(m.sim_batches, 0);
    assert_eq!(m.sim_cycles, 0);
    assert_eq!(m.sim_joules, 0.0);
    assert_eq!(m.sim_gops, 0.0);
}

/// Acceptance: a Router over ≥ 2 farms (heterogeneous engine counts)
/// serves a batch **bit-identically** to a single farm and to the golden
/// reference, and its merged metrics equal the sum of the per-farm
/// snapshots on every countable field.
#[test]
fn router_over_two_farms_is_bit_identical_and_merges_metrics() {
    let probe = SimBackend::new(1);
    let len = probe.input_len();
    let images: Vec<Vec<i32>> =
        (0..24).map(|i| SplitMix64::new(0x2024 + i as u64).vec_i32(len, 0, 256)).collect();

    let single = sim_coordinator(2, 8);
    let single_logits: Vec<Vec<i32>> =
        images.iter().map(|img| single.infer(img.clone()).unwrap().logits).collect();

    let router = Router::new(vec![sim_coordinator(2, 8), sim_coordinator(3, 8)]).unwrap();
    assert_eq!(router.farms(), 2);
    let pending: Vec<_> = images.iter().map(|img| router.submit(img.clone()).unwrap()).collect();
    for ((img, expect), mut rx) in images.iter().zip(&single_logits).zip(pending) {
        let resp = rx.recv().unwrap();
        assert_eq!(&resp.logits, expect, "router must serve bit-identically to a single farm");
        assert_eq!(resp.logits, probe.reference_logits(img), "…and to the golden reference");
        assert!(resp.cost.is_some());
    }

    let merged = router.metrics();
    let per = router.farm_metrics();
    assert!(per.iter().all(|m| m.requests > 0), "least-outstanding dispatch must use both farms");
    assert_eq!(merged.requests, per.iter().map(|m| m.requests).sum::<u64>());
    assert_eq!(merged.requests, 24);
    assert_eq!(merged.batches, per.iter().map(|m| m.batches).sum::<u64>());
    assert_eq!(merged.sim_batches, per.iter().map(|m| m.sim_batches).sum::<u64>());
    assert_eq!(merged.sim_cycles, per.iter().map(|m| m.sim_cycles).sum::<u64>());
    assert_eq!(
        merged.sim_off_chip_accesses,
        per.iter().map(|m| m.sim_off_chip_accesses).sum::<u64>()
    );
    assert_eq!(
        merged.sim_on_chip_accesses,
        per.iter().map(|m| m.sim_on_chip_accesses).sum::<u64>()
    );
    assert_eq!(merged.sim_macs, per.iter().map(|m| m.sim_macs).sum::<u64>());
    let joules: f64 = per.iter().map(|m| m.sim_joules).sum();
    assert!(merged.sim_joules > 0.0 && (merged.sim_joules - joules).abs() <= 1e-12 * joules);
    assert!(merged.sim_gops > 0.0);
}
