//! Chaos acceptance tests (ISSUE 9): seeded hardware fault injection
//! through the full serving stack must never produce a wrong answer.
//!
//! The fault draws are deterministic per (seed, engine, shard signature),
//! but *which* engine first executes a shard is a work-stealing race — so
//! these tests assert per-run invariants (every injected fault detected,
//! every detected fault re-executed, outputs bit-exact or a typed error)
//! and scan a handful of seeds for the runs that must exist (a healed
//! fault, a quarantined engine) rather than pinning one seed's schedule.
//!
//! Kept deliberately small (tiny spec, fast fidelity, few requests) so
//! the CI chaos job stays timeout-bounded.

use std::time::{Duration, Instant};
use trim_sa::arch::{ArchConfig, ExecFidelity};
use trim_sa::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, FaultConfig, FaultModel, FaultReport,
    InferenceBackend, Router, ServeError,
};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::model::ConvLayer;
use trim_sa::scheduler::{CanaryConfig, EngineFarm, FarmConfig, ShardMode, SimBackend, SimNetSpec};

fn chaos_router(chaos: FaultConfig, engines: usize) -> Router {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        ..Default::default()
    };
    let c = Coordinator::start_with(
        move || {
            Ok(Box::new(SimBackend::with_chaos(
                engines,
                ArchConfig::small(3, 2, 1),
                SimNetSpec::tiny(),
                ShardMode::FilterShards,
                ExecFidelity::Fast,
                CanaryConfig::default(),
                chaos,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap();
    Router::new(vec![c]).unwrap()
}

/// Serving stack over a farm with *timing* chaos (gray failures) and
/// hedged re-execution. The valve floor is pulled down from its 300 s
/// production default so an unresolvable hang types out within the test
/// budget instead of stalling CI.
fn timing_router(chaos: FaultConfig, engines: usize, hedge_factor: f64, threshold: u32) -> Router {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        ..Default::default()
    };
    let c = Coordinator::start_with(
        move || {
            let farm = FarmConfig::with_fidelity(
                engines,
                ArchConfig::small(3, 2, 1),
                ExecFidelity::Fast,
            )
            .with_chaos(chaos)
            .with_hedge(hedge_factor, threshold)
            .with_valve(Duration::from_secs(5), 8.0);
            Ok(Box::new(SimBackend::with_farm_config(
                farm,
                SimNetSpec::tiny(),
                ShardMode::FilterShards,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap();
    Router::new(vec![c]).unwrap()
}

fn image(i: usize, len: usize) -> Vec<i32> {
    (0..len).map(|j| ((i * 7919 + j * 31) % 256) as i32).collect()
}

/// Fault-free reference logits for `n` deterministic images.
fn reference_logits(n: usize) -> Vec<Vec<i32>> {
    let router = chaos_router(FaultConfig::disabled(), 2);
    let len = router.input_len();
    let out = (0..n).map(|i| router.infer(image(i, len)).unwrap().logits).collect();
    router.drain(Duration::from_secs(5));
    out
}

#[test]
fn abft_detects_every_injected_fault_and_serving_stays_bit_exact() {
    let t0 = Instant::now();
    let n_req = 12usize;
    let reference = reference_logits(n_req);
    let mut healed_run_seen = false;
    for seed in 0..16u64 {
        let chaos = FaultConfig::new(0.3, seed, FaultModel::Pe);
        let router = chaos_router(chaos, 4);
        let len = router.input_len();
        let mut all_ok = true;
        for i in 0..n_req {
            match router.infer(image(i, len)) {
                Ok(resp) => assert_eq!(
                    resp.logits, reference[i],
                    "seed {seed} req {i}: a served answer must be bit-exact"
                ),
                Err(e) => {
                    // The only permitted failure: a shard whose draw fires
                    // on every engine exhausts its bounded retries into a
                    // typed error — never a silently wrong answer.
                    let se = e.downcast_ref::<ServeError>();
                    assert!(se.is_some(), "seed {seed}: untyped failure {e:#}");
                    all_ok = false;
                }
            }
        }
        let m = router.drain(Duration::from_secs(10));
        // 100% detection: every injected output-corrupting fault is caught
        // by the ABFT checksum, and every detection triggers re-execution.
        assert_eq!(
            m.fault.detected, m.fault.injected,
            "seed {seed}: ABFT must catch every injected fault (router-merged snapshot)"
        );
        assert_eq!(
            m.fault.reexecuted, m.fault.detected,
            "seed {seed}: every detected fault re-executes"
        );
        if all_ok && m.fault.injected > 0 {
            assert!(
                m.fault.corrected > 0,
                "seed {seed}: an all-served run with injections healed at least one shard"
            );
            healed_run_seen = true;
            break;
        }
    }
    assert!(
        healed_run_seen,
        "no seed in 0..16 produced an injected-and-fully-healed run — \
         the self-healing path never exercised"
    );
    assert!(t0.elapsed() < Duration::from_secs(300), "chaos acceptance must stay bounded");
}

#[test]
fn zero_rate_chaos_reports_zero_counters_and_serves_clean() {
    let router = chaos_router(FaultConfig::disabled(), 2);
    let len = router.input_len();
    let reference = reference_logits(4);
    for (i, want) in reference.iter().enumerate() {
        assert_eq!(&router.infer(image(i, len)).unwrap().logits, want);
    }
    let m = router.drain(Duration::from_secs(5));
    assert_eq!(m.fault, FaultReport::default(), "disabled injection leaves every counter zero");
    assert!(m.fault.is_clean());
}

#[test]
fn hedged_hang_chaos_serves_bit_exact_through_the_stack() {
    // Gray-failure acceptance: hang chaos parks seeded (engine, shard)
    // executions forever. With hedging on, the shard is re-injected past
    // its analytic service budget and the duplicate resolves it on
    // another engine — first result wins, so every served answer is
    // bit-exact. A shard unlucky enough to hang everywhere may only fail
    // through the typed valve, never a wrong answer or a 300 s stall.
    let t0 = Instant::now();
    let n_req = 6usize;
    let reference = reference_logits(n_req);
    let mut hedged_total = 0u64;
    let mut clean_run_seen = false;
    for seed in 0..12u64 {
        let chaos = FaultConfig::new(0.2, seed, FaultModel::Hang);
        let router = timing_router(chaos, 4, 4.0, 3);
        let len = router.input_len();
        let mut all_ok = true;
        for i in 0..n_req {
            match router.infer(image(i, len)) {
                Ok(resp) => assert_eq!(
                    resp.logits, reference[i],
                    "seed {seed} req {i}: a hedged answer must be bit-exact"
                ),
                Err(e) => {
                    assert!(
                        e.downcast_ref::<ServeError>().is_some(),
                        "seed {seed} req {i}: untyped failure under hang chaos: {e:#}"
                    );
                    all_ok = false;
                }
            }
        }
        let m = router.drain(Duration::from_secs(10));
        assert_eq!(m.fault.injected, 0, "seed {seed}: timing chaos corrupts no outputs");
        hedged_total += m.fault.hedged;
        if all_ok && m.fault.hedged > 0 {
            assert!(
                m.fault.stragglers_detected > 0,
                "seed {seed}: a hedge implies a detected straggler"
            );
            clean_run_seen = true;
            break;
        }
    }
    assert!(hedged_total > 0, "hang rate 0.2 over 12 seeds must hedge at least once");
    assert!(
        clean_run_seen,
        "no seed in 0..12 produced a fully-served hedged run — \
         the hedging path never resolved a hang end-to-end"
    );
    assert!(t0.elapsed() < Duration::from_secs(300), "straggler acceptance must stay bounded");
}

#[test]
fn persistent_slow_engines_trip_timing_quarantine_and_serving_stays_exact() {
    // Slow chaos sleeps seeded (engine, shard) pairs 2–8 ms — far past
    // the cold-farm hedge budget — so losers of the first-wins race are
    // discarded late and attributed as timing strikes. An engine that
    // keeps straggling crosses `straggler_threshold` and is quarantined
    // as `Slow`; the request stream stays bit-exact throughout.
    let t0 = Instant::now();
    let n_req = 10usize;
    let reference = reference_logits(n_req);
    let mut quarantine_seen = false;
    for seed in 0..8u64 {
        let chaos = FaultConfig::new(0.5, seed, FaultModel::Slow);
        let router = timing_router(chaos, 4, 2.0, 2);
        let len = router.input_len();
        for i in 0..n_req {
            match router.infer(image(i, len)) {
                Ok(resp) => assert_eq!(
                    resp.logits, reference[i],
                    "seed {seed} req {i}: slow chaos must never change an answer"
                ),
                Err(e) => assert!(
                    e.downcast_ref::<ServeError>().is_some(),
                    "seed {seed} req {i}: untyped failure under slow chaos: {e:#}"
                ),
            }
        }
        let m = router.drain(Duration::from_secs(10));
        assert_eq!(m.fault.injected, 0, "seed {seed}: slow chaos corrupts nothing");
        assert!(
            m.fault.hedge_won <= m.fault.hedged,
            "seed {seed}: a hedge can only win if it was dispatched"
        );
        if m.fault.timing_quarantined > 0 {
            assert!(
                m.fault.stragglers_detected > 0,
                "seed {seed}: timing quarantine implies detected stragglers"
            );
            quarantine_seen = true;
            break;
        }
    }
    assert!(
        quarantine_seen,
        "no seed in 0..8 pushed a persistently slow engine over the timing \
         threshold — health-aware scheduling never exercised"
    );
    assert!(t0.elapsed() < Duration::from_secs(300), "slow-chaos scan must stay bounded");
}

#[test]
fn threshold_crossing_engines_quarantine_and_the_farm_replans() {
    // Direct farm-level check: enough detected faults must push engines
    // over the quarantine threshold, after which the planner replans over
    // the survivors — degraded capacity, still bit-exact.
    let engines = 3usize;
    let layer = ConvLayer::new("cl", 10, 3, 3, 6, 1, 1);
    let input = Tensor3::from_fn(3, 10, 10, |c, y, x| ((c * 31 + y * 7 + x) % 23) as i32 - 11);
    let weights: Vec<i32> = (0..layer.weight_elems() as usize).map(|i| ((i as i32 * 37) % 15) - 7).collect();
    let golden = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);

    let mut quarantine_seen = false;
    'seeds: for seed in 0..8u64 {
        let chaos = FaultConfig::new(0.35, seed, FaultModel::Pe);
        let farm = EngineFarm::new(
            FarmConfig::with_fidelity(engines, ArchConfig::small(3, 2, 1), ExecFidelity::Fast)
                .with_chaos(chaos),
        );
        // Distinct layer names give every run independent fault draws, so
        // detected faults accumulate against the engines' health records.
        for run in 0..12 {
            let l = ConvLayer { name: format!("cl{run}"), ..layer.clone() };
            match farm.run_layer_mode(&l, &input, &weights, ShardMode::FilterShards) {
                Ok(r) => assert_eq!(
                    r.ofmaps, golden,
                    "seed {seed} run {run}: healed output must stay bit-exact"
                ),
                Err(e) => {
                    // bounded-retry exhaustion — typed, not a wrong answer
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("attempts") || msg.contains("quarantin"),
                        "seed {seed} run {run}: unexpected failure {msg}"
                    );
                }
            }
            let fr = farm.fault_report();
            assert_eq!(fr.detected, fr.injected, "seed {seed}: detection stays total");
            if fr.quarantined > 0 {
                assert!(
                    farm.live_engines() >= 1 && farm.live_engines() < engines,
                    "seed {seed}: quarantine shrinks the live set but never empties it"
                );
                // Replanning proof: the degraded farm still answers
                // correctly (or types out) on a fresh layer.
                let l = ConvLayer { name: "post-quarantine".into(), ..layer.clone() };
                if let Ok(r) = farm.run_layer_mode(&l, &input, &weights, ShardMode::FilterShards) {
                    assert_eq!(r.ofmaps, golden, "seed {seed}: degraded replan stays bit-exact");
                }
                quarantine_seen = true;
                break 'seeds;
            }
        }
    }
    assert!(
        quarantine_seen,
        "no seed in 0..8 pushed an engine over the quarantine threshold within 12 runs"
    );
}
