//! Chaos acceptance tests (ISSUE 9): seeded hardware fault injection
//! through the full serving stack must never produce a wrong answer.
//!
//! The fault draws are deterministic per (seed, engine, shard signature),
//! but *which* engine first executes a shard is a work-stealing race — so
//! these tests assert per-run invariants (every injected fault detected,
//! every detected fault re-executed, outputs bit-exact or a typed error)
//! and scan a handful of seeds for the runs that must exist (a healed
//! fault, a quarantined engine) rather than pinning one seed's schedule.
//!
//! Kept deliberately small (tiny spec, fast fidelity, few requests) so
//! the CI chaos job stays timeout-bounded.

use std::time::{Duration, Instant};
use trim_sa::arch::{ArchConfig, ExecFidelity};
use trim_sa::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, FaultConfig, FaultModel, FaultReport,
    InferenceBackend, Router, ServeError,
};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::model::ConvLayer;
use trim_sa::scheduler::{CanaryConfig, EngineFarm, FarmConfig, ShardMode, SimBackend, SimNetSpec};

fn chaos_router(chaos: FaultConfig, engines: usize) -> Router {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        ..Default::default()
    };
    let c = Coordinator::start_with(
        move || {
            Ok(Box::new(SimBackend::with_chaos(
                engines,
                ArchConfig::small(3, 2, 1),
                SimNetSpec::tiny(),
                ShardMode::FilterShards,
                ExecFidelity::Fast,
                CanaryConfig::default(),
                chaos,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )
    .unwrap();
    Router::new(vec![c]).unwrap()
}

fn image(i: usize, len: usize) -> Vec<i32> {
    (0..len).map(|j| ((i * 7919 + j * 31) % 256) as i32).collect()
}

/// Fault-free reference logits for `n` deterministic images.
fn reference_logits(n: usize) -> Vec<Vec<i32>> {
    let router = chaos_router(FaultConfig::disabled(), 2);
    let len = router.input_len();
    let out = (0..n).map(|i| router.infer(image(i, len)).unwrap().logits).collect();
    router.drain(Duration::from_secs(5));
    out
}

#[test]
fn abft_detects_every_injected_fault_and_serving_stays_bit_exact() {
    let t0 = Instant::now();
    let n_req = 12usize;
    let reference = reference_logits(n_req);
    let mut healed_run_seen = false;
    for seed in 0..16u64 {
        let chaos = FaultConfig::new(0.3, seed, FaultModel::Pe);
        let router = chaos_router(chaos, 4);
        let len = router.input_len();
        let mut all_ok = true;
        for i in 0..n_req {
            match router.infer(image(i, len)) {
                Ok(resp) => assert_eq!(
                    resp.logits, reference[i],
                    "seed {seed} req {i}: a served answer must be bit-exact"
                ),
                Err(e) => {
                    // The only permitted failure: a shard whose draw fires
                    // on every engine exhausts its bounded retries into a
                    // typed error — never a silently wrong answer.
                    let se = e.downcast_ref::<ServeError>();
                    assert!(se.is_some(), "seed {seed}: untyped failure {e:#}");
                    all_ok = false;
                }
            }
        }
        let m = router.drain(Duration::from_secs(10));
        // 100% detection: every injected output-corrupting fault is caught
        // by the ABFT checksum, and every detection triggers re-execution.
        assert_eq!(
            m.fault.detected, m.fault.injected,
            "seed {seed}: ABFT must catch every injected fault (router-merged snapshot)"
        );
        assert_eq!(
            m.fault.reexecuted, m.fault.detected,
            "seed {seed}: every detected fault re-executes"
        );
        if all_ok && m.fault.injected > 0 {
            assert!(
                m.fault.corrected > 0,
                "seed {seed}: an all-served run with injections healed at least one shard"
            );
            healed_run_seen = true;
            break;
        }
    }
    assert!(
        healed_run_seen,
        "no seed in 0..16 produced an injected-and-fully-healed run — \
         the self-healing path never exercised"
    );
    assert!(t0.elapsed() < Duration::from_secs(300), "chaos acceptance must stay bounded");
}

#[test]
fn zero_rate_chaos_reports_zero_counters_and_serves_clean() {
    let router = chaos_router(FaultConfig::disabled(), 2);
    let len = router.input_len();
    let reference = reference_logits(4);
    for (i, want) in reference.iter().enumerate() {
        assert_eq!(&router.infer(image(i, len)).unwrap().logits, want);
    }
    let m = router.drain(Duration::from_secs(5));
    assert_eq!(m.fault, FaultReport::default(), "disabled injection leaves every counter zero");
    assert!(m.fault.is_clean());
}

#[test]
fn threshold_crossing_engines_quarantine_and_the_farm_replans() {
    // Direct farm-level check: enough detected faults must push engines
    // over the quarantine threshold, after which the planner replans over
    // the survivors — degraded capacity, still bit-exact.
    let engines = 3usize;
    let layer = ConvLayer::new("cl", 10, 3, 3, 6, 1, 1);
    let input = Tensor3::from_fn(3, 10, 10, |c, y, x| ((c * 31 + y * 7 + x) % 23) as i32 - 11);
    let weights: Vec<i32> = (0..layer.weight_elems() as usize).map(|i| ((i as i32 * 37) % 15) - 7).collect();
    let golden = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);

    let mut quarantine_seen = false;
    'seeds: for seed in 0..8u64 {
        let chaos = FaultConfig::new(0.35, seed, FaultModel::Pe);
        let farm = EngineFarm::new(
            FarmConfig::with_fidelity(engines, ArchConfig::small(3, 2, 1), ExecFidelity::Fast)
                .with_chaos(chaos),
        );
        // Distinct layer names give every run independent fault draws, so
        // detected faults accumulate against the engines' health records.
        for run in 0..12 {
            let l = ConvLayer { name: format!("cl{run}"), ..layer.clone() };
            match farm.run_layer_mode(&l, &input, &weights, ShardMode::FilterShards) {
                Ok(r) => assert_eq!(
                    r.ofmaps, golden,
                    "seed {seed} run {run}: healed output must stay bit-exact"
                ),
                Err(e) => {
                    // bounded-retry exhaustion — typed, not a wrong answer
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("attempts") || msg.contains("quarantin"),
                        "seed {seed} run {run}: unexpected failure {msg}"
                    );
                }
            }
            let fr = farm.fault_report();
            assert_eq!(fr.detected, fr.injected, "seed {seed}: detection stays total");
            if fr.quarantined > 0 {
                assert!(
                    farm.live_engines() >= 1 && farm.live_engines() < engines,
                    "seed {seed}: quarantine shrinks the live set but never empties it"
                );
                // Replanning proof: the degraded farm still answers
                // correctly (or types out) on a fresh layer.
                let l = ConvLayer { name: "post-quarantine".into(), ..layer.clone() };
                if let Ok(r) = farm.run_layer_mode(&l, &input, &weights, ShardMode::FilterShards) {
                    assert_eq!(r.ofmaps, golden, "seed {seed}: degraded replan stays bit-exact");
                }
                quarantine_seen = true;
                break 'seeds;
            }
        }
    }
    assert!(
        quarantine_seen,
        "no seed in 0..8 pushed an engine over the quarantine threshold within 12 runs"
    );
}
