//! Integration over the real PJRT runtime + AOT artifacts.
//!
//! These tests need `make artifacts` to have run (the artifacts directory
//! is a build product, not checked in). They SKIP with a notice when it is
//! absent so `cargo test` stays green on a fresh clone; CI/`make test`
//! always builds artifacts first.

use trim_sa::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, PjrtBackend};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::runtime::{Manifest, Runtime};
use trim_sa::util::SplitMix64;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_covers_serving_set() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in ["trimnet_block0", "trimnet_block1", "trimnet_block2", "trimnet_head", "trimnet_full", "conv_unit"] {
        let a = m.get(name).unwrap();
        assert!(a.file.exists(), "{name} file missing");
    }
}

/// The PJRT-executed conv artifact is bit-exact against the Rust golden
/// model — the cross-language, cross-stack numeric contract: Pallas
/// kernel (python) == HLO artifact (XLA) == golden conv (rust).
#[test]
fn conv_unit_matches_golden_across_the_stack() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let conv = rt.module("conv_unit").unwrap();
    let mut rng = SplitMix64::new(2024);
    for round in 0..5 {
        let x = rng.vec_i32(2 * 8 * 8, 0, 256);
        let w = rng.vec_i32(3 * 2 * 3 * 3, -8, 8);
        let got = conv.run_i32(&[&x, &w]).unwrap();

        let input = Tensor3 { c: 2, h: 8, w: 8, data: x };
        let golden = conv3d_i32(&input, &w, 3, 3, 1, 1);
        assert_eq!(got, golden.data, "round {round}");
    }
}

/// Blockwise pipeline == fused forward (the serving-path identity).
#[test]
fn blockwise_equals_fused_artifact() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mut rng = SplitMix64::new(7);
    let image = rng.vec_i32(3 * 32 * 32, 0, 256);
    let mut act = image.clone();
    for b in 0..3 {
        act = rt.module(&format!("trimnet_block{b}")).unwrap().run_i32(&[&act]).unwrap();
    }
    let blockwise = rt.module("trimnet_head").unwrap().run_i32(&[&act]).unwrap();
    let fused = rt.module("trimnet_full").unwrap().run_i32(&[&image]).unwrap();
    assert_eq!(blockwise, fused);
    assert_eq!(fused.len(), 10);
}

/// Full e2e: coordinator + PJRT backend serves a batch correctly.
#[test]
fn coordinator_serves_pjrt_backend() {
    let Some(dir) = artifact_dir() else { return };
    // expected logits via the raw runtime
    let rt = Runtime::load(&dir).unwrap();
    let mut rng = SplitMix64::new(99);
    let images: Vec<Vec<i32>> = (0..6).map(|_| rng.vec_i32(3 * 32 * 32, 0, 256)).collect();
    let expected: Vec<Vec<i32>> =
        images.iter().map(|img| rt.module("trimnet_full").unwrap().run_i32(&[img]).unwrap()).collect();

    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(5) },
        ..Default::default()
    };
    let d = dir.clone();
    let c = Coordinator::start_with(move || Ok(Box::new(PjrtBackend::load(&d)?) as _), cfg).unwrap();
    let rxs: Vec<_> = images.iter().map(|img| c.submit(img.clone()).unwrap()).collect();
    for (rx, exp) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(&resp.logits, exp);
    }
    assert_eq!(c.metrics().requests, 6);
}

/// Bad inputs are rejected with errors, not UB or silent wrong answers.
#[test]
fn runtime_validates_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let conv = rt.module("conv_unit").unwrap();
    assert!(conv.run_i32(&[&[0i32; 3]]).is_err(), "wrong arity");
    let x = vec![0i32; 2 * 8 * 8];
    assert!(conv.run_i32(&[&x, &[0i32; 5]]).is_err(), "wrong shape");
    assert!(rt.module("nonexistent").is_err());
}
