//! `trim` — CLI for the TrIM reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artefacts:
//!
//! ```text
//! trim fig1                      Fig. 1  (VGG-16 memory/ops profile)
//! trim sweep                     Fig. 7  (design-space exploration)
//! trim table --net vgg16        Table I  (TrIM vs Eyeriss, VGG-16)
//! trim table --net alexnet      Table II (TrIM vs Eyeriss, AlexNet)
//! trim table3                   Table III (FPGA comparison + cost model)
//! trim analyze [--net ...]      §V headline numbers
//! trim sim [--hw N] [--k K]     cycle-accurate slice run + measured stats
//! trim validate                 simulator vs golden + paper invariants
//! trim serve [--backend auto|pjrt|sim] [--engines N] [--artifacts DIR]
//!            [--requests N] [--max-batch B] [--fidelity fast|register]
//!            [--farms F] [--shard filter|pipeline|spatial|hybrid|auto]
//!            [--canary RATE] [--metrics-out PATH]
//!            [--queue-cap N] [--budget-cycles C] [--deadline-ms D]
//!            [--drain-ms G] [--http PORT] [--http-secs S]
//!            [--client-rps R] [--chaos RATE] [--chaos-seed S]
//!            [--chaos-model pe|rsrb|mem|slow|hang]
//!            [--hedge-factor F] [--straggler-threshold N]
//!                               e2e batched inference. Backends:
//!                                 pjrt — compiled XLA artifacts (needs
//!                                        `make artifacts` + the `pjrt`
//!                                        cargo feature)
//!                                 sim  — the simulated TrIM engine farm,
//!                                        zero build products required
//!                                 auto — pjrt if available, else sim
//!                                        with a printed notice (default)
//!                               --fidelity picks the sim engines' tier:
//!                               fast (functional + closed-form counters,
//!                               default) or register (cycle-accurate
//!                               oracle); logits are bit-identical.
//!                               --shard picks how the sim farm cuts each
//!                               layer: filter (filter groups), spatial
//!                               (output-row bands), hybrid (2-D filter ×
//!                               row grid), auto (per-layer best of the
//!                               three — the default) or pipeline (layer
//!                               chain as independent stage jobs); logits
//!                               are bit-identical across modes.
//!                               --farms F fronts F coordinators (one
//!                               farm each) with the cost-aware Router
//!                               (EWMA of reported per-request sim
//!                               cycles × queue depth; least-outstanding
//!                               until a cost is reported) and reports
//!                               merged metrics. Sim-backed serving also reports
//!                               the simulated cost per snapshot: cycles,
//!                               off-/on-chip accesses, joules, GOPS and
//!                               the per-layer cost breakdown table.
//!                               --canary RATE shadow-executes that
//!                               fraction of fast-tier shards on a
//!                               register-fidelity oracle off the hot
//!                               path and reports bit/counter divergence
//!                               in the metrics (0 = off, the default).
//!                               --metrics-out PATH writes the final
//!                               merged snapshot as Prometheus text
//!                               (PATH `-` prints it to stdout)
//!                               Robustness knobs (ISSUE 7): --queue-cap
//!                               bounds each farm's ingress queue
//!                               (default 256; admission sheds with
//!                               Overloaded past it), --budget-cycles
//!                               sheds once queued simulated work
//!                               (depth × EWMA cycles/request) exceeds C,
//!                               --deadline-ms gives every synthetic
//!                               request a deadline budget (hopeless ones
//!                               reject as DeadlineExceeded), --drain-ms
//!                               is the graceful-drain grace period
//!                               (default 2000; the backlog past it
//!                               rejects as Shutdown), and --http PORT
//!                               serves POST /infer, GET /metrics and
//!                               GET /healthz on 127.0.0.1:PORT for
//!                               --http-secs seconds (default 30; the
//!                               timer is the stand-in for SIGINT — when
//!                               it fires the server stops accepting and
//!                               the fleet drains gracefully)
//!                               Fault tolerance: --client-rps R sheds
//!                               each client past R requests/s with 429 +
//!                               Retry-After (the "client" body field keys
//!                               the bucket; anonymous requests share
//!                               one), --chaos RATE injects seeded
//!                               hardware faults into that fraction of
//!                               (engine, shard) executions —
//!                               --chaos-model picks PE MAC bit flips
//!                               (default), stuck-at RSRB rows, corrupted
//!                               memory reads, or the gray-failure timing
//!                               models: slow (seeded deterministic
//!                               per-(engine, shard) slowdown — results
//!                               stay correct, just late) and hang (the
//!                               execution never completes); --chaos-seed
//!                               makes the plan reproducible. Every
//!                               merged shard is ABFT-checksum-verified;
//!                               detected faults re-execute on another
//!                               engine, repeat offenders quarantine and
//!                               the farm replans at degraded capacity —
//!                               logits stay bit-exact, and the fault
//!                               counters land in /metrics and the final
//!                               summary. --hedge-factor F (default 4)
//!                               hedges any shard outstanding past F ×
//!                               its analytic service budget onto another
//!                               engine — first bit-exact result wins, so
//!                               stragglers bound tail latency instead of
//!                               setting it (0 disables hedging);
//!                               --straggler-threshold N quarantines an
//!                               engine caught straggling N times
//!                               (probation applies, like fault
//!                               quarantine)
//! trim farm [--engines N] [--net vgg16|alexnet] [--batch B]
//!           [--shard filter|pipeline|spatial|hybrid|auto]
//!           [--fidelity fast|register]
//!           [--chaos RATE] [--chaos-seed S]
//!           [--chaos-model pe|rsrb|mem|slow|hang]
//!           [--hedge-factor F] [--straggler-threshold N]
//!                               shard real network layers across a farm
//!                               of simulated engines: per-layer speedup
//!                               table (chosen axis + speedup bound) +
//!                               per-layer cost breakdown +
//!                               bit-exactness check. --mode is accepted
//!                               as a legacy alias of --shard.
//!                               pipeline mode streams a batch of B images
//!                               through the serving chain instead of
//!                               --net (real CNNs pool between CLs).
//!                               --canary RATE shadow-checks sharded
//!                               layers against the register oracle;
//!                               --metrics-out PATH dumps the farm's
//!                               telemetry registry as Prometheus text
//! trim trace [--requests N] [--engines N] [--canary RATE]
//!                               run a small sim serving workload and
//!                               export the trace ring (serve.request /
//!                               serve.batch / batch.formed /
//!                               router.dispatch / farm.* / canary.*
//!                               spans and events) as JSON lines
//! trim check [--sweep]          static invariant verification: prove the
//!                               shard planner + closed-form counter
//!                               model consistent (coverage, halo
//!                               conservation, cycle bounds, Tables I–II
//!                               counter conservation) over a design-
//!                               space sweep without running any
//!                               convolution, then corrupt a known-good
//!                               plan to prove the checker can fail.
//!                               --sweep runs the full CI grid (≥ 200
//!                               layer × arch × mode × engine points);
//!                               default is a quick subset. Exits
//!                               nonzero with a per-violation report
//!                               (geometry, mode, law, expected vs got)
//!                               and emits a `JSON ` summary line.
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use trim_sa::analytics::EnergyModel;
use trim_sa::arch::control::plan_layer;
use trim_sa::arch::{ArchConfig, EngineSim, ExecFidelity, SimStats, SliceSim};
use trim_sa::coordinator::{
    make_backend, AdmissionConfig, BackendKind, BatchCost, BatcherConfig, Coordinator,
    CoordinatorConfig, FaultConfig, FaultModel, FaultReport, HttpServer, LayerCost, Router,
    ServeError,
};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::model::{alexnet::alexnet, vgg16::vgg16, ConvLayer, Network};
use trim_sa::obs;
use trim_sa::report::{render_fig1, render_fig7, render_table1_or_2, render_table3};
use trim_sa::scheduler::{CanaryConfig, EngineFarm, FarmConfig, PipelineStage, ShardMode};
use trim_sa::util::SplitMix64;

/// Minimal flag parser: `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = it.next().cloned().unwrap_or_else(|| "true".into());
            map.insert(key.to_string(), val);
        }
    }
    map
}

fn net_by_name(name: &str) -> Network {
    match name {
        "alexnet" => alexnet(),
        _ => vgg16(),
    }
}

/// `--chaos RATE [--chaos-seed S] [--chaos-model pe|rsrb|mem]` → the
/// fault-injection plan (disabled when `--chaos` is absent or 0).
fn chaos_from_flags(flags: &HashMap<String, String>) -> anyhow::Result<FaultConfig> {
    let rate: f64 = flags.get("chaos").and_then(|v| v.parse().ok()).unwrap_or(0.0);
    if rate <= 0.0 {
        return Ok(FaultConfig::disabled());
    }
    let seed: u64 = flags
        .get("chaos-seed")
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| FaultConfig::default().seed);
    let model: FaultModel = match flags.get("chaos-model") {
        Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
        None => FaultModel::Pe,
    };
    Ok(FaultConfig::new(rate, seed, model))
}

fn cmd_analyze(net: &Network) {
    let cfg = ArchConfig::paper_engine();
    let m = trim_sa::analytics::trim_model::analyze_network(&cfg, net);
    println!(
        "TrIM engine: P_N={} cores x P_M={} slices of {}x{} PEs = {} PEs @ {:.0} MHz",
        cfg.p_n,
        cfg.p_m,
        cfg.k,
        cfg.k,
        cfg.total_pes(),
        cfg.f_clk / 1e6
    );
    println!("peak throughput      : {:>8.1} GOPs/s", cfg.peak_ops_per_s() / 1e9);
    println!("{:<10} throughput: {:>8.1} GOPs/s", net.name, m.total_gops);
    println!("{:<10} inference : {:>8.1} ms", net.name, m.total_time_s * 1e3);
    println!("mean PE utilisation  : {:>8.2}", m.mean_utilization);
    println!("off-chip accesses    : {:>8.1} M (batch {})", m.total_off_chip_m, net.batch);
    println!("on-chip  accesses    : {:>8.2} M (off-chip equivalents)", m.total_on_chip_m);
    println!("I/O bandwidth (eq.4) : {:>8} bits/cycle", cfg.io_bandwidth_bits());
    println!("psum buffers (eq.3)  : {:>8.2} Mbit", cfg.psum_buffer_bits() as f64 / 1e6);
}

fn cmd_sim(flags: &HashMap<String, String>) {
    let hw: usize = flags.get("hw").and_then(|v| v.parse().ok()).unwrap_or(224);
    let k: usize = flags.get("k").and_then(|v| v.parse().ok()).unwrap_or(3);
    let pad: usize = flags.get("pad").and_then(|v| v.parse().ok()).unwrap_or(1);
    println!("cycle-accurate slice: {hw}x{hw} ifmap, {k}x{k} kernel, pad {pad}");
    let ifmap: Vec<i32> = (0..hw * hw).map(|i| (i as i32 * 31 + 7) % 251).collect();
    let weights: Vec<i32> = (0..k * k).map(|i| (i as i32 % 7) - 3).collect();
    let mut slice = SliceSim::new(k, hw + 2 * pad);
    let t0 = std::time::Instant::now();
    let r = slice.run_conv(&ifmap, hw, hw, &weights, pad, 1);
    let dt = t0.elapsed();
    let min_reads = (hw * hw) as u64;
    println!("cycles                : {}", r.stats.cycles);
    println!(
        "external input reads  : {} (overhead {:+.2}% vs minimum)",
        r.stats.ext_input_reads,
        r.stats.input_read_overhead(min_reads) * 100.0
    );
    println!(
        "peak inputs per cycle : {} (eq. 4 predicts {})",
        r.stats.peak_ext_inputs_per_cycle,
        2 * k - 1
    );
    println!("max RSRB occupancy    : {}", r.stats.max_rsrb_occupancy);
    println!("MACs                  : {}", r.stats.macs);
    println!(
        "sim wall time         : {:.1} ms ({:.1} Mcycles/s)",
        dt.as_secs_f64() * 1e3,
        r.stats.cycles as f64 / dt.as_secs_f64() / 1e6
    );
}

fn cmd_validate() {
    println!("[1/3] slice simulator vs golden convolution");
    let mut checked = 0;
    for (h, w, k, pad, stride) in
        [(16, 16, 3, 1, 1), (12, 9, 3, 0, 1), (14, 14, 5, 2, 1), (13, 13, 3, 1, 2), (31, 31, 3, 0, 4)]
    {
        let ifmap: Vec<i32> = (0..h * w).map(|i| (i as i32 * 17 + 5) % 251).collect();
        let weights: Vec<i32> = (0..k * k).map(|i| (i as i32 % 9) - 4).collect();
        let golden = trim_sa::golden::conv2d_i32(&ifmap, h, w, &weights, k, stride, pad);
        let r = SliceSim::new(k, w + 2 * pad).run_conv(&ifmap, h, w, &weights, pad, stride);
        assert_eq!(r.output, golden, "{h}x{w} k{k}");
        checked += 1;
    }
    println!("      {checked} geometries bit-exact");

    println!("[2/3] engine simulator vs golden (native + tiled kernels)");
    for (hw, k, m, n, stride, pad) in
        [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0)]
    {
        let layer = ConvLayer::new("v", hw, k, m, n, stride, pad);
        let input = Tensor3::from_fn(m, hw, hw, |c, y, x| ((c * 31 + y * 7 + x) % 23) as i32 - 11);
        let weights: Vec<i32> = (0..n * m * k * k).map(|i| ((i as i32 * 37) % 15) - 7).collect();
        let r = EngineSim::new(ArchConfig::small(3, 2, 2)).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, n, k, stride, pad), "k={k}");
    }
    println!("      native 3x3, tiled 5x5, strided tiled 11x11 bit-exact");

    println!("[3/3] paper invariants (measured, not assumed)");
    let hw = 224;
    let ifmap: Vec<i32> = (0..hw * hw).map(|i| i as i32 % 255).collect();
    let w9 = [1i32, -2, 3, -4, 5, -6, 7, -8, 9];
    let r = SliceSim::new(3, 226).run_conv(&ifmap, hw, hw, &w9, 1, 1);
    let ovh = r.stats.input_read_overhead((hw * hw) as u64) * 100.0;
    println!("      3x3 over 224x224: input-read overhead {ovh:.2}% (paper: ~1.8%)");
    println!("      peak inputs/cycle {} (paper eq. 4: 5)", r.stats.peak_ext_inputs_per_cycle);
    let plan = plan_layer(&ArchConfig::paper_engine(), &vgg16().layers[1]);
    println!(
        "      VGG-16 CL2 via eq. 2: {} cycles/step x {} steps",
        plan.weight_load_cycles + plan.sweep_cycles,
        plan.steps
    );
    println!("validation OK");
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dir = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let n_req: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(96);
    let max_batch: usize = flags.get("max-batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let engines: usize = flags.get("engines").and_then(|v| v.parse().ok()).unwrap_or(4);
    let farms: usize = flags.get("farms").and_then(|v| v.parse().ok()).unwrap_or(1).max(1);
    let kind: BackendKind = match flags.get("backend") {
        Some(s) => s.parse()?,
        None => BackendKind::Auto,
    };
    let fidelity: ExecFidelity = match flags.get("fidelity") {
        Some(s) => s.parse()?,
        None => ExecFidelity::Fast,
    };
    let shard: ShardMode = match flags.get("shard") {
        Some(s) => s.parse()?,
        None => ShardMode::Auto,
    };
    let canary: f64 = flags.get("canary").and_then(|v| v.parse().ok()).unwrap_or(0.0);
    let chaos = chaos_from_flags(flags)?;
    let hedge_factor: f64 =
        flags.get("hedge-factor").and_then(|v| v.parse().ok()).unwrap_or(4.0);
    let straggler_threshold: u32 =
        flags.get("straggler-threshold").and_then(|v| v.parse().ok()).unwrap_or(3);
    let queue_cap: usize = flags.get("queue-cap").and_then(|v| v.parse().ok()).unwrap_or(256);
    let budget_cycles: Option<f64> = flags.get("budget-cycles").and_then(|v| v.parse().ok());
    let client_rps: Option<f64> = flags.get("client-rps").and_then(|v| v.parse().ok());
    let deadline_ms: Option<u64> = flags.get("deadline-ms").and_then(|v| v.parse().ok());
    let drain_ms: u64 = flags.get("drain-ms").and_then(|v| v.parse().ok()).unwrap_or(2000);
    let http_port: Option<u16> = flags.get("http").and_then(|v| v.parse().ok());
    let http_secs: u64 = flags.get("http-secs").and_then(|v| v.parse().ok()).unwrap_or(30);
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        admission: AdmissionConfig { queue_cap, budget_cycles, client_rps },
    };
    if chaos.enabled() {
        println!(
            "chaos: injecting {} faults at rate {} (seed {:#x}) — ABFT checksums verify \
             every shard, faulty engines re-execute and quarantine",
            chaos.model, chaos.rate, chaos.seed
        );
    }
    if hedge_factor > 0.0 {
        println!(
            "hedging: shards overdue past {hedge_factor}x their analytic budget re-execute \
             on another engine (first bit-exact result wins); {straggler_threshold} straggles \
             quarantine an engine"
        );
    }
    // One ingress, `farms` farms: a single-farm router degenerates to the
    // plain coordinator, so serve always goes through the front door.
    let coordinators: Vec<Coordinator> = (0..farms)
        .map(|_| {
            let d = dir.clone();
            Coordinator::start_with(
                move || {
                    make_backend(
                        kind,
                        &d,
                        engines,
                        fidelity,
                        shard,
                        canary,
                        chaos,
                        hedge_factor,
                        straggler_threshold,
                    )
                },
                cfg,
            )
        })
        .collect::<anyhow::Result<_>>()?;
    let router = Arc::new(Router::new(coordinators)?);
    for (i, desc) in router.backend_descriptions().iter().enumerate() {
        println!("farm {i}: {desc} ({} int32 inputs per request)", router.input_len());
    }
    let http = match http_port {
        Some(port) => {
            let server = HttpServer::start(port, Arc::clone(&router))?;
            println!(
                "http ingress: http://{} (POST /infer, GET /metrics, GET /healthz) for {http_secs}s",
                server.local_addr()
            );
            Some(server)
        }
        None => None,
    };

    // Synthetic load. Admission may shed (Overloaded) and deadlines may
    // expire (DeadlineExceeded) — typed rejections are counted, not fatal.
    let len = router.input_len();
    let mut pending = Vec::new();
    let mut submit_rejected = 0usize;
    for i in 0..n_req {
        let img: Vec<i32> = (0..len).map(|j| ((i * 7919 + j * 31) % 256) as i32).collect();
        let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        match router.submit_with(img, deadline) {
            Ok(r) => pending.push(r),
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(se) => {
                    submit_rejected += 1;
                    if submit_rejected <= 3 {
                        println!("submit rejected: {se}");
                    }
                }
                None => return Err(e),
            },
        }
    }
    let mut classes = vec![0usize; 10];
    let mut reply_failed = 0usize;
    for mut rx in pending {
        match rx.recv() {
            Ok(resp) => {
                if let Some(class) = resp.class {
                    if class < classes.len() {
                        classes[class] += 1;
                    }
                }
            }
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(_) => reply_failed += 1,
                None => return Err(e),
            },
        }
    }

    // The --http-secs timer is the SIGINT stand-in: when it fires, stop
    // accepting, then drain the fleet gracefully.
    if let Some(mut server) = http {
        std::thread::sleep(Duration::from_secs(http_secs));
        println!("http window over: stopping ingress, draining fleet");
        server.stop();
    }
    let m = router.drain(Duration::from_millis(drain_ms));
    println!("requests  : {}", m.requests);
    println!("batches   : {} (mean batch {:.1})", m.batches, m.mean_batch);
    println!(
        "latency   : p50 {:?}  p95 {:?}  p99 {:?}  max {:?}",
        m.p50_latency, m.p95_latency, m.p99_latency, m.max_latency
    );
    println!(
        "queue/svc : wait mean {:.0} µs ({} samples)  service mean {:.0} µs ({} batches)",
        m.queue_wait.mean(),
        m.queue_wait.count,
        m.service.mean(),
        m.service.count
    );
    println!("throughput: {:.1} req/s", m.throughput_rps);
    println!(
        "robustness: shed {}  deadline-expired {}  engine-failed {}  drain-rejected {}  retries {}",
        m.shed, m.deadline_expired, m.engine_failed, m.drain_rejected, m.retries
    );
    if chaos.enabled() || m.fault != FaultReport::default() {
        println!(
            "faults    : injected {}  detected {}  corrected {}  reexecuted {}  quarantined {}{}",
            m.fault.injected,
            m.fault.detected,
            m.fault.corrected,
            m.fault.reexecuted,
            m.fault.quarantined,
            if m.fault.is_clean() {
                "  (clean)"
            } else if m.fault.corrected == m.fault.detected {
                "  (all detected faults healed)"
            } else {
                ""
            }
        );
        if m.fault.hedged > 0 || m.fault.stragglers_detected > 0 {
            println!(
                "gray      : stragglers {}  hedged {}  hedge won {}  hedge wasted {}  timing-quarantined {}",
                m.fault.stragglers_detected,
                m.fault.hedged,
                m.fault.hedge_won,
                m.fault.hedge_wasted,
                m.fault.timing_quarantined
            );
        }
    }
    if m.sim_batches > 0 {
        println!(
            "sim cost  : {} cycles  {} off-chip + {} on-chip accesses  {:.3} mJ  {:.2} GOPs/s @ {:.0} MHz",
            m.sim_cycles,
            m.sim_off_chip_accesses,
            m.sim_on_chip_accesses,
            m.sim_joules * 1e3,
            m.sim_gops,
            m.sim_f_clk / 1e6
        );
        print_per_layer_costs(&m.sim_per_layer);
    }
    if m.canary.sampled > 0 || canary > 0.0 {
        println!(
            "canary    : {} shards shadow-checked  bit divergence {}  counter divergence {}{}",
            m.canary.sampled,
            m.canary.bit_divergence,
            m.canary.counter_divergence,
            if m.canary.is_clean() { "  (clean)" } else { "  (DIVERGED)" }
        );
    }
    println!(
        "class histogram: {classes:?} ({submit_rejected} rejected at submit, {reply_failed} failed typed)"
    );
    if let Some(path) = flags.get("metrics-out") {
        write_metrics_out(path, &m.render_prometheus())?;
    }
    Ok(())
}

/// Write Prometheus exposition text to `path` (`-` = stdout).
fn write_metrics_out(path: &str, text: &str) -> anyhow::Result<()> {
    if path == "-" {
        print!("{text}");
    } else {
        std::fs::write(path, text)?;
        println!("metrics written to {path}");
    }
    Ok(())
}

/// Scale a real network layer down so the cycle-accurate farm demo runs in
/// seconds, while keeping the kernel/stride/pad geometry (and therefore
/// the layer's native-vs-tiled schedule and shard structure).
fn scale_layer(l: &ConvLayer, max_hw: usize, max_m: usize, max_n: usize) -> ConvLayer {
    let hw = l.h_i.min(max_hw).max(l.k);
    ConvLayer {
        name: l.name.clone(),
        h_i: hw,
        w_i: hw,
        k: l.k,
        stride: l.stride,
        pad: l.pad,
        m: l.m.min(max_m),
        n: l.n.min(max_n),
    }
}

fn cmd_farm(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let engines: usize = flags.get("engines").and_then(|v| v.parse().ok()).unwrap_or(4);
    // `--shard` is the canonical flag; `--mode` stays as a legacy alias.
    let mode: ShardMode = match flags.get("shard").or_else(|| flags.get("mode")) {
        Some(s) => s.parse()?,
        None => ShardMode::FilterShards,
    };
    let fidelity: ExecFidelity = match flags.get("fidelity") {
        Some(s) => s.parse()?,
        None => ExecFidelity::Fast,
    };
    let canary: f64 = flags.get("canary").and_then(|v| v.parse().ok()).unwrap_or(0.0);
    let chaos = chaos_from_flags(flags)?;
    let hedge_factor: f64 =
        flags.get("hedge-factor").and_then(|v| v.parse().ok()).unwrap_or(4.0);
    let straggler_threshold: u32 =
        flags.get("straggler-threshold").and_then(|v| v.parse().ok()).unwrap_or(3);
    let arch = ArchConfig::small(3, 2, 2);
    match mode {
        ShardMode::FilterShards | ShardMode::Spatial | ShardMode::Hybrid | ShardMode::Auto => {
            let net = net_by_name(flags.get("net").map(|s| s.as_str()).unwrap_or("vgg16"));
            println!(
                "engine farm: {engines} engines of P_N={} x P_M={} (scaled-down {} layers, {mode} shard mode, {fidelity} fidelity)",
                arch.p_n, arch.p_m, net.name
            );
            if chaos.enabled() {
                println!(
                    "chaos: injecting {} faults at rate {} (seed {:#x}) — the bit-exactness \
                     column now also proves ABFT detection + re-execution heal every fault",
                    chaos.model, chaos.rate, chaos.seed
                );
            }
            let farm = EngineFarm::new(
                FarmConfig::with_fidelity(engines, arch, fidelity)
                    .with_canary(CanaryConfig::sampled(canary))
                    .with_chaos(chaos)
                    .with_hedge(hedge_factor, straggler_threshold),
            );
            let single = EngineSim::with_fidelity(arch, fidelity);
            let mut rng = SplitMix64::new(2024);
            let (mut tot_single, mut tot_farm) = (0u64, 0u64);
            let mut farm_stats = SimStats::default();
            let mut per_layer: Vec<LayerCost> = Vec::new();
            println!(
                "{:<6} {:>3} {:>7} {:>6} {:>6} {:>13} {:>13} {:>8}  exact",
                "layer", "K", "axis", "shards", "bound", "1-engine cyc", "farm cyc", "speedup"
            );
            for l in &net.layers {
                let l = scale_layer(l, 32, 8, 16);
                let input =
                    Tensor3 { c: l.m, h: l.h_i, w: l.w_i, data: rng.vec_i32(l.m * l.h_i * l.w_i, 0, 256) };
                let weights = rng.vec_i32(l.weight_elems() as usize, -8, 8);
                let s = single.run_layer(&l, &input, &weights);
                let f = farm.run_layer_mode(&l, &input, &weights, mode)?;
                let golden = conv3d_i32(&input, &weights, l.n, l.k, l.stride, l.pad);
                let ok = f.ofmaps == golden && f.ofmaps == s.ofmaps;
                tot_single += s.stats.cycles;
                tot_farm += f.stats.cycles;
                farm_stats.merge_sequential(&f.stats); // layers run back to back
                LayerCost::fold_into(&mut per_layer, &LayerCost::from_stats(l.name.as_str(), &f.stats));
                println!(
                    "{:<6} {:>3} {:>7} {:>6} {:>5.2}x {:>13} {:>13} {:>7.2}x  {}",
                    l.name,
                    l.k,
                    f.plan.axis.as_str(),
                    f.plan.shards.len(),
                    f.plan.speedup_bound(),
                    s.stats.cycles,
                    f.stats.cycles,
                    s.stats.cycles as f64 / f.stats.cycles as f64,
                    if ok { "yes" } else { "NO — MISMATCH" }
                );
                anyhow::ensure!(ok, "{}: farm output diverged from single engine / golden", l.name);
            }
            println!(
                "total: {tot_single} -> {tot_farm} cycles ({:.2}x with {engines} engines); \
                 all layers bit-exact vs single engine and golden conv",
                tot_single as f64 / tot_farm as f64
            );
            let cost = BatchCost::from_stats(farm_stats, arch.f_clk, &EnergyModel::paper())
                .with_per_layer(per_layer);
            println!(
                "sim cost: {} off-chip + {} on-chip accesses  {:.3} mJ  {:.2} GOPs/s achieved",
                cost.stats.off_chip_accesses(),
                cost.stats.on_chip_accesses(),
                cost.joules * 1e3,
                cost.gops
            );
            print_per_layer_costs(&cost.per_layer);
            // Exact per-layer farm-cycle quantiles (nearest-rank).
            let mut layer_cycles: Vec<u64> = cost.per_layer.iter().map(|l| l.cycles).collect();
            layer_cycles.sort_unstable();
            println!(
                "layer cyc : p50 {}  p95 {}  p99 {}",
                obs::percentile_u64(&layer_cycles, 0.50),
                obs::percentile_u64(&layer_cycles, 0.95),
                obs::percentile_u64(&layer_cycles, 0.99)
            );
            // Per-engine telemetry from the farm's metrics registry.
            let reg = farm.registry();
            let jobs: Vec<u64> =
                (0..engines).map(|i| reg.counter_value(&format!("engine{i}.jobs"))).collect();
            let steals: Vec<u64> =
                (0..engines).map(|i| reg.counter_value(&format!("engine{i}.steals"))).collect();
            println!(
                "telemetry : jobs/engine {jobs:?}  steals/engine {steals:?}  scratch fills {} hits {}  microkernel k3/unit/strided {}/{}/{}",
                reg.counter_value("scratch.fills"),
                reg.counter_value("scratch.hits"),
                reg.counter_value("microkernel.k3"),
                reg.counter_value("microkernel.unit"),
                reg.counter_value("microkernel.strided")
            );
            if farm.canary_enabled() {
                farm.canary_drain();
                let c = farm.canary_report();
                println!(
                    "canary    : {} shards shadow-checked  bit divergence {}  counter divergence {}{}",
                    c.sampled,
                    c.bit_divergence,
                    c.counter_divergence,
                    if c.is_clean() { "  (clean)" } else { "  (DIVERGED)" }
                );
            }
            if farm.chaos_enabled() {
                let fr = farm.fault_report();
                println!(
                    "chaos     : injected {}  detected {}  corrected {}  reexecuted {}  quarantined {}  live engines {}/{engines}",
                    fr.injected,
                    fr.detected,
                    fr.corrected,
                    fr.reexecuted,
                    fr.quarantined,
                    farm.live_engines()
                );
                if fr.hedged > 0 || fr.stragglers_detected > 0 {
                    println!(
                        "gray      : stragglers {}  hedged {}  hedge won {}  hedge wasted {}  timing-quarantined {}",
                        fr.stragglers_detected,
                        fr.hedged,
                        fr.hedge_won,
                        fr.hedge_wasted,
                        fr.timing_quarantined
                    );
                }
            }
            if let Some(path) = flags.get("metrics-out") {
                write_metrics_out(path, &farm.registry().render_prometheus())?;
            }
        }
        ShardMode::LayerPipeline => {
            // Real CNNs interleave pooling between CLs (out of scope, §IV),
            // so the pipeline demo streams a batch through the serving
            // chain (the same network `trim serve --backend sim` runs).
            use trim_sa::model::quant::Requant;
            use trim_sa::scheduler::SimNetSpec;
            if flags.contains_key("net") {
                println!("note: --net is ignored in pipeline mode; streaming the serving chain instead");
            }
            if chaos.enabled() {
                println!("note: --chaos applies to sharded layer runs; pipeline mode ignores it");
            }
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
            let spec = SimNetSpec::tiny();
            let q = Requant::new(spec.requant_shift, 8);
            let stages: Vec<PipelineStage> = spec
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| PipelineStage {
                    layer: l.clone(),
                    weights: std::sync::Arc::new(spec.layer_weights(i)),
                    requant: Some(q),
                })
                .collect();
            let (c0, h0, w0) = spec.input;
            let mut rng = SplitMix64::new(7);
            let images: Vec<Tensor3> = (0..batch)
                .map(|_| Tensor3 { c: c0, h: h0, w: w0, data: rng.vec_i32(c0 * h0 * w0, 0, 256) })
                .collect();
            let serial = EngineFarm::new(FarmConfig::with_fidelity(1, arch, fidelity));
            let farm = EngineFarm::new(FarmConfig::with_fidelity(engines, arch, fidelity));
            let r1 = serial.run_pipeline(&stages, images.clone())?;
            let rn = farm.run_pipeline(&stages, images)?;
            anyhow::ensure!(r1.outputs == rn.outputs, "pipeline outputs diverged across engine counts");
            println!(
                "layer pipeline: {} stages, batch {batch}: {} -> {} cycles ({:.2}x with {engines} engines), bit-exact",
                stages.len(),
                r1.stats.cycles,
                rn.stats.cycles,
                r1.stats.cycles as f64 / rn.stats.cycles as f64
            );
            for (i, s) in rn.per_engine.iter().enumerate() {
                println!("  engine {i}: {:>10} cycles  {:>10} MACs", s.cycles, s.macs);
            }
            let per_layer: Vec<LayerCost> = spec
                .layers
                .iter()
                .zip(&rn.per_stage)
                .map(|(l, s)| LayerCost::from_stats(l.name.as_str(), s))
                .collect();
            let cost = BatchCost::from_stats(rn.stats, arch.f_clk, &EnergyModel::paper())
                .with_per_layer(per_layer);
            println!(
                "sim cost: {} off-chip + {} on-chip accesses  {:.3} mJ  {:.2} GOPs/s achieved",
                cost.stats.off_chip_accesses(),
                cost.stats.on_chip_accesses(),
                cost.joules * 1e3,
                cost.gops
            );
            print_per_layer_costs(&cost.per_layer);
        }
    }
    Ok(())
}

/// `trim trace`: run a small sim serving workload end to end, then export
/// the process-global trace ring as JSON lines on stdout. Every stage of
/// the stack contributes: `serve.request` spans from admission,
/// `batch.formed` events from the batcher, `serve.batch` spans from the
/// engine loop, `router.dispatch` events from the front door, and
/// `farm.layer`/`farm.shard` (plus `canary.shard` when `--canary` is set)
/// spans from the farm workers.
fn cmd_trace(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let n_req: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(8);
    let engines: usize = flags.get("engines").and_then(|v| v.parse().ok()).unwrap_or(2);
    let canary: f64 = flags.get("canary").and_then(|v| v.parse().ok()).unwrap_or(0.0);
    let cfg = CoordinatorConfig::default();
    let coordinator = Coordinator::start_with(
        move || {
            make_backend(
                BackendKind::Sim,
                "artifacts",
                engines,
                ExecFidelity::Fast,
                ShardMode::Auto,
                canary,
                FaultConfig::disabled(),
                0.0,
                3,
            )
        },
        cfg,
    )?;
    let router = Router::new(vec![coordinator])?;
    let len = router.input_len();
    let pending: Vec<_> = (0..n_req)
        .map(|i| {
            let img: Vec<i32> = (0..len).map(|j| ((i * 7919 + j * 31) % 256) as i32).collect();
            router.submit(img)
        })
        .collect::<anyhow::Result<_>>()?;
    for mut rx in pending {
        rx.recv()?;
    }
    drop(router); // join the engine thread so every span is finished
    let t = obs::tracer();
    print!("{}", t.export_json_lines());
    eprintln!("# {} trace events exported ({} dropped by the ring)", t.len(), t.dropped());
    Ok(())
}

/// `trim check`: the static invariant checker (ISSUE 8). Sweeps the
/// design space through [`trim_sa::verify`], reports every violation in
/// file-able form, runs the seeded-corruption self-test, and exits
/// nonzero if anything failed — the CI gate parses the `JSON ` line.
fn cmd_check(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let full = flags.contains_key("sweep");
    let t0 = Instant::now();
    let s = trim_sa::verify::sweep_design_space(full);
    println!(
        "checked {} design-space points ({} law evaluations): {} violation(s)",
        s.points,
        s.checks,
        s.violations.len()
    );
    for v in &s.violations {
        println!("VIOLATION {v}");
    }
    let self_test = trim_sa::verify::self_test();
    match &self_test {
        Ok(()) => println!("self-test: corrupted plans rejected with named violations"),
        Err(e) => println!("self-test FAILED: {e}"),
    }
    println!(
        "JSON {{\"kind\":\"check\",\"sweep\":{},\"points\":{},\"checks\":{},\"violations\":{},\"self_test_ok\":{},\"elapsed_ms\":{}}}",
        full,
        s.points,
        s.checks,
        s.violations.len(),
        self_test.is_ok(),
        t0.elapsed().as_millis()
    );
    if full {
        anyhow::ensure!(s.points >= 200, "full sweep covers only {} points (need ≥ 200)", s.points);
    }
    anyhow::ensure!(
        s.violations.is_empty(),
        "{} invariant violation(s) — see the VIOLATION lines above",
        s.violations.len()
    );
    self_test.map_err(|e| anyhow::anyhow!("checker self-test failed: {e}"))?;
    Ok(())
}

/// The per-layer cost breakdown table (ROADMAP §Serving: the 2408.01254
/// companion's per-layer accounting, at the CLI).
fn print_per_layer_costs(per_layer: &[LayerCost]) {
    if per_layer.is_empty() {
        return;
    }
    println!(
        "{:<8} {:>13} {:>14} {:>14} {:>14}",
        "layer", "cycles", "off-chip", "on-chip", "MACs"
    );
    for l in per_layer {
        println!(
            "{:<8} {:>13} {:>14} {:>14} {:>14}",
            l.name, l.cycles, l.off_chip_accesses, l.on_chip_accesses, l.macs
        );
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let cfg = ArchConfig::paper_engine();

    match cmd {
        "fig1" => print!("{}", render_fig1(&vgg16(), 8)),
        "sweep" => print!("{}", render_fig7(&cfg, &vgg16())),
        "table" => {
            let net = net_by_name(flags.get("net").map(|s| s.as_str()).unwrap_or("vgg16"));
            print!("{}", render_table1_or_2(&cfg, &net));
        }
        "table3" => print!("{}", render_table3(&cfg)),
        "analyze" => cmd_analyze(&net_by_name(flags.get("net").map(|s| s.as_str()).unwrap_or("vgg16"))),
        "sim" => cmd_sim(&flags),
        "validate" => cmd_validate(),
        "serve" => cmd_serve(&flags)?,
        "farm" => cmd_farm(&flags)?,
        "trace" => cmd_trace(&flags)?,
        "check" => cmd_check(&flags)?,
        _ => {
            println!("usage: trim <fig1|sweep|table|table3|analyze|sim|validate|serve|farm|trace|check> [--flags]");
            println!("see rust/src/main.rs docs for details");
        }
    }
    Ok(())
}
