//! CNN workload descriptions.
//!
//! A [`ConvLayer`] captures everything the TrIM engine (and the analytical
//! models) need to know about one convolutional layer; a [`Network`] is an
//! ordered list of layers plus bookkeeping. The two networks the paper
//! evaluates — VGG-16 (Table I) and AlexNet (Table II) — are provided as
//! constructors, matching the per-layer parameters printed in the tables.

pub mod alexnet;
pub mod layer;
pub mod network;
pub mod quant;
pub mod tiling;
pub mod vgg16;

pub use layer::ConvLayer;
pub use network::Network;
pub use tiling::{KernelTiling, TileTask};
