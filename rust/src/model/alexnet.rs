//! AlexNet convolutional layers, exactly as listed in Table II of the paper.

use super::{ConvLayer, Network};

/// The 5 convolutional layers of AlexNet (Krizhevsky et al., 2012).
///
/// Channel counts follow Table II, which lists the *per-group* input
/// channels for the grouped layers (CL2: M = 48, CL4/CL5: M = 192), so
/// eq. (1) with these values yields the true grouped-conv op counts.
/// Strides/pads are the canonical AlexNet ones (CL1: stride 4 pad 0 →
/// 55×55; CL2: pad 2 → 27×27; CL3-5: pad 1 → 13×13).
///
/// Batch = 4 matches Table II footnote a (the Eyeriss JSSC'17 AlexNet
/// measurement batch).
pub fn alexnet() -> Network {
    let layers = vec![
        ConvLayer::new("CL1", 227, 11, 3, 96, 4, 0),
        ConvLayer::new("CL2", 27, 5, 48, 256, 1, 2),
        ConvLayer::new("CL3", 13, 3, 256, 384, 1, 1),
        ConvLayer::new("CL4", 13, 3, 192, 384, 1, 1),
        ConvLayer::new("CL5", 13, 3, 192, 256, 1, 1),
    ];
    Network::new("AlexNet", 4, layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_row_parameters() {
        let net = alexnet();
        assert_eq!(net.layers[0].k, 11);
        assert_eq!(net.layers[1].k, 5);
        assert_eq!(net.layers[0].h_o(), 55);
        assert_eq!(net.layers[1].h_o(), 27);
        for l in &net.layers[2..] {
            assert_eq!(l.h_o(), 13);
        }
    }

    #[test]
    fn total_ops_about_1_33_gops() {
        // Grouped AlexNet conv ops ≈ 1.33 G (2 ops per MAC); the paper's
        // 12.9 GOPs/s × 103.1 ms ≈ 1.33 G confirms this accounting.
        let g = alexnet().total_ops() as f64 / 1e9;
        assert!((g - 1.33).abs() < 0.05, "AlexNet GOPs = {g}");
    }
}
