//! A network = an ordered list of convolutional layers plus metadata.

use super::layer::ConvLayer;


/// An ordered CNN workload (convolutional layers only — the paper
/// accelerates CLs; FC layers are out of scope, as in Section IV).
#[derive(Debug, Clone)]
pub struct Network {
    /// e.g. `"VGG-16"`.
    pub name: String,
    /// The batch size the paper normalises this network's numbers to
    /// (3 for VGG-16, 4 for AlexNet — the batches used by the Eyeriss
    /// JSSC'17 measurements the paper compares against).
    pub batch: usize,
    /// Convolutional layers in execution order.
    pub layers: Vec<ConvLayer>,
}

impl Network {
    pub fn new(name: &str, batch: usize, layers: Vec<ConvLayer>) -> Self {
        Self { name: name.to_string(), batch, layers }
    }

    /// Total operations over all layers for ONE inference (paper eq. (1)).
    pub fn total_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    /// Total MACs over all layers for one inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total ifmap bytes at `bits` precision (sum over layers; this is the
    /// "ifmaps memory" series of Fig. 1).
    pub fn total_ifmap_bytes(&self, bits: usize) -> u64 {
        self.layers.iter().map(|l| l.ifmap_bytes(bits)).sum()
    }

    /// Total weight bytes at `bits` precision (the "weights memory" series
    /// of Fig. 1).
    pub fn total_weight_bytes(&self, bits: usize) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes(bits)).sum()
    }

    /// Largest ofmap (elements) across layers — sizes the psum buffers
    /// (`H_OM × W_OM` in the paper).
    pub fn max_ofmap_hw(&self) -> (usize, usize) {
        self.layers
            .iter()
            .map(|l| (l.h_o(), l.w_o()))
            .max_by_key(|(h, w)| h * w)
            .unwrap_or((0, 0))
    }

    /// Largest ifmap width across layers — sizes the RSRBs (`W_IM`).
    pub fn max_ifmap_width(&self) -> usize {
        self.layers.iter().map(|l| l.w_i + 2 * l.pad).max().unwrap_or(0)
    }

    /// Look a layer up by name.
    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }
}

#[cfg(test)]
mod tests {
    use crate::model::{alexnet, vgg16};

    #[test]
    fn vgg16_totals_match_paper_intro() {
        let net = vgg16::vgg16();
        // §I: "~30.7 billion operations" (conv layers, 224×224 RGB).
        let gops = net.total_ops() as f64 / 1e9;
        assert!((gops - 30.7).abs() < 0.3, "VGG-16 total GOPs = {gops}");
        // §I: "~22.7 MB of memory ... 8-bit ifmaps and weights".
        // Fig. 1 counts ifmaps + weights across CLs (+ FC weights are
        // excluded here; conv-only memory is ~ 9.4 MB ifmaps + 14.7 MB
        // weights ≈ 24 MB; the paper's 22.7 MB counts ifmaps once).
        let mb = (net.total_ifmap_bytes(8) + net.total_weight_bytes(8)) as f64 / 1e6;
        assert!(mb > 20.0 && mb < 26.0, "VGG-16 conv memory = {mb} MB");
    }

    #[test]
    fn vgg16_has_13_cls_alexnet_5() {
        assert_eq!(vgg16::vgg16().layers.len(), 13);
        assert_eq!(alexnet::alexnet().layers.len(), 5);
    }

    #[test]
    fn max_sizes_for_buffers() {
        let net = vgg16::vgg16();
        assert_eq!(net.max_ofmap_hw(), (224, 224));
        assert_eq!(net.max_ifmap_width(), 226); // padded first layer
    }
}
