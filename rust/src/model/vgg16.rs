//! VGG-16 convolutional layers, exactly as listed in Table I of the paper.

use super::{ConvLayer, Network};

/// The 13 convolutional layers of VGG-16 (Simonyan & Zisserman, 2014) on
/// 224×224 RGB inputs: all 3×3 kernels, stride 1, pad 1 (ofmap-preserving),
/// with the channel progression 3→64→128→256→512.
///
/// Batch = 3 matches the normalisation of Table I (footnote a), inherited
/// from the Eyeriss JSSC'17 VGG-16 measurement batch.
pub fn vgg16() -> Network {
    let spec: &[(usize, usize, usize)] = &[
        // (H_I = W_I, M, N) — K = 3, stride 1, pad 1 throughout.
        (224, 3, 64),    // CL1
        (224, 64, 64),   // CL2
        (112, 64, 128),  // CL3
        (112, 128, 128), // CL4
        (56, 128, 256),  // CL5
        (56, 256, 256),  // CL6
        (56, 256, 256),  // CL7
        (28, 256, 512),  // CL8
        (28, 512, 512),  // CL9
        (28, 512, 512),  // CL10
        (14, 512, 512),  // CL11
        (14, 512, 512),  // CL12
        (14, 512, 512),  // CL13
    ];
    let layers = spec
        .iter()
        .enumerate()
        .map(|(i, &(hw, m, n))| ConvLayer::new(&format!("CL{}", i + 1), hw, 3, m, n, 1, 1))
        .collect();
    Network::new("VGG-16", 3, layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_row_parameters() {
        let net = vgg16();
        let l5 = net.layer("CL5").unwrap();
        assert_eq!((l5.h_i, l5.m, l5.n), (56, 128, 256));
        let l13 = net.layer("CL13").unwrap();
        assert_eq!((l13.h_i, l13.m, l13.n), (14, 512, 512));
    }

    #[test]
    fn all_layers_preserve_spatial_size() {
        for l in &vgg16().layers {
            assert_eq!(l.h_o(), l.h_i, "{}", l.name);
        }
    }
}
