//! Quantisation helpers matching the paper's data representation.
//!
//! Section III-A: *"the PEs support B-bit unsigned integer inputs and B-bit
//! signed integer weights"*; psums leaving the bottom PE row are
//! `2B + K`-bit signed, the slice output is `2B + K + ⌈log2 K⌉`-bit, and
//! ofmaps are re-quantised to B-bit before going off-chip (eq. (4) counts
//! B-bit output activations).



/// Bit-width bookkeeping for the datapath of a slice/core/engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatapathBits {
    /// Operand precision B (8 in the paper's implementation).
    pub b: usize,
    /// Kernel size K.
    pub k: usize,
}

impl DatapathBits {
    pub fn new(b: usize, k: usize) -> Self {
        Self { b, k }
    }

    /// Psum width at the bottom of the PE array: `2B + K`.
    pub fn psum_bits(&self) -> usize {
        2 * self.b + self.k
    }

    /// Slice output width: `2B + K + ⌈log2 K⌉`.
    pub fn slice_out_bits(&self) -> usize {
        self.psum_bits() + (self.k as f64).log2().ceil() as usize
    }

    /// Core output width for `p_m` parallel slices:
    /// `2B + K + ⌈log2 K⌉ + ⌈log2 P_M⌉`.
    pub fn core_out_bits(&self, p_m: usize) -> usize {
        self.slice_out_bits() + (p_m as f64).log2().ceil() as usize
    }

    /// Engine accumulator width for `m` total input channels:
    /// `2B + K + ⌈log2 K⌉ + ⌈log2 M⌉` (the psum-buffer activation width).
    pub fn engine_acc_bits(&self, m: usize) -> usize {
        self.slice_out_bits() + (m as f64).log2().ceil() as usize
    }
}

/// Power-of-two output re-quantiser: `y = clamp(round(x / 2^shift), 0, 2^B-1)`.
///
/// The paper does not specify its re-quantisation scheme (outputs are
/// "B-bit quantized output activations"); a power-of-two scale with
/// round-half-up and unsigned clamping is the standard FPGA choice (a
/// barrel shift, no DSP) and is what the Python model layer replicates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requant {
    pub shift: u32,
    pub bits: usize,
}

impl Requant {
    pub fn new(shift: u32, bits: usize) -> Self {
        assert!(bits <= 16);
        Self { shift, bits }
    }

    /// Re-quantise one accumulator value.
    pub fn apply(&self, x: i64) -> u32 {
        let half = if self.shift == 0 { 0 } else { 1i64 << (self.shift - 1) };
        let y = (x + half) >> self.shift;
        let max = (1i64 << self.bits) - 1;
        y.clamp(0, max) as u32
    }

    /// Re-quantise a slice of accumulators.
    pub fn apply_all(&self, xs: &[i64]) -> Vec<u32> {
        xs.iter().map(|&x| self.apply(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bitwidths_k3_b8() {
        let d = DatapathBits::new(8, 3);
        assert_eq!(d.psum_bits(), 19); // 2·8 + 3
        assert_eq!(d.slice_out_bits(), 21); // + ⌈log2 3⌉ = 2
        assert_eq!(d.core_out_bits(24), 26); // + ⌈log2 24⌉ = 5
        // engine accumulator for M = 512: + ⌈log2 512⌉ = 9 → 30 ≤ 32-bit
        assert_eq!(d.engine_acc_bits(512), 30);
        assert!(d.engine_acc_bits(512) <= 32, "32-bit psum buffers suffice");
    }

    #[test]
    fn requant_rounds_and_clamps() {
        let q = Requant::new(4, 8);
        assert_eq!(q.apply(0), 0);
        assert_eq!(q.apply(16), 1);
        assert_eq!(q.apply(24), 2); // round half up: 24/16 = 1.5 → 2
        assert_eq!(q.apply(23), 1);
        assert_eq!(q.apply(-100), 0); // unsigned clamp
        assert_eq!(q.apply(1 << 30), 255);
    }

    #[test]
    fn requant_zero_shift_is_identity_with_clamp() {
        let q = Requant::new(0, 8);
        assert_eq!(q.apply(17), 17);
        assert_eq!(q.apply(300), 255);
    }
}
