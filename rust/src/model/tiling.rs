//! Large-kernel decomposition into native-size tiles.
//!
//! Section V of the paper: *"To cope with the different kernel sizes
//! required by AlexNet, the TrIM architecture splits large kernels in 3×3
//! tiles. For example, P_M 5×5 kernels are split in 4 groups of P_M tiles
//! each. Each group is processed by a TrIM Core and the psums are
//! accumulated at the top level."*
//!
//! A `K×K` kernel with `K > K_nat` is split into `⌈K/K_nat⌉²` tiles of
//! `K_nat × K_nat` (zero-padded at the right/bottom edges). Each tile is an
//! ordinary `K_nat×K_nat` convolution applied to the ifmap *shifted* by the
//! tile's origin; summing all tile outputs reproduces the full convolution
//! exactly (verified by property tests against the golden model).

use super::ConvLayer;


/// One tile of a decomposed kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileTask {
    /// Tile grid coordinates (`0 ≤ tr,tc < grid`).
    pub tr: usize,
    pub tc: usize,
    /// Offset of the tile's (0,0) weight inside the full kernel.
    pub row0: usize,
    pub col0: usize,
    /// Number of *real* (non-padding) weight rows/cols in this tile.
    pub rows: usize,
    pub cols: usize,
}

/// Decomposition of a `K×K` kernel into `grid×grid` tiles of `k_nat×k_nat`.
#[derive(Debug, Clone)]
pub struct KernelTiling {
    /// Full kernel size.
    pub k: usize,
    /// Native slice kernel size (3 for the paper's engine).
    pub k_nat: usize,
    /// Tiles per side: `⌈K / K_nat⌉`.
    pub grid: usize,
    /// All tiles in row-major order.
    pub tiles: Vec<TileTask>,
}

impl KernelTiling {
    /// Build the tiling for kernel size `k` on a native `k_nat` slice.
    /// For `k ≤ k_nat` the result is a single identity tile.
    pub fn new(k: usize, k_nat: usize) -> Self {
        assert!(k >= 1 && k_nat >= 1);
        let grid = k.div_ceil(k_nat);
        let mut tiles = Vec::with_capacity(grid * grid);
        for tr in 0..grid {
            for tc in 0..grid {
                let row0 = tr * k_nat;
                let col0 = tc * k_nat;
                tiles.push(TileTask {
                    tr,
                    tc,
                    row0,
                    col0,
                    rows: k_nat.min(k - row0),
                    cols: k_nat.min(k - col0),
                });
            }
        }
        Self { k, k_nat, grid, tiles }
    }

    /// Number of tiles (`T` in the scheduling model).
    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Fraction of PE slots in the tiled schedule that hold real weights
    /// (e.g. 5×5 → 25/36 ≈ 0.694; 11×11 → 121/144 ≈ 0.84). The remainder
    /// compute on zero-padded weights.
    pub fn fill_ratio(&self) -> f64 {
        (self.k * self.k) as f64 / (self.num_tiles() * self.k_nat * self.k_nat) as f64
    }

    /// Extract the zero-padded `k_nat × k_nat` sub-kernel for `tile` from a
    /// row-major `k×k` weight slice.
    pub fn extract_tile_weights(&self, full: &[i32], tile: &TileTask) -> Vec<i32> {
        assert_eq!(full.len(), self.k * self.k);
        let mut out = vec![0i32; self.k_nat * self.k_nat];
        for r in 0..tile.rows {
            for c in 0..tile.cols {
                out[r * self.k_nat + c] = full[(tile.row0 + r) * self.k + (tile.col0 + c)];
            }
        }
        out
    }
}

/// Tiling for a whole layer on a native-`k_nat` engine: identity when the
/// kernel already fits.
pub fn layer_tiling(layer: &ConvLayer, k_nat: usize) -> KernelTiling {
    KernelTiling::new(layer.k, k_nat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv2d_i32;

    #[test]
    fn grid_counts_match_paper() {
        assert_eq!(KernelTiling::new(5, 3).num_tiles(), 4); // "4 groups"
        assert_eq!(KernelTiling::new(11, 3).num_tiles(), 16);
        assert_eq!(KernelTiling::new(3, 3).num_tiles(), 1);
        assert_eq!(KernelTiling::new(7, 3).num_tiles(), 9);
    }

    #[test]
    fn fill_ratios() {
        assert!((KernelTiling::new(5, 3).fill_ratio() - 25.0 / 36.0).abs() < 1e-12);
        assert!((KernelTiling::new(11, 3).fill_ratio() - 121.0 / 144.0).abs() < 1e-12);
    }

    /// Sum of shifted tile convolutions == full convolution (stride 1).
    #[test]
    fn tile_decomposition_is_exact() {
        let (h, w, k, k_nat) = (12usize, 13usize, 5usize, 3usize);
        let input: Vec<i32> = (0..h * w).map(|i| (i as i32 * 7 + 3) % 17).collect();
        let weights: Vec<i32> = (0..k * k).map(|i| (i as i32 % 5) - 2).collect();

        let full = conv2d_i32(&input, h, w, &weights, k, 1, 0);
        let h_o = h - k + 1;
        let w_o = w - k + 1;

        let tiling = KernelTiling::new(k, k_nat);
        let mut acc = vec![0i32; h_o * w_o];
        for tile in &tiling.tiles {
            let tw = tiling.extract_tile_weights(&weights, tile);
            // The tile convolves the ifmap shifted by (row0, col0); output
            // positions that exist for the full kernel always exist for the
            // shifted tile because row0 + k_nat ≤ grid·k_nat and the input
            // window of the full kernel covers them — pad the input
            // logically by reading within the valid region.
            for oy in 0..h_o {
                for ox in 0..w_o {
                    let mut s = 0i32;
                    for r in 0..k_nat {
                        for c in 0..k_nat {
                            let iy = oy + tile.row0 + r;
                            let ix = ox + tile.col0 + c;
                            if iy < h && ix < w {
                                s += input[iy * w + ix] * tw[r * k_nat + c];
                            }
                        }
                    }
                    acc[oy * w_o + ox] += s;
                }
            }
        }
        assert_eq!(acc, full);
    }
}
