//! Convolutional-layer description and derived quantities.



/// One convolutional layer, in the nomenclature of the paper:
///
/// * ifmaps: `M` channels of `H_I × W_I` activations,
/// * filters: `N` 3-D filters of `M` kernels, each `K × K`,
/// * ofmaps: `N` channels of `H_O × W_O` activations.
///
/// `stride`/`pad` extend the paper's tables (VGG-16 is stride 1 / pad 1
/// throughout; AlexNet CL1 is stride 4 / pad 0, CL2 pad 2, CL3-5 pad 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    /// Human-readable name, e.g. `"CL3"`.
    pub name: String,
    /// Ifmap height (pre-padding).
    pub h_i: usize,
    /// Ifmap width (pre-padding).
    pub w_i: usize,
    /// Kernel size (square kernels, as in the paper).
    pub k: usize,
    /// Convolution stride.
    pub stride: usize,
    /// Zero-padding on each border.
    pub pad: usize,
    /// Number of input channels (ifmaps). For grouped convolutions this is
    /// the *per-group* channel count, which is exactly how Table II lists
    /// AlexNet (e.g. CL2 has M = 48 because of its two groups).
    pub m: usize,
    /// Number of filters (= ofmaps).
    pub n: usize,
}

impl ConvLayer {
    /// Convenience constructor for the common stride-1 / square case.
    pub fn new(name: &str, h_w: usize, k: usize, m: usize, n: usize, stride: usize, pad: usize) -> Self {
        Self { name: name.to_string(), h_i: h_w, w_i: h_w, k, stride, pad, m, n }
    }

    /// Ofmap height: `⌊(H_I + 2·pad − K)/stride⌋ + 1`.
    pub fn h_o(&self) -> usize {
        (self.h_i + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Ofmap width.
    pub fn w_o(&self) -> usize {
        (self.w_i + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Total operations, paper eq. (1): `2·K²·H_O·W_O·M·N`
    /// (a MAC counts as two operations).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Multiply-accumulate count: `K²·H_O·W_O·M·N`.
    pub fn macs(&self) -> u64 {
        (self.k as u64)
            * (self.k as u64)
            * (self.h_o() as u64)
            * (self.w_o() as u64)
            * (self.m as u64)
            * (self.n as u64)
    }

    /// Ifmap element count (`M·H_I·W_I`, unpadded — what is resident in DRAM).
    pub fn ifmap_elems(&self) -> u64 {
        (self.m * self.h_i * self.w_i) as u64
    }

    /// Weight element count (`N·M·K²`).
    pub fn weight_elems(&self) -> u64 {
        (self.n * self.m * self.k * self.k) as u64
    }

    /// Ofmap element count (`N·H_O·W_O`).
    pub fn ofmap_elems(&self) -> u64 {
        (self.n * self.h_o() * self.w_o()) as u64
    }

    /// Ifmap memory in bytes at `bits`-bit precision.
    pub fn ifmap_bytes(&self, bits: usize) -> u64 {
        self.ifmap_elems() * bits as u64 / 8
    }

    /// Weight memory in bytes at `bits`-bit precision.
    pub fn weight_bytes(&self, bits: usize) -> u64 {
        self.weight_elems() * bits as u64 / 8
    }

    /// Ofmap memory in bytes at `bits`-bit precision.
    pub fn ofmap_bytes(&self, bits: usize) -> u64 {
        self.ofmap_elems() * bits as u64 / 8
    }

    /// Whether the layer's kernel exceeds the native slice size and must be
    /// decomposed into `K_T × K_T` tiles (Section V of the paper: AlexNet's
    /// 11×11 and 5×5 kernels are split into 3×3 tiles).
    pub fn needs_tiling(&self, native_k: usize) -> bool {
        self.k > native_k
    }

    /// The *padded* ifmap rows needed to compute output rows
    /// `[rows.start, rows.end)`: `[rows.start·stride, (rows.end−1)·stride + K)`.
    /// This is the slab an output-row shard must read — overlapping slabs of
    /// adjacent bands are the halo rows (`K − stride` per interior boundary
    /// when `stride < K`; strides beyond `K` leave gaps instead).
    pub fn band_input_rows(&self, rows: &std::ops::Range<usize>) -> std::ops::Range<usize> {
        assert!(rows.start < rows.end && rows.end <= self.h_o(), "bad output-row range {rows:?}");
        rows.start * self.stride..(rows.end - 1) * self.stride + self.k
    }

    /// The synthetic layer equivalent to computing only output rows `rows`
    /// of `self`: its ifmap is the band's slab of the *explicitly padded*
    /// input ([`ConvLayer::band_input_rows`] tall, `W_I + 2·pad` wide, all
    /// padding materialised as zeros), so `pad = 0`. Convolving that slab
    /// yields exactly rows `rows` of the full ofmap, and the layer is a
    /// perfectly ordinary [`ConvLayer`] — the row-shard path of the engine
    /// runs it through the standard native/tiled schedules on both
    /// fidelity tiers, which is what keeps row shards bit- and
    /// counter-exact across tiers for free.
    pub fn row_band(&self, rows: &std::ops::Range<usize>) -> ConvLayer {
        let slab = self.band_input_rows(rows);
        ConvLayer {
            name: format!("{}[r{}..{}]", self.name, rows.start, rows.end),
            h_i: slab.len(),
            w_i: self.w_i + 2 * self.pad,
            k: self.k,
            stride: self.stride,
            pad: 0,
            m: self.m,
            n: self.n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_cl1_derived_quantities() {
        // VGG-16 CL1: 224×224, K=3, M=3, N=64, stride 1, pad 1.
        let l = ConvLayer::new("CL1", 224, 3, 3, 64, 1, 1);
        assert_eq!(l.h_o(), 224);
        assert_eq!(l.w_o(), 224);
        // 2·9·224²·3·64 = 173.4 Mops
        assert_eq!(l.ops(), 2 * 9 * 224 * 224 * 3 * 64);
    }

    #[test]
    fn alexnet_cl1_stride4() {
        let l = ConvLayer::new("CL1", 227, 11, 3, 96, 4, 0);
        assert_eq!(l.h_o(), 55);
        assert_eq!(l.w_o(), 55);
    }

    #[test]
    fn alexnet_cl2_padded() {
        let l = ConvLayer::new("CL2", 27, 5, 48, 256, 1, 2);
        assert_eq!(l.h_o(), 27);
    }

    #[test]
    fn byte_accounting_8bit() {
        let l = ConvLayer::new("x", 10, 3, 4, 8, 1, 1);
        assert_eq!(l.ifmap_bytes(8), 4 * 100);
        assert_eq!(l.weight_bytes(8), 8 * 4 * 9);
        assert_eq!(l.ofmap_bytes(8), 8 * 100);
        assert_eq!(l.ifmap_bytes(16), 2 * 4 * 100);
    }

    #[test]
    fn tiling_predicate() {
        assert!(ConvLayer::new("a", 27, 5, 48, 256, 1, 2).needs_tiling(3));
        assert!(!ConvLayer::new("b", 14, 3, 512, 512, 1, 1).needs_tiling(3));
    }

    #[test]
    fn band_geometry_round_trips() {
        // stride 1: band of 4 rows needs 4+K−1 slab rows.
        let l = ConvLayer::new("x", 10, 3, 4, 8, 1, 1);
        assert_eq!(l.band_input_rows(&(0..4)), 0..6);
        assert_eq!(l.band_input_rows(&(4..10)), 4..12); // = hp
        let b = l.row_band(&(4..10));
        assert_eq!((b.h_i, b.w_i, b.pad), (8, 12, 0));
        assert_eq!(b.h_o(), 6, "band layer computes exactly the band rows");
        assert_eq!(b.w_o(), l.w_o());

        // stride 4 tiled (AlexNet CL1-like): slabs of adjacent bands gap.
        let l = ConvLayer::new("t", 31, 11, 2, 3, 4, 0);
        assert_eq!(l.h_o(), 6);
        let lo = l.row_band(&(0..3));
        let hi = l.row_band(&(3..6));
        assert_eq!(lo.h_i, 2 * 4 + 11);
        assert_eq!(hi.h_i, 2 * 4 + 11);
        assert_eq!((lo.h_o(), hi.h_o()), (3, 3));
    }
}
