//! Design-space exploration (Fig. 7): throughput, psum-buffer size and
//! I/O bandwidth as functions of the parallelism parameters (P_N, P_M).

use crate::arch::control::plan_layer;
use crate::arch::ArchConfig;
use crate::model::Network;

/// One (P_N, P_M) sample of Fig. 7.
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    pub p_n: usize,
    pub p_m: usize,
    /// Sustained network throughput, GOPs/s (Fig. 7a bars).
    pub gops: f64,
    /// Psum buffer size, Mbit — eq. (3) (Fig. 7a points).
    pub psum_buffer_mbit: f64,
    /// I/O bandwidth, bits/cycle — eq. (4) (Fig. 7b bars).
    pub io_bandwidth_bits: u64,
    /// Total PEs (for iso-PE comparisons in §IV).
    pub pes: usize,
}

/// Evaluate one configuration on a network.
pub fn evaluate(base: &ArchConfig, net: &Network, p_n: usize, p_m: usize) -> DesignPoint {
    let cfg = ArchConfig { p_n, p_m, ..*base };
    let total_time: f64 = net.layers.iter().map(|l| plan_layer(&cfg, l).time_s(&cfg)).sum();
    let gops = net.total_ops() as f64 / total_time / 1e9;
    DesignPoint {
        p_n,
        p_m,
        gops,
        psum_buffer_mbit: cfg.psum_buffer_bits() as f64 / 1e6,
        io_bandwidth_bits: cfg.io_bandwidth_bits(),
        pes: cfg.total_pes(),
    }
}

/// The paper's sweep grid: P_N, P_M ∈ {1, 4, 8, 16, 24}.
pub const PAPER_GRID: [usize; 5] = [1, 4, 8, 16, 24];

/// Full Fig. 7 sweep.
pub fn sweep(base: &ArchConfig, net: &Network) -> Vec<DesignPoint> {
    let mut out = vec![];
    for &p_n in &PAPER_GRID {
        for &p_m in &PAPER_GRID {
            out.push(evaluate(base, net, p_n, p_m));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::vgg16;

    fn base() -> ArchConfig {
        ArchConfig::paper_engine()
    }

    /// §IV: "The best-case with P_N = P_M = 24 leads to a performance of
    /// 1243 GOPs/s".
    #[test]
    fn best_case_hits_1243_gops() {
        let p = evaluate(&base(), &vgg16(), 24, 24);
        assert!((p.gops - 1243.0).abs() / 1243.0 < 0.03, "best case = {:.0} GOPs/s", p.gops);
    }

    /// §IV: 4 cores × 16 slices and 16 cores × 4 slices use 576 PEs each
    /// and reach the same throughput, but the former needs 4× less psum
    /// buffer and ~2.3× more bandwidth.
    #[test]
    fn iso_pe_tradeoff() {
        let a = evaluate(&base(), &vgg16(), 4, 16);
        let b = evaluate(&base(), &vgg16(), 16, 4);
        assert_eq!(a.pes, 576);
        assert_eq!(b.pes, 576);
        assert!((a.gops - b.gops).abs() / b.gops < 0.10, "{} vs {}", a.gops, b.gops);
        assert!((b.psum_buffer_mbit / a.psum_buffer_mbit - 4.0).abs() < 1e-9);
        let bw_ratio = a.io_bandwidth_bits as f64 / b.io_bandwidth_bits as f64;
        assert!((bw_ratio - 2.3).abs() < 0.2, "bw ratio = {bw_ratio:.2}");
    }

    #[test]
    fn throughput_monotone_in_parallelism() {
        let net = vgg16();
        let g1 = evaluate(&base(), &net, 1, 1).gops;
        let g2 = evaluate(&base(), &net, 8, 8).gops;
        let g3 = evaluate(&base(), &net, 24, 24).gops;
        assert!(g1 < g2 && g2 < g3, "{g1} {g2} {g3}");
    }

    #[test]
    fn sweep_covers_grid() {
        let pts = sweep(&base(), &vgg16());
        assert_eq!(pts.len(), 25);
        // psum buffer size depends only on P_N (the Fig. 7a points)
        for w in pts.chunks(5) {
            let first = w[0].psum_buffer_mbit;
            assert!(w.iter().all(|p| (p.psum_buffer_mbit - first).abs() < 1e-12));
        }
    }
}
