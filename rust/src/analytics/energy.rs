//! Access-energy constants and the on-chip normalisation of Tables I–II.
//!
//! Tables I–II report on-chip accesses "normalized to off-chip memory
//! accesses" (footnote b): raw on-chip access counts are scaled by the
//! relative energy of an on-chip vs an off-chip access so they can be
//! summed into a single energy-meaningful total. Reverse-engineering the
//! published columns fixes the ratio:
//!
//! * TrIM VGG-16 CL11: raw psum traffic 3·512·196·43 = 12.94 M, published
//!   0.17 M → ratio ≈ 76;
//! * Eyeriss VGG-16 total: 4 spad accesses/MAC × 46.05 G MACs = 184 G raw,
//!   published 2427.63 M → ratio ≈ 75.9.
//!
//! A ratio of 76 is exactly what Horowitz-style numbers give for a ~100 kB
//! SRAM vs DRAM (≈ 8.4 pJ vs 640 pJ per 32-bit access), so we adopt
//! `E_DRAM = 640 pJ`, `E_ONCHIP = 8.42 pJ`.

/// Energy per access (pJ, 32-bit word), 45 nm-class estimates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// Off-chip DRAM access.
    pub e_dram_pj: f64,
    /// On-chip buffer access (global buffer / psum buffer class).
    pub e_onchip_pj: f64,
    /// MAC operation (8-bit operands, 45 nm-class).
    pub e_mac_pj: f64,
}

impl EnergyModel {
    /// The calibration that reproduces the paper's normalised columns.
    pub fn paper() -> Self {
        Self { e_dram_pj: 640.0, e_onchip_pj: 640.0 / 76.0, e_mac_pj: 0.2 }
    }

    /// Tables I–II footnote b: on-chip accesses expressed in off-chip
    /// equivalents.
    pub fn normalize_onchip(&self, raw_accesses: f64) -> f64 {
        raw_accesses * self.e_onchip_pj / self.e_dram_pj
    }

    /// Total memory energy (J) for raw access counts.
    pub fn memory_energy_j(&self, off_chip: f64, on_chip_raw: f64) -> f64 {
        (off_chip * self.e_dram_pj + on_chip_raw * self.e_onchip_pj) * 1e-12
    }

    /// Compute energy (J) for a MAC count.
    pub fn compute_energy_j(&self, macs: f64) -> f64 {
        macs * self.e_mac_pj * 1e-12
    }
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalisation_ratio_is_76() {
        let e = EnergyModel::paper();
        let r = e.e_dram_pj / e.e_onchip_pj;
        assert!((r - 76.0).abs() < 1e-9);
        assert!((e.normalize_onchip(76.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dram_dominates_memory_energy() {
        let e = EnergyModel::paper();
        // §I: a DRAM read is ~200× a 32-bit multiply; our constants keep
        // DRAM ≫ on-chip ≫ MAC.
        assert!(e.e_dram_pj / e.e_onchip_pj > 10.0);
        assert!(e.e_onchip_pj / e.e_mac_pj > 10.0);
    }
}
