//! Weight-stationary GeMM baseline (TPU-style Conv-to-GeMM).
//!
//! The predecessor dataflow paper (arXiv:2408.01254, cited as [27])
//! motivates TrIM with "one order of magnitude saving in terms of memory
//! accesses when compared to the GeMM-based WS dataflow". This module
//! reproduces that ablation: im2col materialises every K×K sliding window,
//! so each ifmap element is read ≈K² times from memory (window overlap
//! becomes data redundancy), and psums stream through the array once per
//! reduction tile.

use crate::model::{ConvLayer, Network};

/// WS-GeMM array parameters.
#[derive(Debug, Clone, Copy)]
pub struct WsGemmConfig {
    /// Systolic array rows (reduction dimension tile).
    pub rows: usize,
    /// Systolic array columns (output-channel tile).
    pub cols: usize,
}

impl Default for WsGemmConfig {
    /// A 256×256 TPU-like array (the paper's reference point [18]).
    fn default() -> Self {
        Self { rows: 256, cols: 256 }
    }
}

/// Access counts for one layer under Conv-to-GeMM + WS.
#[derive(Debug, Clone)]
pub struct WsGemmLayer {
    pub name: String,
    /// Off-chip accesses (millions): im2col-expanded ifmap + weights per
    /// reduction pass + ofmaps.
    pub off_chip_m: f64,
    /// im2col redundancy factor actually incurred (≈ K²/stride²).
    pub redundancy: f64,
}

/// Model one layer.
pub fn model_layer(cfg: &WsGemmConfig, layer: &ConvLayer, batch: usize) -> WsGemmLayer {
    let b = batch as f64;
    // GeMM dims: (H_O·W_O) × (M·K²) · (M·K² × N)
    let gemm_k = (layer.m * layer.k * layer.k) as f64;
    let out_rows = (layer.h_o() * layer.w_o()) as f64;

    // im2col matrix has out_rows × gemm_k elements — every one read from
    // memory (this IS the redundancy: the same ifmap element appears in up
    // to K²/stride² windows).
    let im2col_reads = out_rows * gemm_k * b;
    let redundancy = im2col_reads / (layer.ifmap_elems() as f64 * b);

    // Weights stream once per output-row tile group: the WS array holds a
    // (rows × cols) weight tile; the full weight matrix is gemm_k × N and
    // each tile is re-loaded once (weights stationary while the whole
    // im2col matrix streams through).
    let weight_reads = gemm_k * layer.n as f64;

    // Psums leave the array once per reduction tile beyond the first.
    let red_tiles = (gemm_k / cfg.rows as f64).ceil();
    let psum_traffic = out_rows * layer.n as f64 * (red_tiles - 1.0).max(0.0) * 2.0 * b;

    let ofmap_writes = layer.ofmap_elems() as f64 * b;
    WsGemmLayer {
        name: layer.name.clone(),
        off_chip_m: (im2col_reads + weight_reads + psum_traffic + ofmap_writes) / 1e6,
        redundancy,
    }
}

/// Sum over a network.
pub fn model_network(cfg: &WsGemmConfig, net: &Network) -> Vec<WsGemmLayer> {
    net.layers.iter().map(|l| model_layer(cfg, l, net.batch)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_redundancy_is_about_k_squared() {
        let l = ConvLayer::new("x", 56, 3, 128, 256, 1, 1);
        let r = model_layer(&WsGemmConfig::default(), &l, 1);
        assert!(r.redundancy > 8.0 && r.redundancy < 9.5, "redundancy = {}", r.redundancy);
    }

    #[test]
    fn trim_saves_about_an_order_of_magnitude_vs_ws_per_pass() {
        // The dataflow paper's headline: ~one order of magnitude fewer
        // ifmap memory reads than GeMM-based WS. This is a *dataflow*
        // (per weight-resident pass) property: TrIM reads the padded
        // ifmap once (1.018× of minimum for 3×3/224), im2col reads every
        // window element (≈K² per ifmap element).
        let l = ConvLayer::new("cl", 224, 3, 1, 1, 1, 1);
        let ws = model_layer(&WsGemmConfig::default(), &l, 1);
        let trim_reads = 226.0 * 226.0; // padded ifmap, once (measured by the slice sim)
        let ws_ifmap_reads = (l.h_o() * l.w_o() * l.k * l.k) as f64;
        let ratio = ws_ifmap_reads / trim_reads;
        assert!(ratio > 7.0 && ratio < 10.0, "per-pass read ratio = {ratio:.1}");
        assert!(ws.redundancy > 8.0, "im2col redundancy = {:.1}", ws.redundancy);
    }

    #[test]
    fn strided_layer_redundancy_shrinks() {
        let l = ConvLayer::new("cl1", 227, 11, 3, 96, 4, 0);
        let r = model_layer(&WsGemmConfig::default(), &l, 1);
        // 11²/4² ≈ 7.6 — stride eats part of the window overlap.
        assert!(r.redundancy > 5.0 && r.redundancy < 9.0, "redundancy = {}", r.redundancy);
    }
}
