//! §VI future-work extensions, implemented as first-class analytical
//! features so their impact can be quantified (ablation bench:
//! `rust/benches/ablations.rs`):
//!
//! 1. **RSRB sharing** — *"different processing elements may work on the
//!    same set of ifmaps, it is possible to share the same shift register
//!    buffers"*: the P_N cores of the engine all consume the same
//!    broadcast ifmaps, so the (K−1) RSRBs per slice can be shared across
//!    the P_N cores' homologous slices → the register count (and its
//!    LUT/FF cost) divides by the sharing degree.
//! 2. **Ifmap tiling** — *"reduce the area required by the reconfigurable
//!    shift register buffers ... constrained on the largest ifmap size"*:
//!    processing ifmaps in vertical stripes of width `W_T < W_IM` shrinks
//!    each RSRB to `W_T (+ halo)` registers at the cost of re-reading the
//!    (K−1)-column halo between adjacent stripes.
//! 3. **Ifmap/weight global buffer** — *"reduce the count of off-chip
//!    memory access"*: an on-chip buffer holding the current ifmap group
//!    turns the ⌈N/P_N⌉ off-chip re-broadcasts into on-chip reads
//!    (one DRAM pass), trading BRAM for DRAM energy.

use super::energy::EnergyModel;
use super::fpga::{estimate, CostCoefficients, FpgaCost};
use super::trim_model::{analyze_layer, LayerMetrics};
use crate::arch::control::plan_layer;
use crate::arch::ArchConfig;
use crate::model::{ConvLayer, Network};

/// Extension knobs (§VI list, in order).
#[derive(Debug, Clone, Copy)]
pub struct Extensions {
    /// Share each slice's RSRBs across the engine's P_N cores
    /// (homologous slices see identical ifmap streams).
    pub rsrb_sharing: bool,
    /// Vertical stripe width for ifmap tiling (None = full width W_IM).
    pub ifmap_tile_width: Option<usize>,
    /// On-chip global buffer for ifmaps (+ weights), in bits.
    pub global_buffer_bits: Option<u64>,
}

impl Extensions {
    pub fn none() -> Self {
        Self { rsrb_sharing: false, ifmap_tile_width: None, global_buffer_bits: None }
    }

    /// Everything §VI proposes, with an 18 Mb ifmap buffer (enough for the
    /// largest VGG-16 ifmap group at 8 bit: 24 × 226² ≈ 9.8 Mb ×
    /// double-buffering).
    pub fn all() -> Self {
        Self { rsrb_sharing: true, ifmap_tile_width: Some(64), global_buffer_bits: Some(18_000_000) }
    }
}

/// RSRB register count per engine without/with sharing.
pub fn rsrb_registers(cfg: &ArchConfig, ext: &Extensions) -> u64 {
    let width = ext.ifmap_tile_width.map(|w| w + cfg.k - 1).unwrap_or(cfg.w_im) as u64;
    let per_slice = (cfg.k as u64 - 1) * width;
    let slices = (cfg.p_n * cfg.p_m) as u64;
    if ext.rsrb_sharing {
        // one RSRB set per *slice position*, shared by the P_N cores
        per_slice * cfg.p_m as u64
    } else {
        per_slice * slices
    }
}

/// FPGA cost with the extensions applied (RSRB savings + global-buffer
/// BRAM).
pub fn extended_cost(cfg: &ArchConfig, ext: &Extensions) -> FpgaCost {
    let coef = CostCoefficients::default();
    let mut cost = estimate(cfg, &coef);
    let base_regs = rsrb_registers(cfg, &Extensions::none());
    let ext_regs = rsrb_registers(cfg, ext);
    let delta = base_regs.saturating_sub(ext_regs) as f64;
    cost.luts -= delta * coef.lut_per_rsrb_stage;
    // SRL-packed stages carry ~1/8 FF each on average (taps + boundaries)
    cost.ffs -= delta * 0.125;
    if let Some(bits) = ext.global_buffer_bits {
        cost.bram_mbit += bits as f64 / 1e6;
    }
    cost
}

/// Off-chip / on-chip accesses for one layer with the extensions.
///
/// * global buffer: ifmaps cross DRAM once; the ⌈N/filters_parallel⌉
///   re-broadcasts become on-chip buffer reads (normalised like psums);
/// * ifmap tiling: stripes re-read a (K−1)-column halo per stripe
///   boundary (from DRAM without the buffer, on-chip with it).
pub fn analyze_layer_ext(cfg: &ArchConfig, layer: &ConvLayer, batch: usize, ext: &Extensions) -> LayerMetrics {
    let base = analyze_layer(cfg, layer, batch);
    let plan = plan_layer(cfg, layer);
    let b = batch as f64;
    let hp = (layer.h_i + 2 * layer.pad) as f64;
    let wp = (layer.w_i + 2 * layer.pad) as f64;

    // halo overhead factor from ifmap tiling
    let tile_factor = match ext.ifmap_tile_width {
        Some(wt) if (wt as f64) < wp => {
            let stripes = (wp / wt as f64).ceil();
            (wp + (stripes - 1.0) * (cfg.k as f64 - 1.0)) / wp
        }
        _ => 1.0,
    };

    let ifmap_stream = b * layer.m as f64 * hp * wp * tile_factor;
    let passes = plan.filter_steps as f64;
    let energy = EnergyModel::paper();

    let (off_chip, on_chip_extra_raw) = match ext.global_buffer_bits {
        Some(bits) => {
            let need = (layer.m.min(cfg.p_m) as f64) * hp * wp * cfg.bits as f64;
            if need <= bits as f64 {
                // DRAM once; re-broadcasts served on-chip
                (ifmap_stream + layer.weight_elems() as f64 + b * layer.ofmap_elems() as f64,
                 ifmap_stream * (passes - 1.0).max(0.0))
            } else {
                (ifmap_stream * passes + layer.weight_elems() as f64 + b * layer.ofmap_elems() as f64, 0.0)
            }
        }
        None => (ifmap_stream * passes + layer.weight_elems() as f64 + b * layer.ofmap_elems() as f64, 0.0),
    };

    let on_chip_raw = base.on_chip_raw_m * 1e6 + on_chip_extra_raw;
    LayerMetrics {
        off_chip_m: off_chip / 1e6,
        on_chip_m: energy.normalize_onchip(on_chip_raw) / 1e6,
        on_chip_raw_m: on_chip_raw / 1e6,
        ..base
    }
}

/// Network totals with extensions: (off-chip M, on-chip M).
pub fn analyze_network_ext(cfg: &ArchConfig, net: &Network, ext: &Extensions) -> (f64, f64) {
    let mut off = 0.0;
    let mut on = 0.0;
    for l in &net.layers {
        let m = analyze_layer_ext(cfg, l, net.batch, ext);
        off += m.off_chip_m;
        on += m.on_chip_m;
    }
    (off, on)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::vgg16;

    fn cfg() -> ArchConfig {
        ArchConfig::paper_engine()
    }

    #[test]
    fn rsrb_sharing_divides_registers_by_p_n() {
        let base = rsrb_registers(&cfg(), &Extensions::none());
        let shared = rsrb_registers(
            &cfg(),
            &Extensions { rsrb_sharing: true, ifmap_tile_width: None, global_buffer_bits: None },
        );
        assert_eq!(base, shared * cfg().p_n as u64);
    }

    #[test]
    fn ifmap_tiling_shrinks_rsrbs_with_halo() {
        let tiled = Extensions { rsrb_sharing: false, ifmap_tile_width: Some(64), global_buffer_bits: None };
        let regs = rsrb_registers(&cfg(), &tiled);
        let base = rsrb_registers(&cfg(), &Extensions::none());
        // 226 → 64+2 registers per line: ~3.4× smaller
        assert!(base as f64 / regs as f64 > 3.0, "{base} vs {regs}");
    }

    #[test]
    fn global_buffer_cuts_off_chip_toward_single_pass() {
        let net = vgg16();
        let (off_base, on_base) = analyze_network_ext(&cfg(), &net, &Extensions::none());
        let gb = Extensions { rsrb_sharing: false, ifmap_tile_width: None, global_buffer_bits: Some(18_000_000) };
        let (off_gb, on_gb) = analyze_network_ext(&cfg(), &net, &gb);
        // §VI: "reduce the count of off-chip memory access" — the VGG-16
        // ifmap re-broadcast dominates, so the cut is large...
        assert!(off_gb < off_base * 0.30, "off {off_gb:.0} vs {off_base:.0}");
        // ...while the buffered re-reads reappear (cheaply) on-chip.
        assert!(on_gb > on_base);
        // and the *energy-equivalent* total still improves
        assert!(off_gb + on_gb < off_base + on_base);
    }

    #[test]
    fn baseline_ext_matches_plain_model() {
        let net = vgg16();
        let (off, on) = analyze_network_ext(&cfg(), &net, &Extensions::none());
        let plain = crate::analytics::trim_model::analyze_network(&cfg(), &net);
        assert!((off - plain.total_off_chip_m).abs() < 1e-6);
        assert!((on - plain.total_on_chip_m).abs() < 1e-6);
    }

    #[test]
    fn extended_cost_saves_luts_and_spends_bram() {
        let all = Extensions::all();
        let base = extended_cost(&cfg(), &Extensions::none());
        let ext = extended_cost(&cfg(), &all);
        assert!(ext.luts < base.luts);
        assert!(ext.bram_mbit > base.bram_mbit);
    }

    #[test]
    fn halo_overhead_is_small_for_reasonable_tiles() {
        let ext = Extensions { rsrb_sharing: false, ifmap_tile_width: Some(64), global_buffer_bits: None };
        let l = &vgg16().layers[1]; // 224², K=3
        let base = analyze_layer_ext(&cfg(), l, 3, &Extensions::none());
        let tiled = analyze_layer_ext(&cfg(), l, 3, &ext);
        let overhead = tiled.off_chip_m / base.off_chip_m - 1.0;
        assert!(overhead > 0.0 && overhead < 0.05, "halo overhead = {:.1}%", overhead * 100.0);
    }
}
