//! TrIM per-layer analytical model: timing from the control plan
//! (eqs. (1)–(2)) and the memory-access model behind Tables I–II.
//!
//! ## Off-chip access model
//!
//! Two loop orders are available to the control logic; it picks the
//! cheaper one per layer (this is what reconciles the VGG-16 and AlexNet
//! columns of the paper):
//!
//! * **Policy A — ifmap-streaming** (weights resident per step): the
//!   padded ifmaps are re-broadcast for each filter group, weights are
//!   loaded once per step:
//!   `batch·M·H_P·W_P·⌈N/filters_parallel⌉ + K²MN + batch·N·H_O·W_O`.
//! * **Policy B — ifmap-resident** (weights re-streamed): ifmaps are read
//!   once per image, weights reload for every channel-group pass:
//!   `batch·M·H_P·W_P + batch·K²MN·m_steps + batch·N·H_O·W_O`.
//!
//! ## On-chip (psum-buffer) model
//!
//! Temporal accumulation only exists when `m_steps > 1` (Fig. 6): per
//! ofmap element, `m_steps` writes and `m_steps − 1` reads plus the final
//! read-out → `(2·m_steps − 1)` accesses. Normalised per Tables I–II
//! footnote b (÷76, see [`super::energy`]).

use super::energy::EnergyModel;
use crate::arch::control::{plan_layer, StepPlan};
use crate::arch::ArchConfig;
use crate::model::{ConvLayer, Network};

/// Which off-chip loop order the control logic picked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffChipPolicy {
    IfmapStreaming,
    IfmapResident,
}

/// Per-layer analytical results (one Table I/II row).
#[derive(Debug, Clone)]
pub struct LayerMetrics {
    pub name: String,
    pub gops: f64,
    pub utilization: f64,
    pub time_s: f64,
    /// Off-chip accesses (millions, batch-normalised like the tables).
    pub off_chip_m: f64,
    /// On-chip accesses in off-chip equivalents (millions).
    pub on_chip_m: f64,
    /// Raw (un-normalised) on-chip accesses (millions).
    pub on_chip_raw_m: f64,
    pub policy: OffChipPolicy,
    pub plan: StepPlan,
}

impl LayerMetrics {
    pub fn total_m(&self) -> f64 {
        self.off_chip_m + self.on_chip_m
    }
}

/// Whole-network analytical results.
#[derive(Debug, Clone)]
pub struct NetworkMetrics {
    pub network: String,
    pub batch: usize,
    pub layers: Vec<LayerMetrics>,
    pub total_time_s: f64,
    pub total_gops: f64,
    pub mean_utilization: f64,
    pub total_off_chip_m: f64,
    pub total_on_chip_m: f64,
}

impl NetworkMetrics {
    pub fn total_m(&self) -> f64 {
        self.total_off_chip_m + self.total_on_chip_m
    }
}

/// Analyse one layer on `cfg` with the given batch.
pub fn analyze_layer(cfg: &ArchConfig, layer: &ConvLayer, batch: usize) -> LayerMetrics {
    let plan = plan_layer(cfg, layer);
    let b = batch as f64;
    let hp = (layer.h_i + 2 * layer.pad) as f64;
    let wp = (layer.w_i + 2 * layer.pad) as f64;
    let ifmap_padded = layer.m as f64 * hp * wp;
    let weights = layer.weight_elems() as f64;
    let ofmap = layer.ofmap_elems() as f64;

    // Policy A: padded ifmaps re-broadcast per filter group.
    let a = b * ifmap_padded * plan.filter_steps as f64 + weights + b * ofmap;
    // Policy B: ifmaps once, weights per channel-group pass and per image.
    let m_passes = plan.m_steps.max(1) as f64;
    let bpol = b * ifmap_padded + b * weights * m_passes + b * ofmap;

    // The control logic streams ifmaps (A) in the native and many-tile
    // modes — TrIM has no ifmap buffer (adding one is the paper's listed
    // future work). In the cooperative-core 5×5 mode only one filter is in
    // flight, and the idle cores' psum buffers can cache the (small)
    // ifmap set, so the ifmap-resident order (B) applies — this is the
    // reading that reproduces Table II's CL2 column.
    let cooperative = plan.tiles > 1 && plan.tiles <= cfg.p_n;
    let (off_chip, policy) = if cooperative {
        (bpol, OffChipPolicy::IfmapResident)
    } else {
        (a, OffChipPolicy::IfmapStreaming)
    };

    // Psum-buffer traffic (temporal accumulation, Fig. 6): per ofmap
    // element, m_steps writes + (m_steps − 1) accumulation reads + the
    // final read-out → 2·m_steps − 1 accesses when m_steps > 1.
    let on_chip_raw = if plan.m_steps > 1 { b * ofmap * (2.0 * plan.m_steps as f64 - 1.0) } else { 0.0 };
    let energy = EnergyModel::paper();
    let on_chip = energy.normalize_onchip(on_chip_raw);

    LayerMetrics {
        name: layer.name.clone(),
        gops: plan.gops(cfg, layer),
        utilization: plan.utilization,
        time_s: plan.time_s(cfg),
        off_chip_m: off_chip / 1e6,
        on_chip_m: on_chip / 1e6,
        on_chip_raw_m: on_chip_raw / 1e6,
        policy,
        plan,
    }
}

/// Analyse a whole network (one Table I/II).
pub fn analyze_network(cfg: &ArchConfig, net: &Network) -> NetworkMetrics {
    let layers: Vec<LayerMetrics> = net.layers.iter().map(|l| analyze_layer(cfg, l, net.batch)).collect();
    let total_time_s: f64 = layers.iter().map(|l| l.time_s).sum();
    let total_gops = net.total_ops() as f64 / total_time_s / 1e9;
    let mean_utilization = layers.iter().map(|l| l.utilization).sum::<f64>() / layers.len() as f64;
    NetworkMetrics {
        network: net.name.clone(),
        batch: net.batch,
        total_off_chip_m: layers.iter().map(|l| l.off_chip_m).sum(),
        total_on_chip_m: layers.iter().map(|l| l.on_chip_m).sum(),
        layers,
        total_time_s,
        total_gops,
        mean_utilization,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet::alexnet, vgg16::vgg16};

    /// Table I off-chip column, per layer (paper values, millions).
    const PAPER_VGG_OFF: [f64; 13] = [
        13.57, 102.79, 49.96, 95.33, 48.51, 94.71, 94.71, 52.44, 103.72, 103.72, 33.05, 33.05, 33.05,
    ];
    /// Table I on-chip column (paper values, millions, normalised).
    const PAPER_VGG_ON: [f64; 13] =
        [0.00, 0.57, 0.27, 0.68, 0.33, 0.66, 0.66, 0.33, 0.70, 0.70, 0.17, 0.17, 0.17];

    #[test]
    fn vgg16_off_chip_within_7pct_per_layer() {
        let m = analyze_network(&ArchConfig::paper_engine(), &vgg16());
        for (l, &p) in m.layers.iter().zip(&PAPER_VGG_OFF) {
            let dev = (l.off_chip_m - p).abs() / p;
            assert!(dev < 0.07, "{}: model {:.2} vs paper {p} ({:.1}%)", l.name, l.off_chip_m, dev * 100.0);
        }
    }

    #[test]
    fn vgg16_on_chip_within_20pct_per_layer() {
        let m = analyze_network(&ArchConfig::paper_engine(), &vgg16());
        for (l, &p) in m.layers.iter().zip(&PAPER_VGG_ON) {
            if p == 0.0 {
                assert_eq!(l.on_chip_m, 0.0, "{}", l.name);
            } else {
                let dev = (l.on_chip_m - p).abs() / p;
                assert!(dev < 0.20, "{}: model {:.3} vs paper {p}", l.name, l.on_chip_m);
            }
        }
    }

    #[test]
    fn vgg16_totals_match_table1() {
        let m = analyze_network(&ArchConfig::paper_engine(), &vgg16());
        // paper totals: off-chip 858.63 M, on-chip 5.44 M, total 864.06 M
        assert!((m.total_off_chip_m - 858.63).abs() / 858.63 < 0.05, "off = {:.1}", m.total_off_chip_m);
        assert!((m.total_on_chip_m - 5.44).abs() / 5.44 < 0.15, "on = {:.2}", m.total_on_chip_m);
        assert!((m.total_gops - 391.0).abs() < 5.0);
        assert!((m.mean_utilization - 0.93).abs() < 0.01);
    }

    #[test]
    fn vgg16_prefers_ifmap_streaming() {
        let m = analyze_network(&ArchConfig::paper_engine(), &vgg16());
        for l in &m.layers {
            assert_eq!(l.policy, OffChipPolicy::IfmapStreaming, "{}", l.name);
        }
    }

    #[test]
    fn alexnet_mixes_policies_and_stays_in_band() {
        let m = analyze_network(&ArchConfig::paper_engine(), &alexnet());
        // CL2 (5×5, 256 filters · 1 at a time) must flip to ifmap-resident.
        assert_eq!(m.layers[1].policy, OffChipPolicy::IfmapResident);
        // paper Table II: CL2 total 3.71 M
        assert!((m.layers[1].total_m() - 3.71).abs() / 3.71 < 0.15, "CL2 = {:.2}", m.layers[1].total_m());
        // native layers within 10%
        for (l, &p) in m.layers[2..].iter().zip(&[14.95f64, 11.27, 7.57]) {
            assert!((l.total_m() - p).abs() / p < 0.10, "{}: {:.2} vs {p}", l.name, l.total_m());
        }
        // network total lands in the paper's neighbourhood (46.03 M);
        // CL1's underspecified schedule dominates the deviation.
        assert!(m.total_m() > 25.0 && m.total_m() < 60.0, "total = {:.1}", m.total_m());
    }
}
