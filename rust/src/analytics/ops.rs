//! Fig. 1: per-layer memory requirements and operation counts for VGG-16.

use crate::model::{ConvLayer, Network};

/// One Fig. 1 bar/point.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub name: String,
    pub ifmap_mb: f64,
    pub weight_mb: f64,
    pub gops: f64,
}

impl LayerProfile {
    pub fn total_mb(&self) -> f64 {
        self.ifmap_mb + self.weight_mb
    }
}

/// Profile one layer at `bits` precision.
pub fn profile_layer(layer: &ConvLayer, bits: usize) -> LayerProfile {
    LayerProfile {
        name: layer.name.clone(),
        ifmap_mb: layer.ifmap_bytes(bits) as f64 / 1e6,
        weight_mb: layer.weight_bytes(bits) as f64 / 1e6,
        gops: layer.ops() as f64 / 1e9,
    }
}

/// Fig. 1 data for a whole network (8-bit, as in the paper).
pub fn profile_network(net: &Network, bits: usize) -> Vec<LayerProfile> {
    net.layers.iter().map(|l| profile_layer(l, bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::vgg16;

    #[test]
    fn early_layers_are_ifmap_bound_late_layers_weight_bound() {
        // The Fig. 1 narrative: "former CLs ... require massive memory for
        // inputs ... deeper CLs extract features requiring a dominant
        // contribution of weights."
        let p = profile_network(&vgg16(), 8);
        assert!(p[1].ifmap_mb > 10.0 * p[1].weight_mb, "CL2 is ifmap-bound");
        assert!(p[12].weight_mb > 20.0 * p[12].ifmap_mb, "CL13 is weight-bound");
    }

    #[test]
    fn totals_match_intro_numbers() {
        let p = profile_network(&vgg16(), 8);
        let gops: f64 = p.iter().map(|l| l.gops).sum();
        assert!((gops - 30.7).abs() < 0.3, "total = {gops:.1} GOPs");
        let mb: f64 = p.iter().map(|l| l.total_mb()).sum();
        assert!(mb > 20.0 && mb < 26.0, "total = {mb:.1} MB");
    }

    #[test]
    fn cl2_is_among_the_compute_peaks() {
        // Several VGG-16 layers tie at the 3.7 GOPs peak (CL2/CL4/CL6...);
        // Fig. 1's dashed line is flat-topped across them.
        let p = profile_network(&vgg16(), 8);
        let max = p.iter().map(|l| l.gops).fold(0.0, f64::max);
        assert!((max - 3.7).abs() < 0.05, "peak = {max:.2} GOPs");
        assert!((p[1].gops - max).abs() < 1e-9, "CL2 at the peak");
    }
}
