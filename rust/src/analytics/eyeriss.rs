//! Eyeriss row-stationary baseline (Tables I–II comparison columns).
//!
//! The paper's Eyeriss numbers derive from the Eyeriss JSSC'17 chip
//! measurements (hence the batch-3/batch-4 normalisation). We provide:
//!
//! 1. the **published columns** exactly as printed (what the paper's
//!    ratios are computed from), and
//! 2. a **structural access model** of the RS dataflow for comparison:
//!    per-MAC scratch-pad traffic (ifmap read, weight read, psum
//!    read+write = 4/MAC — this alone reproduces the published VGG-16
//!    on-chip total within 0.5 %), a global-buffer term for psum passes
//!    and fmap staging, and a DRAM term with RLC fmap compression.
//!
//! Timing (GOPs/s) is taken from the published measurements: it is a chip
//! property the paper itself quotes, not something TrIM's authors (or we)
//! re-derive; our contribution is modelling the *access counts*, which is
//! what the paper's headline ratios (≈3× on VGG-16, ≈1.8× on AlexNet)
//! are about.

use super::energy::EnergyModel;
use crate::model::{ConvLayer, Network};

/// Eyeriss chip parameters (JSSC'17).
#[derive(Debug, Clone, Copy)]
pub struct EyerissConfig {
    /// PE array (12 × 14).
    pub pes: usize,
    /// Channels accumulated per processing pass (psum spad depth bound).
    pub q_channels_per_pass: usize,
    /// Effective DRAM ifmap read amplification (staging/halo reloads net
    /// of RLC compression; fitted to the published VGG-16 CL2/CL11 rows and the AlexNet total).
    pub ifmap_reload: f64,
    /// Scratch-pad accesses per MAC (ifmap rd, weight rd, psum rd+wr).
    pub spad_per_mac: f64,
    /// Ofmap-row strip height per weight-resident pass: weights re-stream
    /// from DRAM once per strip (RS folds tall fmaps over the 12-row
    /// array).
    pub strip_rows: usize,
}

impl Default for EyerissConfig {
    fn default() -> Self {
        Self { pes: 168, q_channels_per_pass: 4, ifmap_reload: 2.5, spad_per_mac: 4.0, strip_rows: 16 }
    }
}

/// One modelled Eyeriss layer row.
#[derive(Debug, Clone)]
pub struct EyerissLayer {
    pub name: String,
    /// Modelled on-chip accesses in off-chip equivalents (millions).
    pub on_chip_m: f64,
    /// Modelled off-chip accesses (millions).
    pub off_chip_m: f64,
    /// Share of on-chip equivalents due to spads (paper: ~94 % on VGG-16).
    pub spad_share: f64,
}

impl EyerissLayer {
    pub fn total_m(&self) -> f64 {
        self.on_chip_m + self.off_chip_m
    }
}

/// Structural RS access model for one layer.
pub fn model_layer(cfg: &EyerissConfig, layer: &ConvLayer, batch: usize) -> EyerissLayer {
    let b = batch as f64;
    let macs = layer.macs() as f64 * b;
    let ofmap = layer.ofmap_elems() as f64 * b;
    let ifmap = layer.ifmap_elems() as f64 * b;
    let weights = layer.weight_elems() as f64;

    // --- scratch pads: per-MAC traffic (RS circulation at the PE level) --
    let spad = macs * cfg.spad_per_mac;

    // --- global buffer: psum round-trips between processing passes ------
    // Each ofmap element accumulates over ⌈M/q⌉ passes; all but the last
    // spill to the GLB and return (2 accesses each), plus staged ifmap
    // tiles transit the GLB once per filter-group pass.
    let m_passes = (layer.m as f64 / cfg.q_channels_per_pass as f64).ceil();
    let glb_psum = 2.0 * ofmap * (m_passes - 1.0).max(0.0);
    let glb_ifmap = ifmap; // staged once (RS reuses rows inside the array)
    let glb = glb_psum + glb_ifmap;

    // --- DRAM: ifmaps with staging amplification, ofmaps once, weights
    // once per ofmap-row strip (fold of tall fmaps over the array) -------
    let strips = (layer.h_o() as f64 / cfg.strip_rows as f64).ceil();
    let off_chip = ifmap * cfg.ifmap_reload + ofmap + weights * strips;

    let e = EnergyModel::paper();
    let on_spad = e.normalize_onchip(spad);
    let on_glb = e.normalize_onchip(glb);
    EyerissLayer {
        name: layer.name.clone(),
        on_chip_m: (on_spad + on_glb) / 1e6,
        off_chip_m: off_chip / 1e6,
        spad_share: on_spad / (on_spad + on_glb),
    }
}

/// Model all layers of a network.
pub fn model_network(cfg: &EyerissConfig, net: &Network) -> Vec<EyerissLayer> {
    net.layers.iter().map(|l| model_layer(cfg, l, net.batch)).collect()
}

/// Published per-layer Eyeriss columns (exactly as printed in the paper).
#[derive(Debug, Clone, Copy)]
pub struct PublishedRow {
    pub gops: f64,
    pub pe_util: f64,
    pub on_chip_m: f64,
    pub off_chip_m: f64,
}

impl PublishedRow {
    pub fn total_m(&self) -> f64 {
        self.on_chip_m + self.off_chip_m
    }
}

/// Table I, Eyeriss columns (VGG-16, batch 3).
pub const PUBLISHED_VGG16: [PublishedRow; 13] = [
    PublishedRow { gops: 13.7, pe_util: 0.93, on_chip_m: 43.81, off_chip_m: 7.70 },
    PublishedRow { gops: 13.7, pe_util: 0.93, on_chip_m: 477.14, off_chip_m: 27.00 },
    PublishedRow { gops: 13.7, pe_util: 0.93, on_chip_m: 271.44, off_chip_m: 16.70 },
    PublishedRow { gops: 13.7, pe_util: 0.93, on_chip_m: 495.48, off_chip_m: 24.25 },
    PublishedRow { gops: 27.2, pe_util: 0.93, on_chip_m: 145.57, off_chip_m: 10.10 },
    PublishedRow { gops: 27.2, pe_util: 0.93, on_chip_m: 259.22, off_chip_m: 16.10 },
    PublishedRow { gops: 27.2, pe_util: 0.93, on_chip_m: 255.46, off_chip_m: 15.40 },
    PublishedRow { gops: 52.8, pe_util: 1.00, on_chip_m: 89.08, off_chip_m: 8.90 },
    PublishedRow { gops: 52.8, pe_util: 1.00, on_chip_m: 157.88, off_chip_m: 14.30 },
    PublishedRow { gops: 52.8, pe_util: 1.00, on_chip_m: 141.23, off_chip_m: 11.40 },
    PublishedRow { gops: 57.4, pe_util: 1.00, on_chip_m: 32.69, off_chip_m: 3.15 },
    PublishedRow { gops: 57.2, pe_util: 1.00, on_chip_m: 29.68, off_chip_m: 2.85 },
    PublishedRow { gops: 57.2, pe_util: 1.00, on_chip_m: 28.95, off_chip_m: 2.80 },
];

/// Table II, Eyeriss columns (AlexNet, batch 4).
pub const PUBLISHED_ALEXNET: [PublishedRow; 5] = [
    PublishedRow { gops: 51.1, pe_util: 0.92, on_chip_m: 17.92, off_chip_m: 2.50 },
    PublishedRow { gops: 45.7, pe_util: 0.80, on_chip_m: 28.64, off_chip_m: 2.00 },
    PublishedRow { gops: 54.9, pe_util: 0.93, on_chip_m: 15.09, off_chip_m: 1.50 },
    PublishedRow { gops: 56.1, pe_util: 0.93, on_chip_m: 10.44, off_chip_m: 1.05 },
    PublishedRow { gops: 59.8, pe_util: 0.93, on_chip_m: 5.36, off_chip_m: 0.65 },
];

/// Published totals.
pub const PUBLISHED_VGG16_TOTAL: PublishedRow =
    PublishedRow { gops: 24.5, pe_util: 0.94, on_chip_m: 2427.63, off_chip_m: 160.65 };
pub const PUBLISHED_ALEXNET_TOTAL: PublishedRow =
    PublishedRow { gops: 51.5, pe_util: 0.88, on_chip_m: 77.45, off_chip_m: 7.70 };

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet::alexnet, vgg16::vgg16};

    #[test]
    fn published_totals_are_column_sums() {
        let on: f64 = PUBLISHED_VGG16.iter().map(|r| r.on_chip_m).sum();
        let off: f64 = PUBLISHED_VGG16.iter().map(|r| r.off_chip_m).sum();
        assert!((on - PUBLISHED_VGG16_TOTAL.on_chip_m).abs() < 0.5, "on = {on}");
        assert!((off - PUBLISHED_VGG16_TOTAL.off_chip_m).abs() < 0.5, "off = {off}");
    }

    #[test]
    fn modeled_vgg_on_chip_total_matches_published_within_15pct() {
        let rows = model_network(&EyerissConfig::default(), &vgg16());
        let on: f64 = rows.iter().map(|r| r.on_chip_m).sum();
        let dev = (on - PUBLISHED_VGG16_TOTAL.on_chip_m).abs() / PUBLISHED_VGG16_TOTAL.on_chip_m;
        assert!(dev < 0.15, "modeled {on:.0} vs published {} ({:.0}%)", PUBLISHED_VGG16_TOTAL.on_chip_m, dev * 100.0);
    }

    #[test]
    fn modeled_vgg_off_chip_total_matches_published_within_20pct() {
        // Off-chip is the hardest term (compression + reload policy are
        // workload-adaptive on the real chip) — the *order* matters for
        // the paper's claims, not the last 15 %.
        let rows = model_network(&EyerissConfig::default(), &vgg16());
        let off: f64 = rows.iter().map(|r| r.off_chip_m).sum();
        let dev = (off - PUBLISHED_VGG16_TOTAL.off_chip_m).abs() / PUBLISHED_VGG16_TOTAL.off_chip_m;
        assert!(dev < 0.20, "modeled {off:.0} vs published {}", PUBLISHED_VGG16_TOTAL.off_chip_m);
    }

    #[test]
    fn modeled_alexnet_off_chip_matches_published_within_10pct() {
        let rows = model_network(&EyerissConfig::default(), &alexnet());
        let off: f64 = rows.iter().map(|r| r.off_chip_m).sum();
        let dev = (off - PUBLISHED_ALEXNET_TOTAL.off_chip_m).abs() / PUBLISHED_ALEXNET_TOTAL.off_chip_m;
        assert!(dev < 0.10, "modeled {off:.1} vs published {}", PUBLISHED_ALEXNET_TOTAL.off_chip_m);
    }

    #[test]
    fn spads_dominate_on_chip_as_stated_in_section5() {
        // §V: "~94 % of equivalent on-chip memory accesses relates to
        // scratch pads in the Eyeriss architecture".
        let rows = model_network(&EyerissConfig::default(), &vgg16());
        let spad_share: f64 = rows.iter().map(|r| r.spad_share).sum::<f64>() / rows.len() as f64;
        assert!(spad_share > 0.85, "spad share = {spad_share:.2}");
    }

    #[test]
    fn modeled_alexnet_on_chip_within_2x_of_published() {
        // The published AlexNet on-chip column implies only ~2.2 spad
        // accesses/MAC vs VGG-16's 4.0 — the JSSC AlexNet mapping is more
        // spad-efficient than its VGG-16 mapping. We keep the structural
        // 4/MAC model and document the gap (EXPERIMENTS.md): the ordering
        // TrIM < Eyeriss is unaffected (our over-estimate is conservative
        // *against* the comparison the paper favours... i.e. favours
        // TrIM; the published columns are what the report prints).
        let rows = model_network(&EyerissConfig::default(), &alexnet());
        let on: f64 = rows.iter().map(|r| r.on_chip_m).sum();
        let ratio = on / PUBLISHED_ALEXNET_TOTAL.on_chip_m;
        assert!(ratio > 1.0 && ratio < 2.0, "modeled {on:.1} vs published {} (×{ratio:.2})", PUBLISHED_ALEXNET_TOTAL.on_chip_m);
    }
}
