//! Analytical models of the paper's evaluation section.
//!
//! * [`energy`] — access-energy constants and the on-chip/off-chip
//!   normalisation used by Tables I–II (footnote b).
//! * [`trim_model`] — TrIM per-layer metrics: eq. (1)–(2) timing via the
//!   control plan, plus the memory-access model (off-chip ifmap/weight/
//!   ofmap streams, on-chip psum-buffer traffic).
//! * [`eyeriss`] — the Eyeriss row-stationary baseline: published JSSC'17
//!   measurement columns (what the paper compares against) plus our
//!   structural access model with documented calibration.
//! * [`ws_gemm`] — weight-stationary GeMM (TPU-style im2col) baseline for
//!   the dataflow ablation (the predecessor paper's 10× claim).
//! * [`design_space`] — the Fig. 7 sweep (throughput, psum-buffer size,
//!   I/O bandwidth over the (P_N, P_M) grid).
//! * [`extensions`] — the paper's §VI future-work features (RSRB
//!   sharing, ifmap tiling, ifmap/weight global buffer) as quantifiable
//!   extensions with an ablation bench.
//! * [`fpga`] — the Table III FPGA cost model (LUT/FF/BRAM/power) and the
//!   published comparison rows.
//! * [`ops`] — Fig. 1 (per-layer memory and operation profile).

pub mod design_space;
pub mod extensions;
pub mod energy;
pub mod eyeriss;
pub mod fpga;
pub mod ops;
pub mod trim_model;
pub mod ws_gemm;

pub use energy::EnergyModel;
pub use trim_model::{LayerMetrics, NetworkMetrics};
