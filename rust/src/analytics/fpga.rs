//! FPGA cost model (Table III) — resource/power estimates from the
//! architecture parameters, calibrated to the paper's reported
//! implementation, plus the published comparison rows.
//!
//! The paper implements MACs in LUTs (0 DSPs — 8-bit operands don't need
//! 48-bit DSP slices). We decompose the reported totals into per-unit
//! costs so the model scales with (K, P_M, P_N, W_IM):
//!
//! * PE: an 8×8→16 LUT multiplier (~40 LUTs), a ~20-bit add (~20 LUTs),
//!   4 registers (~57 FFs incl. width growth);
//! * RSRB: `W_IM` B-bit shift registers → SRL-packed LUTs + mux;
//! * adder trees: (fan_in−1) adders of growing width;
//! * psum buffers: eq. (3) bits of BRAM;
//! * power: calibrated W per GOPs/s of active logic + clock tree share.

use crate::arch::ArchConfig;

/// Modelled FPGA implementation costs.
#[derive(Debug, Clone, Copy)]
pub struct FpgaCost {
    pub luts: f64,
    pub ffs: f64,
    pub dsps: u32,
    pub bram_mbit: f64,
    pub f_clk_mhz: f64,
    pub peak_gops: f64,
    pub power_w: f64,
}

impl FpgaCost {
    pub fn efficiency_gops_per_w(&self) -> f64 {
        self.peak_gops / self.power_w
    }
}

/// Per-unit cost coefficients (calibrated against the paper's engine).
#[derive(Debug, Clone, Copy)]
pub struct CostCoefficients {
    pub lut_per_pe: f64,
    pub ff_per_pe: f64,
    pub lut_per_rsrb_stage: f64,
    pub lut_per_tree_add: f64,
    pub ff_per_tree_stage_bit: f64,
    /// Dynamic power per GOPs/s of peak compute (computation + movement).
    pub w_per_gops: f64,
    /// Clock-tree + BRAM share of total power (paper: 10 % + 4 %).
    pub static_share: f64,
}

impl Default for CostCoefficients {
    fn default() -> Self {
        Self {
            // 8×8 LUT multiplier (~70) + 20-bit add (~20) + input muxes
            lut_per_pe: 105.0,
            // input(8) + weight(8) + psum(~20) + pass(8) registers
            ff_per_pe: 44.0,
            // SRL32 packing: a 226-deep 8-bit line ≈ 64 LUTs → ~0.3/stage
            lut_per_rsrb_stage: 0.30,
            lut_per_tree_add: 24.0,
            ff_per_tree_stage_bit: 1.0,
            w_per_gops: 0.00820,
            static_share: 0.14,
        }
    }
}

/// Estimate the FPGA cost of a TrIM engine configuration.
pub fn estimate(cfg: &ArchConfig, coef: &CostCoefficients) -> FpgaCost {
    let pes = cfg.total_pes() as f64;
    let slices = (cfg.p_n * cfg.p_m) as f64;

    // PEs
    let mut luts = pes * coef.lut_per_pe;
    let mut ffs = pes * coef.ff_per_pe;

    // RSRBs: (K−1) per slice, W_IM stages each (SRL-packed) + tap mux.
    let rsrb_stages = slices * (cfg.k as f64 - 1.0) * cfg.w_im as f64;
    luts += rsrb_stages * coef.lut_per_rsrb_stage;
    ffs += slices * (cfg.k as f64 - 1.0) * 24.0; // SB boundary registers

    // Slice adder trees: (K−1) adds each; core trees: (P_M−1) adds each;
    // engine accumulators: P_N adds.
    let tree_adds = slices * (cfg.k as f64 - 1.0)
        + cfg.p_n as f64 * (cfg.p_m as f64 - 1.0)
        + cfg.p_n as f64;
    luts += tree_adds * coef.lut_per_tree_add;
    ffs += tree_adds * 26.0 * coef.ff_per_tree_stage_bit; // pipeline regs

    let peak_gops = cfg.peak_ops_per_s() / 1e9;
    let power = peak_gops * coef.w_per_gops / (1.0 - coef.static_share);

    FpgaCost {
        luts,
        ffs,
        dsps: 0, // LUT-based MACs, as in the paper
        bram_mbit: cfg.psum_buffer_bits() as f64 / 1e6 * 0.91, // utilised share
        f_clk_mhz: cfg.f_clk / 1e6,
        peak_gops,
        power_w: power,
    }
}

/// A published Table III row.
#[derive(Debug, Clone, Copy)]
pub struct PublishedImpl {
    pub label: &'static str,
    pub device: &'static str,
    pub precision_bits: u32,
    pub pes: u32,
    pub dataflow: &'static str,
    pub luts: f64,
    pub ffs: Option<f64>,
    pub dsps: u32,
    pub bram_mbit: Option<f64>,
    pub f_clk_mhz: f64,
    pub peak_gops: f64,
    pub power_w: f64,
}

impl PublishedImpl {
    pub fn efficiency_gops_per_w(&self) -> f64 {
        self.peak_gops / self.power_w
    }
}

/// Table III, published rows (competitors + the paper's own TrIM column).
pub const PUBLISHED_TABLE3: [PublishedImpl; 4] = [
    PublishedImpl {
        label: "Sense (TVLSI'23) [25]",
        device: "XCZU9EG",
        precision_bits: 16,
        pes: 1024,
        dataflow: "OS,WS",
        luts: 348_000.0,
        ffs: None,
        dsps: 1061,
        bram_mbit: Some(8.82),
        f_clk_mhz: 200.0,
        peak_gops: 409.6,
        power_w: 11.0,
    },
    PublishedImpl {
        label: "TCAS-I'24 [21]",
        device: "XCZU3EG",
        precision_bits: 8,
        pes: 256,
        dataflow: "WS",
        luts: 40_780.0,
        ffs: Some(45_250.0),
        dsps: 257,
        bram_mbit: Some(4.15),
        f_clk_mhz: 150.0,
        peak_gops: 76.8,
        power_w: 1.398,
    },
    PublishedImpl {
        label: "TCAS-II'24 [24]",
        device: "XCVX690T",
        precision_bits: 16,
        pes: 243,
        dataflow: "RS",
        luts: 107_170.0,
        ffs: Some(34_450.0),
        dsps: 7,
        bram_mbit: None,
        f_clk_mhz: 150.0,
        peak_gops: 72.9,
        power_w: 8.25,
    },
    PublishedImpl {
        label: "TrIM (this work)",
        device: "XCZU7EV",
        precision_bits: 8,
        pes: 1512,
        dataflow: "TrIM",
        luts: 194_350.0,
        ffs: Some(89_720.0),
        dsps: 0,
        bram_mbit: Some(10.21),
        f_clk_mhz: 150.0,
        peak_gops: 453.6,
        power_w: 4.329,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    fn paper() -> FpgaCost {
        estimate(&ArchConfig::paper_engine(), &CostCoefficients::default())
    }

    #[test]
    fn model_matches_reported_resources_within_10pct() {
        let c = paper();
        let reported = &PUBLISHED_TABLE3[3];
        assert!((c.luts - reported.luts).abs() / reported.luts < 0.10, "LUTs = {:.0}", c.luts);
        assert!((c.ffs - reported.ffs.unwrap()).abs() / reported.ffs.unwrap() < 0.15, "FFs = {:.0}", c.ffs);
        assert!((c.bram_mbit - 10.21).abs() / 10.21 < 0.05, "BRAM = {:.2}", c.bram_mbit);
        assert_eq!(c.dsps, 0);
    }

    #[test]
    fn model_matches_reported_power_and_efficiency() {
        let c = paper();
        assert!((c.power_w - 4.329).abs() / 4.329 < 0.05, "power = {:.2} W", c.power_w);
        assert!((c.peak_gops - 453.6).abs() < 1e-6);
        assert!((c.efficiency_gops_per_w() - 104.78).abs() / 104.78 < 0.06, "eff = {:.1}", c.efficiency_gops_per_w());
    }

    #[test]
    fn trim_wins_energy_efficiency_in_table3() {
        // §V: "the best energy efficiency among state-of-the-art FPGA
        // counterparts", up to ~11.9× vs [24].
        let trim = PUBLISHED_TABLE3[3].efficiency_gops_per_w();
        for other in &PUBLISHED_TABLE3[..3] {
            assert!(trim > other.efficiency_gops_per_w(), "{}", other.label);
        }
        let ratio = trim / PUBLISHED_TABLE3[2].efficiency_gops_per_w();
        assert!((ratio - 11.9).abs() < 0.2, "vs [24] = {ratio:.1}×");
        let vs_sense = trim / PUBLISHED_TABLE3[0].efficiency_gops_per_w();
        assert!((vs_sense - 2.8).abs() < 0.3, "vs Sense ≈ 3× (paper: ~3×), got {vs_sense:.1}");
        let vs_ws = trim / PUBLISHED_TABLE3[1].efficiency_gops_per_w();
        assert!((vs_ws - 1.9).abs() < 0.2, "vs [21] ≈ 1.9×, got {vs_ws:.1}");
    }

    #[test]
    fn cost_scales_with_parallelism() {
        let coef = CostCoefficients::default();
        let small = estimate(&ArchConfig { p_n: 2, p_m: 4, ..ArchConfig::paper_engine() }, &coef);
        let big = paper();
        assert!(big.luts > small.luts * 10.0);
        assert!(big.power_w > small.power_w * 10.0);
    }
}
