//! Thin std-only HTTP/JSON ingress over the [`Router`] — the production
//! front door's network face, hand-rolled on `std::net::TcpListener` so
//! serving needs **zero** new dependencies.
//!
//! Endpoints (`trim serve --http PORT`):
//!
//! * `POST /infer` — body `{"image":[i32,…],"deadline_ms":N,"client":"id"}`
//!   (`deadline_ms` and `client` optional; `client` keys the per-client
//!   quota bucket when the server runs with `--client-rps`). Replies
//!   `200` with
//!   `{"id","class","logits","latency_us","batch_size","deadline_slack_us"}`,
//!   or the typed [`ServeError`] mapped onto HTTP: `429 Too Many
//!   Requests` + `Retry-After` for `Overloaded`, `504` for
//!   `DeadlineExceeded`, `500` for `EngineFailed`, `503` for `Shutdown`.
//! * `GET /metrics` — the Prometheus text exposition of the merged
//!   [`MetricsSnapshot`](super::MetricsSnapshot).
//! * `GET /healthz` — `200 ok` while admitting, `503 draining` once a
//!   drain has begun (load balancers stop sending traffic before the
//!   drain deadline rejects it). A fleet serving at degraded capacity —
//!   quarantined engines after ABFT-detected faults — stays `200` (it
//!   still answers correctly) but reports `degraded` with the quarantine
//!   count so operators see the lost capacity.
//!
//! Deliberately minimal: HTTP/1.1 with `Connection: close`, one request
//! per connection, a detached thread per connection (connections are
//! short-lived and bounded by a read timeout), and a hand-rolled JSON
//! field scanner rather than a parser — enough for the serving API and
//! for `curl`, not a general web server.
//!
//! Slowloris guard: every connection carries a read **and** write
//! timeout, the header block is capped at [`MAX_HEADER_BYTES`] and the
//! body at [`MAX_BODY_BYTES`] — a client that trickles one byte and
//! stalls gets a typed `408 Request Timeout`, an oversized request a
//! `413 Payload Too Large`, and its thread is freed either way instead
//! of being held open indefinitely.

use super::error::ServeError;
use super::router::Router;
use crate::util::sync::{AtomicBool, Ordering};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest accepted request body (a flat int32 image as JSON text).
const MAX_BODY_BYTES: usize = 4 << 20;
/// Largest accepted header block (request line + all headers): nobody
/// needs more than this to call `/infer`, and an unbounded header loop
/// is a slowloris drip-feed target.
const MAX_HEADER_BYTES: usize = 8 << 10;
/// Per-connection read timeout: a stalled client frees its thread.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Per-connection write timeout: a client that stops draining its
/// response cannot pin the connection thread either.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// The running HTTP ingress; dropping it (or calling
/// [`HttpServer::stop`]) stops accepting. In-flight connection threads
/// finish their one request on their own.
pub struct HttpServer {
    addr: SocketAddr,
    running: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `127.0.0.1:port` (`port` 0 picks a free port — see
    /// [`HttpServer::local_addr`]) and start the accept thread.
    pub fn start(port: u16, router: Arc<Router>) -> Result<Self> {
        Self::start_with_read_timeout(port, router, READ_TIMEOUT)
    }

    /// [`HttpServer::start`] with an explicit per-connection read
    /// timeout (tests shrink it to exercise the slowloris guard without
    /// waiting out the production ten seconds).
    pub fn start_with_read_timeout(
        port: u16,
        router: Arc<Router>,
        read_timeout: Duration,
    ) -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding HTTP ingress on 127.0.0.1:{port}"))?;
        let addr = listener.local_addr()?;
        let running = Arc::new(AtomicBool::new(true));
        let accept_running = running.clone();
        let accept = std::thread::Builder::new()
            .name("trim-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if !accept_running.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let router = router.clone();
                    let _ = std::thread::Builder::new()
                        .name("trim-http-conn".into())
                        .spawn(move || handle_connection(stream, &router, read_timeout));
                }
            })
            .context("spawning HTTP accept thread")?;
        Ok(Self { addr, running, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept thread
    /// (idempotent). Does not touch the router — pair with
    /// [`Router::drain`] for a full graceful shutdown.
    pub fn stop(&mut self) {
        if !self.running.swap(false, Ordering::AcqRel) {
            return;
        }
        // Poke the blocking accept() awake so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// Typed ingress read failure, mapped onto HTTP by
/// [`handle_connection`]: the slowloris guard's verdicts.
enum ReadError {
    /// Headers or body exceed the fixed caps → `413 Payload Too Large`.
    TooLarge(String),
    /// The client stalled past the read timeout → `408 Request Timeout`.
    TimedOut(String),
    /// Anything else unparseable → `400 Bad Request`.
    Malformed(String),
}

impl ReadError {
    /// Classify an I/O failure: timeout kinds (Unix reports a read
    /// timeout as `WouldBlock`, Windows as `TimedOut`) become the typed
    /// stall verdict, everything else is a malformed request.
    fn from_io(e: std::io::Error, what: &str) -> Self {
        match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                Self::TimedOut(format!("client stalled while {what}"))
            }
            kind => Self::Malformed(format!("{what}: {kind}")),
        }
    }

    fn into_response(self) -> (u16, &'static str, Option<String>, String) {
        let (status, kind, detail) = match self {
            Self::TimedOut(d) => (408, "request_timeout", d),
            Self::TooLarge(d) => (413, "payload_too_large", d),
            Self::Malformed(d) => (400, "bad_request", d),
        };
        (status, "application/json", None, json_error(kind, &detail))
    }
}

fn handle_connection(stream: TcpStream, router: &Router, read_timeout: Duration) {
    let _ = stream.set_read_timeout(Some(read_timeout));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = BufReader::new(stream);
    let (status, content_type, extra_header, body) = match read_request(&mut reader) {
        Ok(req) => route(router, &req),
        Err(e) => e.into_response(),
    };
    let mut stream = reader.into_inner();
    let _ = write_response(&mut stream, status, content_type, extra_header.as_deref(), &body);
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request, ReadError> {
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| ReadError::from_io(e, "reading request line"))?;
    if line.len() > MAX_HEADER_BYTES {
        return Err(ReadError::TooLarge(format!("request line of {} bytes", line.len())));
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("request line missing path".into()))?
        .to_string();
    let mut content_length = 0usize;
    let mut header_bytes = line.len();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).map_err(|e| ReadError::from_io(e, "reading header"))?;
        if h.is_empty() {
            // EOF before the blank line that ends the header block.
            return Err(ReadError::Malformed("connection closed mid-headers".into()));
        }
        header_bytes += h.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Err(ReadError::TooLarge(format!(
                "header block exceeds {MAX_HEADER_BYTES} bytes"
            )));
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length =
                v.trim().parse().map_err(|_| ReadError::Malformed("bad Content-Length".into()))?;
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge(format!(
            "body of {content_length} bytes (cap {MAX_BODY_BYTES})"
        )));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| ReadError::from_io(e, "reading body"))?;
    Ok(Request { method, path, body })
}

fn route(router: &Router, req: &Request) -> (u16, &'static str, Option<String>, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if router.is_draining() {
                (503, "text/plain", None, "draining\n".into())
            } else {
                let fault = router.metrics().fault;
                if fault.quarantined > 0 || fault.timing_quarantined > 0 {
                    // Degraded ≠ down: quarantined engines cost capacity,
                    // never correctness, so the fleet keeps taking traffic.
                    let mut line = format!("degraded quarantined={}", fault.quarantined);
                    if fault.timing_quarantined > 0 {
                        line.push_str(&format!(
                            " timing_quarantined={}",
                            fault.timing_quarantined
                        ));
                    }
                    line.push('\n');
                    (200, "text/plain", None, line)
                } else {
                    (200, "text/plain", None, "ok\n".into())
                }
            }
        }
        ("GET", "/metrics") => {
            (200, "text/plain; version=0.0.4", None, router.metrics().render_prometheus())
        }
        ("POST", "/infer") => infer(router, &req.body),
        ("GET" | "PUT" | "DELETE" | "HEAD", "/infer") => (
            405,
            "application/json",
            None,
            json_error("method_not_allowed", "use POST /infer"),
        ),
        _ => (404, "application/json", None, json_error("not_found", &req.path)),
    }
}

fn infer(router: &Router, body: &[u8]) -> (u16, &'static str, Option<String>, String) {
    let bad = |detail: &str| (400, "application/json", None, json_error("bad_request", detail));
    let Ok(text) = std::str::from_utf8(body) else { return bad("body is not UTF-8") };
    let (image, deadline_ms, client) = match parse_infer_body(text) {
        Ok(p) => p,
        Err(e) => return bad(&format!("{e:#}")),
    };
    let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
    match router.submit_for(image, deadline, client).and_then(|mut r| r.recv()) {
        Ok(resp) => {
            let logits =
                resp.logits.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");
            let class = resp.class.map_or("null".to_string(), |c| c.to_string());
            let slack = resp
                .deadline_slack
                .map_or("null".to_string(), |s| s.as_micros().to_string());
            (
                200,
                "application/json",
                None,
                format!(
                    "{{\"id\":{},\"class\":{class},\"logits\":[{logits}],\"latency_us\":{},\
                     \"batch_size\":{},\"deadline_slack_us\":{slack}}}\n",
                    resp.id,
                    resp.latency.as_micros(),
                    resp.batch_size,
                ),
            )
        }
        Err(e) => match e.downcast_ref::<ServeError>() {
            Some(se @ ServeError::Overloaded { retry_after }) => {
                let secs = retry_after.as_secs_f64().ceil().max(1.0) as u64;
                (
                    429,
                    "application/json",
                    Some(format!("Retry-After: {secs}")),
                    json_error(se.kind(), &se.to_string()),
                )
            }
            Some(se @ ServeError::DeadlineExceeded { .. }) => {
                (504, "application/json", None, json_error(se.kind(), &se.to_string()))
            }
            Some(se @ ServeError::Shutdown) => {
                (503, "application/json", None, json_error(se.kind(), &se.to_string()))
            }
            Some(se @ ServeError::EngineFailed { .. }) => {
                (500, "application/json", None, json_error(se.kind(), &se.to_string()))
            }
            // Untyped errors are submit-side validation (wrong image size).
            None => bad(&format!("{e:#}")),
        },
    }
}

/// Scan the fields the ingress accepts out of a JSON body:
/// `"image":[i32,…]` (required), `"deadline_ms":N` and `"client":"id"`
/// (optional).
fn parse_infer_body(s: &str) -> Result<(Vec<i32>, Option<u64>, Option<String>)> {
    let key = "\"image\"";
    let at = s.find(key).context("missing \"image\" field")?;
    let rest = &s[at + key.len()..];
    let open = rest.find('[').context("\"image\" is not an array")?;
    let close = rest[open..].find(']').context("unterminated \"image\" array")? + open;
    let mut image = Vec::new();
    for tok in rest[open + 1..close].split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        image.push(tok.parse::<i32>().with_context(|| format!("bad image element {tok:?}"))?);
    }
    let deadline_ms = match s.find("\"deadline_ms\"") {
        None => None,
        Some(at) => {
            let rest = &s[at + "\"deadline_ms\"".len()..];
            let colon = rest.find(':').context("malformed \"deadline_ms\"")?;
            let num: String = rest[colon + 1..]
                .chars()
                .skip_while(|c| c.is_whitespace())
                .take_while(|c| c.is_ascii_digit())
                .collect();
            anyhow::ensure!(!num.is_empty(), "\"deadline_ms\" is not a nonnegative integer");
            Some(num.parse::<u64>().context("\"deadline_ms\" out of range")?)
        }
    };
    let client = match s.find("\"client\"") {
        None => None,
        Some(at) => {
            let rest = &s[at + "\"client\"".len()..];
            let colon = rest.find(':').context("malformed \"client\"")?;
            let rest = rest[colon + 1..].trim_start();
            let inner = rest.strip_prefix('"').context("\"client\" is not a string")?;
            let end = inner.find('"').context("unterminated \"client\" string")?;
            Some(inner[..end].to_string())
        }
    };
    Ok((image, deadline_ms, client))
}

fn json_error(kind: &str, detail: &str) -> String {
    format!("{{\"error\":\"{kind}\",\"detail\":\"{}\"}}\n", json_escape(detail))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_header: Option<&str>,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    };
    let extra = extra_header.map(|h| format!("{h}\r\n")).unwrap_or_default();
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n{extra}\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{InferenceBackend, MockBackend};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::coordinator::{Coordinator, CoordinatorConfig};

    fn mock_router() -> Arc<Router> {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        let c = Coordinator::start_with(
            || Ok(Box::new(MockBackend::new(4, 3)) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap();
        Arc::new(Router::new(vec![c]).unwrap())
    }

    /// Fire one raw HTTP request and return the full response text
    /// (the server closes the connection after one exchange).
    fn send(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post_infer(addr: SocketAddr, body: &str) -> String {
        send(
            addr,
            &format!(
                "POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    fn status_of(resp: &str) -> u16 {
        resp.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0)
    }

    #[test]
    fn serves_healthz_metrics_and_infer() {
        let router = mock_router();
        let server = HttpServer::start(0, router.clone()).unwrap();
        let addr = server.local_addr();

        let health = send(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&health), 200);
        assert!(health.contains("ok"), "got {health}");

        let probe = MockBackend::new(4, 3);
        let infer = post_infer(addr, "{\"image\":[1,2,3,4]}");
        assert_eq!(status_of(&infer), 200, "got {infer}");
        let want = probe.expected_logits(&[1, 2, 3, 4]);
        let want_logits = format!(
            "\"logits\":[{}]",
            want.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
        );
        assert!(infer.contains(&want_logits), "got {infer}, want {want_logits}");
        assert!(infer.contains("\"class\":"), "got {infer}");

        let metrics = send(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&metrics), 200);
        assert!(metrics.contains("trim_requests_total"), "got {metrics}");
        assert!(metrics.contains("trim_shed_total"), "new shed counter exposed: {metrics}");
    }

    #[test]
    fn maps_client_errors_onto_http_statuses() {
        let router = mock_router();
        let server = HttpServer::start(0, router.clone()).unwrap();
        let addr = server.local_addr();

        let missing = send(addr, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&missing), 404);

        let wrong_method = send(addr, "GET /infer HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&wrong_method), 405);

        let bad_json = post_infer(addr, "{\"picture\":[1]}");
        assert_eq!(status_of(&bad_json), 400, "got {bad_json}");
        assert!(bad_json.contains("image"), "names the missing field: {bad_json}");

        let wrong_len = post_infer(addr, "{\"image\":[1,2]}");
        assert_eq!(status_of(&wrong_len), 400, "got {wrong_len}");

        // A deadline of zero is expired on arrival → typed 504.
        let expired = post_infer(addr, "{\"image\":[1,2,3,4],\"deadline_ms\":0}");
        assert_eq!(status_of(&expired), 504, "got {expired}");
        assert!(expired.contains("deadline_exceeded"), "got {expired}");
    }

    #[test]
    fn drain_surfaces_as_unhealthy_and_shutdown() {
        let router = mock_router();
        let server = HttpServer::start(0, router.clone()).unwrap();
        let addr = server.local_addr();
        router.drain(Duration::from_secs(1));

        let health = send(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&health), 503);
        assert!(health.contains("draining"), "got {health}");

        let infer = post_infer(addr, "{\"image\":[1,2,3,4]}");
        assert_eq!(status_of(&infer), 503, "got {infer}");
        assert!(infer.contains("shutdown"), "got {infer}");
    }

    #[test]
    fn per_client_quota_maps_to_429_with_retry_after() {
        use crate::coordinator::admission::AdmissionConfig;
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig {
                // burst of one token, slow refill: the second request from
                // the same client inside the window must shed
                client_rps: Some(0.5),
                ..Default::default()
            },
        };
        let c = Coordinator::start_with(
            || Ok(Box::new(MockBackend::new(4, 3)) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap();
        let router = Arc::new(Router::new(vec![c]).unwrap());
        let server = HttpServer::start(0, router).unwrap();
        let addr = server.local_addr();

        let ok = post_infer(addr, "{\"image\":[1,2,3,4],\"client\":\"hog\"}");
        assert_eq!(status_of(&ok), 200, "first request spends the burst token: {ok}");
        let shed = post_infer(addr, "{\"image\":[1,2,3,4],\"client\":\"hog\"}");
        assert_eq!(status_of(&shed), 429, "over-quota client sheds: {shed}");
        assert!(shed.contains("Retry-After:"), "hints when to come back: {shed}");
        let other = post_infer(addr, "{\"image\":[1,2,3,4],\"client\":\"quiet\"}");
        assert_eq!(status_of(&other), 200, "quotas are per client: {other}");
    }

    #[test]
    fn degraded_fleet_reports_quarantine_but_keeps_serving() {
        use crate::analytics::EnergyModel;
        use crate::arch::SimStats;
        use crate::coordinator::backend::{BatchCost, BatchReport};
        use crate::fault::FaultReport;

        /// Answers like the mock but reports one quarantined engine per
        /// batch — the shape a self-healed chaos farm presents.
        struct DegradedBackend(MockBackend);
        impl InferenceBackend for DegradedBackend {
            fn input_len(&self) -> usize {
                self.0.input_len()
            }
            fn infer_batch(&mut self, images: &[&[i32]]) -> anyhow::Result<BatchReport> {
                let outputs =
                    images.iter().map(|img| self.0.expected_logits(img)).collect();
                let stats = SimStats { cycles: 100, macs: 100, ..Default::default() };
                let cost = BatchCost::from_stats(stats, 150.0e6, &EnergyModel::paper())
                    .with_faults(FaultReport {
                        injected: 2,
                        detected: 2,
                        corrected: 1,
                        reexecuted: 2,
                        quarantined: 1,
                        ..FaultReport::default()
                    });
                Ok(BatchReport::with_cost(outputs, cost))
            }
            fn describe(&self) -> String {
                "degraded-mock".into()
            }
        }

        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        let c = Coordinator::start_with(
            || Ok(Box::new(DegradedBackend(MockBackend::new(4, 3))) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap();
        let router = Arc::new(Router::new(vec![c]).unwrap());
        let server = HttpServer::start(0, router.clone()).unwrap();
        let addr = server.local_addr();

        let fresh = send(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&fresh), 200);
        assert!(fresh.contains("ok"), "nothing quarantined yet: {fresh}");

        let infer = post_infer(addr, "{\"image\":[1,2,3,4]}");
        assert_eq!(status_of(&infer), 200, "degraded farm still answers: {infer}");

        let health = send(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&health), 200, "degraded is not down: {health}");
        assert!(health.contains("degraded quarantined=1"), "got {health}");

        let metrics = send(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(metrics.contains("trim_fault_quarantined_total 1"), "got {metrics}");
    }

    #[test]
    fn concurrent_infers_during_drain_see_only_typed_statuses() {
        /// Mock answers delayed by `delay` — holds the engine busy long
        /// enough for the drain to be observably in flight.
        struct SlowBackend(MockBackend, Duration);
        impl InferenceBackend for SlowBackend {
            fn input_len(&self) -> usize {
                self.0.input_len()
            }
            fn infer_batch(
                &mut self,
                images: &[&[i32]],
            ) -> anyhow::Result<crate::coordinator::backend::BatchReport> {
                std::thread::sleep(self.1);
                self.0.infer_batch(images)
            }
            fn describe(&self) -> String {
                "slow-mock".into()
            }
        }

        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        let delay = Duration::from_millis(400);
        let c = Coordinator::start_with(
            move || {
                Ok(Box::new(SlowBackend(MockBackend::new(4, 3), delay))
                    as Box<dyn InferenceBackend>)
            },
            cfg,
        )
        .unwrap();
        let router = Arc::new(Router::new(vec![c]).unwrap());
        let server = HttpServer::start(0, router.clone()).unwrap();
        let addr = server.local_addr();

        // One admitted-and-executing request keeps the engine (and thus
        // the drain) busy for ~400 ms.
        let pre_drain = std::thread::spawn(move || post_infer(addr, "{\"image\":[1,2,3,4]}"));
        std::thread::sleep(Duration::from_millis(100));
        let r = router.clone();
        let drainer = std::thread::spawn(move || r.drain(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(50));

        // The drain has begun but the farm has not finished joining:
        // /healthz must already steer load balancers away.
        assert!(router.is_draining());
        let health = send(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status_of(&health), 503, "draining before the last farm joins: {health}");
        assert!(health.contains("draining"), "got {health}");

        // Requests racing the drain must resolve as typed rejections —
        // never hang, never return a bogus 200.
        let racers: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || post_infer(addr, "{\"image\":[1,2,3,4]}")))
            .collect();
        for t in racers {
            let resp = t.join().unwrap();
            let status = status_of(&resp);
            assert!(
                matches!(status, 429 | 503 | 504),
                "in-drain requests see typed shed statuses only, got {status}: {resp}"
            );
        }

        drainer.join().unwrap();
        // The pre-drain request was admitted before the drain began: it
        // either completed (200) or was flushed into a typed rejection —
        // under CI scheduling it may also have lost the admission race.
        let first = pre_drain.join().unwrap();
        assert!(
            matches!(status_of(&first), 200 | 503 | 504),
            "pre-drain request resolves, never hangs: {first}"
        );
    }

    #[test]
    fn slowloris_one_byte_then_stall_gets_408() {
        // The classic drip-feed: open a connection, send a single byte,
        // then stall. The read timeout must fire, answer with a typed
        // 408, and free the connection thread — not hold it forever.
        let router = mock_router();
        let server =
            HttpServer::start_with_read_timeout(0, router, Duration::from_millis(200)).unwrap();
        let addr = server.local_addr();
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"P").unwrap();
        let t0 = Instant::now();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert_eq!(status_of(&out), 408, "stalled client gets a typed timeout: {out}");
        assert!(out.contains("request_timeout"), "got {out}");
        assert!(t0.elapsed() < Duration::from_secs(5), "the shortened timeout fired");
    }

    #[test]
    fn oversized_body_and_header_block_get_413() {
        let router = mock_router();
        let server = HttpServer::start(0, router).unwrap();
        let addr = server.local_addr();
        // A declared body beyond the cap is rejected before reading it.
        let huge = MAX_BODY_BYTES + 1;
        let resp = send(
            addr,
            &format!("POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: {huge}\r\n\r\n"),
        );
        assert_eq!(status_of(&resp), 413, "got {resp}");
        assert!(resp.contains("payload_too_large"), "got {resp}");
        // So is a header block past its own cap.
        let padding = "x".repeat(MAX_HEADER_BYTES);
        let resp = send(addr, &format!("GET /healthz HTTP/1.1\r\nX-Pad: {padding}\r\n\r\n"));
        assert_eq!(status_of(&resp), 413, "got {resp}");
        assert!(resp.contains("payload_too_large"), "got {resp}");
    }

    #[test]
    fn stop_is_idempotent_and_drops_cleanly() {
        let router = mock_router();
        let mut server = HttpServer::start(0, router).unwrap();
        server.stop();
        server.stop();
        drop(server); // second stop via Drop must not hang or panic
    }

    #[test]
    fn body_scanner_parses_and_rejects() {
        let (img, dl, cl) = parse_infer_body("{\"image\":[1, -2,3],\"deadline_ms\": 250}").unwrap();
        assert_eq!(img, vec![1, -2, 3]);
        assert_eq!(dl, Some(250));
        assert_eq!(cl, None);
        let (img, dl, cl) = parse_infer_body("{\"image\":[]}").unwrap();
        assert!(img.is_empty() && dl.is_none() && cl.is_none());
        let (_, _, cl) =
            parse_infer_body("{\"client\": \"tenant-a\", \"image\":[7]}").unwrap();
        assert_eq!(cl.as_deref(), Some("tenant-a"));
        assert!(parse_infer_body("{}").is_err(), "missing image");
        assert!(parse_infer_body("{\"image\":[1,x]}").is_err(), "non-integer element");
        assert!(parse_infer_body("{\"image\":[1],\"deadline_ms\":-5}").is_err(), "negative ms");
        assert!(parse_infer_body("{\"image\":[1").is_err(), "unterminated array");
        assert!(parse_infer_body("{\"image\":[1],\"client\":7}").is_err(), "non-string client");
        assert!(parse_infer_body("{\"image\":[1],\"client\":\"x").is_err(), "unterminated client");
    }
}
