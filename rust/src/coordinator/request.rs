//! Request/response types flowing through the coordinator.

use super::backend::SimCost;
use super::error::ServeResult;
use crate::obs;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A single inference request (one image).
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flat `C×H×W` int32 image (uint8 values carried as int32).
    pub image: Vec<i32>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: Instant,
    /// Absolute deadline by which the response must be produced; `None`
    /// means best-effort. The deadline-aware batcher rejects requests
    /// whose deadline cannot be met (`ServeError::DeadlineExceeded`) and
    /// closes batches early enough that the members it keeps still make
    /// theirs.
    pub deadline: Option<Instant>,
    /// Client identity for per-client quotas (`"client"` in the HTTP
    /// body, `--client-rps` on the CLI); `None` shares the anonymous
    /// quota bucket. Carried on the request so retries and metrics can
    /// attribute by client.
    pub client: Option<String>,
    /// The request's `serve.request` trace span, opened at admission and
    /// finished when the reply (or typed rejection) is sent — its
    /// duration is the request's end-to-end time inside the coordinator.
    pub span: obs::Span,
    /// Where the response goes: the logits, or a typed [`super::ServeError`].
    pub reply: mpsc::Sender<ServeResult>,
}

impl InferenceRequest {
    /// Remaining deadline budget at `now` (`None` = no deadline;
    /// `Some(ZERO)` = already expired).
    pub fn remaining_budget(&self, now: Instant) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(now))
    }
}

/// The completed inference.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Classifier logits (always non-empty: failures resolve as typed
    /// [`super::ServeError`]s now, never as an empty-logits sentinel).
    pub logits: Vec<i32>,
    /// argmax of the logits; `None` only for degenerate zero-class
    /// models, so failure is never mistaken for class 0.
    pub class: Option<usize>,
    /// Queue + execution latency.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Deadline slack when the reply was produced: how much budget was
    /// left (`None` when the request carried no deadline). Zero means
    /// the response landed exactly at — or technically past — the
    /// deadline but was already executing and so was delivered.
    pub deadline_slack: Option<Duration>,
    /// This request's attributed share of the batch's simulated execution
    /// cost; `None` for backends with no cost model (PJRT, mock).
    pub cost: Option<SimCost>,
}

impl InferenceResponse {
    pub fn from_logits(
        id: u64,
        logits: Vec<i32>,
        enqueued_at: Instant,
        deadline: Option<Instant>,
        batch_size: usize,
        cost: Option<SimCost>,
    ) -> Self {
        // first maximum wins (deterministic tie-break)
        let mut class = None;
        for (i, &v) in logits.iter().enumerate() {
            if class.map_or(true, |c: usize| v > logits[c]) {
                class = Some(i);
            }
        }
        let now = Instant::now();
        Self {
            id,
            logits,
            class,
            latency: now.saturating_duration_since(enqueued_at),
            batch_size,
            deadline_slack: deadline.map(|d| d.saturating_duration_since(now)),
            cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_class() {
        let r = InferenceResponse::from_logits(1, vec![3, 9, -2, 9], Instant::now(), None, 4, None);
        assert_eq!(r.class, Some(1)); // first max wins
        assert_eq!(r.batch_size, 4);
        assert!(r.cost.is_none());
        assert!(r.deadline_slack.is_none(), "no deadline → no slack");
    }

    #[test]
    fn empty_logits_have_no_class() {
        let r = InferenceResponse::from_logits(1, vec![], Instant::now(), None, 1, None);
        assert_eq!(r.class, None);
    }

    #[test]
    fn single_logit_is_class_zero() {
        let r = InferenceResponse::from_logits(1, vec![-7], Instant::now(), None, 1, None);
        assert_eq!(r.class, Some(0));
    }

    #[test]
    fn deadline_slack_propagates() {
        let soon = Instant::now() + Duration::from_secs(60);
        let r = InferenceResponse::from_logits(1, vec![1], Instant::now(), Some(soon), 1, None);
        let slack = r.deadline_slack.expect("deadline carried through");
        assert!(slack > Duration::from_secs(50), "fresh response keeps most of the budget");
        // an already-expired deadline saturates at zero, never panics
        let past = Instant::now() - Duration::from_secs(1);
        let r = InferenceResponse::from_logits(1, vec![1], Instant::now(), Some(past), 1, None);
        assert_eq!(r.deadline_slack, Some(Duration::ZERO));
    }

    #[test]
    fn remaining_budget_saturates() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let req = InferenceRequest {
            id: 0,
            image: vec![],
            enqueued_at: now,
            deadline: Some(now + Duration::from_millis(5)),
            client: None,
            span: obs::tracer().begin("serve.request", 0),
            reply: tx,
        };
        assert!(req.remaining_budget(now).unwrap() > Duration::ZERO);
        assert_eq!(
            req.remaining_budget(now + Duration::from_secs(1)),
            Some(Duration::ZERO),
            "expired budget saturates at zero"
        );
        obs::tracer().finish(req.span);
    }
}
