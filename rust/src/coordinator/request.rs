//! Request/response types flowing through the coordinator.

use std::sync::mpsc;
use std::time::Instant;

/// A single inference request (one image).
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flat `C×H×W` int32 image (uint8 values carried as int32).
    pub image: Vec<i32>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: Instant,
    /// Where the response goes.
    pub reply: mpsc::Sender<InferenceResponse>,
}

/// The completed inference.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Classifier logits.
    pub logits: Vec<i32>,
    /// argmax of the logits.
    pub class: usize,
    /// Queue + execution latency.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

impl InferenceResponse {
    pub fn from_logits(id: u64, logits: Vec<i32>, enqueued_at: Instant, batch_size: usize) -> Self {
        // first maximum wins (deterministic tie-break)
        let mut class = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[class] {
                class = i;
            }
        }
        Self { id, logits, class, latency: enqueued_at.elapsed(), batch_size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_class() {
        let r = InferenceResponse::from_logits(1, vec![3, 9, -2, 9], Instant::now(), 4);
        assert_eq!(r.class, 1); // first max wins
        assert_eq!(r.batch_size, 4);
    }

    #[test]
    fn empty_logits_class_zero() {
        let r = InferenceResponse::from_logits(1, vec![], Instant::now(), 1);
        assert_eq!(r.class, 0);
    }
}
