//! Request/response types flowing through the coordinator.

use super::backend::SimCost;
use crate::obs;
use std::sync::mpsc;
use std::time::Instant;

/// A single inference request (one image).
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flat `C×H×W` int32 image (uint8 values carried as int32).
    pub image: Vec<i32>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: Instant,
    /// The request's `serve.request` trace span, opened at admission and
    /// finished by the engine loop when the reply is sent — its duration
    /// is the request's end-to-end time inside the coordinator.
    pub span: obs::Span,
    /// Where the response goes.
    pub reply: mpsc::Sender<InferenceResponse>,
}

/// The completed inference.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Classifier logits. Empty when the backend failed the batch.
    pub logits: Vec<i32>,
    /// argmax of the logits; `None` when there are no logits (failed
    /// batch), so failure is never mistaken for class 0.
    pub class: Option<usize>,
    /// Queue + execution latency.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// This request's attributed share of the batch's simulated execution
    /// cost; `None` for backends with no cost model (PJRT, mock).
    pub cost: Option<SimCost>,
}

impl InferenceResponse {
    pub fn from_logits(
        id: u64,
        logits: Vec<i32>,
        enqueued_at: Instant,
        batch_size: usize,
        cost: Option<SimCost>,
    ) -> Self {
        // first maximum wins (deterministic tie-break)
        let mut class = None;
        for (i, &v) in logits.iter().enumerate() {
            if class.map_or(true, |c: usize| v > logits[c]) {
                class = Some(i);
            }
        }
        Self { id, logits, class, latency: enqueued_at.elapsed(), batch_size, cost }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_class() {
        let r = InferenceResponse::from_logits(1, vec![3, 9, -2, 9], Instant::now(), 4, None);
        assert_eq!(r.class, Some(1)); // first max wins
        assert_eq!(r.batch_size, 4);
        assert!(r.cost.is_none());
    }

    #[test]
    fn empty_logits_have_no_class() {
        let r = InferenceResponse::from_logits(1, vec![], Instant::now(), 1, None);
        assert_eq!(r.class, None);
    }

    #[test]
    fn single_logit_is_class_zero() {
        let r = InferenceResponse::from_logits(1, vec![-7], Instant::now(), 1, None);
        assert_eq!(r.class, Some(0));
    }
}
