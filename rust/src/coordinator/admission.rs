//! Admission control: the token/cost-based policy behind the bounded
//! ingress.
//!
//! The TrIM analytical cost model (the closed-form eq. (2) cycles the
//! fast tier synthesizes per batch) gives the serving layer something a
//! production front door rarely has: an *exact* per-request cost signal.
//! [`AdmissionControl`] keeps an EWMA of that signal (simulated cycles
//! per request, the same statistic the router's dispatch EWMA tracks) and
//! an EWMA of the wall-clock service time per batch, and admits a request
//! only while
//!
//! ```text
//! depth < queue_cap                       (bounded ingress)
//! (depth + 1) × ewma_cycles ≤ budget      (cost budget, when configured)
//! ```
//!
//! where `depth` is the number of admitted-but-not-yet-executing
//! requests. Past either bound the request is shed with
//! [`ServeError::Overloaded`] carrying a `retry_after` hint of
//! `depth × EWMA service time` — the expected time for the queue ahead to
//! clear. Shedding is **synchronous** at submit: the caller learns
//! immediately, nothing unbounded queues behind the scenes.
//!
//! The same struct carries the drain state ([`AdmissionControl::begin_drain`]):
//! draining closes admission (submits fail with [`ServeError::Shutdown`])
//! and arms a deadline after which the engine loop rejects, rather than
//! executes, whatever is still queued.

use super::error::ServeError;
use crate::util::sync::{
    lock_unpoisoned, AtomicBool, AtomicU64, AtomicUsize, Mutex, Ordering,
};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// EWMA smoothing factor (`new = old + α·(x − old)`) shared by the
/// admission cost/service estimators and the router's dispatch EWMA:
/// small enough to ride out batch-size noise, large enough that the
/// first few observations dominate a cold start.
pub const EWMA_ALPHA: f64 = 0.25;

/// Lock-free EWMA of a nonnegative signal; the f64 is stored as bits,
/// `None` until the first observation. [`Ewma::reset`] returns it to the
/// unobserved state — the router uses this to mark a failing farm cold.
#[derive(Debug, Default)]
pub struct Ewma(AtomicU64);

impl Ewma {
    const UNSET: u64 = 0;

    pub fn get(&self) -> Option<f64> {
        match self.0.load(Ordering::Acquire) {
            Self::UNSET => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    /// Fold one observation in. Races between concurrent observers may
    /// drop an update; the EWMA is a heuristic, so last-writer-wins is
    /// fine. Samples clamp at ≥ 1 so the stored bits never collide with
    /// the `UNSET` sentinel.
    pub fn observe(&self, sample: f64) {
        let next = match self.get() {
            None => sample,
            Some(old) => old + EWMA_ALPHA * (sample - old),
        };
        self.0.store(f64::to_bits(next.max(1.0)), Ordering::Release);
    }

    /// Forget everything: back to the unobserved state.
    pub fn reset(&self) {
        self.0.store(Self::UNSET, Ordering::Release);
    }
}

/// Admission policy knobs (`trim serve --queue-cap N --budget-cycles X
/// --client-rps R`).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Maximum admitted-but-not-executing requests — the bounded ingress
    /// queue depth. Submits past this shed with `Overloaded`.
    pub queue_cap: usize,
    /// Cost budget in simulated cycles: shed when `(depth + 1) × EWMA
    /// per-request cycles` would exceed it. `None` disables the cost
    /// term (the queue cap still bounds the ingress). Only
    /// cost-reporting backends (the sim farm) feed the EWMA; against
    /// PJRT/mock backends the term never triggers.
    pub budget_cycles: Option<f64>,
    /// Per-client sustained request rate (requests/second) enforced by a
    /// token bucket *before* the shared queue-cap/budget checks, so one
    /// chatty client cannot starve the others out of the bounded
    /// ingress. Requests carrying no client id share one anonymous
    /// bucket. `None` (the default) disables per-client quotas.
    pub client_rps: Option<f64>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { queue_cap: 256, budget_cycles: None, client_rps: None }
    }
}

/// Per-client token buckets: each client id accrues `rps` tokens per
/// second up to a burst of `rps.max(1)` (a one-second window), and each
/// admitted request spends one. Over-quota requests shed with a
/// `retry_after` hint of the time until the next token accrues.
///
/// One `Mutex<HashMap>` guards all buckets — the critical section is a
/// couple of float ops, and admission already takes atomics, so this is
/// far off the engine hot path.
#[derive(Debug, Default)]
pub struct ClientQuota {
    buckets: Mutex<HashMap<String, TokenBucket>>,
}

#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    tokens: f64,
    refilled_at: Instant,
}

impl ClientQuota {
    /// Spend one token from `client`'s bucket at rate `rps`. `Err` is
    /// the duration until the bucket next holds a full token.
    pub fn try_take(&self, client: &str, rps: f64) -> Result<(), Duration> {
        let burst = rps.max(1.0);
        let now = Instant::now();
        let mut g = lock_unpoisoned(&self.buckets);
        let b = g
            .entry(client.to_owned())
            .or_insert(TokenBucket { tokens: burst, refilled_at: now });
        let elapsed = now.saturating_duration_since(b.refilled_at).as_secs_f64();
        b.tokens = (b.tokens + elapsed * rps).min(burst);
        b.refilled_at = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((1.0 - b.tokens) / rps))
        }
    }

    /// Number of clients currently tracked (test/introspection hook).
    pub fn clients(&self) -> usize {
        lock_unpoisoned(&self.buckets).len()
    }
}

/// Shared admission + drain state between the submit side (any caller
/// thread) and the engine loop (which feeds the estimators back).
#[derive(Debug, Default)]
pub struct AdmissionControl {
    cfg: AdmissionConfig,
    /// Admitted requests not yet pulled into an executing batch.
    depth: AtomicUsize,
    /// EWMA of simulated cycles per request (from reported batch costs).
    cost_cycles: Ewma,
    /// EWMA of wall-clock backend service time per batch, µs.
    service_us: Ewma,
    /// Drain flag: set once, never cleared — admission stays closed.
    draining: AtomicBool,
    /// Instant after which the engine loop stops executing queued work
    /// and rejects it with `Shutdown` instead.
    drain_deadline: Mutex<Option<Instant>>,
    /// Per-client token buckets (active when `cfg.client_rps` is set).
    quota: ClientQuota,
}

impl AdmissionControl {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self { cfg, ..Self::default() }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }

    /// Currently admitted-but-not-executing requests.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// [`AdmissionControl::try_admit`] with the per-client quota check in
    /// front: when `cfg.client_rps` is set, the request first spends a
    /// token from `client`'s bucket (`None` shares the anonymous
    /// bucket), shedding with `Overloaded` and a token-accrual
    /// `retry_after` when the client is over quota. The quota check runs
    /// *before* the shared depth/budget checks so an over-quota client
    /// never consumes a queue slot.
    pub fn try_admit_for(&self, client: Option<&str>) -> Result<(), ServeError> {
        if let Some(rps) = self.cfg.client_rps {
            if !self.draining.load(Ordering::Acquire) {
                if let Err(wait) = self.quota.try_take(client.unwrap_or(""), rps) {
                    return Err(ServeError::Overloaded { retry_after: wait });
                }
            }
        }
        self.try_admit()
    }

    /// Admit one request or shed it. On `Ok` the queue depth slot is
    /// held until the engine loop pulls the request
    /// ([`AdmissionControl::release`]); a caller whose enqueue fails
    /// after admission must release the slot itself.
    pub fn try_admit(&self) -> Result<(), ServeError> {
        if self.draining.load(Ordering::Acquire) {
            return Err(ServeError::Shutdown);
        }
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.cfg.queue_cap {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            return Err(ServeError::Overloaded { retry_after: self.retry_after() });
        }
        if let (Some(budget), Some(cost)) = (self.cfg.budget_cycles, self.cost_cycles.get()) {
            if (prev + 1) as f64 * cost > budget {
                self.depth.fetch_sub(1, Ordering::AcqRel);
                return Err(ServeError::Overloaded { retry_after: self.retry_after() });
            }
        }
        Ok(())
    }

    /// Release `n` queue slots (requests pulled into a batch, or a
    /// failed enqueue after `try_admit`).
    pub fn release(&self, n: usize) {
        // Saturating: a release can never underflow below zero even if
        // racing with a concurrent failed-admit rollback.
        let mut cur = self.depth.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(n);
            match self.depth.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(observed) => cur = observed,
            }
        }
    }

    /// Feed the estimators from one executed batch: the batch's reported
    /// simulated cycles (when the backend measures them) and its
    /// wall-clock service time.
    pub fn observe_batch(&self, batch_size: usize, sim_cycles: Option<u64>, service: Duration) {
        let n = batch_size.max(1) as f64;
        if let Some(c) = sim_cycles {
            self.cost_cycles.observe(c as f64 / n);
        }
        self.service_us.observe(service.as_micros() as f64);
    }

    /// EWMA of simulated cycles per request (`None` until a
    /// cost-reporting backend has executed a batch).
    pub fn cost_estimate(&self) -> Option<f64> {
        self.cost_cycles.get()
    }

    /// EWMA of wall-clock service time per batch — the deadline-aware
    /// batcher's estimate of "how long will the next batch take".
    pub fn service_estimate(&self) -> Duration {
        Duration::from_micros(self.service_us.get().unwrap_or(0.0) as u64)
    }

    /// Retry hint for a shed request: the expected time for the queue
    /// ahead to clear (`depth × EWMA service time per batch`, floored at
    /// 1 ms when no estimate exists yet).
    pub fn retry_after(&self) -> Duration {
        let per_batch = self.service_us.get().unwrap_or(1_000.0);
        let est = self.depth() as f64 * per_batch;
        Duration::from_micros(est.max(1_000.0) as u64)
    }

    /// Close admission and arm the drain deadline. Idempotent: the
    /// earliest deadline wins so a `Router::drain` after a
    /// `Coordinator::shutdown` cannot extend the window.
    pub fn begin_drain(&self, by: Instant) {
        self.draining.store(true, Ordering::Release);
        let mut g = lock_unpoisoned(&self.drain_deadline);
        *g = Some(match *g {
            Some(existing) => existing.min(by),
            None => by,
        });
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// True once draining *and* past the drain deadline — the engine
    /// loop rejects queued batches with `Shutdown` from here on.
    pub fn drain_expired(&self) -> bool {
        if !self.is_draining() {
            return false;
        }
        match *lock_unpoisoned(&self.drain_deadline) {
            Some(by) => Instant::now() >= by,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_follows_observations_and_resets() {
        let e = Ewma::default();
        assert_eq!(e.get(), None);
        e.observe(100.0);
        assert_eq!(e.get(), Some(100.0));
        e.observe(200.0);
        let v = e.get().unwrap();
        assert!((v - (100.0 + EWMA_ALPHA * 100.0)).abs() < 1e-9);
        e.reset();
        assert_eq!(e.get(), None, "reset returns to the unobserved state");
        e.observe(0.0);
        assert_eq!(e.get(), Some(1.0), "samples clamp at 1 — never the UNSET bits");
    }

    #[test]
    fn queue_cap_bounds_admission() {
        let a = AdmissionControl::new(AdmissionConfig { queue_cap: 2, budget_cycles: None, client_rps: None });
        assert!(a.try_admit().is_ok());
        assert!(a.try_admit().is_ok());
        let e = a.try_admit().unwrap_err();
        assert!(matches!(e, ServeError::Overloaded { .. }), "past the cap sheds, got {e:?}");
        assert_eq!(a.depth(), 2, "failed admit must not leak a slot");
        a.release(1);
        assert!(a.try_admit().is_ok(), "released slot admits again");
    }

    #[test]
    fn cost_budget_sheds_before_the_cap() {
        let a = AdmissionControl::new(AdmissionConfig {
            queue_cap: 1000,
            budget_cycles: Some(250.0),
            client_rps: None,
        });
        // No cost observed yet: the budget term can't trigger.
        assert!(a.try_admit().is_ok());
        a.release(1);
        // 100 cycles/request EWMA → 3rd concurrent request would be
        // (2+1)×100 = 300 > 250 → shed.
        a.observe_batch(1, Some(100), Duration::from_micros(500));
        assert!(a.try_admit().is_ok());
        assert!(a.try_admit().is_ok());
        assert!(matches!(a.try_admit(), Err(ServeError::Overloaded { .. })));
        assert_eq!(a.depth(), 2);
    }

    #[test]
    fn retry_after_scales_with_queue_depth() {
        let a = AdmissionControl::new(AdmissionConfig { queue_cap: 100, budget_cycles: None, client_rps: None });
        let base = a.retry_after();
        assert!(base >= Duration::from_millis(1), "floor with no estimate");
        a.observe_batch(4, None, Duration::from_millis(10));
        for _ in 0..10 {
            a.try_admit().unwrap();
        }
        let loaded = a.retry_after();
        assert!(loaded >= Duration::from_millis(100), "10 × 10 ms queue ahead, got {loaded:?}");
    }

    #[test]
    fn release_saturates_at_zero() {
        let a = AdmissionControl::new(AdmissionConfig::default());
        a.try_admit().unwrap();
        a.release(100);
        assert_eq!(a.depth(), 0);
        assert!(a.try_admit().is_ok());
    }

    #[test]
    fn client_quota_is_per_client_and_refills() {
        let q = ClientQuota::default();
        // 2 rps → burst of 2 tokens: two immediate takes, then shed.
        assert!(q.try_take("alice", 2.0).is_ok());
        assert!(q.try_take("alice", 2.0).is_ok());
        let wait = q.try_take("alice", 2.0).unwrap_err();
        assert!(wait > Duration::ZERO && wait <= Duration::from_millis(500), "got {wait:?}");
        // Another client has its own bucket.
        assert!(q.try_take("bob", 2.0).is_ok());
        assert_eq!(q.clients(), 2);
        // Tokens accrue with time: after ≥ half a second at 2 rps the
        // bucket holds a full token again.
        std::thread::sleep(Duration::from_millis(550));
        assert!(q.try_take("alice", 2.0).is_ok(), "bucket refills at rps");
    }

    #[test]
    fn over_quota_client_sheds_without_consuming_queue_slots() {
        let a = AdmissionControl::new(AdmissionConfig {
            queue_cap: 100,
            budget_cycles: None,
            client_rps: Some(1.0),
        });
        assert!(a.try_admit_for(Some("hog")).is_ok());
        let e = a.try_admit_for(Some("hog")).unwrap_err();
        assert!(matches!(e, ServeError::Overloaded { .. }), "over quota sheds, got {e:?}");
        assert_eq!(a.depth(), 1, "shed request never took a queue slot");
        // Other clients — and the anonymous bucket — are unaffected.
        assert!(a.try_admit_for(Some("quiet")).is_ok());
        assert!(a.try_admit_for(None).is_ok());
        assert_eq!(a.depth(), 3);
        // With no quota configured, try_admit_for is plain try_admit.
        let open = AdmissionControl::new(AdmissionConfig::default());
        for _ in 0..8 {
            assert!(open.try_admit_for(Some("hog")).is_ok());
        }
    }

    #[test]
    fn drain_closes_admission_and_earliest_deadline_wins() {
        let a = AdmissionControl::new(AdmissionConfig::default());
        assert!(!a.is_draining() && !a.drain_expired());
        let now = Instant::now();
        a.begin_drain(now + Duration::from_secs(60));
        assert!(a.is_draining());
        assert!(!a.drain_expired(), "deadline is in the future");
        assert!(matches!(a.try_admit(), Err(ServeError::Shutdown)));
        // A second, earlier drain tightens the deadline.
        a.begin_drain(now);
        assert!(a.drain_expired());
        // ... and a later one cannot loosen it back.
        a.begin_drain(now + Duration::from_secs(60));
        assert!(a.drain_expired());
    }
}
