//! Multi-farm front door: one ingress over N coordinators.
//!
//! Each [`Coordinator`] owns one backend — typically one simulated engine
//! farm — and the [`Router`] puts a single `submit`/`infer`/`metrics`
//! surface in front of a fleet of them, the "one ingress, many farms"
//! shape of ROADMAP §Serving. Farms may be heterogeneous (different
//! engine counts, shard modes or [`crate::arch::ExecFidelity`] tiers);
//! the only requirement is that they serve the same model, i.e. agree on
//! `input_len` — bit-exactness across farm shapes is property-tested, so
//! a client cannot tell which farm answered.
//!
//! Dispatch is **least-outstanding-requests**: every submit goes to the
//! farm with the fewest in-flight requests (first farm wins ties), which
//! keeps a slow register-fidelity farm from starving a fast one. The
//! in-flight count is decremented when the reply is received (or the
//! [`RouterReply`] dropped), not when the request is enqueued.

use super::coordinator::Coordinator;
use super::metrics::MetricsSnapshot;
use super::request::InferenceResponse;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

struct RoutedFarm {
    coordinator: Coordinator,
    /// Requests submitted to this farm whose replies are still pending.
    outstanding: Arc<AtomicUsize>,
}

/// One ingress over many coordinators (one farm each).
pub struct Router {
    farms: Vec<RoutedFarm>,
    input_len: usize,
}

/// Pending reply to a routed request. Receiving the response — or
/// dropping the handle — releases the request's slot in the owning farm's
/// outstanding count.
pub struct RouterReply {
    rx: mpsc::Receiver<InferenceResponse>,
    outstanding: Arc<AtomicUsize>,
    farm: usize,
    settled: bool,
}

impl RouterReply {
    /// Block for the response.
    pub fn recv(&mut self) -> Result<InferenceResponse> {
        let resp = self.rx.recv()?;
        self.settle();
        Ok(resp)
    }

    /// Index of the farm this request was dispatched to.
    pub fn farm(&self) -> usize {
        self.farm
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.outstanding.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

impl Drop for RouterReply {
    fn drop(&mut self) {
        self.settle();
    }
}

impl Router {
    /// Front a fleet of running coordinators. Fails on an empty fleet or
    /// when the farms disagree on the model's input length.
    pub fn new(coordinators: Vec<Coordinator>) -> Result<Self> {
        let Some(first) = coordinators.first() else {
            bail!("router needs at least one farm");
        };
        let input_len = first.input_len();
        for (i, c) in coordinators.iter().enumerate() {
            if c.input_len() != input_len {
                bail!(
                    "farm {i} expects {} int32 inputs but farm 0 expects {input_len} — \
                     all farms behind one router must serve the same model",
                    c.input_len()
                );
            }
        }
        let farms = coordinators
            .into_iter()
            .map(|coordinator| RoutedFarm { coordinator, outstanding: Arc::new(AtomicUsize::new(0)) })
            .collect();
        Ok(Self { farms, input_len })
    }

    pub fn farms(&self) -> usize {
        self.farms.len()
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Descriptions of every farm's backend, in dispatch-index order.
    pub fn backend_descriptions(&self) -> Vec<String> {
        self.farms.iter().map(|f| f.coordinator.backend_description().to_string()).collect()
    }

    fn least_loaded(&self) -> usize {
        self.farms
            .iter()
            .enumerate()
            .min_by_key(|(_, f)| f.outstanding.load(Ordering::Acquire))
            .map(|(i, _)| i)
            .expect("router has at least one farm")
    }

    /// Submit one image to the least-loaded farm.
    pub fn submit(&self, image: Vec<i32>) -> Result<RouterReply> {
        let idx = self.least_loaded();
        let farm = &self.farms[idx];
        farm.outstanding.fetch_add(1, Ordering::AcqRel);
        match farm.coordinator.submit(image) {
            Ok(rx) => Ok(RouterReply {
                rx,
                outstanding: Arc::clone(&farm.outstanding),
                farm: idx,
                settled: false,
            }),
            Err(e) => {
                farm.outstanding.fetch_sub(1, Ordering::AcqRel);
                Err(e)
            }
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferenceResponse> {
        self.submit(image)?.recv()
    }

    /// Merged snapshot across every farm (see [`MetricsSnapshot::merge`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for f in &self.farms {
            merged.merge(&f.coordinator.metrics());
        }
        merged
    }

    /// Per-farm snapshots, in dispatch-index order.
    pub fn farm_metrics(&self) -> Vec<MetricsSnapshot> {
        self.farms.iter().map(|f| f.coordinator.metrics()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{InferenceBackend, MockBackend};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::coordinator::CoordinatorConfig;
    use std::time::Duration;

    fn mock_coordinator(input_len: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        };
        Coordinator::start_with(
            move || Ok(Box::new(MockBackend::new(input_len, 3)) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn empty_fleet_is_rejected() {
        assert!(Router::new(vec![]).is_err());
    }

    #[test]
    fn mismatched_input_lens_are_rejected() {
        let r = Router::new(vec![mock_coordinator(4), mock_coordinator(8)]);
        assert!(r.is_err(), "farms serving different models must not share a router");
    }

    #[test]
    fn routes_and_answers_like_a_single_coordinator() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        assert_eq!(router.farms(), 2);
        assert_eq!(router.input_len(), 4);
        let probe = MockBackend::new(4, 3);
        let img = vec![1, 2, 3, 4];
        let resp = router.infer(img.clone()).unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(router.metrics().requests, 1);
    }

    #[test]
    fn least_outstanding_dispatch_spreads_load() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        // Submit without receiving: outstanding counts force alternation.
        let pending: Vec<_> = (0..10).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let farm0 = pending.iter().filter(|r| r.farm() == 0).count();
        assert_eq!(farm0, 5, "in-flight dispatch must alternate across equal farms");
        for mut p in pending {
            p.recv().unwrap();
        }
        let per = router.farm_metrics();
        assert_eq!(per.iter().map(|m| m.requests).sum::<u64>(), 10);
        assert!(per.iter().all(|m| m.requests == 5));
    }

    #[test]
    fn dropping_a_reply_releases_the_slot() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        let first = router.submit(vec![0; 4]).unwrap();
        let farm = first.farm();
        drop(first);
        // With the slot released, the next submit goes to the same farm
        // again (ties break toward farm 0 and counts are equal).
        let second = router.submit(vec![0; 4]).unwrap();
        assert_eq!(second.farm(), farm);
    }

    #[test]
    fn wrong_image_size_is_rejected_and_slot_released() {
        let router = Router::new(vec![mock_coordinator(4)]).unwrap();
        assert!(router.submit(vec![1, 2]).is_err());
        let mut ok = router.submit(vec![0; 4]).unwrap();
        ok.recv().unwrap();
        assert_eq!(router.metrics().requests, 1);
    }
}
