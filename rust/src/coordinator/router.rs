//! Multi-farm front door: one ingress over N coordinators.
//!
//! Each [`Coordinator`] owns one backend — typically one simulated engine
//! farm — and the [`Router`] puts a single `submit`/`infer`/`metrics`
//! surface in front of a fleet of them, the "one ingress, many farms"
//! shape of ROADMAP §Serving. Farms may be heterogeneous (different
//! engine counts, shard modes or [`crate::arch::ExecFidelity`] tiers);
//! the only requirement is that they serve the same model, i.e. agree on
//! `input_len` — bit-exactness across farm shapes is property-tested, so
//! a client cannot tell which farm answered.
//!
//! Dispatch is **cost-aware**: each farm keeps an EWMA of the
//! per-request simulated cycles its responses report
//! ([`crate::coordinator::SimCost::batch_cycles`] divided by the batch
//! size, so the estimate measures the farm rather than how full the
//! batcher ran), and every submit goes to the farm minimising
//! `EWMA cycles × (outstanding + 1)` — the expected simulated cost of its
//! queue with this request appended. Farms that have not yet reported a
//! cost are scored optimistically with the cheapest EWMA observed in the
//! fleet (they win ties at equal queue depth, so cold farms get probed,
//! but still pay for their queue — a backend that never reports, like
//! PJRT or the mock, competes on load instead of monopolising dispatch);
//! with no cost reported anywhere dispatch degenerates to plain
//! **least-outstanding-requests**, the pre-cost-aware behaviour. Either
//! way the in-flight count is decremented when the reply is received (or
//! the [`RouterReply`] dropped), not when the request is enqueued.

use super::coordinator::Coordinator;
use super::metrics::MetricsSnapshot;
use super::request::InferenceResponse;
use crate::obs;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// EWMA smoothing factor for reported batch cycles (`new = old + α·(x −
/// old)`); small enough to ride out batch-size noise, large enough that a
/// farm's first few reports dominate its cold-start estimate.
const COST_EWMA_ALPHA: f64 = 0.25;

/// Lock-free EWMA of a farm's reported simulated batch cycles; the f64 is
/// stored as bits, `None` until the first report.
#[derive(Default)]
struct CostEwma(AtomicU64);

impl CostEwma {
    const UNSET: u64 = 0;

    fn get(&self) -> Option<f64> {
        match self.0.load(Ordering::Acquire) {
            Self::UNSET => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    fn observe(&self, sample: f64) {
        // Races between concurrent receivers may drop an update; the EWMA
        // is a dispatch heuristic, so last-writer-wins is fine.
        let next = match self.get() {
            None => sample,
            Some(old) => old + COST_EWMA_ALPHA * (sample - old),
        };
        // `max(1)`: cycles are ≥ 1 in practice; never store the UNSET bits.
        self.0.store(f64::to_bits(next.max(1.0)), Ordering::Release);
    }
}

struct RoutedFarm {
    coordinator: Coordinator,
    /// Requests submitted to this farm whose replies are still pending.
    outstanding: Arc<AtomicUsize>,
    /// EWMA of the simulated per-request cycles this farm's responses
    /// report (batch cycles normalised by batch size).
    cost: Arc<CostEwma>,
}

/// One ingress over many coordinators (one farm each).
pub struct Router {
    farms: Vec<RoutedFarm>,
    input_len: usize,
}

/// Pending reply to a routed request. Receiving the response — or
/// dropping the handle — releases the request's slot in the owning farm's
/// outstanding count; a received response carrying a simulated cost also
/// feeds the farm's dispatch EWMA.
pub struct RouterReply {
    rx: mpsc::Receiver<InferenceResponse>,
    outstanding: Arc<AtomicUsize>,
    cost: Arc<CostEwma>,
    farm: usize,
    settled: bool,
}

impl RouterReply {
    /// Block for the response.
    pub fn recv(&mut self) -> Result<InferenceResponse> {
        let resp = self.rx.recv()?;
        if let Some(c) = &resp.cost {
            // Normalise per request: `batch_cycles` is the whole batch's
            // simulated wall-clock (shared, not divided), so dividing by
            // the batch size measures the farm's per-request cost rather
            // than how full the batcher happened to run.
            self.cost.observe(c.batch_cycles as f64 / resp.batch_size.max(1) as f64);
        }
        self.settle();
        Ok(resp)
    }

    /// Index of the farm this request was dispatched to.
    pub fn farm(&self) -> usize {
        self.farm
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.outstanding.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

impl Drop for RouterReply {
    fn drop(&mut self) {
        self.settle();
    }
}

impl Router {
    /// Front a fleet of running coordinators. Fails on an empty fleet or
    /// when the farms disagree on the model's input length.
    pub fn new(coordinators: Vec<Coordinator>) -> Result<Self> {
        let Some(first) = coordinators.first() else {
            bail!("router needs at least one farm");
        };
        let input_len = first.input_len();
        for (i, c) in coordinators.iter().enumerate() {
            if c.input_len() != input_len {
                bail!(
                    "farm {i} expects {} int32 inputs but farm 0 expects {input_len} — \
                     all farms behind one router must serve the same model",
                    c.input_len()
                );
            }
        }
        let farms = coordinators
            .into_iter()
            .map(|coordinator| RoutedFarm {
                coordinator,
                outstanding: Arc::new(AtomicUsize::new(0)),
                cost: Arc::new(CostEwma::default()),
            })
            .collect();
        Ok(Self { farms, input_len })
    }

    pub fn farms(&self) -> usize {
        self.farms.len()
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Descriptions of every farm's backend, in dispatch-index order.
    pub fn backend_descriptions(&self) -> Vec<String> {
        self.farms.iter().map(|f| f.coordinator.backend_description().to_string()).collect()
    }

    /// Pick the dispatch target: minimise the expected simulated queue
    /// cost `EWMA cycles × (outstanding + 1)`. Farms that have not yet
    /// reported a cost are scored **optimistically** with the cheapest
    /// EWMA observed anywhere in the fleet — at equal queue depth they win
    /// ties against sampled farms (so a cold farm gets probed) but they
    /// still pay for their outstanding queue, so a backend that *never*
    /// reports cost (PJRT/mock) competes on load like everyone else
    /// instead of monopolising dispatch. With no cost reported anywhere
    /// this degenerates to plain least-outstanding. First farm wins ties.
    fn pick_farm(&self) -> usize {
        let snaps: Vec<(usize, Option<f64>)> = self
            .farms
            .iter()
            .map(|f| (f.outstanding.load(Ordering::Acquire), f.cost.get()))
            .collect();
        let min_ewma = snaps.iter().filter_map(|(_, e)| *e).fold(f64::INFINITY, f64::min);
        let idx = if min_ewma.is_infinite() {
            // no farm has reported yet: least-outstanding
            snaps
                .iter()
                .enumerate()
                .min_by_key(|(_, (out, _))| *out)
                .map(|(i, _)| i)
                .expect("router has at least one farm")
        } else {
            snaps
                .iter()
                .enumerate()
                .min_by(|(_, (oa, ea)), (_, (ob, eb))| {
                    let sa = ea.unwrap_or(min_ewma) * (oa + 1) as f64;
                    let sb = eb.unwrap_or(min_ewma) * (ob + 1) as f64;
                    sa.partial_cmp(&sb)
                        .expect("queue scores are finite")
                        // Equal expected cost: probe the farm with no sample
                        // yet (`false < true`, so `None`-cost farms win — the
                        // documented cold-farm guarantee; min_by alone would
                        // keep the lowest index and never sample a cold farm
                        // listed after the current cheapest).
                        .then_with(|| ea.is_some().cmp(&eb.is_some()))
                })
                .map(|(i, _)| i)
                .expect("router has at least one farm")
        };
        // Publish the dispatch decision: chosen farm, its queue depth and
        // its EWMA score (the expected-cost term the comparison ran on).
        let (out, ewma) = snaps[idx];
        obs::tracer().event(
            "router.dispatch",
            0,
            match ewma {
                Some(e) => format!("farm={idx} outstanding={out} ewma_cycles={e:.1}"),
                None => format!("farm={idx} outstanding={out} ewma_cycles=cold"),
            },
        );
        idx
    }

    /// Per-farm dispatch cost estimates (EWMA of reported simulated
    /// **per-request** cycles — batch cycles normalised by batch size),
    /// in dispatch-index order; `None` until a farm's first cost-carrying
    /// response.
    pub fn farm_cost_estimates(&self) -> Vec<Option<f64>> {
        self.farms.iter().map(|f| f.cost.get()).collect()
    }

    /// Submit one image to the farm [`Router::pick_farm`] selects.
    pub fn submit(&self, image: Vec<i32>) -> Result<RouterReply> {
        let idx = self.pick_farm();
        let farm = &self.farms[idx];
        farm.outstanding.fetch_add(1, Ordering::AcqRel);
        match farm.coordinator.submit(image) {
            Ok(rx) => Ok(RouterReply {
                rx,
                outstanding: Arc::clone(&farm.outstanding),
                cost: Arc::clone(&farm.cost),
                farm: idx,
                settled: false,
            }),
            Err(e) => {
                farm.outstanding.fetch_sub(1, Ordering::AcqRel);
                Err(e)
            }
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferenceResponse> {
        self.submit(image)?.recv()
    }

    /// Merged snapshot across every farm (see [`MetricsSnapshot::merge`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for f in &self.farms {
            merged.merge(&f.coordinator.metrics());
        }
        merged
    }

    /// Per-farm snapshots, in dispatch-index order.
    pub fn farm_metrics(&self) -> Vec<MetricsSnapshot> {
        self.farms.iter().map(|f| f.coordinator.metrics()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::EnergyModel;
    use crate::arch::SimStats;
    use crate::coordinator::backend::{BatchCost, BatchReport, InferenceBackend, MockBackend};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::coordinator::CoordinatorConfig;
    use std::time::Duration;

    fn mock_coordinator(input_len: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        };
        Coordinator::start_with(
            move || Ok(Box::new(MockBackend::new(input_len, 3)) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap()
    }

    /// A backend whose every batch reports a fixed simulated cycle count —
    /// the minimal cost model the EWMA dispatch tests need.
    struct FixedCostBackend {
        input_len: usize,
        cycles: u64,
    }

    impl InferenceBackend for FixedCostBackend {
        fn input_len(&self) -> usize {
            self.input_len
        }

        fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
            let outputs = images.iter().map(|_| vec![1i32, 0, 0]).collect();
            let stats = SimStats {
                cycles: self.cycles,
                ext_input_reads: 10,
                output_writes: 10,
                macs: 100,
                ..Default::default()
            };
            // every batch claims one canary sample, so the router-merged
            // canary totals are checkable against sim_batches
            Ok(BatchReport::with_cost(
                outputs,
                BatchCost::from_stats(stats, 150.0e6, &EnergyModel::paper()).with_canary(
                    crate::scheduler::CanaryReport {
                        sampled: 1,
                        bit_divergence: 0,
                        counter_divergence: 0,
                    },
                ),
            ))
        }

        fn describe(&self) -> String {
            format!("fixed[{} cycles]", self.cycles)
        }
    }

    fn fixed_cost_coordinator(cycles: u64) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        };
        Coordinator::start_with(
            move || Ok(Box::new(FixedCostBackend { input_len: 4, cycles }) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn empty_fleet_is_rejected() {
        assert!(Router::new(vec![]).is_err());
    }

    #[test]
    fn mismatched_input_lens_are_rejected() {
        let r = Router::new(vec![mock_coordinator(4), mock_coordinator(8)]);
        assert!(r.is_err(), "farms serving different models must not share a router");
    }

    #[test]
    fn routes_and_answers_like_a_single_coordinator() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        assert_eq!(router.farms(), 2);
        assert_eq!(router.input_len(), 4);
        let probe = MockBackend::new(4, 3);
        let img = vec![1, 2, 3, 4];
        let resp = router.infer(img.clone()).unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(router.metrics().requests, 1);
    }

    #[test]
    fn cost_aware_dispatch_follows_reported_cycles() {
        // Farm 0 reports 1000× the simulated batch cycles of farm 1. Cold
        // start probes both (least-outstanding fallback); once both have
        // reported, every sequential request must go to the cheap farm.
        let router =
            Router::new(vec![fixed_cost_coordinator(100_000), fixed_cost_coordinator(100)]).unwrap();
        assert_eq!(router.farm_cost_estimates(), vec![None, None], "no cost reported yet");
        let mut a = router.submit(vec![0; 4]).unwrap();
        let mut b = router.submit(vec![0; 4]).unwrap();
        assert_ne!(a.farm(), b.farm(), "cold start probes every unsampled farm");
        a.recv().unwrap();
        b.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert!((est[0].unwrap() - 100_000.0).abs() < 1e-6);
        assert!((est[1].unwrap() - 100.0).abs() < 1e-6);
        for _ in 0..8 {
            let mut r = router.submit(vec![0; 4]).unwrap();
            assert_eq!(r.farm(), 1, "dispatch must follow the lower EWMA cost");
            r.recv().unwrap();
        }
        let per = router.farm_metrics();
        assert_eq!(per[1].requests, 9, "cheap farm serves the warmed-up load");
        assert_eq!(per[0].requests, 1, "expensive farm only saw its probe");
        // the router-merged snapshot folds both farms' canary totals
        // (FixedCostBackend reports one sample per batch)
        let merged = router.metrics();
        assert_eq!(merged.canary.sampled, merged.sim_batches);
        assert_eq!(merged.canary.bit_divergence, 0);
    }

    #[test]
    fn unreported_farms_do_not_monopolise_dispatch() {
        // Farm 0 never reports cost (mock); farm 1 does. Once farm 1 has
        // an EWMA the mock is scored optimistically at that same EWMA, so
        // it is probed at equal queue depth but loses as soon as requests
        // pile up on it — a permanently-unsampled farm must not pin all
        // dispatch to itself.
        let router = Router::new(vec![mock_coordinator(4), fixed_cost_coordinator(100)]).unwrap();
        let mut a = router.submit(vec![0; 4]).unwrap();
        let mut b = router.submit(vec![0; 4]).unwrap();
        assert_eq!((a.farm(), b.farm()), (0, 1), "cold start is least-outstanding");
        a.recv().unwrap();
        b.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert_eq!(est[0], None, "mock never reports a cost");
        assert!(est[1].is_some());
        // Equal depth: optimistic tie goes to the first (unsampled) farm…
        let hold = router.submit(vec![0; 4]).unwrap();
        assert_eq!(hold.farm(), 0);
        // …but with its slot still held, the sampled farm must win.
        let mut next = router.submit(vec![0; 4]).unwrap();
        assert_eq!(next.farm(), 1, "queued unsampled farm loses to the idle sampled farm");
        drop(hold);
        next.recv().unwrap();
    }

    #[test]
    fn cold_farm_listed_after_the_cheapest_still_gets_probed() {
        // Regression (PR 5): score ties between a sampled farm and a cold
        // farm scored at the fleet-minimum EWMA must go to the COLD farm
        // even when it has the higher index — a plain min_by keeps the
        // lowest index, pinning all sequential traffic to farm 0 and
        // never sampling the (here 1000× cheaper) farm 1.
        let router = Router::new(vec![
            fixed_cost_coordinator(100_000), // expensive, sampled first
            fixed_cost_coordinator(100),     // much cheaper, initially cold
        ])
        .unwrap();
        // Request 1: nothing sampled → least-outstanding → farm 0.
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 0);
        r.recv().unwrap();
        // Request 2: farm 0 has an EWMA; farm 1 scores the same optimistic
        // value at equal depth — the tie must probe the cold farm.
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 1, "cold farm must win the tie and get probed");
        r.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert!(est[0].is_some() && est[1].is_some(), "both farms sampled: {est:?}");
        // From here the genuinely cheaper farm wins on cost, not luck.
        for _ in 0..6 {
            let mut r = router.submit(vec![0; 4]).unwrap();
            assert_eq!(r.farm(), 1, "dispatch follows the cheaper EWMA");
            r.recv().unwrap();
        }
    }

    #[test]
    fn cost_free_backends_keep_least_outstanding_dispatch() {
        // Mock backends never report a cost, so the router must behave
        // exactly like the pre-cost-aware least-outstanding dispatcher.
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        let pending: Vec<_> = (0..6).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        assert_eq!(pending.iter().filter(|r| r.farm() == 0).count(), 3);
        for mut p in pending {
            p.recv().unwrap();
        }
        assert_eq!(router.farm_cost_estimates(), vec![None, None], "mocks never report cost");
    }

    #[test]
    fn least_outstanding_dispatch_spreads_load() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        // Submit without receiving: outstanding counts force alternation.
        let pending: Vec<_> = (0..10).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let farm0 = pending.iter().filter(|r| r.farm() == 0).count();
        assert_eq!(farm0, 5, "in-flight dispatch must alternate across equal farms");
        for mut p in pending {
            p.recv().unwrap();
        }
        let per = router.farm_metrics();
        assert_eq!(per.iter().map(|m| m.requests).sum::<u64>(), 10);
        assert!(per.iter().all(|m| m.requests == 5));
    }

    #[test]
    fn dropping_a_reply_releases_the_slot() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        let first = router.submit(vec![0; 4]).unwrap();
        let farm = first.farm();
        drop(first);
        // With the slot released, the next submit goes to the same farm
        // again (ties break toward farm 0 and counts are equal).
        let second = router.submit(vec![0; 4]).unwrap();
        assert_eq!(second.farm(), farm);
    }

    #[test]
    fn wrong_image_size_is_rejected_and_slot_released() {
        let router = Router::new(vec![mock_coordinator(4)]).unwrap();
        assert!(router.submit(vec![1, 2]).is_err());
        let mut ok = router.submit(vec![0; 4]).unwrap();
        ok.recv().unwrap();
        assert_eq!(router.metrics().requests, 1);
    }
}
