//! Multi-farm front door: one ingress over N coordinators.
//!
//! Each [`Coordinator`] owns one backend — typically one simulated engine
//! farm — and the [`Router`] puts a single `submit`/`infer`/`metrics`
//! surface in front of a fleet of them, the "one ingress, many farms"
//! shape of ROADMAP §Serving. Farms may be heterogeneous (different
//! engine counts, shard modes or [`crate::arch::ExecFidelity`] tiers);
//! the only requirement is that they serve the same model, i.e. agree on
//! `input_len` — bit-exactness across farm shapes is property-tested, so
//! a client cannot tell which farm answered.
//!
//! Dispatch is **cost-aware**: each farm keeps an EWMA of the
//! per-request simulated cycles its responses report
//! ([`crate::coordinator::SimCost::batch_cycles`] divided by the batch
//! size, so the estimate measures the farm rather than how full the
//! batcher ran), and every submit goes to the farm minimising
//! `EWMA cycles × (outstanding + 1) × (1 + consecutive failures)` — the
//! expected simulated cost of its queue with this request appended,
//! penalised while the farm is failing. Farms that have not yet reported
//! a cost are scored optimistically with the cheapest EWMA observed in
//! the fleet (they win ties at equal queue depth, so cold farms get
//! probed, but still pay for their queue — a backend that never reports,
//! like PJRT or the mock, competes on load instead of monopolising
//! dispatch); with no cost reported anywhere dispatch degenerates to
//! plain **least-outstanding-requests**, the pre-cost-aware behaviour.
//! Either way the in-flight count is decremented when the reply is
//! received (or the [`RouterReply`] dropped), not when the request is
//! enqueued.
//!
//! The router is also the **retry layer**: when a farm's batch fails or
//! panics ([`ServeError::EngineFailed`]), [`RouterReply::recv`] marks the
//! farm cold (EWMA reset + failure penalty) and resubmits to the
//! next-cheapest farm with capped exponential backoff, up to
//! [`RetryConfig::max_attempts`] total attempts. Admission rejections
//! (`Overloaded`/`Shutdown`) from one farm fall through to the next at
//! submit time; only when every farm rejects does the caller see a typed
//! error (preferring `Overloaded` with the smallest `retry_after` hint).
//! [`Router::drain`] shuts the whole fleet down gracefully: admission
//! closes everywhere first, then every engine thread is joined — every
//! in-flight request resolves before it returns.

use super::admission::Ewma;
use super::coordinator::Coordinator;
use super::error::{ServeError, ServeResult};
use super::metrics::MetricsSnapshot;
use super::request::InferenceResponse;
use crate::obs;
use crate::util::sync::{lock_unpoisoned, AtomicU64, AtomicUsize, Mutex, Ordering};
use anyhow::{bail, Result};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Retry policy for failed/panicked farm batches.
#[derive(Debug, Clone, Copy)]
pub struct RetryConfig {
    /// Total submission attempts per request, including the first
    /// (`3` = one submit + up to two retries). `1` disables retries.
    pub max_attempts: u32,
    /// Backoff before retry k (0-based) is `base_backoff × 2^k`, capped
    /// at `max_backoff`.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Consecutive failed batches after which a farm is treated as
    /// quarantined by dispatch: it stops receiving submits — including
    /// retries of other farms' failures — as long as at least one farm
    /// in the fleet is below the threshold. A single-farm fleet (or a
    /// fleet where *everything* crossed it) still dispatches, so retries
    /// in place keep working and transient faults recover. Cleared by
    /// the farm's first successful reply.
    pub quarantine_after: usize,
    /// Probation for quarantined farms: after this cooldown, exactly one
    /// probe request is routed to the farm — a success restores it to
    /// full rotation (failure count cleared, cooldown back to base), a
    /// failure re-quarantines it with the cooldown **doubled** (capped
    /// at an hour), so a permanent flapper converges to near-zero probe
    /// traffic instead of oscillating back into dispatch.
    pub probation_cooldown: Duration,
}

impl Default for RetryConfig {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(20),
            quarantine_after: 3,
            probation_cooldown: Duration::from_secs(60),
        }
    }
}

impl RetryConfig {
    /// Capped exponential backoff before 0-based retry `k`.
    fn backoff(&self, k: u32) -> Duration {
        let mult = 1u32.checked_shl(k).unwrap_or(u32::MAX);
        self.base_backoff.checked_mul(mult).unwrap_or(self.max_backoff).min(self.max_backoff)
    }
}

struct RoutedFarm {
    coordinator: Coordinator,
    /// Requests submitted to this farm whose replies are still pending.
    outstanding: AtomicUsize,
    /// EWMA of the simulated per-request cycles this farm's responses
    /// report (batch cycles normalised by batch size). Reset — marked
    /// cold — when a batch fails, so the farm re-earns its estimate.
    cost: Ewma,
    /// Consecutive failed batches; scores the failure penalty in
    /// dispatch, cleared by the first successful reply.
    failures: AtomicUsize,
    /// Probation clock for a quarantined farm (dispatch-path only, so a
    /// mutex is fine — replies never take it on the success fast path
    /// unless the farm actually recovered from quarantine).
    probe: Mutex<ProbeState>,
}

/// Probation bookkeeping for one quarantined farm (see
/// [`RetryConfig::probation_cooldown`]).
#[derive(Debug, Default)]
struct ProbeState {
    /// When the current cooldown expires; `None` while the farm is
    /// healthy (below the quarantine threshold).
    until: Option<Instant>,
    /// Current cooldown length; starts at the configured base and
    /// doubles on every failed probe (capped at an hour).
    cooldown: Option<Duration>,
    /// A probe request has been routed and has not resolved yet —
    /// at most one probe is in flight per quarantined farm.
    inflight: bool,
}

/// Shared state behind [`Router`] and its in-flight [`RouterReply`]s
/// (replies need it to resubmit on retry).
struct RouterInner {
    farms: Vec<RoutedFarm>,
    input_len: usize,
    retry: RetryConfig,
    /// Cross-farm resubmissions performed (`trim_retries_total`).
    retries: AtomicU64,
}

/// One ingress over many coordinators (one farm each).
pub struct Router {
    inner: Arc<RouterInner>,
}

/// Pending reply to a routed request. Receiving the response — or
/// dropping the handle — releases the request's slot in the owning farm's
/// outstanding count; a received response carrying a simulated cost also
/// feeds the farm's dispatch EWMA, and a failed batch triggers the
/// retry-with-backoff path (see module docs).
pub struct RouterReply {
    inner: Arc<RouterInner>,
    rx: mpsc::Receiver<ServeResult>,
    farm: usize,
    /// Kept for resubmission on retry.
    image: Vec<i32>,
    deadline: Option<Instant>,
    client: Option<String>,
    /// Submission attempts made so far (≥ 1).
    attempts: u32,
    settled: bool,
}

impl RouterReply {
    /// Block for the response, retrying failed batches on the
    /// next-cheapest farm with capped exponential backoff. Non-retryable
    /// typed errors ([`ServeError::DeadlineExceeded`],
    /// [`ServeError::Shutdown`], …) pass straight through inside the
    /// returned `anyhow::Error` (downcastable to [`ServeError`]).
    pub fn recv(&mut self) -> Result<InferenceResponse> {
        loop {
            let received = match self.rx.recv() {
                Ok(Ok(resp)) => Ok(resp),
                Ok(Err(e)) => Err(Some(e)),
                // Reply channel dropped without an answer: the engine
                // thread died harder than the catch_unwind containment.
                Err(_) => Err(None),
            };
            let failed_reason = match received {
                Ok(resp) => {
                    let farm = &self.inner.farms[self.farm];
                    if let Some(c) = &resp.cost {
                        // Normalise per request: `batch_cycles` is the whole
                        // batch's simulated wall-clock (shared, not divided),
                        // so dividing by the batch size measures the farm
                        // rather than how full the batcher happened to run.
                        farm.cost.observe(c.batch_cycles as f64 / resp.batch_size.max(1) as f64);
                    }
                    self.inner.note_farm_ok(self.farm);
                    self.settle();
                    return Ok(resp);
                }
                Err(Some(ServeError::EngineFailed { reason })) => reason,
                Err(Some(other)) => {
                    self.settle();
                    return Err(other.into());
                }
                Err(None) => "engine reply channel dropped".to_string(),
            };
            // Retryable failure: mark the farm cold, penalise it, and —
            // budget permitting — resubmit elsewhere after a backoff.
            self.settle();
            let failed = self.farm;
            self.inner.note_farm_failed(failed);
            let err = ServeError::EngineFailed { reason: failed_reason };
            if self.attempts >= self.inner.retry.max_attempts {
                obs::tracer().event(
                    "router.retry",
                    0,
                    format!("farm={failed} attempts={} verdict=exhausted", self.attempts),
                );
                return Err(err.into());
            }
            if let Some(d) = self.deadline {
                // No point retrying a request whose deadline already passed.
                let now = Instant::now();
                if now >= d {
                    return Err(ServeError::DeadlineExceeded {
                        missed_by: now.saturating_duration_since(d),
                    }
                    .into());
                }
            }
            let backoff = self.inner.retry.backoff(self.attempts - 1);
            std::thread::sleep(backoff);
            self.attempts += 1;
            self.inner.retries.fetch_add(1, Ordering::AcqRel);
            obs::tracer().event(
                "router.retry",
                0,
                format!("farm={failed} attempt={} backoff_us={}", self.attempts, backoff.as_micros()),
            );
            // Exclude the failed farm when the fleet has alternatives; a
            // single farm retries in place (transient faults recover).
            let exclude = (self.inner.farms.len() > 1).then_some(failed);
            match self.inner.submit_at(
                self.image.clone(),
                self.deadline,
                self.client.clone(),
                exclude,
            ) {
                Ok((idx, rx)) => {
                    self.farm = idx;
                    self.rx = rx;
                    self.settled = false;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Index of the farm this request was (last) dispatched to.
    pub fn farm(&self) -> usize {
        self.farm
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.inner.farms[self.farm].outstanding.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

impl Drop for RouterReply {
    fn drop(&mut self) {
        if !self.settled {
            // Abandoned without `recv` resolving it: if this was the
            // probation probe, release the claim so the next request can
            // re-probe instead of wedging the farm in quarantine forever.
            self.inner.release_probe(self.farm);
        }
        self.settle();
    }
}

impl RouterInner {
    /// Pick the dispatch target among the non-`excluded` farms: minimise
    /// the expected simulated queue cost `EWMA cycles × (outstanding + 1)
    /// × (1 + failures)`. Farms that have not yet reported a cost are
    /// scored **optimistically** with the cheapest EWMA observed anywhere
    /// in the candidate set — at equal queue depth they win ties against
    /// sampled farms (so a cold farm gets probed) but they still pay for
    /// their outstanding queue, so a backend that *never* reports cost
    /// (PJRT/mock) competes on load like everyone else instead of
    /// monopolising dispatch. With no cost reported anywhere this
    /// degenerates to plain least-outstanding (failure count breaking
    /// ties). First farm wins remaining ties. `None` when every farm is
    /// excluded.
    ///
    /// Farms whose consecutive-failure count reached
    /// [`RetryConfig::quarantine_after`] are dropped from the candidate
    /// set entirely — not just penalised — whenever at least one
    /// below-threshold candidate remains, so a permanently failing farm
    /// stops receiving traffic (and retries) instead of soaking up one
    /// doomed attempt per request. When *every* candidate crossed the
    /// threshold (including the single-farm fleet) the filter is a
    /// no-op: in-place retries still reach the farm and its first
    /// success clears the count.
    ///
    /// Quarantine is probation, not a death sentence: once a quarantined
    /// farm's [`RetryConfig::probation_cooldown`] expires, exactly one
    /// probe request is force-routed to it (returned with `probe =
    /// true`). A successful reply restores the farm; a failed probe
    /// re-quarantines it with the cooldown doubled, so a permanent
    /// flapper's probe traffic decays geometrically.
    fn pick_farm(&self, excluded: &[bool]) -> Option<(usize, bool)> {
        let mut snaps: Vec<(usize, usize, Option<f64>, usize)> = self
            .farms
            .iter()
            .enumerate()
            .filter(|(i, _)| !excluded[*i])
            .map(|(i, f)| {
                (
                    i,
                    f.outstanding.load(Ordering::Acquire),
                    f.cost.get(),
                    f.failures.load(Ordering::Acquire),
                )
            })
            .collect();
        if snaps.is_empty() {
            return None;
        }
        let threshold = self.retry.quarantine_after.max(1);
        if snaps.iter().any(|(_, _, _, fails)| *fails < threshold) {
            // Probation check first: a quarantined candidate whose
            // cooldown has expired wins dispatch outright — the failure
            // penalty in the score below would otherwise starve it of
            // the one probe it needs to prove recovery.
            for (i, _, _, fails) in &snaps {
                if *fails >= threshold && self.take_probe(*i) {
                    obs::tracer().event("router.dispatch", 0, format!("farm={i} probe=probation"));
                    return Some((*i, true));
                }
            }
            snaps.retain(|(i, _, _, fails)| {
                let keep = *fails < threshold;
                if !keep {
                    obs::tracer().event("router.dispatch", 0, format!("farm={i} skipped=quarantined"));
                }
                keep
            });
        }
        let min_ewma = snaps.iter().filter_map(|(_, _, e, _)| *e).fold(f64::INFINITY, f64::min);
        let idx = if min_ewma.is_infinite() {
            // no candidate has reported yet: least-outstanding, failing
            // farms losing ties at equal depth
            snaps.iter().min_by_key(|(_, out, _, fails)| (*out, *fails)).map(|(i, _, _, _)| *i)?
        } else {
            snaps
                .iter()
                .min_by(|(_, oa, ea, fa), (_, ob, eb, fb)| {
                    let sa = ea.unwrap_or(min_ewma) * (oa + 1) as f64 * (fa + 1) as f64;
                    let sb = eb.unwrap_or(min_ewma) * (ob + 1) as f64 * (fb + 1) as f64;
                    // Scores are finite and nonnegative (EWMA clamps ≥ 1),
                    // so total_cmp agrees with partial_cmp everywhere the
                    // old comparison was defined.
                    sa.total_cmp(&sb)
                        // Equal expected cost: probe the farm with no sample
                        // yet (`false < true`, so `None`-cost farms win — the
                        // documented cold-farm guarantee; min_by alone would
                        // keep the lowest index and never sample a cold farm
                        // listed after the current cheapest).
                        .then_with(|| ea.is_some().cmp(&eb.is_some()))
                })
                .map(|(i, _, _, _)| *i)?
        };
        // Publish the dispatch decision: chosen farm, its queue depth and
        // its EWMA score (the expected-cost term the comparison ran on).
        if let Some(&(_, out, ewma, _)) = snaps.iter().find(|(i, ..)| *i == idx) {
            obs::tracer().event(
                "router.dispatch",
                0,
                match ewma {
                    Some(e) => format!("farm={idx} outstanding={out} ewma_cycles={e:.1}"),
                    None => format!("farm={idx} outstanding={out} ewma_cycles=cold"),
                },
            );
        }
        Some((idx, false))
    }

    /// Claim the probation probe for farm `idx`: `true` exactly when the
    /// cooldown has expired and no probe is already in flight. A farm
    /// that just crossed the quarantine threshold starts its cooldown
    /// clock here if the failure path has not already done so.
    fn take_probe(&self, idx: usize) -> bool {
        let now = Instant::now();
        let mut p = lock_unpoisoned(&self.farms[idx].probe);
        if p.inflight {
            return false;
        }
        match p.until {
            Some(at) if now >= at => {
                p.inflight = true;
                true
            }
            Some(_) => false,
            None => {
                let cd = p.cooldown.unwrap_or(self.retry.probation_cooldown);
                p.cooldown = Some(cd);
                p.until = Some(now + cd);
                false
            }
        }
    }

    /// Drop an unresolved probe claim (admission rejection, abandoned
    /// reply) so a later request can re-probe.
    fn release_probe(&self, idx: usize) {
        lock_unpoisoned(&self.farms[idx].probe).inflight = false;
    }

    /// A reply from farm `idx` succeeded: clear the consecutive-failure
    /// count and all probation state — a recovered farm re-enters full
    /// rotation and a future quarantine starts from the base cooldown.
    fn note_farm_ok(&self, idx: usize) {
        let farm = &self.farms[idx];
        farm.failures.store(0, Ordering::Release);
        let mut p = lock_unpoisoned(&farm.probe);
        if p.until.is_some() || p.inflight {
            obs::tracer().event("router.dispatch", 0, format!("farm={idx} probe=restored"));
            *p = ProbeState::default();
        }
    }

    /// A reply from farm `idx` failed: mark it cold and bump the failure
    /// count; at or past the quarantine threshold, manage the probation
    /// clock — a failed probe re-quarantines with the cooldown doubled
    /// (capped at an hour), a fresh quarantine starts the base cooldown.
    fn note_farm_failed(&self, idx: usize) {
        let farm = &self.farms[idx];
        farm.cost.reset();
        let fails = farm.failures.fetch_add(1, Ordering::AcqRel) + 1;
        if fails < self.retry.quarantine_after.max(1) {
            return;
        }
        let now = Instant::now();
        let mut p = lock_unpoisoned(&farm.probe);
        if p.inflight {
            let doubled = p
                .cooldown
                .unwrap_or(self.retry.probation_cooldown)
                .saturating_mul(2)
                .min(Duration::from_secs(3600));
            obs::tracer().event(
                "router.dispatch",
                0,
                format!("farm={idx} probe=failed cooldown_ms={}", doubled.as_millis()),
            );
            p.cooldown = Some(doubled);
            p.until = Some(now + doubled);
            p.inflight = false;
        } else if p.until.is_none() {
            let cd = p.cooldown.unwrap_or(self.retry.probation_cooldown);
            p.cooldown = Some(cd);
            p.until = Some(now + cd);
        }
    }

    /// Submit to the best candidate farm, falling through admission
    /// rejections (`Overloaded`/`Shutdown`) to the next-best until one
    /// accepts or every farm has rejected. Non-admission errors (wrong
    /// image size, dead engine) propagate immediately.
    fn submit_at(
        &self,
        image: Vec<i32>,
        deadline: Option<Instant>,
        client: Option<String>,
        exclude: Option<usize>,
    ) -> Result<(usize, mpsc::Receiver<ServeResult>)> {
        let mut excluded = vec![false; self.farms.len()];
        if let Some(x) = exclude {
            excluded[x] = true;
        }
        let mut min_retry_after: Option<Duration> = None;
        while let Some((idx, probe)) = self.pick_farm(&excluded) {
            let farm = &self.farms[idx];
            farm.outstanding.fetch_add(1, Ordering::AcqRel);
            match farm.coordinator.submit_for(image.clone(), deadline, client.clone()) {
                Ok(rx) => return Ok((idx, rx)),
                Err(e) => {
                    farm.outstanding.fetch_sub(1, Ordering::AcqRel);
                    if probe {
                        // The probe never reached the farm — let a later
                        // request claim it instead.
                        self.release_probe(idx);
                    }
                    match e.downcast::<ServeError>() {
                        Ok(ServeError::Overloaded { retry_after }) => {
                            min_retry_after = Some(match min_retry_after {
                                Some(cur) => cur.min(retry_after),
                                None => retry_after,
                            });
                            excluded[idx] = true;
                        }
                        Ok(ServeError::Shutdown) => {
                            excluded[idx] = true;
                        }
                        Ok(other) => return Err(other.into()),
                        Err(orig) => return Err(orig),
                    }
                }
            }
        }
        // Every candidate rejected: report Overloaded (with the most
        // optimistic retry hint) over Shutdown — as long as one farm is
        // merely overloaded the fleet is alive and worth retrying.
        match min_retry_after {
            Some(retry_after) => Err(ServeError::Overloaded { retry_after }.into()),
            None => Err(ServeError::Shutdown.into()),
        }
    }
}

impl Router {
    /// Front a fleet of running coordinators (default [`RetryConfig`]).
    /// Fails on an empty fleet or when the farms disagree on the model's
    /// input length.
    pub fn new(coordinators: Vec<Coordinator>) -> Result<Self> {
        Self::with_retry(coordinators, RetryConfig::default())
    }

    /// [`Router::new`] with an explicit retry policy.
    pub fn with_retry(coordinators: Vec<Coordinator>, retry: RetryConfig) -> Result<Self> {
        let Some(first) = coordinators.first() else {
            bail!("router needs at least one farm");
        };
        let input_len = first.input_len();
        for (i, c) in coordinators.iter().enumerate() {
            if c.input_len() != input_len {
                bail!(
                    "farm {i} expects {} int32 inputs but farm 0 expects {input_len} — \
                     all farms behind one router must serve the same model",
                    c.input_len()
                );
            }
        }
        let farms = coordinators
            .into_iter()
            .map(|coordinator| RoutedFarm {
                coordinator,
                outstanding: AtomicUsize::new(0),
                cost: Ewma::default(),
                failures: AtomicUsize::new(0),
                probe: Mutex::new(ProbeState::default()),
            })
            .collect();
        Ok(Self {
            inner: Arc::new(RouterInner { farms, input_len, retry, retries: AtomicU64::new(0) }),
        })
    }

    pub fn farms(&self) -> usize {
        self.inner.farms.len()
    }

    pub fn input_len(&self) -> usize {
        self.inner.input_len
    }

    /// Descriptions of every farm's backend, in dispatch-index order.
    pub fn backend_descriptions(&self) -> Vec<String> {
        self.inner
            .farms
            .iter()
            .map(|f| f.coordinator.backend_description().to_string())
            .collect()
    }

    /// Per-farm dispatch cost estimates (EWMA of reported simulated
    /// **per-request** cycles — batch cycles normalised by batch size),
    /// in dispatch-index order; `None` until a farm's first cost-carrying
    /// response (or after a failure reset it to cold).
    pub fn farm_cost_estimates(&self) -> Vec<Option<f64>> {
        self.inner.farms.iter().map(|f| f.cost.get()).collect()
    }

    /// Submit one image (best-effort, no deadline) to the best farm.
    pub fn submit(&self, image: Vec<i32>) -> Result<RouterReply> {
        self.submit_with(image, None)
    }

    /// Submit one image with an optional absolute deadline. Admission
    /// rejections fall through to the next-best farm; the returned error
    /// is typed (`downcast_ref::<ServeError>()`) when every farm rejects.
    pub fn submit_with(&self, image: Vec<i32>, deadline: Option<Instant>) -> Result<RouterReply> {
        self.submit_for(image, deadline, None)
    }

    /// [`Router::submit_with`] carrying a client identity for per-client
    /// quotas (`--client-rps`); the identity sticks to the request across
    /// cross-farm retries so a shed client cannot launder load through
    /// the retry path.
    pub fn submit_for(
        &self,
        image: Vec<i32>,
        deadline: Option<Instant>,
        client: Option<String>,
    ) -> Result<RouterReply> {
        let (farm, rx) = self.inner.submit_at(image.clone(), deadline, client.clone(), None)?;
        Ok(RouterReply {
            inner: Arc::clone(&self.inner),
            rx,
            farm,
            image,
            deadline,
            client,
            attempts: 1,
            settled: false,
        })
    }

    /// Submit and block for the result.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferenceResponse> {
        self.submit(image)?.recv()
    }

    /// Merged snapshot across every farm (see [`MetricsSnapshot::merge`]),
    /// plus the router-level retry counter.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut merged = MetricsSnapshot::default();
        for f in &self.inner.farms {
            merged.merge(&f.coordinator.metrics());
        }
        merged.retries = merged.retries.saturating_add(self.inner.retries.load(Ordering::Acquire));
        merged
    }

    /// Per-farm snapshots, in dispatch-index order.
    pub fn farm_metrics(&self) -> Vec<MetricsSnapshot> {
        self.inner.farms.iter().map(|f| f.coordinator.metrics()).collect()
    }

    /// True once a drain has begun anywhere in the fleet.
    pub fn is_draining(&self) -> bool {
        self.inner.farms.iter().any(|f| f.coordinator.is_draining())
    }

    /// Graceful fleet drain: close admission on **every** farm first
    /// (so nothing re-routes into a farm that is about to stop), let
    /// queued work flush within `grace`, reject the remainder as
    /// [`ServeError::Shutdown`], join all engine threads, and return the
    /// final merged snapshot. Every in-flight request has resolved — with
    /// logits or a typed error — by the time this returns.
    pub fn drain(&self, grace: Duration) -> MetricsSnapshot {
        let by = Instant::now() + grace;
        for f in &self.inner.farms {
            f.coordinator.begin_drain(by);
        }
        for f in &self.inner.farms {
            f.coordinator.join_engine();
        }
        self.metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::EnergyModel;
    use crate::arch::SimStats;
    use crate::coordinator::backend::{BatchCost, BatchReport, InferenceBackend, MockBackend};
    use crate::coordinator::testing::FaultInjectingBackend;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::coordinator::CoordinatorConfig;
    use std::time::Duration;

    fn mock_coordinator(input_len: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        Coordinator::start_with(
            move || Ok(Box::new(MockBackend::new(input_len, 3)) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap()
    }

    /// A backend whose every batch reports a fixed simulated cycle count —
    /// the minimal cost model the EWMA dispatch tests need.
    struct FixedCostBackend {
        input_len: usize,
        cycles: u64,
    }

    impl InferenceBackend for FixedCostBackend {
        fn input_len(&self) -> usize {
            self.input_len
        }

        fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
            let outputs = images.iter().map(|_| vec![1i32, 0, 0]).collect();
            let stats = SimStats {
                cycles: self.cycles,
                ext_input_reads: 10,
                output_writes: 10,
                macs: 100,
                ..Default::default()
            };
            // every batch claims one canary sample, so the router-merged
            // canary totals are checkable against sim_batches
            Ok(BatchReport::with_cost(
                outputs,
                BatchCost::from_stats(stats, 150.0e6, &EnergyModel::paper()).with_canary(
                    crate::scheduler::CanaryReport {
                        sampled: 1,
                        bit_divergence: 0,
                        counter_divergence: 0,
                    },
                ),
            ))
        }

        fn describe(&self) -> String {
            format!("fixed[{} cycles]", self.cycles)
        }
    }

    fn fixed_cost_coordinator(cycles: u64) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        Coordinator::start_with(
            move || Ok(Box::new(FixedCostBackend { input_len: 4, cycles }) as Box<dyn InferenceBackend>),
            cfg,
        )
        .unwrap()
    }

    fn faulty_coordinator(fail_every: u64, panic_instead: bool) -> Coordinator {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        };
        Coordinator::start_with(
            move || {
                let b = FaultInjectingBackend::new(4, 3, fail_every);
                let b = if panic_instead { b.panicking() } else { b };
                Ok(Box::new(b) as Box<dyn InferenceBackend>)
            },
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn empty_fleet_is_rejected() {
        assert!(Router::new(vec![]).is_err());
    }

    #[test]
    fn mismatched_input_lens_are_rejected() {
        let r = Router::new(vec![mock_coordinator(4), mock_coordinator(8)]);
        assert!(r.is_err(), "farms serving different models must not share a router");
    }

    #[test]
    fn routes_and_answers_like_a_single_coordinator() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        assert_eq!(router.farms(), 2);
        assert_eq!(router.input_len(), 4);
        let probe = MockBackend::new(4, 3);
        let img = vec![1, 2, 3, 4];
        let resp = router.infer(img.clone()).unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(router.metrics().requests, 1);
    }

    #[test]
    fn cost_aware_dispatch_follows_reported_cycles() {
        // Farm 0 reports 1000× the simulated batch cycles of farm 1. Cold
        // start probes both (least-outstanding fallback); once both have
        // reported, every sequential request must go to the cheap farm.
        let router =
            Router::new(vec![fixed_cost_coordinator(100_000), fixed_cost_coordinator(100)]).unwrap();
        assert_eq!(router.farm_cost_estimates(), vec![None, None], "no cost reported yet");
        let mut a = router.submit(vec![0; 4]).unwrap();
        let mut b = router.submit(vec![0; 4]).unwrap();
        assert_ne!(a.farm(), b.farm(), "cold start probes every unsampled farm");
        a.recv().unwrap();
        b.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert!((est[0].unwrap() - 100_000.0).abs() < 1e-6);
        assert!((est[1].unwrap() - 100.0).abs() < 1e-6);
        for _ in 0..8 {
            let mut r = router.submit(vec![0; 4]).unwrap();
            assert_eq!(r.farm(), 1, "dispatch must follow the lower EWMA cost");
            r.recv().unwrap();
        }
        let per = router.farm_metrics();
        assert_eq!(per[1].requests, 9, "cheap farm serves the warmed-up load");
        assert_eq!(per[0].requests, 1, "expensive farm only saw its probe");
        // the router-merged snapshot folds both farms' canary totals
        // (FixedCostBackend reports one sample per batch)
        let merged = router.metrics();
        assert_eq!(merged.canary.sampled, merged.sim_batches);
        assert_eq!(merged.canary.bit_divergence, 0);
    }

    #[test]
    fn unreported_farms_do_not_monopolise_dispatch() {
        // Farm 0 never reports cost (mock); farm 1 does. Once farm 1 has
        // an EWMA the mock is scored optimistically at that same EWMA, so
        // it is probed at equal queue depth but loses as soon as requests
        // pile up on it — a permanently-unsampled farm must not pin all
        // dispatch to itself.
        let router = Router::new(vec![mock_coordinator(4), fixed_cost_coordinator(100)]).unwrap();
        let mut a = router.submit(vec![0; 4]).unwrap();
        let mut b = router.submit(vec![0; 4]).unwrap();
        assert_eq!((a.farm(), b.farm()), (0, 1), "cold start is least-outstanding");
        a.recv().unwrap();
        b.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert_eq!(est[0], None, "mock never reports a cost");
        assert!(est[1].is_some());
        // Equal depth: optimistic tie goes to the first (unsampled) farm…
        let hold = router.submit(vec![0; 4]).unwrap();
        assert_eq!(hold.farm(), 0);
        // …but with its slot still held, the sampled farm must win.
        let mut next = router.submit(vec![0; 4]).unwrap();
        assert_eq!(next.farm(), 1, "queued unsampled farm loses to the idle sampled farm");
        drop(hold);
        next.recv().unwrap();
    }

    #[test]
    fn cold_farm_listed_after_the_cheapest_still_gets_probed() {
        // Regression (PR 5): score ties between a sampled farm and a cold
        // farm scored at the fleet-minimum EWMA must go to the COLD farm
        // even when it has the higher index — a plain min_by keeps the
        // lowest index, pinning all sequential traffic to farm 0 and
        // never sampling the (here 1000× cheaper) farm 1.
        let router = Router::new(vec![
            fixed_cost_coordinator(100_000), // expensive, sampled first
            fixed_cost_coordinator(100),     // much cheaper, initially cold
        ])
        .unwrap();
        // Request 1: nothing sampled → least-outstanding → farm 0.
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 0);
        r.recv().unwrap();
        // Request 2: farm 0 has an EWMA; farm 1 scores the same optimistic
        // value at equal depth — the tie must probe the cold farm.
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 1, "cold farm must win the tie and get probed");
        r.recv().unwrap();
        let est = router.farm_cost_estimates();
        assert!(est[0].is_some() && est[1].is_some(), "both farms sampled: {est:?}");
        // From here the genuinely cheaper farm wins on cost, not luck.
        for _ in 0..6 {
            let mut r = router.submit(vec![0; 4]).unwrap();
            assert_eq!(r.farm(), 1, "dispatch follows the cheaper EWMA");
            r.recv().unwrap();
        }
    }

    #[test]
    fn cost_free_backends_keep_least_outstanding_dispatch() {
        // Mock backends never report a cost, so the router must behave
        // exactly like the pre-cost-aware least-outstanding dispatcher.
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        let pending: Vec<_> = (0..6).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        assert_eq!(pending.iter().filter(|r| r.farm() == 0).count(), 3);
        for mut p in pending {
            p.recv().unwrap();
        }
        assert_eq!(router.farm_cost_estimates(), vec![None, None], "mocks never report cost");
    }

    #[test]
    fn least_outstanding_dispatch_spreads_load() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        // Submit without receiving: outstanding counts force alternation.
        let pending: Vec<_> = (0..10).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let farm0 = pending.iter().filter(|r| r.farm() == 0).count();
        assert_eq!(farm0, 5, "in-flight dispatch must alternate across equal farms");
        for mut p in pending {
            p.recv().unwrap();
        }
        let per = router.farm_metrics();
        assert_eq!(per.iter().map(|m| m.requests).sum::<u64>(), 10);
        assert!(per.iter().all(|m| m.requests == 5));
    }

    #[test]
    fn dropping_a_reply_releases_the_slot() {
        let router = Router::new(vec![mock_coordinator(4), mock_coordinator(4)]).unwrap();
        let first = router.submit(vec![0; 4]).unwrap();
        let farm = first.farm();
        drop(first);
        // With the slot released, the next submit goes to the same farm
        // again (ties break toward farm 0 and counts are equal).
        let second = router.submit(vec![0; 4]).unwrap();
        assert_eq!(second.farm(), farm);
    }

    #[test]
    fn wrong_image_size_is_rejected_and_slot_released() {
        let router = Router::new(vec![mock_coordinator(4)]).unwrap();
        assert!(router.submit(vec![1, 2]).is_err());
        let mut ok = router.submit(vec![0; 4]).unwrap();
        ok.recv().unwrap();
        assert_eq!(router.metrics().requests, 1);
    }

    #[test]
    fn failed_batch_retries_on_the_other_farm() {
        // Farm 0 fails every batch; farm 1 is healthy. The cold-start
        // least-outstanding pick sends the first request to farm 0, whose
        // failure must transparently retry onto farm 1 and succeed.
        let router =
            Router::new(vec![faulty_coordinator(1, false), mock_coordinator(4)]).unwrap();
        let probe = MockBackend::new(4, 3);
        let img = vec![1, 2, 3, 4];
        let mut reply = router.submit(img.clone()).unwrap();
        assert_eq!(reply.farm(), 0, "cold start dispatches to the (failing) first farm");
        let resp = reply.recv().expect("retry on the healthy farm must succeed");
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(reply.farm(), 1, "reply records the farm that actually answered");
        let m = router.metrics();
        assert!(m.retries >= 1, "retry counter flows into the merged snapshot");
        assert!(m.engine_failed >= 1, "the failed attempt is accounted");
        // The failing farm is penalised: at equal depth, dispatch now
        // prefers the healthy farm instead of alternating.
        let mut r2 = router.submit(img.clone()).unwrap();
        assert_eq!(r2.farm(), 1, "failure penalty steers dispatch away from the flaky farm");
        r2.recv().unwrap();
    }

    #[test]
    fn single_farm_retries_in_place_and_recovers_from_transient_faults() {
        // fail_every=2: calls 2, 4, … fault. The first infer succeeds
        // (call 1); the second hits the injected fault (call 2) and must
        // recover by retrying on the same — only — farm (call 3).
        let router = Router::new(vec![faulty_coordinator(2, false)]).unwrap();
        router.infer(vec![0; 4]).expect("call 1 is clean");
        router.infer(vec![0; 4]).expect("transient fault must be retried in place");
        assert_eq!(router.metrics().retries, 1);
    }

    #[test]
    fn permanently_failing_farm_is_quarantined_from_dispatch_and_retries() {
        // Regression: before the quarantine filter, a permanently failing
        // farm was only *penalised* — under queue depth the
        // least-outstanding fallback kept feeding it one doomed attempt
        // (plus a retry) per request forever. Past `quarantine_after`
        // consecutive failures it must drop out of the candidate set
        // entirely while a healthy farm exists.
        let retry = RetryConfig {
            max_attempts: 3,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
            quarantine_after: 2,
            // Far beyond the test's runtime: no probation probe fires.
            probation_cooldown: Duration::from_secs(60),
        };
        let router = Router::with_retry(
            vec![faulty_coordinator(1, false), mock_coordinator(4)],
            retry,
        )
        .unwrap();
        // Concurrent submits alternate on outstanding counts, so the
        // failing farm 0 takes half; each of its replies fails, retries
        // onto farm 1, and bumps the consecutive-failure count past 2.
        let pending: Vec<_> = (0..4).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        for mut p in pending {
            p.recv().expect("every request recovers via retry on the healthy farm");
        }
        let retries_before = router.metrics().retries;
        let failing_farm_requests = router.farm_metrics()[0].requests;
        assert!(retries_before >= 2, "the failing farm's share was retried across");
        // Quarantined: even with depth piling up on farm 1, nothing may
        // be dispatched to farm 0 any more — the old penalty-only scoring
        // would alternate here.
        let pending: Vec<_> = (0..6).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        assert!(
            pending.iter().all(|r| r.farm() == 1),
            "quarantined farm must not receive new dispatch even under depth"
        );
        for mut p in pending {
            p.recv().unwrap();
        }
        let m = router.metrics();
        assert_eq!(m.retries, retries_before, "no further retries: nothing reached the dead farm");
        assert_eq!(
            router.farm_metrics()[0].requests,
            failing_farm_requests,
            "the quarantined farm stopped receiving requests"
        );
    }

    #[test]
    fn farm_probation_probes_after_cooldown_and_contains_flappers() {
        // Quarantine is probation, not a death sentence — but a permanent
        // flapper must not oscillate back into rotation either. After the
        // cooldown exactly one probe is routed to the quarantined farm;
        // when it fails, the farm re-quarantines with the cooldown
        // DOUBLED, so the base interval elapsing again releases nothing.
        let retry = RetryConfig {
            max_attempts: 3,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
            quarantine_after: 2,
            probation_cooldown: Duration::from_millis(300),
        };
        let router = Router::with_retry(
            vec![faulty_coordinator(1, false), mock_coordinator(4)],
            retry,
        )
        .unwrap();
        // Drive the always-failing farm 0 past the quarantine threshold.
        let pending: Vec<_> = (0..4).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        for mut p in pending {
            p.recv().expect("every request recovers via retry on the healthy farm");
        }
        let quarantined_requests = router.farm_metrics()[0].requests;
        // Inside the cooldown: the quarantined farm receives nothing.
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 1, "no probe before the cooldown expires");
        r.recv().unwrap();
        assert_eq!(router.farm_metrics()[0].requests, quarantined_requests);
        // Past the cooldown: exactly one probe goes to farm 0. It fails
        // there, transparently retries onto the healthy farm, and the
        // flapper re-quarantines with its cooldown doubled to 600 ms.
        std::thread::sleep(Duration::from_millis(400));
        let mut probe = router.submit(vec![0; 4]).unwrap();
        assert_eq!(probe.farm(), 0, "cooldown expiry routes one probe to the flapper");
        probe.recv().expect("the probe's failure is retried on the healthy farm");
        assert_eq!(probe.farm(), 1, "reply records the farm that actually answered");
        let after_probe = router.farm_metrics()[0].requests;
        assert!(after_probe > quarantined_requests, "the probe reached the flapper");
        // Containment: the BASE cooldown elapsing again must not release
        // another probe — the doubled cooldown is still running.
        std::thread::sleep(Duration::from_millis(400));
        let mut r = router.submit(vec![0; 4]).unwrap();
        assert_eq!(r.farm(), 1, "flapper containment: doubled cooldown, no probe yet");
        r.recv().unwrap();
        assert_eq!(router.farm_metrics()[0].requests, after_probe);
    }

    #[test]
    fn retries_exhaust_into_a_typed_engine_error() {
        let retry = RetryConfig {
            max_attempts: 3,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
            quarantine_after: 3,
            probation_cooldown: Duration::from_secs(60),
        };
        let router = Router::with_retry(vec![faulty_coordinator(1, false)], retry).unwrap();
        let err = router.infer(vec![0; 4]).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::EngineFailed { reason }) => {
                assert!(reason.contains("injected fault"), "got {reason}")
            }
            other => panic!("expected typed EngineFailed, got {other:?}"),
        }
        assert_eq!(router.metrics().retries, 2, "max_attempts=3 → two retries then give up");
    }

    #[test]
    fn drain_completes_with_a_panicking_farm_and_resolves_everything() {
        // Regression: a farm whose backend panics mid-drain must not wedge
        // Router::drain() — the catch_unwind containment keeps its engine
        // loop alive to flush (fail) the backlog, and every submitted
        // request still resolves with logits or a typed error.
        let retry = RetryConfig {
            max_attempts: 2,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
            quarantine_after: 3,
            probation_cooldown: Duration::from_secs(60),
        };
        let router = Router::with_retry(
            vec![mock_coordinator(4), faulty_coordinator(1, true)],
            retry,
        )
        .unwrap();
        let mut pending: Vec<_> =
            (0..8).map(|i| router.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let t0 = Instant::now();
        let snap = router.drain(Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(20), "drain must terminate");
        assert!(router.is_draining());
        for p in pending.iter_mut() {
            match p.recv() {
                Ok(resp) => assert!(!resp.logits.is_empty(), "no empty-logits sentinels"),
                Err(e) => {
                    assert!(e.downcast_ref::<ServeError>().is_some(), "typed failure: {e:#}")
                }
            }
        }
        assert!(snap.requests > 0);
        // After drain, new submits are rejected with a typed Shutdown.
        let err = router.submit(vec![0; 4]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Shutdown));
    }
}
