//! Inference backends: the PJRT-artifact pipeline, the simulated engine
//! farm (re-exported from [`crate::scheduler`]), and a mock for testing
//! the coordination logic in isolation. [`make_backend`] is the single
//! construction point the CLI and examples plumb `--backend` through.

use crate::runtime::Runtime;
use anyhow::Result;

/// Something that can turn a batch of images into logits.
///
/// Not `Send`: PJRT clients are `Rc`-based, so the backend is constructed
/// *on* the engine thread via the factory passed to
/// [`super::Coordinator::start_with`].
pub trait InferenceBackend {
    /// Flat image length this backend expects.
    fn input_len(&self) -> usize;
    /// Run a batch; returns one logits vector per image.
    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<Vec<Vec<i32>>>;
    /// Human-readable identification.
    fn describe(&self) -> String;
}

/// The real backend: TrimNet as per-block AOT artifacts, executed
/// layer-serially across the batch — the same order the TrIM engine
/// processes a layer for all images of a batch while its weights are
/// resident (weight-stationary at the artifact level: weights are baked
/// into each block's HLO).
pub struct PjrtBackend {
    rt: Runtime,
    blocks: Vec<String>,
    head: String,
    input_len: usize,
}

impl PjrtBackend {
    /// Load from an artifact directory produced by `make artifacts`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let rt = Runtime::load(dir)?;
        let blocks: Vec<String> = (0..3).map(|i| format!("trimnet_block{i}")).collect();
        for b in &blocks {
            rt.module(b)?;
        }
        let input_len = rt.module(&blocks[0])?.spec.inputs[0].elems();
        rt.module("trimnet_head")?;
        Ok(Self { rt, blocks, head: "trimnet_head".into(), input_len })
    }

    /// Access the underlying runtime (for cross-checks).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl InferenceBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        // Layer-serial over the batch: block b for every image, then b+1 —
        // one weight-resident pass per layer, like the engine's steps.
        let mut acts: Vec<Vec<i32>> = images.iter().map(|v| v.to_vec()).collect();
        for b in &self.blocks {
            let module = self.rt.module(b)?;
            for a in acts.iter_mut() {
                *a = module.run_i32(&[a])?;
            }
        }
        let head = self.rt.module(&self.head)?;
        acts.iter().map(|a| head.run_i32(&[a])).collect()
    }

    fn describe(&self) -> String {
        format!("pjrt[{}] blocks={}+head", self.rt.platform(), self.blocks.len())
    }
}

/// Which backend the serving layer should construct — the CLI plumbing
/// behind `trim serve --backend auto|pjrt|sim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Try PJRT artifacts first; fall back to the sim farm with a notice.
    #[default]
    Auto,
    /// Compiled XLA artifacts via PJRT (needs `make artifacts` and the
    /// `pjrt` cargo feature).
    Pjrt,
    /// The simulated TrIM engine farm ([`crate::scheduler::SimBackend`]) —
    /// zero build products required.
    Sim,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "pjrt" => Ok(Self::Pjrt),
            "sim" => Ok(Self::Sim),
            other => Err(anyhow::anyhow!("unknown backend {other:?} (expected auto|pjrt|sim)")),
        }
    }
}

/// Construct the requested backend. `Auto` prefers the PJRT artifacts in
/// `artifact_dir` and falls back to a `sim_engines`-engine farm (with a
/// printed notice) when they are missing or PJRT support is compiled out —
/// serving always comes up. `sim_fidelity` selects the sim engines'
/// execution tier (`trim serve --fidelity fast|register`); both tiers
/// serve bit-identical logits.
pub fn make_backend(
    kind: BackendKind,
    artifact_dir: impl AsRef<std::path::Path>,
    sim_engines: usize,
    sim_fidelity: crate::arch::ExecFidelity,
) -> Result<Box<dyn InferenceBackend>> {
    use crate::scheduler::{ShardMode, SimBackend, SimNetSpec};
    use crate::arch::ArchConfig;
    let dir = artifact_dir.as_ref();
    let make_sim = || {
        Box::new(SimBackend::with_fidelity(
            sim_engines,
            ArchConfig::small(3, 2, 1),
            SimNetSpec::tiny(),
            ShardMode::FilterShards,
            sim_fidelity,
        )) as Box<dyn InferenceBackend>
    };
    match kind {
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(dir)?)),
        BackendKind::Sim => Ok(make_sim()),
        BackendKind::Auto => match PjrtBackend::load(dir) {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => {
                eprintln!(
                    "notice: PJRT backend unavailable ({e:#}); \
                     falling back to the simulated engine farm \
                     ({sim_engines} engines, {sim_fidelity} fidelity)"
                );
                Ok(make_sim())
            }
        },
    }
}

/// Deterministic mock backend (no PJRT): logits[k] = Σ image · (k+1) mod
/// prime — enough structure to verify routing, ordering and batching.
pub struct MockBackend {
    pub input_len: usize,
    pub classes: usize,
    /// Artificial per-image latency, for batching experiments.
    pub delay: std::time::Duration,
    /// Number of infer_batch calls observed.
    pub calls: u64,
}

impl MockBackend {
    pub fn new(input_len: usize, classes: usize) -> Self {
        Self { input_len, classes, delay: std::time::Duration::ZERO, calls: 0 }
    }

    /// The logits the mock produces for `image` (exposed for assertions).
    pub fn expected_logits(&self, image: &[i32]) -> Vec<i32> {
        let s: i64 = image.iter().map(|&v| v as i64).sum();
        (0..self.classes).map(|k| ((s * (k as i64 + 1)) % 9973) as i32).collect()
    }
}

impl InferenceBackend for MockBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        self.calls += 1;
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay * images.len() as u32);
        }
        Ok(images.iter().map(|img| self.expected_logits(img)).collect())
    }

    fn describe(&self) -> String {
        format!("mock[{} classes]", self.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert!("tpu".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }

    #[test]
    fn sim_backend_needs_no_artifacts() {
        let mut b = make_backend(
            BackendKind::Sim,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast,
        )
        .unwrap();
        let img = vec![7i32; b.input_len()];
        let out = b.infer_batch(&[&img]).unwrap();
        assert_eq!(out.len(), 1);
        assert!(b.describe().starts_with("sim["));
    }

    #[test]
    fn auto_falls_back_to_sim_without_artifacts() {
        let b = make_backend(
            BackendKind::Auto,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast,
        )
        .unwrap();
        assert!(b.describe().starts_with("sim["), "got {}", b.describe());
    }

    #[test]
    fn explicit_pjrt_still_errors_without_artifacts() {
        assert!(make_backend(
            BackendKind::Pjrt,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast
        )
        .is_err());
    }

    #[test]
    fn mock_is_deterministic_and_order_preserving() {
        let mut b = MockBackend::new(4, 3);
        let i1 = vec![1, 2, 3, 4];
        let i2 = vec![5, 5, 5, 5];
        let out = b.infer_batch(&[&i1, &i2]).unwrap();
        assert_eq!(out[0], b.expected_logits(&i1));
        assert_eq!(out[1], b.expected_logits(&i2));
        assert_eq!(b.calls, 1);
    }
}
