//! Inference backends: the PJRT-artifact pipeline, the simulated engine
//! farm (re-exported from [`crate::scheduler`]), and a mock for testing
//! the coordination logic in isolation. [`make_backend`] is the single
//! construction point the CLI and examples plumb `--backend` through.
//!
//! Every `infer_batch` returns a [`BatchReport`]: logits plus an optional
//! [`BatchCost`] carrying the farm-aggregated [`SimStats`] and the derived
//! GOPS/joules, so execution cost is a first-class part of the serving
//! API rather than something the simulators compute and throw away.

use crate::analytics::EnergyModel;
use crate::arch::SimStats;
use crate::fault::{FaultConfig, FaultReport};
use crate::runtime::Runtime;
use crate::scheduler::CanaryReport;
use anyhow::Result;

/// One layer's share of a [`BatchCost`] — the per-layer accounting of the
/// TrIM FPGA companion (arXiv 2408.01254), carried through the serving
/// API so a client can see *where* a batch's cycles and memory traffic
/// went, not just the totals.
///
/// Observations of the same layer fold with [`LayerCost::add`] (layers of
/// a batch run sequentially per image and across images, so cycles and
/// counters both add).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerCost {
    /// Layer name (e.g. `"SL2"`, `"CL13"`).
    pub name: String,
    /// Simulated cycles this layer contributed to the batch (already
    /// shard-reduced per run: max over parallel shards, summed across the
    /// sequential images of the batch).
    pub cycles: u64,
    /// Off-chip (DRAM-side) element accesses attributed to this layer.
    pub off_chip_accesses: u64,
    /// On-chip (psum-buffer) element accesses attributed to this layer.
    pub on_chip_accesses: u64,
    /// MACs attributed to this layer.
    pub macs: u64,
}

impl LayerCost {
    /// A layer's cost from one aggregated stats observation.
    pub fn from_stats(name: impl Into<String>, stats: &SimStats) -> Self {
        let mut l = Self { name: name.into(), ..Self::default() };
        l.add_stats(stats);
        l
    }

    /// Fold another sequential stats observation of this layer in.
    /// Saturating: a long-lived accumulator pegs at `u64::MAX` instead of
    /// wrapping (or panicking in debug builds).
    pub fn add_stats(&mut self, stats: &SimStats) {
        self.cycles = self.cycles.saturating_add(stats.cycles);
        self.off_chip_accesses = self.off_chip_accesses.saturating_add(stats.off_chip_accesses());
        self.on_chip_accesses = self.on_chip_accesses.saturating_add(stats.on_chip_accesses());
        self.macs = self.macs.saturating_add(stats.macs);
    }

    /// Fold another observation of the same layer in (saturating, like
    /// [`LayerCost::add_stats`]).
    pub fn add(&mut self, other: &LayerCost) {
        self.cycles = self.cycles.saturating_add(other.cycles);
        self.off_chip_accesses = self.off_chip_accesses.saturating_add(other.off_chip_accesses);
        self.on_chip_accesses = self.on_chip_accesses.saturating_add(other.on_chip_accesses);
        self.macs = self.macs.saturating_add(other.macs);
    }

    /// Fold `l` into `acc` by layer name; unseen names append in arrival
    /// order (layer chains are short, so the linear scan beats a map).
    pub fn fold_into(acc: &mut Vec<LayerCost>, l: &LayerCost) {
        match acc.iter_mut().find(|e| e.name == l.name) {
            Some(e) => e.add(l),
            None => acc.push(l.clone()),
        }
    }
}

/// Farm-aggregated execution cost of one served batch.
///
/// The counters follow the Tables I–II accounting the farm already uses:
/// cycles take the **max** over parallel shards and **add** across
/// sequential phases (layers of one image, images of one batch), while
/// access/MAC counters always **sum** — every access really happens. GOPS
/// and joules are derived once per batch via [`EnergyModel`], so the cost
/// a client sees is priced in the same units as the paper's headline
/// claims (453.6 GOPS peak, Tables I–II energy columns).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchCost {
    /// Aggregated simulation counters for the whole batch.
    pub stats: SimStats,
    /// Per-layer breakdown of `stats` (empty when the backend does not
    /// attribute cost per layer). Sums to the batch totals on
    /// layer-serial execution; on pipelined execution the per-layer
    /// cycles sum to the total *work*, which is ≥ the parallel
    /// wall-clock `stats.cycles`.
    pub per_layer: Vec<LayerCost>,
    /// Clock the cycles are priced at (Hz) — the farm engines' `f_clk`.
    pub f_clk: f64,
    /// Achieved throughput over the batch, GOPs/s
    /// (`2·MACs·f_clk/cycles`).
    pub gops: f64,
    /// Total simulated energy in joules: off-chip + on-chip memory
    /// traffic plus MAC compute, at the paper-calibrated constants.
    pub joules: f64,
    /// Shadow-execution canary activity attributable to this batch
    /// (shards re-run on the `Register`-fidelity oracle, divergences
    /// found). All zero when the farm runs no canary — which keeps
    /// canary-off reports byte-identical to pre-canary ones.
    pub canary: CanaryReport,
    /// Fault-tolerance activity attributable to this batch: faults
    /// injected (`--chaos`), faults the ABFT checksum detected, shards
    /// re-executed and corrected, engines quarantined. All zero on a
    /// fault-free farm, so chaos-off reports stay byte-identical.
    pub faults: FaultReport,
}

impl BatchCost {
    /// Price aggregated counters: derive GOPS and joules from `stats`.
    pub fn from_stats(stats: SimStats, f_clk: f64, energy: &EnergyModel) -> Self {
        let gops = stats.ops_per_s(f_clk) / 1e9;
        let joules = energy
            .memory_energy_j(stats.off_chip_accesses() as f64, stats.on_chip_accesses() as f64)
            + energy.compute_energy_j(stats.macs as f64);
        Self {
            stats,
            per_layer: Vec::new(),
            f_clk,
            gops,
            joules,
            canary: CanaryReport::default(),
            faults: FaultReport::default(),
        }
    }

    /// Attach the per-layer breakdown (builder style).
    pub fn with_per_layer(mut self, per_layer: Vec<LayerCost>) -> Self {
        self.per_layer = per_layer;
        self
    }

    /// Attach the batch's shadow-canary delta (builder style).
    pub fn with_canary(mut self, canary: CanaryReport) -> Self {
        self.canary = canary;
        self
    }

    /// Attach the batch's fault-tolerance delta (builder style).
    pub fn with_faults(mut self, faults: FaultReport) -> Self {
        self.faults = faults;
        self
    }

    /// Attribute this batch's cost to one of its `batch_size` requests:
    /// divisible counters (accesses, MACs, joules) are split evenly, while
    /// cycles and GOPS describe the whole batch the request shared.
    pub fn per_request(&self, batch_size: usize) -> SimCost {
        let n = batch_size.max(1) as f64;
        SimCost {
            batch_cycles: self.stats.cycles,
            off_chip_accesses: self.stats.off_chip_accesses() as f64 / n,
            on_chip_accesses: self.stats.on_chip_accesses() as f64 / n,
            macs: self.stats.macs as f64 / n,
            joules: self.joules / n,
            gops: self.gops,
        }
    }
}

/// Per-request attributed share of a [`BatchCost`] (carried on
/// [`super::InferenceResponse`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCost {
    /// Simulated wall-clock cycles of the batch this request rode in —
    /// shared by every request of the batch, not divided.
    pub batch_cycles: u64,
    /// This request's share of off-chip (DRAM-side) element accesses.
    pub off_chip_accesses: f64,
    /// This request's share of on-chip (psum-buffer) element accesses.
    pub on_chip_accesses: f64,
    /// This request's share of the batch's MACs.
    pub macs: f64,
    /// This request's share of the batch's simulated energy (J).
    pub joules: f64,
    /// Achieved GOPs/s of the batch (a rate — shared, not divided).
    pub gops: f64,
}

/// What one [`InferenceBackend::infer_batch`] call produced: the logits,
/// plus the simulated execution cost when the backend can measure one.
///
/// Simulation-backed backends ([`crate::scheduler::SimBackend`]) always
/// attach a [`BatchCost`]; backends that run on real hardware or carry no
/// cost model ([`PjrtBackend`], [`MockBackend`]) return `None`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchReport {
    /// One logits vector per input image, in input order.
    pub outputs: Vec<Vec<i32>>,
    /// Farm-aggregated execution cost, when the backend measures one.
    pub cost: Option<BatchCost>,
}

impl BatchReport {
    /// A report with no cost model (hardware or mock backends).
    pub fn functional(outputs: Vec<Vec<i32>>) -> Self {
        Self { outputs, cost: None }
    }

    /// A report with measured/synthesized cost (simulation backends).
    pub fn with_cost(outputs: Vec<Vec<i32>>, cost: BatchCost) -> Self {
        Self { outputs, cost: Some(cost) }
    }
}

/// Something that can turn a batch of images into logits (and, when it
/// simulates the hardware, say what the batch cost to execute).
///
/// Not `Send`: PJRT clients are `Rc`-based, so the backend is constructed
/// *on* the engine thread via the factory passed to
/// [`super::Coordinator::start_with`].
pub trait InferenceBackend {
    /// Flat image length this backend expects.
    fn input_len(&self) -> usize;
    /// Run a batch; returns one logits vector per image plus the optional
    /// execution cost.
    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport>;
    /// Human-readable identification.
    fn describe(&self) -> String;
}

/// The real backend: TrimNet as per-block AOT artifacts, executed
/// layer-serially across the batch — the same order the TrIM engine
/// processes a layer for all images of a batch while its weights are
/// resident (weight-stationary at the artifact level: weights are baked
/// into each block's HLO).
pub struct PjrtBackend {
    rt: Runtime,
    blocks: Vec<String>,
    head: String,
    input_len: usize,
}

impl PjrtBackend {
    /// Load from an artifact directory produced by `make artifacts`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let rt = Runtime::load(dir)?;
        let blocks: Vec<String> = (0..3).map(|i| format!("trimnet_block{i}")).collect();
        for b in &blocks {
            rt.module(b)?;
        }
        let input_len = rt.module(&blocks[0])?.spec.inputs[0].elems();
        rt.module("trimnet_head")?;
        Ok(Self { rt, blocks, head: "trimnet_head".into(), input_len })
    }

    /// Access the underlying runtime (for cross-checks).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl InferenceBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
        // Layer-serial over the batch: block b for every image, then b+1 —
        // one weight-resident pass per layer, like the engine's steps.
        let mut acts: Vec<Vec<i32>> = images.iter().map(|v| v.to_vec()).collect();
        for b in &self.blocks {
            let module = self.rt.module(b)?;
            for a in acts.iter_mut() {
                *a = module.run_i32(&[a])?;
            }
        }
        let head = self.rt.module(&self.head)?;
        let outputs: Result<Vec<Vec<i32>>> = acts.iter().map(|a| head.run_i32(&[a])).collect();
        // Real-hardware execution: no simulated cost to report.
        Ok(BatchReport::functional(outputs?))
    }

    fn describe(&self) -> String {
        format!("pjrt[{}] blocks={}+head", self.rt.platform(), self.blocks.len())
    }
}

/// Which backend the serving layer should construct — the CLI plumbing
/// behind `trim serve --backend auto|pjrt|sim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Try PJRT artifacts first; fall back to the sim farm with a notice.
    #[default]
    Auto,
    /// Compiled XLA artifacts via PJRT (needs `make artifacts` and the
    /// `pjrt` cargo feature).
    Pjrt,
    /// The simulated TrIM engine farm ([`crate::scheduler::SimBackend`]) —
    /// zero build products required.
    Sim,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "pjrt" => Ok(Self::Pjrt),
            "sim" => Ok(Self::Sim),
            other => Err(anyhow::anyhow!("unknown backend {other:?} (expected auto|pjrt|sim)")),
        }
    }
}

/// Construct the requested backend. `Auto` prefers the PJRT artifacts in
/// `artifact_dir` and falls back to a `sim_engines`-engine farm (with a
/// printed notice) when they are missing or PJRT support is compiled out —
/// serving always comes up. `sim_fidelity` selects the sim engines'
/// execution tier (`trim serve --fidelity fast|register`); both tiers
/// serve bit-identical logits. `sim_shard` selects how the farm cuts each
/// batch (`trim serve --shard filter|pipeline|spatial|hybrid|auto`);
/// every mode serves bit-identical logits too. `sim_canary` is the
/// shadow-execution sampling rate (`trim serve --canary RATE`): the
/// fraction of fast-tier shards re-run on a `Register`-fidelity oracle
/// off the hot path, with divergence surfaced through the metrics
/// (0 disables the canary thread entirely). `sim_chaos` is the seeded
/// fault-injection plan (`trim serve --chaos RATE --chaos-seed S
/// --chaos-model pe|rsrb|mem|slow|hang`): each sim engine
/// deterministically corrupts — or, under the timing models, delays or
/// hangs — that fraction of its shard results, exercising the farm's
/// ABFT detection and self-healing loop in a live deployment
/// ([`FaultConfig::disabled`] for a fault-free farm). `sim_hedge_factor`
/// and `sim_straggler_threshold` wire the gray-failure defence
/// (`trim serve --hedge-factor F --straggler-threshold N`): shards
/// overdue past `F ×` their analytic service budget are hedged onto
/// another engine (first bit-exact result wins; `F = 0` disables
/// hedging), and an engine caught straggling `N` times is quarantined
/// on probation like a fault-corrupting one.
pub fn make_backend(
    kind: BackendKind,
    artifact_dir: impl AsRef<std::path::Path>,
    sim_engines: usize,
    sim_fidelity: crate::arch::ExecFidelity,
    sim_shard: crate::scheduler::ShardMode,
    sim_canary: f64,
    sim_chaos: FaultConfig,
    sim_hedge_factor: f64,
    sim_straggler_threshold: u32,
) -> Result<Box<dyn InferenceBackend>> {
    use crate::arch::ArchConfig;
    use crate::scheduler::{CanaryConfig, FarmConfig, SimBackend, SimNetSpec};
    let dir = artifact_dir.as_ref();
    let make_sim = || {
        let cfg = FarmConfig::with_fidelity(sim_engines, ArchConfig::small(3, 2, 1), sim_fidelity)
            .with_canary(CanaryConfig::sampled(sim_canary))
            .with_chaos(sim_chaos)
            .with_hedge(sim_hedge_factor, sim_straggler_threshold);
        Box::new(SimBackend::with_farm_config(cfg, SimNetSpec::tiny(), sim_shard))
            as Box<dyn InferenceBackend>
    };
    match kind {
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(dir)?)),
        BackendKind::Sim => Ok(make_sim()),
        BackendKind::Auto => match PjrtBackend::load(dir) {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => {
                eprintln!(
                    "notice: PJRT backend unavailable ({e:#}); \
                     falling back to the simulated engine farm \
                     ({sim_engines} engines, {sim_fidelity} fidelity, {sim_shard} sharding)"
                );
                Ok(make_sim())
            }
        },
    }
}

/// Deterministic mock backend (no PJRT): logits[k] = Σ image · (k+1) mod
/// prime — enough structure to verify routing, ordering and batching.
pub struct MockBackend {
    pub input_len: usize,
    pub classes: usize,
    /// Artificial per-image latency, for batching experiments.
    pub delay: std::time::Duration,
    /// Number of infer_batch calls observed.
    pub calls: u64,
}

impl MockBackend {
    pub fn new(input_len: usize, classes: usize) -> Self {
        Self { input_len, classes, delay: std::time::Duration::ZERO, calls: 0 }
    }

    /// The logits the mock produces for `image` (exposed for assertions).
    pub fn expected_logits(&self, image: &[i32]) -> Vec<i32> {
        let s: i64 = image.iter().map(|&v| v as i64).sum();
        (0..self.classes).map(|k| ((s * (k as i64 + 1)) % 9973) as i32).collect()
    }
}

impl InferenceBackend for MockBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
        self.calls += 1;
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay * images.len() as u32);
        }
        Ok(BatchReport::functional(images.iter().map(|img| self.expected_logits(img)).collect()))
    }

    fn describe(&self) -> String {
        format!("mock[{} classes]", self.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert!("tpu".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }

    #[test]
    fn sim_backend_needs_no_artifacts() {
        let mut b = make_backend(
            BackendKind::Sim,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast,
            crate::scheduler::ShardMode::Auto,
            0.0,
            FaultConfig::disabled(),
            0.0,
            8,
        )
        .unwrap();
        let img = vec![7i32; b.input_len()];
        let r = b.infer_batch(&[&img]).unwrap();
        assert_eq!(r.outputs.len(), 1);
        let cost = r.cost.expect("sim backend must report a batch cost");
        assert!(cost.stats.cycles > 0 && cost.joules > 0.0 && cost.gops > 0.0);
        assert!(b.describe().starts_with("sim["));
    }

    #[test]
    fn auto_falls_back_to_sim_without_artifacts() {
        let b = make_backend(
            BackendKind::Auto,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast,
            crate::scheduler::ShardMode::FilterShards,
            0.0,
            FaultConfig::disabled(),
            0.0,
            8,
        )
        .unwrap();
        assert!(b.describe().starts_with("sim["), "got {}", b.describe());
    }

    #[test]
    fn explicit_pjrt_still_errors_without_artifacts() {
        assert!(make_backend(
            BackendKind::Pjrt,
            "definitely/not/a/dir",
            2,
            crate::arch::ExecFidelity::Fast,
            crate::scheduler::ShardMode::FilterShards,
            0.0,
            FaultConfig::disabled(),
            0.0,
            8,
        )
        .is_err());
    }

    #[test]
    fn mock_is_deterministic_and_order_preserving() {
        let mut b = MockBackend::new(4, 3);
        let i1 = vec![1, 2, 3, 4];
        let i2 = vec![5, 5, 5, 5];
        let r = b.infer_batch(&[&i1, &i2]).unwrap();
        assert_eq!(r.outputs[0], b.expected_logits(&i1));
        assert_eq!(r.outputs[1], b.expected_logits(&i2));
        assert!(r.cost.is_none(), "mock has no cost model");
        assert_eq!(b.calls, 1);
    }

    #[test]
    fn batch_cost_derivations_and_attribution() {
        let stats = SimStats {
            cycles: 1000,
            ext_input_reads: 300,
            weight_reads: 100,
            output_writes: 100,
            psum_buf_reads: 40,
            psum_buf_writes: 60,
            macs: 5000,
            ..Default::default()
        };
        let e = EnergyModel::paper();
        let c = BatchCost::from_stats(stats, 150.0e6, &e);
        // gops = 2·MACs·f_clk/cycles
        assert!((c.gops - 2.0 * 5000.0 * 150.0e6 / 1000.0 / 1e9).abs() < 1e-12);
        let expect_j = e.memory_energy_j(500.0, 100.0) + e.compute_energy_j(5000.0);
        assert!((c.joules - expect_j).abs() < 1e-18);
        // attribution: divisible counters split, cycles/GOPS shared
        let per = c.per_request(4);
        assert_eq!(per.batch_cycles, 1000);
        assert!((per.off_chip_accesses - 125.0).abs() < 1e-12);
        assert!((per.on_chip_accesses - 25.0).abs() < 1e-12);
        assert!((per.macs - 1250.0).abs() < 1e-12);
        assert!((per.joules - expect_j / 4.0).abs() < 1e-18);
        assert!((per.gops - c.gops).abs() < 1e-12);
        // degenerate batch size never divides by zero
        assert_eq!(c.per_request(0).batch_cycles, 1000);
    }

    #[test]
    fn layer_cost_folds_by_name() {
        let s1 = SimStats { cycles: 10, ext_input_reads: 4, weight_reads: 1, output_writes: 2,
            psum_buf_reads: 3, psum_buf_writes: 5, macs: 100, ..Default::default() };
        let s2 = SimStats { cycles: 7, ext_input_reads: 2, macs: 50, ..Default::default() };
        let mut acc: Vec<LayerCost> = Vec::new();
        LayerCost::fold_into(&mut acc, &LayerCost::from_stats("A", &s1));
        LayerCost::fold_into(&mut acc, &LayerCost::from_stats("B", &s2));
        LayerCost::fold_into(&mut acc, &LayerCost::from_stats("A", &s2));
        assert_eq!(acc.len(), 2, "folds by name, appends new names");
        assert_eq!(acc[0].name, "A");
        assert_eq!(acc[0].cycles, 17);
        assert_eq!(acc[0].off_chip_accesses, 4 + 1 + 2 + 2);
        assert_eq!(acc[0].on_chip_accesses, 8);
        assert_eq!(acc[0].macs, 150);
        assert_eq!(acc[1].name, "B");
        assert_eq!(acc[1].cycles, 7);
        // accumulation saturates instead of wrapping near u64::MAX
        let mut pegged = LayerCost { name: "A".into(), cycles: u64::MAX - 5, ..Default::default() };
        pegged.add(&acc[0]);
        assert_eq!(pegged.cycles, u64::MAX);
        pegged.add_stats(&s1);
        assert_eq!(pegged.cycles, u64::MAX);
        // the builder attaches the breakdown without touching the totals
        let c = BatchCost::from_stats(s1, 150.0e6, &EnergyModel::paper());
        let gops = c.gops;
        let c = c.with_per_layer(acc.clone());
        assert_eq!(c.per_layer, acc);
        assert_eq!(c.gops, gops);
    }
}
