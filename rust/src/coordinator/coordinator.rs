//! The coordinator proper: bounded ingress queue → admission control →
//! deadline-aware batcher → engine thread → typed responses, with shared
//! metrics and a graceful drain path.
//!
//! Request lifecycle:
//!
//! ```text
//! submit_with ── try_admit ──► sync_channel(queue_cap) ──► Batcher ──► backend
//!      │              │                                       │           │
//!      │         Overloaded /                          DeadlineExceeded   │
//!      │          Shutdown                               (screened)       │
//!      └──◄────── typed ServeError ◄──── EngineFailed / Shutdown ◄────────┘
//! ```
//!
//! Every admitted request resolves exactly once over its reply channel
//! with a [`ServeResult`] — logits or a typed [`ServeError`], never an
//! empty-logits sentinel and never a silent hang.

use super::admission::{AdmissionConfig, AdmissionControl};
use super::backend::InferenceBackend;
use super::batcher::{Batcher, BatcherConfig};
use super::error::{ServeError, ServeResult};
use super::metrics::{MetricsSnapshot, ServeMetrics};
use super::request::{InferenceRequest, InferenceResponse};
use crate::obs;
use crate::util::sync::{lock_unpoisoned, AtomicU64, Mutex, Ordering};
use anyhow::{bail, Context as _, Result};
use std::panic::AssertUnwindSafe;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration: batching policy + admission policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub admission: AdmissionConfig,
}

/// Handle to a running coordinator (one engine thread over one backend).
///
/// All methods take `&self`, so a `Coordinator` can be shared behind an
/// `Arc` (the router does) — including [`Coordinator::shutdown`], which
/// any holder may invoke; drain is idempotent.
pub struct Coordinator {
    /// Bounded ingress sender; `None` once draining (admission closed).
    tx: Mutex<Option<mpsc::SyncSender<InferenceRequest>>>,
    admission: Arc<AdmissionControl>,
    metrics: Arc<ServeMetrics>,
    next_id: AtomicU64,
    input_len: usize,
    engine: Mutex<Option<JoinHandle<()>>>,
    backend_desc: String,
}

impl Coordinator {
    /// Start the engine thread. The `factory` runs *on* the engine thread
    /// because PJRT handles are `Rc`-based (not `Send`); startup errors
    /// (missing artifacts, compile failures) are propagated back here.
    pub fn start_with<F>(factory: F, cfg: CoordinatorConfig) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        // The channel itself is sized to the admission cap; admission
        // accounting guarantees occupancy stays strictly below it, so a
        // `try_send` after a successful `try_admit` can only fail when the
        // engine side is gone (never `Full` in practice — handled anyway).
        let (tx, rx) = mpsc::sync_channel::<InferenceRequest>(cfg.admission.queue_cap.max(1));
        let admission = Arc::new(AdmissionControl::new(cfg.admission));
        let metrics = Arc::new(ServeMetrics::new());
        let engine_metrics = metrics.clone();
        let engine_admission = admission.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, String)>>();
        let engine = std::thread::Builder::new()
            .name("trim-engine".into())
            .spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.input_len(), b.describe())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::engine_loop(backend, cfg, rx, engine_admission, engine_metrics)
            })
            .context("spawning engine thread")?;
        match ready_rx.recv() {
            Ok(Ok((input_len, backend_desc))) => Ok(Self {
                tx: Mutex::new(Some(tx)),
                admission,
                metrics,
                next_id: AtomicU64::new(0),
                input_len,
                engine: Mutex::new(Some(engine)),
                backend_desc,
            }),
            Ok(Err(e)) => {
                let _ = engine.join();
                Err(e)
            }
            Err(_) => bail!("engine thread died during startup"),
        }
    }

    fn engine_loop(
        mut backend: Box<dyn InferenceBackend>,
        cfg: CoordinatorConfig,
        rx: mpsc::Receiver<InferenceRequest>,
        admission: Arc<AdmissionControl>,
        metrics: Arc<ServeMetrics>,
    ) {
        let batcher = Batcher::new(cfg.batcher, rx, admission.clone(), metrics.clone());
        while let Some(batch) = batcher.next_batch() {
            // Past the drain deadline: stop executing, reject the backlog.
            if admission.drain_expired() {
                metrics.record_drain_rejected(batch.len() as u64);
                for req in batch {
                    let InferenceRequest { id, span, reply, .. } = req;
                    let _ = reply.send(Err(ServeError::Shutdown));
                    obs::tracer()
                        .finish_with(span, format!("id={id} err=shutdown cause=drain-deadline"));
                }
                continue;
            }
            // Queue wait per request = admission → batch execution start;
            // service = the backend call itself. Both feed the obs
            // histograms so the two components of latency stay separable.
            let exec_start = Instant::now();
            let waits: Vec<_> = batch
                .iter()
                .map(|r| exec_start.saturating_duration_since(r.enqueued_at))
                .collect();
            let batch_span = obs::tracer().begin("serve.batch", 0);
            let images: Vec<&[i32]> = batch.iter().map(|r| r.image.as_slice()).collect();
            // A panicking backend must not take the engine loop — and with
            // it every queued request — down: contain the unwind and treat
            // it as a failed batch.
            let result = std::panic::catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&images)))
                .unwrap_or_else(|payload| {
                    Err(anyhow::anyhow!("backend panicked: {}", panic_message(payload.as_ref())))
                });
            let service = exec_start.elapsed();
            metrics.record_queue_service(&waits, service);
            obs::tracer()
                .finish_with(batch_span, format!("n={} ok={}", batch.len(), result.is_ok()));
            match result {
                Ok(report) => {
                    let n = batch.len();
                    // Feed the admission estimators (cost budget + the
                    // batcher's service-time estimate) from the executed
                    // batch before attributing cost per request.
                    admission.observe_batch(n, report.cost.as_ref().map(|c| c.stats.cycles), service);
                    // Attribute the batch's simulated cost per request:
                    // divisible counters split evenly, cycles are shared.
                    let per_req = report.cost.as_ref().map(|c| c.per_request(n));
                    let resps: Vec<(InferenceRequest, InferenceResponse)> = batch
                        .into_iter()
                        .zip(report.outputs)
                        .map(|(req, logits)| {
                            let resp = InferenceResponse::from_logits(
                                req.id,
                                logits,
                                req.enqueued_at,
                                req.deadline,
                                n,
                                per_req,
                            );
                            (req, resp)
                        })
                        .collect();
                    // record before replying so observers see consistent
                    // counters as soon as their response arrives
                    let lats: Vec<_> = resps.iter().map(|(_, r)| r.latency).collect();
                    metrics.record_batch(&lats, report.cost.as_ref());
                    for (req, resp) in resps {
                        let detail = format!("id={} batch={n} class={:?}", req.id, resp.class);
                        let _ = req.reply.send(Ok(resp)); // receiver may be gone
                        obs::tracer().finish_with(req.span, detail);
                    }
                }
                Err(e) => {
                    eprintln!("engine batch failed: {e:#}");
                    metrics.record_engine_failed(batch.len() as u64);
                    let reason = format!("{e:#}");
                    for req in batch {
                        let InferenceRequest { id, span, reply, .. } = req;
                        let _ = reply.send(Err(ServeError::EngineFailed { reason: reason.clone() }));
                        obs::tracer().finish_with(span, format!("id={id} err=engine_failed"));
                    }
                }
            }
        }
    }

    /// Submit one image (best-effort, no deadline); returns the channel
    /// the typed result arrives on. Synchronous rejections (shed,
    /// draining, engine gone) come back as an `anyhow::Error` wrapping a
    /// [`ServeError`] — recover the variant with
    /// `err.downcast_ref::<ServeError>()`.
    #[must_use = "the receiver resolves the request — dropping it loses the reply"]
    pub fn submit(&self, image: Vec<i32>) -> Result<mpsc::Receiver<ServeResult>> {
        self.submit_with(image, None)
    }

    /// Submit one image with an optional absolute deadline.
    #[must_use = "the receiver resolves the request — dropping it loses the reply"]
    pub fn submit_with(
        &self,
        image: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<ServeResult>> {
        self.submit_for(image, deadline, None)
    }

    /// Submit one image with an optional deadline and client identity.
    /// The client id keys the per-client admission quota
    /// (`--client-rps`); `None` shares the anonymous bucket.
    #[must_use = "the receiver resolves the request — dropping it loses the reply"]
    pub fn submit_for(
        &self,
        image: Vec<i32>,
        deadline: Option<Instant>,
        client: Option<String>,
    ) -> Result<mpsc::Receiver<ServeResult>> {
        if image.len() != self.input_len {
            bail!("image length {} != expected {}", image.len(), self.input_len);
        }
        if let Err(e) = self.admission.try_admit_for(client.as_deref()) {
            if matches!(e, ServeError::Overloaded { .. }) {
                self.metrics.record_shed();
            }
            return Err(e.into());
        }
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let span = obs::tracer().begin("serve.request", 0);
        let req = InferenceRequest {
            id,
            image,
            enqueued_at: Instant::now(),
            deadline,
            client,
            span,
            reply,
        };
        let send_result = {
            let guard = lock_unpoisoned(&self.tx);
            match guard.as_ref() {
                // try_send never blocks, so holding the lock here is fine.
                Some(tx) => tx.try_send(req).map_err(|e| match e {
                    mpsc::TrySendError::Full(r) => {
                        (r, ServeError::Overloaded { retry_after: self.admission.retry_after() })
                    }
                    mpsc::TrySendError::Disconnected(r) => {
                        (r, ServeError::EngineFailed { reason: "engine thread gone".into() })
                    }
                }),
                // Raced with begin_drain between try_admit and here.
                None => Err((req, ServeError::Shutdown)),
            }
        };
        match send_result {
            Ok(()) => Ok(rx),
            Err((req, err)) => {
                // The request never reached the queue: give its admission
                // slot back and — crucially — finish the span it opened,
                // so a dead engine no longer leaks `serve.request` spans.
                self.admission.release(1);
                if matches!(err, ServeError::Overloaded { .. }) {
                    self.metrics.record_shed();
                }
                obs::tracer().finish_with(req.span, format!("id={id} err={}", err.kind()));
                Err(err.into())
            }
        }
    }

    /// Submit and block for the result.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferenceResponse> {
        Ok(self.submit(image)?.recv()??)
    }

    /// Close admission and arm the drain deadline: new submits fail with
    /// [`ServeError::Shutdown`]; already-queued work keeps executing until
    /// `by`, after which the engine loop rejects the backlog. Idempotent —
    /// the earliest deadline wins. Does not block; pair with
    /// [`Coordinator::join_engine`] (or use [`Coordinator::shutdown`]).
    pub fn begin_drain(&self, by: Instant) {
        self.admission.begin_drain(by);
        // Dropping the ingress sender disconnects the batcher's channel
        // once the queue empties, which ends the engine loop.
        lock_unpoisoned(&self.tx).take();
    }

    /// Join the engine thread (idempotent; no-op if already joined).
    pub fn join_engine(&self) {
        let handle = lock_unpoisoned(&self.engine).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop admission, flush what is queued within
    /// `grace`, reject the remainder as `Shutdown`, join the engine
    /// thread, and return the final metrics snapshot. Every in-flight
    /// request has resolved (one way or the other) by the time this
    /// returns.
    pub fn shutdown(&self, grace: Duration) -> MetricsSnapshot {
        self.begin_drain(Instant::now() + grace);
        self.join_engine();
        self.metrics.snapshot()
    }

    /// True once a drain has begun (admission closed).
    pub fn is_draining(&self) -> bool {
        self.admission.is_draining()
    }

    /// The admission controller (shared with the engine thread).
    pub fn admission(&self) -> &AdmissionControl {
        &self.admission
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn backend_description(&self) -> &str {
        &self.backend_desc
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Preserve drain-everything semantics on drop: a generous grace
        // window means whatever is queued still executes before the join.
        self.shutdown(Duration::from_secs(60));
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use crate::coordinator::testing::FaultInjectingBackend;
    use std::time::Duration;

    fn mock_coordinator(max_batch: usize, max_wait_ms: u64) -> (Coordinator, MockBackend) {
        let probe = MockBackend::new(4, 3);
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
            admission: AdmissionConfig::default(),
        };
        let c = Coordinator::start_with(|| Ok(Box::new(MockBackend::new(4, 3)) as _), cfg).unwrap();
        (c, probe)
    }

    #[test]
    fn single_request_roundtrip() {
        let (c, probe) = mock_coordinator(4, 1);
        let img = vec![1, 2, 3, 4];
        let resp = c.infer(img.clone()).unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(c.metrics().requests, 1);
    }

    #[test]
    fn rejects_wrong_image_size() {
        let (c, _) = mock_coordinator(4, 1);
        assert!(c.submit(vec![1, 2]).is_err());
    }

    #[test]
    fn many_concurrent_requests_all_resolve_correctly() {
        let (c, probe) = mock_coordinator(8, 5);
        let pending: Vec<_> = (0..50)
            .map(|i| {
                let img = vec![i, i + 1, i + 2, i + 3];
                (img.clone(), c.submit(img).unwrap())
            })
            .collect();
        for (img, rx) in pending {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.logits, probe.expected_logits(&img));
        }
        let m = c.metrics();
        assert_eq!(m.requests, 50);
        assert!(m.batches <= 50);
        assert!(m.throughput_rps > 0.0);
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let (c, _) = mock_coordinator(16, 50);
        let pending: Vec<_> = (0..32).map(|i| c.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let mut max_batch = 0;
        for rx in pending {
            max_batch = max_batch.max(rx.recv().unwrap().unwrap().batch_size);
        }
        assert!(max_batch > 1, "expected batched execution, got singletons");
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let (c, _) = mock_coordinator(4, 1);
        let _ = c.infer(vec![0, 0, 0, 0]).unwrap();
        drop(c); // must not hang
    }

    #[test]
    fn admission_sheds_past_queue_cap_and_everything_resolves() {
        // Slow backend + tiny queue: a burst must shed with typed
        // Overloaded while admitted requests still resolve with logits.
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig { queue_cap: 1, budget_cycles: None, client_rps: None },
        };
        let c = Coordinator::start_with(
            || {
                let mut b = MockBackend::new(4, 3);
                b.delay = Duration::from_millis(30);
                Ok(Box::new(b) as _)
            },
            cfg,
        )
        .unwrap();
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for i in 0..10 {
            match c.submit(vec![i, 0, 0, 0]) {
                Ok(rx) => admitted.push(rx),
                Err(e) => {
                    let se = e.downcast_ref::<ServeError>().expect("typed rejection");
                    match se {
                        ServeError::Overloaded { retry_after } => {
                            assert!(*retry_after >= Duration::from_millis(1));
                            shed += 1;
                        }
                        other => panic!("expected Overloaded, got {other:?}"),
                    }
                }
            }
        }
        assert!(shed > 0, "a 10-burst into a cap-1 queue over a 30 ms backend must shed");
        for rx in admitted {
            assert!(rx.recv().unwrap().is_ok(), "admitted requests resolve with logits");
        }
        assert_eq!(c.metrics().shed, shed, "shed counter matches observed rejections");
    }

    #[test]
    fn per_client_quota_sheds_the_chatty_client_only() {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig {
                queue_cap: 256,
                budget_cycles: None,
                client_rps: Some(2.0),
            },
        };
        let c = Coordinator::start_with(|| Ok(Box::new(MockBackend::new(4, 3)) as _), cfg).unwrap();
        // Burst of 2 (= the 1-second bucket) admits; the 3rd sheds typed.
        let mut oks = Vec::new();
        for _ in 0..2 {
            oks.push(c.submit_for(vec![0, 0, 0, 0], None, Some("hog".into())).unwrap());
        }
        let err = c.submit_for(vec![0, 0, 0, 0], None, Some("hog".into())).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::Overloaded { retry_after }) => {
                assert!(*retry_after > Duration::ZERO, "quota shed carries a token-accrual hint")
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // A different client is untouched by the hog's empty bucket.
        let quiet = c.submit_for(vec![0, 0, 0, 0], None, Some("quiet".into())).unwrap();
        oks.push(quiet);
        for rx in oks {
            assert!(rx.recv().unwrap().is_ok(), "admitted requests still resolve");
        }
        assert_eq!(c.metrics().shed, 1, "the quota shed is counted like any other shed");
    }

    #[test]
    fn draining_rejects_new_submits_with_shutdown() {
        let (c, _) = mock_coordinator(4, 1);
        c.begin_drain(Instant::now() + Duration::from_secs(5));
        assert!(c.is_draining());
        let err = c.submit(vec![0, 0, 0, 0]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Shutdown));
        c.join_engine();
    }

    #[test]
    fn shutdown_resolves_every_inflight_request() {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig::default(),
        };
        let c = Coordinator::start_with(
            || {
                let mut b = MockBackend::new(4, 3);
                b.delay = Duration::from_millis(5);
                Ok(Box::new(b) as _)
            },
            cfg,
        )
        .unwrap();
        let pending: Vec<_> = (0..12).filter_map(|i| c.submit(vec![i, 0, 0, 0]).ok()).collect();
        let snap = c.shutdown(Duration::from_secs(30));
        for rx in pending {
            let r = rx.recv().expect("reply channel resolved, not dropped");
            assert!(r.is_ok() || matches!(r, Err(ServeError::Shutdown)), "got {r:?}");
        }
        assert!(snap.requests > 0);
    }

    #[test]
    fn expired_drain_deadline_rejects_backlog_as_shutdown() {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig::default(),
        };
        let c = Coordinator::start_with(
            || {
                let mut b = MockBackend::new(4, 3);
                b.delay = Duration::from_millis(20);
                Ok(Box::new(b) as _)
            },
            cfg,
        )
        .unwrap();
        let pending: Vec<_> = (0..6).filter_map(|i| c.submit(vec![i, 0, 0, 0]).ok()).collect();
        // Zero grace: whatever is still queued must be rejected, fast.
        let t0 = Instant::now();
        let snap = c.shutdown(Duration::ZERO);
        assert!(t0.elapsed() < Duration::from_secs(5), "zero-grace drain must not linger");
        let mut rejected = 0u64;
        for rx in pending {
            match rx.recv().expect("resolved") {
                Ok(_) => {}
                Err(ServeError::Shutdown) => rejected += 1,
                Err(other) => panic!("unexpected error {other:?}"),
            }
        }
        assert_eq!(snap.drain_rejected, rejected, "counter matches rejected backlog");
    }

    #[test]
    fn engine_failure_is_a_typed_error_not_empty_logits() {
        let cfg = CoordinatorConfig::default();
        let c = Coordinator::start_with(
            || Ok(Box::new(FaultInjectingBackend::new(4, 3, 1)) as _),
            cfg,
        )
        .unwrap();
        let err = c.infer(vec![1, 2, 3, 4]).unwrap_err();
        let se = err.downcast_ref::<ServeError>().expect("typed engine failure");
        match se {
            ServeError::EngineFailed { reason } => {
                assert!(reason.contains("injected fault"), "got {reason}")
            }
            other => panic!("expected EngineFailed, got {other:?}"),
        }
        assert_eq!(c.metrics().engine_failed, 1);
    }

    #[test]
    fn backend_panic_is_contained_as_engine_failure() {
        let cfg = CoordinatorConfig::default();
        let c = Coordinator::start_with(
            || Ok(Box::new(FaultInjectingBackend::new(4, 3, 1).panicking()) as _),
            cfg,
        )
        .unwrap();
        let err = c.infer(vec![1, 2, 3, 4]).unwrap_err();
        let se = err.downcast_ref::<ServeError>().expect("typed engine failure");
        match se {
            ServeError::EngineFailed { reason } => {
                assert!(reason.contains("panicked"), "got {reason}")
            }
            other => panic!("expected EngineFailed, got {other:?}"),
        }
        // fail_every=1 faults every call, so the second request errors too
        // — but getting a *typed* error back proves the engine loop
        // survived the first panic instead of unwinding away.
        let err2 = c.infer(vec![1, 2, 3, 4]).unwrap_err();
        assert!(err2.downcast_ref::<ServeError>().is_some(), "loop survived the panic");
    }
}
