//! The coordinator proper: ingress queue → batcher → engine thread →
//! responses, with shared metrics.

use super::backend::InferenceBackend;
use super::batcher::{Batcher, BatcherConfig};
use super::metrics::{MetricsSnapshot, ServeMetrics};
use super::request::{InferenceRequest, InferenceResponse};
use crate::obs;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

/// Handle to a running coordinator. Cloned handles share the ingress
/// queue; dropping the last handle shuts the engine thread down.
pub struct Coordinator {
    tx: mpsc::Sender<InferenceRequest>,
    metrics: Arc<ServeMetrics>,
    next_id: Arc<AtomicU64>,
    input_len: usize,
    engine: Option<JoinHandle<()>>,
    backend_desc: String,
}

impl Coordinator {
    /// Start the engine thread. The `factory` runs *on* the engine thread
    /// because PJRT handles are `Rc`-based (not `Send`); startup errors
    /// (missing artifacts, compile failures) are propagated back here.
    pub fn start_with<F>(factory: F, cfg: CoordinatorConfig) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        let metrics = Arc::new(ServeMetrics::new());
        let engine_metrics = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, String)>>();
        let engine = std::thread::Builder::new()
            .name("trim-engine".into())
            .spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.input_len(), b.describe())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::engine_loop(backend, cfg, rx, engine_metrics)
            })
            .expect("spawning engine thread");
        match ready_rx.recv() {
            Ok(Ok((input_len, backend_desc))) => Ok(Self {
                tx,
                metrics,
                next_id: Arc::new(AtomicU64::new(0)),
                input_len,
                engine: Some(engine),
                backend_desc,
            }),
            Ok(Err(e)) => {
                let _ = engine.join();
                Err(e)
            }
            Err(_) => bail!("engine thread died during startup"),
        }
    }

    fn engine_loop(
        mut backend: Box<dyn InferenceBackend>,
        cfg: CoordinatorConfig,
        rx: mpsc::Receiver<InferenceRequest>,
        metrics: Arc<ServeMetrics>,
    ) {
        let batcher = Batcher::new(cfg.batcher, rx);
        while let Some(batch) = batcher.next_batch() {
            // Queue wait per request = admission → batch execution start;
            // service = the backend call itself. Both feed the obs
            // histograms so the two components of latency stay separable.
            let exec_start = Instant::now();
            let waits: Vec<_> = batch
                .iter()
                .map(|r| exec_start.saturating_duration_since(r.enqueued_at))
                .collect();
            let batch_span = obs::tracer().begin("serve.batch", 0);
            let images: Vec<&[i32]> = batch.iter().map(|r| r.image.as_slice()).collect();
            let result = backend.infer_batch(&images);
            metrics.record_queue_service(&waits, exec_start.elapsed());
            obs::tracer().finish_with(
                batch_span,
                format!("n={} ok={}", batch.len(), result.is_ok()),
            );
            match result {
                Ok(report) => {
                    let n = batch.len();
                    // Attribute the batch's simulated cost per request:
                    // divisible counters split evenly, cycles are shared.
                    let per_req = report.cost.as_ref().map(|c| c.per_request(n));
                    let resps: Vec<(InferenceRequest, InferenceResponse)> = batch
                        .into_iter()
                        .zip(report.outputs)
                        .map(|(req, logits)| {
                            let resp = InferenceResponse::from_logits(
                                req.id,
                                logits,
                                req.enqueued_at,
                                n,
                                per_req,
                            );
                            (req, resp)
                        })
                        .collect();
                    // record before replying so observers see consistent
                    // counters as soon as their response arrives
                    let lats: Vec<_> = resps.iter().map(|(_, r)| r.latency).collect();
                    metrics.record_batch(&lats, report.cost.as_ref());
                    for (req, resp) in resps {
                        let detail = format!("id={} batch={n} class={:?}", req.id, resp.class);
                        let _ = req.reply.send(resp); // receiver may be gone
                        obs::tracer().finish_with(req.span, detail);
                    }
                }
                Err(e) => {
                    // Report failure as empty logits (class/cost `None`); a
                    // real deployment would attach an error enum — the
                    // tests only need the requests to resolve.
                    eprintln!("engine batch failed: {e:#}");
                    let n = batch.len();
                    for req in batch {
                        let _ = req.reply.send(InferenceResponse::from_logits(
                            req.id,
                            vec![],
                            req.enqueued_at,
                            n,
                            None,
                        ));
                        obs::tracer().finish_with(req.span, format!("id={} ok=false", req.id));
                    }
                }
            }
        }
    }

    /// Submit one image; returns the channel the response arrives on.
    pub fn submit(&self, image: Vec<i32>) -> Result<mpsc::Receiver<InferenceResponse>> {
        if image.len() != self.input_len {
            bail!("image length {} != expected {}", image.len(), self.input_len);
        }
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let span = obs::tracer().begin("serve.request", 0);
        self.tx
            .send(InferenceRequest { id, image, enqueued_at: Instant::now(), span, reply })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferenceResponse> {
        Ok(self.submit(image)?.recv()?)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn backend_description(&self) -> &str {
        &self.backend_desc
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Close the ingress channel, then join the engine thread.
        let (dead_tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dead_tx));
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use std::time::Duration;

    fn mock_coordinator(max_batch: usize, max_wait_ms: u64) -> (Coordinator, MockBackend) {
        let probe = MockBackend::new(4, 3);
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
        };
        let c = Coordinator::start_with(|| Ok(Box::new(MockBackend::new(4, 3)) as _), cfg).unwrap();
        (c, probe)
    }

    #[test]
    fn single_request_roundtrip() {
        let (c, probe) = mock_coordinator(4, 1);
        let img = vec![1, 2, 3, 4];
        let resp = c.infer(img.clone()).unwrap();
        assert_eq!(resp.logits, probe.expected_logits(&img));
        assert_eq!(c.metrics().requests, 1);
    }

    #[test]
    fn rejects_wrong_image_size() {
        let (c, _) = mock_coordinator(4, 1);
        assert!(c.submit(vec![1, 2]).is_err());
    }

    #[test]
    fn many_concurrent_requests_all_resolve_correctly() {
        let (c, probe) = mock_coordinator(8, 5);
        let pending: Vec<_> = (0..50)
            .map(|i| {
                let img = vec![i, i + 1, i + 2, i + 3];
                (img.clone(), c.submit(img).unwrap())
            })
            .collect();
        for (img, rx) in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits, probe.expected_logits(&img));
        }
        let m = c.metrics();
        assert_eq!(m.requests, 50);
        assert!(m.batches <= 50);
        assert!(m.throughput_rps > 0.0);
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let (c, _) = mock_coordinator(16, 50);
        let pending: Vec<_> = (0..32).map(|i| c.submit(vec![i, 0, 0, 0]).unwrap()).collect();
        let mut max_batch = 0;
        for rx in pending {
            max_batch = max_batch.max(rx.recv().unwrap().batch_size);
        }
        assert!(max_batch > 1, "expected batched execution, got singletons");
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let (c, _) = mock_coordinator(4, 1);
        let _ = c.infer(vec![0, 0, 0, 0]).unwrap();
        drop(c); // must not hang
    }
}
