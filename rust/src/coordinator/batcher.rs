//! Dynamic batcher: time-or-size batching over the ingress queue.
//!
//! Policy (the standard serving trade-off): a batch closes when it reaches
//! `max_batch` requests OR `max_wait` has elapsed since its first request
//! arrived — small batches under low load (latency), full batches under
//! high load (throughput). The TrIM engine analogy: a batch is the set of
//! ifmaps sharing one weight-resident pass, like the paper's batch-3/4
//! normalisation reuses loaded weights across images.

use super::request::InferenceRequest;
use crate::obs;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls requests off the ingress channel and forms batches.
pub struct Batcher {
    cfg: BatcherConfig,
    rx: Receiver<InferenceRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, rx: Receiver<InferenceRequest>) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { cfg, rx }
    }

    /// Block for the next batch. Returns `None` when the ingress channel
    /// is closed and drained (shutdown). Each formed batch emits a
    /// `batch.formed` trace event naming which bound closed it (`size`,
    /// `deadline` or `shutdown`).
    pub fn next_batch(&self) -> Option<Vec<InferenceRequest>> {
        // Block indefinitely for the first request of the batch.
        let first = self.rx.recv().ok()?;
        let deadline = Instant::now() + self.cfg.max_wait;
        let mut batch = vec![first];
        let mut cause = "size";
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                cause = "deadline";
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => {
                    cause = "deadline";
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    cause = "shutdown";
                    break;
                }
            }
        }
        obs::tracer().event("batch.formed", 0, format!("n={} cause={cause}", batch.len()));
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> (InferenceRequest, mpsc::Receiver<super::super::request::InferenceResponse>) {
        let (tx, rx) = mpsc::channel();
        let span = obs::tracer().begin("serve.request", 0);
        (InferenceRequest { id, image: vec![], enqueued_at: Instant::now(), span, reply: tx }, rx)
    }

    #[test]
    fn size_bound_closes_batch() {
        let (tx, rx) = mpsc::channel();
        let b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(5) }, rx);
        let keep: Vec<_> = (0..5)
            .map(|i| {
                let (r, rv) = req(i);
                tx.send(r).unwrap();
                rv
            })
            .collect();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3, "size bound");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 2, "drained remainder");
        drop(keep);
    }

    #[test]
    fn time_bound_closes_batch() {
        let (tx, rx) = mpsc::channel();
        let b = Batcher::new(BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(10) }, rx);
        let (r, _rv) = req(7);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block on max_batch");
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        let b = Batcher::new(BatcherConfig::default(), rx);
        drop(tx);
        assert!(b.next_batch().is_none());
    }
}
