//! Deadline-aware continuous batcher over the bounded ingress queue.
//!
//! Policy: a batch closes when it reaches `max_batch` requests OR its
//! close time passes, where the close time is
//!
//! ```text
//! close_by = min( first_arrival + max_wait,
//!                 min_i (deadline_i − EWMA service time) )
//! ```
//!
//! — the standard time-or-size trade-off (small batches under low load
//! for latency, full batches under high load for throughput), tightened
//! so that every deadline-carrying member still makes its deadline after
//! one more estimated backend pass. Requests whose deadline cannot be met
//! even by an immediate pass are rejected up front with
//! [`ServeError::DeadlineExceeded`] rather than executed uselessly.
//!
//! The batcher is also the release point of the admission queue: pulling
//! a request off the ingress channel frees its
//! [`super::AdmissionControl`] depth slot, so "queue depth" always means
//! admitted-but-not-yet-batched.
//!
//! The TrIM engine analogy: a batch is the set of ifmaps sharing one
//! weight-resident pass, like the paper's batch-3/4 normalisation reuses
//! loaded weights across images.

use super::admission::AdmissionControl;
use super::error::ServeError;
use super::metrics::ServeMetrics;
use super::request::InferenceRequest;
use crate::obs;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls requests off the ingress channel and forms batches.
pub struct Batcher {
    cfg: BatcherConfig,
    rx: Receiver<InferenceRequest>,
    admission: Arc<AdmissionControl>,
    metrics: Arc<ServeMetrics>,
}

impl Batcher {
    pub fn new(
        cfg: BatcherConfig,
        rx: Receiver<InferenceRequest>,
        admission: Arc<AdmissionControl>,
        metrics: Arc<ServeMetrics>,
    ) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { cfg, rx, admission, metrics }
    }

    /// Reject a request whose deadline cannot be met even by an immediate
    /// backend pass (`now + estimated service > deadline`); returns the
    /// request when it is still viable. Rejection resolves the caller
    /// with `DeadlineExceeded` and finishes the request span.
    fn screen(&self, req: InferenceRequest, est_service: Duration) -> Option<InferenceRequest> {
        let Some(deadline) = req.deadline else { return Some(req) };
        let projected = Instant::now() + est_service;
        if projected <= deadline {
            return Some(req);
        }
        let missed_by = projected.saturating_duration_since(deadline);
        self.metrics.record_deadline_expired();
        let InferenceRequest { id, span, reply, .. } = req;
        let _ = reply.send(Err(ServeError::DeadlineExceeded { missed_by }));
        obs::tracer().finish_with(
            span,
            format!("id={id} err=deadline_exceeded missed_by_us={}", missed_by.as_micros()),
        );
        None
    }

    /// Block for the next non-empty batch. Returns `None` when the
    /// ingress channel is closed and drained (shutdown). Each formed
    /// batch emits a `batch.formed` trace event naming which bound closed
    /// it (`size`, `wait`, `deadline-budget`, `drain` or `shutdown`).
    pub fn next_batch(&self) -> Option<Vec<InferenceRequest>> {
        'outer: loop {
            // Block indefinitely for the first request of the batch; its
            // depth slot is released the moment it leaves the queue.
            let first = self.rx.recv().ok()?;
            self.admission.release(1);
            let est_service = self.admission.service_estimate();
            let Some(first) = self.screen(first, est_service) else {
                // The whole prospective batch expired before it began —
                // go back to blocking for a fresh first request.
                continue 'outer;
            };
            let arrival = Instant::now();
            let mut close_by = arrival + self.cfg.max_wait;
            let mut tightened = false;
            let mut batch = Vec::with_capacity(self.cfg.max_batch);
            // Tighten the close time so this member still makes its
            // deadline after one more estimated backend pass.
            fn push(
                batch: &mut Vec<InferenceRequest>,
                req: InferenceRequest,
                est_service: Duration,
                close_by: &mut Instant,
                tightened: &mut bool,
            ) {
                if let Some(t) = req.deadline.and_then(|d| d.checked_sub(est_service)) {
                    if t < *close_by {
                        *close_by = t;
                        *tightened = true;
                    }
                }
                batch.push(req);
            }
            push(&mut batch, first, est_service, &mut close_by, &mut tightened);
            let mut cause = "size";
            while batch.len() < self.cfg.max_batch {
                if self.admission.is_draining() {
                    // Drain flush: take whatever is already queued, never
                    // wait for more load that admission no longer accepts.
                    match self.rx.try_recv() {
                        Ok(req) => {
                            self.admission.release(1);
                            if let Some(req) = self.screen(req, est_service) {
                                push(&mut batch, req, est_service, &mut close_by, &mut tightened);
                            }
                        }
                        Err(TryRecvError::Empty) => {
                            cause = "drain";
                            break;
                        }
                        Err(TryRecvError::Disconnected) => {
                            cause = "shutdown";
                            break;
                        }
                    }
                    continue;
                }
                let now = Instant::now();
                if now >= close_by {
                    cause = if tightened { "deadline-budget" } else { "wait" };
                    break;
                }
                match self.rx.recv_timeout(close_by - now) {
                    Ok(req) => {
                        self.admission.release(1);
                        if let Some(req) = self.screen(req, est_service) {
                            push(&mut batch, req, est_service, &mut close_by, &mut tightened);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        cause = if tightened { "deadline-budget" } else { "wait" };
                        break;
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        cause = "shutdown";
                        break;
                    }
                }
            }
            if batch.is_empty() {
                // Everything pulled this round expired. Either the channel
                // is gone (shutdown) or we go back for a fresh first.
                if cause == "shutdown" {
                    return None;
                }
                continue 'outer;
            }
            obs::tracer().event("batch.formed", 0, format!("n={} cause={cause}", batch.len()));
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::error::ServeResult;
    use std::sync::mpsc;
    use std::time::Instant;

    fn harness(cfg: BatcherConfig) -> (mpsc::Sender<InferenceRequest>, Batcher, Arc<AdmissionControl>) {
        let (tx, rx) = mpsc::channel();
        let admission = Arc::new(AdmissionControl::default());
        let b = Batcher::new(cfg, rx, admission.clone(), Arc::new(ServeMetrics::new()));
        (tx, b, admission)
    }

    fn req(id: u64, deadline: Option<Instant>) -> (InferenceRequest, mpsc::Receiver<ServeResult>) {
        let (tx, rx) = mpsc::channel();
        let span = obs::tracer().begin("serve.request", 0);
        let r = InferenceRequest {
            id,
            image: vec![],
            enqueued_at: Instant::now(),
            deadline,
            client: None,
            span,
            reply: tx,
        };
        (r, rx)
    }

    #[test]
    fn size_bound_closes_batch() {
        let (tx, b, _) =
            harness(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(5) });
        let keep: Vec<_> = (0..5)
            .map(|i| {
                let (r, rv) = req(i, None);
                tx.send(r).unwrap();
                rv
            })
            .collect();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3, "size bound");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 2, "drained remainder");
        drop(keep);
    }

    #[test]
    fn time_bound_closes_batch() {
        let (tx, b, _) =
            harness(BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(10) });
        let (r, _rv) = req(7, None);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not block on max_batch");
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, b, _) = harness(BatcherConfig::default());
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn pulling_requests_releases_admission_slots() {
        let (tx, b, admission) =
            harness(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) });
        let mut keep = Vec::new();
        for i in 0..3 {
            admission.try_admit().unwrap();
            let (r, rv) = req(i, None);
            tx.send(r).unwrap();
            keep.push(rv);
        }
        assert_eq!(admission.depth(), 3);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(admission.depth(), 0, "batched requests freed their queue slots");
        drop(keep);
    }

    #[test]
    fn expired_request_rejected_up_front() {
        let (tx, b, _) =
            harness(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) });
        let (dead, dead_rx) = req(0, Some(Instant::now()));
        let (live, _live_rx) = req(1, Some(Instant::now() + Duration::from_secs(60)));
        std::thread::sleep(Duration::from_millis(2)); // let the deadline lapse
        tx.send(dead).unwrap();
        tx.send(live).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        match dead_rx.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            other => panic!("expired request must resolve DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn deadline_tightens_the_close_time() {
        let (tx, b, _) =
            harness(BatcherConfig { max_batch: 100, max_wait: Duration::from_secs(30) });
        let (r, _rv) = req(0, Some(Instant::now() + Duration::from_millis(20)));
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "a 20 ms deadline must close the batch long before max_wait"
        );
    }

    #[test]
    fn drain_flushes_queued_requests_without_waiting() {
        let (tx, b, admission) =
            harness(BatcherConfig { max_batch: 100, max_wait: Duration::from_secs(30) });
        admission.begin_drain(Instant::now() + Duration::from_secs(60));
        let keep: Vec<_> = (0..2)
            .map(|i| {
                let (r, rv) = req(i, None);
                tx.send(r).unwrap();
                rv
            })
            .collect();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "drain flush takes everything queued");
        assert!(t0.elapsed() < Duration::from_secs(5), "drain must not wait out max_wait");
        drop(keep);
    }
}
