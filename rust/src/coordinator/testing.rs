//! Shared test doubles for the serving stack.
//!
//! [`FaultInjectingBackend`] started life as private test scaffolding in
//! the router tests; it is promoted here because every layer of the
//! stack (coordinator engine loop, router retry path, drain logic, the
//! chaos acceptance suite) wants the same deterministic flaky backend.
//! It serves [`MockBackend`] logits but fails (or panics on) chosen
//! `infer_batch` calls — either on a fixed `fail_every` modulus or on a
//! seeded [`FaultConfig`] plan keyed by call index, so failure schedules
//! are reproducible across runs and shareable with the farm-level fault
//! injection (`--chaos`).

use super::backend::{BatchReport, InferenceBackend, MockBackend};
use crate::fault::FaultConfig;
use anyhow::Result;

/// Fault-injecting test double: serves [`MockBackend`] logits but fails
/// (or panics on) selected `infer_batch` calls. Pins the retry/backoff,
/// error-taxonomy and drain-under-failure behaviour of the coordinator
/// and router without needing a real flaky backend.
pub struct FaultInjectingBackend {
    inner: MockBackend,
    /// Every `fail_every`-th call (1-based) is faulted; `0` disables
    /// modulus injection entirely. `1` faults every call.
    pub fail_every: u64,
    /// Panic on the faulted calls instead of returning `Err` — exercises
    /// the engine loop's `catch_unwind` containment.
    pub panic_instead: bool,
    /// Seeded fault plan keyed by call index. When enabled it decides
    /// faults *instead of* `fail_every` — the same [`FaultConfig`] the
    /// farm-level chaos path takes, so a test can drive both layers from
    /// one plan.
    pub plan: FaultConfig,
}

impl FaultInjectingBackend {
    pub fn new(input_len: usize, classes: usize, fail_every: u64) -> Self {
        Self {
            inner: MockBackend::new(input_len, classes),
            fail_every,
            panic_instead: false,
            plan: FaultConfig::disabled(),
        }
    }

    /// A double whose failure schedule is a seeded [`FaultConfig`] draw
    /// keyed by the (1-based) call index.
    pub fn with_plan(input_len: usize, classes: usize, plan: FaultConfig) -> Self {
        Self { inner: MockBackend::new(input_len, classes), fail_every: 0, panic_instead: false, plan }
    }

    /// Builder: make the injected faults panics rather than `Err`s.
    pub fn panicking(mut self) -> Self {
        self.panic_instead = true;
        self
    }

    /// The logits a non-faulted call produces (exposed for assertions).
    pub fn expected_logits(&self, image: &[i32]) -> Vec<i32> {
        self.inner.expected_logits(image)
    }

    fn faulted(&self, call: u64) -> bool {
        if self.plan.enabled() {
            self.plan.draw(call)
        } else {
            self.fail_every > 0 && call % self.fail_every == 0
        }
    }
}

impl InferenceBackend for FaultInjectingBackend {
    fn input_len(&self) -> usize {
        self.inner.input_len
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
        self.inner.calls += 1;
        if self.faulted(self.inner.calls) {
            if self.panic_instead {
                // lint: test-double — the injected panic *is* the fixture.
                panic!("injected panic on call {}", self.inner.calls);
            }
            anyhow::bail!("injected fault on call {}", self.inner.calls);
        }
        if !self.inner.delay.is_zero() {
            std::thread::sleep(self.inner.delay * images.len() as u32);
        }
        Ok(BatchReport::functional(
            images.iter().map(|img| self.inner.expected_logits(img)).collect(),
        ))
    }

    fn describe(&self) -> String {
        let mode = if self.panic_instead { "panic" } else { "err" };
        if self.plan.enabled() {
            format!("fault-injecting[rate={} seed={} mode={mode}]", self.plan.rate, self.plan.seed)
        } else {
            format!("fault-injecting[every={} mode={mode}]", self.fail_every)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultModel;

    #[test]
    fn fault_injection_faults_every_nth_call() {
        let mut b = FaultInjectingBackend::new(4, 3, 2);
        let img = vec![1, 2, 3, 4];
        let ok = b.infer_batch(&[&img]).unwrap();
        assert_eq!(ok.outputs[0], b.expected_logits(&img));
        let err = b.infer_batch(&[&img]).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "got {err:#}");
        assert!(b.infer_batch(&[&img]).is_ok(), "call 3 recovers");
        assert!(b.infer_batch(&[&img]).is_err(), "call 4 faults again");
        // fail_every = 0 disables injection
        let mut never = FaultInjectingBackend::new(4, 3, 0);
        for _ in 0..8 {
            assert!(never.infer_batch(&[&img]).is_ok());
        }
    }

    #[test]
    fn fault_injection_can_panic_instead() {
        let mut b = FaultInjectingBackend::new(4, 3, 1).panicking();
        assert!(b.describe().contains("panic"));
        let img = vec![0, 0, 0, 0];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.infer_batch(&[&img])));
        assert!(r.is_err(), "injected panic must unwind");
    }

    #[test]
    fn seeded_plan_schedule_is_reproducible() {
        let plan = FaultConfig::new(0.5, 0x7E57, FaultModel::Pe);
        let img = vec![1, 1, 1, 1];
        let run = |mut b: FaultInjectingBackend| -> Vec<bool> {
            (0..32).map(|_| b.infer_batch(&[&img]).is_ok()).collect()
        };
        let a = run(FaultInjectingBackend::with_plan(4, 3, plan));
        let b = run(FaultInjectingBackend::with_plan(4, 3, plan));
        assert_eq!(a, b, "same plan → same failure schedule");
        assert!(a.iter().any(|&ok| ok) && a.iter().any(|&ok| !ok), "rate 0.5 mixes outcomes");
        // the plan overrides the modulus path and names itself
        let c = FaultInjectingBackend::with_plan(4, 3, plan);
        assert!(c.describe().contains("rate=0.5"));
        // a different seed gives a different schedule somewhere
        let d = run(FaultInjectingBackend::with_plan(4, 3, FaultConfig::new(0.5, 1, FaultModel::Pe)));
        assert_ne!(a, d, "independent seeds disagree on 32 draws");
    }
}
