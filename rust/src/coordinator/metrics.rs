//! Serving metrics: counters, a bounded latency distribution, and the
//! cumulative simulated execution cost (cycles / memory accesses / joules)
//! reported by cost-carrying backends.
//!
//! Latencies are kept in a fixed-size **reservoir sample** (Vitter's
//! algorithm R, deterministic in-tree PRNG): under sustained load the
//! p50/p95/p99 estimates stay meaningful while memory stays O(1) — the
//! previous unbounded `Vec` grew forever. `max_latency` is tracked exactly
//! outside the reservoir.
//!
//! The accumulator is built on [`crate::obs`]: every countable field is a
//! saturating [`Counter`] (a soak run pegs at `u64::MAX` instead of
//! wrapping or panicking in debug builds), and queue-wait vs service time
//! are log₂-bucketed [`Histogram`]s recorded lock-free from the engine
//! thread. [`MetricsSnapshot`] carries [`HistogramSnapshot`] copies plus
//! the farm's shadow-canary [`CanaryReport`] and fault-tolerance
//! [`FaultReport`], merges across farms at the
//! Router, and renders itself as Prometheus exposition text
//! ([`MetricsSnapshot::render_prometheus`], `trim serve --metrics-out`)
//! or a single JSON line for the bench trajectory
//! ([`MetricsSnapshot::render_json`]).

use super::backend::{BatchCost, LayerCost};
use crate::fault::FaultReport;
use crate::obs::{self, Counter, Histogram, HistogramSnapshot};
use crate::scheduler::CanaryReport;
use crate::util::sync::{lock_unpoisoned, Mutex};
use crate::util::SplitMix64;
use std::fmt::Write as _;
use std::time::Duration;

/// Reservoir capacity: enough for stable p50/p95/p99 estimates, small
/// enough that a week of sustained load costs the same memory as a minute.
pub const LATENCY_RESERVOIR: usize = 4096;

/// Achieved simulated throughput in GOPs/s: `2·MACs / simulated seconds`.
/// Working from accumulated simulated *time* (each batch contributes
/// `cycles/f_clk`) rather than `Σcycles` priced at one clock keeps the
/// figure correct when farms with different clocks merge.
fn achieved_gops(macs: u64, sim_seconds: f64) -> f64 {
    if sim_seconds > 0.0 {
        2.0 * macs as f64 / sim_seconds / 1e9
    } else {
        0.0
    }
}

/// Point-in-time snapshot.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub p99_latency: Duration,
    pub max_latency: Duration,
    pub throughput_rps: f64,
    /// Requests shed by admission control (`ServeError::Overloaded`):
    /// bounded ingress full or queue depth × EWMA cost past the budget.
    pub shed: u64,
    /// Requests rejected because their deadline budget could not be met
    /// (`ServeError::DeadlineExceeded`), at submit or at batch formation.
    pub deadline_expired: u64,
    /// Requests that resolved with `ServeError::EngineFailed` (backend
    /// error or panic on their batch).
    pub engine_failed: u64,
    /// Requests rejected with `ServeError::Shutdown` past the drain
    /// deadline.
    pub drain_rejected: u64,
    /// Router-level retry attempts (re-dispatch of an `EngineFailed`
    /// request to another farm). Always 0 on per-coordinator snapshots;
    /// the router adds its own count into the merged view.
    pub retries: u64,
    /// Batches that carried a simulated [`BatchCost`] (0 for PJRT/mock
    /// backends — all `sim_*` fields stay zero then).
    pub sim_batches: u64,
    /// Cumulative simulated engine cycles (each batch contributes its
    /// farm-aggregated wall-clock cycles: max over parallel shards, sum
    /// over sequential phases).
    pub sim_cycles: u64,
    /// Cumulative off-chip (DRAM-side) element accesses.
    pub sim_off_chip_accesses: u64,
    /// Cumulative on-chip (psum-buffer) element accesses.
    pub sim_on_chip_accesses: u64,
    /// Cumulative MACs.
    pub sim_macs: u64,
    /// Cumulative simulated energy (J).
    pub sim_joules: f64,
    /// Cumulative simulated engine time in seconds (Σ batch
    /// `cycles/f_clk` — well-defined even across mixed-clock farms).
    pub sim_seconds: f64,
    /// Achieved simulated throughput over everything served so far, in
    /// GOPs/s: `2·sim_macs/sim_seconds`.
    pub sim_gops: f64,
    /// Clock (Hz) of the most recent cost seen — display only; rate
    /// derivations use `sim_seconds`, not this. 0 until a cost is seen.
    pub sim_f_clk: f64,
    /// Cumulative per-layer cost breakdown, folded by layer name across
    /// every cost-carrying batch (empty when no backend attributes cost
    /// per layer) — the 2408.01254-style accounting `trim farm`/`trim
    /// serve` print as a table.
    pub sim_per_layer: Vec<LayerCost>,
    /// Shadow-execution canary totals reported by cost-carrying batches
    /// (all zero when no farm runs a canary).
    pub canary: CanaryReport,
    /// Fault-tolerance totals reported by cost-carrying batches: faults
    /// injected (`--chaos`), ABFT-detected, corrected via re-execution,
    /// shards re-executed, engines quarantined, plus the gray-failure
    /// family — hedges dispatched/wasted/won, stragglers detected,
    /// engines timing-quarantined (all zero on fault-free farms).
    pub fault: FaultReport,
    /// Per-request admission→batch-start wait (µs), log₂-bucketed.
    pub queue_wait: HistogramSnapshot,
    /// Per-batch backend service time (µs), log₂-bucketed.
    pub service: HistogramSnapshot,
}

impl MetricsSnapshot {
    /// Fold another farm's snapshot into this one (the [`super::Router`]
    /// merged view): countable fields **sum saturating** (requests,
    /// batches, sim counters, canary totals, joules, throughput; a pegged
    /// counter stays pegged instead of wrapping), latency percentiles
    /// take the conservative **max** across farms, histograms merge
    /// bucket-wise, and derived rates (`mean_batch`, `sim_gops`) are
    /// recomputed from the merged totals.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.requests = self.requests.saturating_add(other.requests);
        self.batches = self.batches.saturating_add(other.batches);
        self.mean_batch =
            if self.batches == 0 { 0.0 } else { self.requests as f64 / self.batches as f64 };
        self.p50_latency = self.p50_latency.max(other.p50_latency);
        self.p95_latency = self.p95_latency.max(other.p95_latency);
        self.p99_latency = self.p99_latency.max(other.p99_latency);
        self.max_latency = self.max_latency.max(other.max_latency);
        self.throughput_rps += other.throughput_rps;
        self.shed = self.shed.saturating_add(other.shed);
        self.deadline_expired = self.deadline_expired.saturating_add(other.deadline_expired);
        self.engine_failed = self.engine_failed.saturating_add(other.engine_failed);
        self.drain_rejected = self.drain_rejected.saturating_add(other.drain_rejected);
        self.retries = self.retries.saturating_add(other.retries);
        self.sim_batches = self.sim_batches.saturating_add(other.sim_batches);
        self.sim_cycles = self.sim_cycles.saturating_add(other.sim_cycles);
        self.sim_off_chip_accesses =
            self.sim_off_chip_accesses.saturating_add(other.sim_off_chip_accesses);
        self.sim_on_chip_accesses =
            self.sim_on_chip_accesses.saturating_add(other.sim_on_chip_accesses);
        self.sim_macs = self.sim_macs.saturating_add(other.sim_macs);
        self.sim_joules += other.sim_joules;
        self.sim_seconds += other.sim_seconds;
        if self.sim_f_clk == 0.0 {
            self.sim_f_clk = other.sim_f_clk;
        }
        for l in &other.sim_per_layer {
            LayerCost::fold_into(&mut self.sim_per_layer, l);
        }
        self.canary.merge(&other.canary);
        self.fault.merge(&other.fault);
        self.queue_wait.merge(&other.queue_wait);
        self.service.merge(&other.service);
        self.sim_gops = achieved_gops(self.sim_macs, self.sim_seconds);
    }

    /// Prometheus text exposition of the snapshot (`trim serve
    /// --metrics-out`): counters as `trim_*_total`, rates/clocks as
    /// gauges, latency quantiles as a summary-style gauge family, the
    /// queue-wait/service histograms with cumulative `le` buckets, and
    /// the per-layer table as labelled counters.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, v: u64| {
            let _ = writeln!(out, "# TYPE {name} counter\n{name} {v}");
        };
        counter("trim_requests_total", self.requests);
        counter("trim_batches_total", self.batches);
        counter("trim_shed_total", self.shed);
        counter("trim_deadline_expired_total", self.deadline_expired);
        counter("trim_engine_failed_total", self.engine_failed);
        counter("trim_drain_rejected_total", self.drain_rejected);
        counter("trim_retries_total", self.retries);
        counter("trim_sim_batches_total", self.sim_batches);
        counter("trim_sim_cycles_total", self.sim_cycles);
        counter("trim_sim_off_chip_accesses_total", self.sim_off_chip_accesses);
        counter("trim_sim_on_chip_accesses_total", self.sim_on_chip_accesses);
        counter("trim_sim_macs_total", self.sim_macs);
        counter("trim_canary_sampled_total", self.canary.sampled);
        counter("trim_canary_bit_divergence_total", self.canary.bit_divergence);
        counter("trim_canary_counter_divergence_total", self.canary.counter_divergence);
        counter("trim_fault_injected_total", self.fault.injected);
        counter("trim_fault_detected_total", self.fault.detected);
        counter("trim_fault_corrected_total", self.fault.corrected);
        counter("trim_fault_reexecuted_total", self.fault.reexecuted);
        counter("trim_fault_quarantined_total", self.fault.quarantined);
        counter("trim_fault_hedged_total", self.fault.hedged);
        counter("trim_fault_hedge_wasted_total", self.fault.hedge_wasted);
        counter("trim_fault_hedge_won_total", self.fault.hedge_won);
        counter("trim_fault_stragglers_total", self.fault.stragglers_detected);
        counter("trim_fault_timing_quarantined_total", self.fault.timing_quarantined);
        let mut gauge = |name: &str, v: f64| {
            let _ = writeln!(out, "# TYPE {name} gauge\n{name} {v}");
        };
        gauge("trim_mean_batch", self.mean_batch);
        gauge("trim_throughput_rps", self.throughput_rps);
        gauge("trim_sim_joules", self.sim_joules);
        gauge("trim_sim_seconds", self.sim_seconds);
        gauge("trim_sim_gops", self.sim_gops);
        gauge("trim_sim_f_clk_hz", self.sim_f_clk);
        let _ = writeln!(out, "# TYPE trim_latency_seconds gauge");
        for (q, d) in [
            ("0.5", self.p50_latency),
            ("0.95", self.p95_latency),
            ("0.99", self.p99_latency),
        ] {
            let _ = writeln!(
                out,
                "trim_latency_seconds{{quantile=\"{q}\"}} {}",
                d.as_secs_f64()
            );
        }
        let _ = writeln!(
            out,
            "trim_latency_seconds{{quantile=\"max\"}} {}",
            self.max_latency.as_secs_f64()
        );
        render_histogram(&mut out, "trim_queue_wait_us", &self.queue_wait);
        render_histogram(&mut out, "trim_service_us", &self.service);
        if !self.sim_per_layer.is_empty() {
            let _ = writeln!(out, "# TYPE trim_sim_layer_cycles_total counter");
            for l in &self.sim_per_layer {
                let _ = writeln!(
                    out,
                    "trim_sim_layer_cycles_total{{layer=\"{}\"}} {}",
                    l.name, l.cycles
                );
            }
            let _ = writeln!(out, "# TYPE trim_sim_layer_macs_total counter");
            for l in &self.sim_per_layer {
                let _ = writeln!(
                    out,
                    "trim_sim_layer_macs_total{{layer=\"{}\"}} {}",
                    l.name, l.macs
                );
            }
        }
        out
    }

    /// The full snapshot as one JSON object (single line, no trailing
    /// newline) — what `benches/e2e_serving.rs` emits into the CI
    /// bench-trajectory artifact.
    pub fn render_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"requests\":{},\"batches\":{},\"mean_batch\":{:.3},\
             \"p50_us\":{},\"p95_us\":{},\"p99_us\":{},\"max_us\":{},\
             \"throughput_rps\":{:.1},\
             \"shed\":{},\"deadline_expired\":{},\"engine_failed\":{},\
             \"drain_rejected\":{},\"retries\":{},\
             \"sim_batches\":{},\"sim_cycles\":{},\
             \"sim_off_chip\":{},\"sim_on_chip\":{},\"sim_macs\":{},\
             \"sim_joules\":{:.6e},\"sim_gops\":{:.2},\
             \"canary_sampled\":{},\"canary_bit_div\":{},\"canary_counter_div\":{},\
             \"fault_injected\":{},\"fault_detected\":{},\"fault_corrected\":{},\
             \"fault_reexecuted\":{},\"fault_quarantined\":{},\
             \"fault_hedged\":{},\"fault_hedge_wasted\":{},\"fault_hedge_won\":{},\
             \"fault_stragglers\":{},\"fault_timing_quarantined\":{},\
             \"queue_wait\":{{\"count\":{},\"mean_us\":{:.1},\"p99_us_est\":{}}},\
             \"service\":{{\"count\":{},\"mean_us\":{:.1},\"p99_us_est\":{}}},\
             \"layers\":{}}}",
            self.requests,
            self.batches,
            self.mean_batch,
            self.p50_latency.as_micros(),
            self.p95_latency.as_micros(),
            self.p99_latency.as_micros(),
            self.max_latency.as_micros(),
            self.throughput_rps,
            self.shed,
            self.deadline_expired,
            self.engine_failed,
            self.drain_rejected,
            self.retries,
            self.sim_batches,
            self.sim_cycles,
            self.sim_off_chip_accesses,
            self.sim_on_chip_accesses,
            self.sim_macs,
            self.sim_joules,
            self.sim_gops,
            self.canary.sampled,
            self.canary.bit_divergence,
            self.canary.counter_divergence,
            self.fault.injected,
            self.fault.detected,
            self.fault.corrected,
            self.fault.reexecuted,
            self.fault.quarantined,
            self.fault.hedged,
            self.fault.hedge_wasted,
            self.fault.hedge_won,
            self.fault.stragglers_detected,
            self.fault.timing_quarantined,
            self.queue_wait.count,
            self.queue_wait.mean(),
            self.queue_wait.quantile(0.99),
            self.service.count,
            self.service.mean(),
            self.service.quantile(0.99),
            self.sim_per_layer.len(),
        );
        s
    }
}

/// Append one Prometheus histogram family (cumulative `le` buckets from
/// the log₂ snapshot, then `_sum`/`_count`).
fn render_histogram(out: &mut String, name: &str, h: &HistogramSnapshot) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, b) in h.buckets.iter().enumerate() {
        if *b == 0 {
            continue;
        }
        cum = cum.saturating_add(*b);
        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", obs::bucket_upper_bound(i));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum {}\n{name}_count {}", h.sum, h.count);
}

/// Mutex-guarded part of the accumulator: the latency reservoir and the
/// float-valued cost sums (the countable u64 fields live on saturating
/// [`Counter`]s outside the lock).
#[derive(Debug)]
struct Inner {
    /// Fixed-size latency reservoir (µs) — see module docs.
    lat_sample: Vec<u64>,
    /// Latencies observed in total (≥ `lat_sample.len()`).
    lat_seen: u64,
    /// Exact maximum, tracked outside the reservoir.
    max_us: u64,
    rng: SplitMix64,
    started: Option<std::time::Instant>,
    sim_joules: f64,
    sim_seconds: f64,
    sim_f_clk: f64,
    sim_layers: Vec<LayerCost>,
}

impl Default for Inner {
    fn default() -> Self {
        Self {
            lat_sample: Vec::new(),
            lat_seen: 0,
            max_us: 0,
            rng: SplitMix64::new(0x5EED_CAFE),
            started: None,
            sim_joules: 0.0,
            sim_seconds: 0.0,
            sim_f_clk: 0.0,
            sim_layers: Vec::new(),
        }
    }
}

impl Inner {
    fn record_latency(&mut self, us: u64) {
        self.max_us = self.max_us.max(us);
        if self.lat_sample.len() < LATENCY_RESERVOIR {
            self.lat_sample.push(us);
        } else {
            // Algorithm R: item i (1-based) replaces a reservoir slot with
            // probability k/i, keeping the sample uniform over all seen.
            let j = self.rng.next_u64() % (self.lat_seen + 1);
            if (j as usize) < LATENCY_RESERVOIR {
                self.lat_sample[j as usize] = us;
            }
        }
        self.lat_seen = self.lat_seen.saturating_add(1);
    }
}

/// Thread-safe metrics accumulator shared between the engine thread and
/// observers. Counters are saturating atomics from [`crate::obs`]; only
/// the reservoir and float sums take the lock.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    requests: Counter,
    batches: Counter,
    shed: Counter,
    deadline_expired: Counter,
    engine_failed: Counter,
    drain_rejected: Counter,
    sim_batches: Counter,
    sim_cycles: Counter,
    sim_off_chip: Counter,
    sim_on_chip: Counter,
    sim_macs: Counter,
    canary_sampled: Counter,
    canary_bit_divergence: Counter,
    canary_counter_divergence: Counter,
    fault_injected: Counter,
    fault_detected: Counter,
    fault_corrected: Counter,
    fault_reexecuted: Counter,
    fault_quarantined: Counter,
    fault_hedged: Counter,
    fault_hedge_wasted: Counter,
    fault_hedge_won: Counter,
    fault_stragglers: Counter,
    fault_timing_quarantined: Counter,
    queue_wait_us: Histogram,
    service_us: Histogram,
    inner: Mutex<Inner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served batch: its per-request latencies plus the
    /// backend's [`BatchCost`] when it reported one. All counter
    /// accumulation saturates.
    pub fn record_batch(&self, latencies: &[Duration], cost: Option<&BatchCost>) {
        self.batches.inc();
        self.requests.add(latencies.len() as u64);
        let mut g = lock_unpoisoned(&self.inner);
        g.started.get_or_insert_with(std::time::Instant::now);
        for d in latencies {
            g.record_latency(d.as_micros() as u64);
        }
        if let Some(c) = cost {
            self.sim_batches.inc();
            self.sim_cycles.add(c.stats.cycles);
            self.sim_off_chip.add(c.stats.off_chip_accesses());
            self.sim_on_chip.add(c.stats.on_chip_accesses());
            self.sim_macs.add(c.stats.macs);
            self.canary_sampled.add(c.canary.sampled);
            self.canary_bit_divergence.add(c.canary.bit_divergence);
            self.canary_counter_divergence.add(c.canary.counter_divergence);
            self.fault_injected.add(c.faults.injected);
            self.fault_detected.add(c.faults.detected);
            self.fault_corrected.add(c.faults.corrected);
            self.fault_reexecuted.add(c.faults.reexecuted);
            self.fault_quarantined.add(c.faults.quarantined);
            self.fault_hedged.add(c.faults.hedged);
            self.fault_hedge_wasted.add(c.faults.hedge_wasted);
            self.fault_hedge_won.add(c.faults.hedge_won);
            self.fault_stragglers.add(c.faults.stragglers_detected);
            self.fault_timing_quarantined.add(c.faults.timing_quarantined);
            g.sim_joules += c.joules;
            if c.f_clk > 0.0 {
                g.sim_seconds += c.stats.cycles as f64 / c.f_clk;
            }
            g.sim_f_clk = c.f_clk;
            for l in &c.per_layer {
                LayerCost::fold_into(&mut g.sim_layers, l);
            }
        }
    }

    /// Record one request shed by admission control (`Overloaded`).
    pub fn record_shed(&self) {
        self.shed.inc();
    }

    /// Record one request rejected for a missed deadline budget.
    pub fn record_deadline_expired(&self) {
        self.deadline_expired.inc();
    }

    /// Record `n` requests that resolved with `EngineFailed` (their
    /// batch's backend call errored or panicked).
    pub fn record_engine_failed(&self, n: u64) {
        self.engine_failed.add(n);
    }

    /// Record `n` requests rejected with `Shutdown` past the drain
    /// deadline.
    pub fn record_drain_rejected(&self, n: u64) {
        self.drain_rejected.add(n);
    }

    /// Record batch-formation timing from the engine loop: each
    /// request's admission→batch-start wait, and the batch's backend
    /// service time. Lock-free (histograms are atomic).
    pub fn record_queue_service(&self, queue_waits: &[Duration], service: Duration) {
        for d in queue_waits {
            self.queue_wait_us.record(d.as_micros() as u64);
        }
        self.service_us.record(service.as_micros() as u64);
    }

    /// Exact nearest-rank quantile (`q ∈ [0, 1]`) over the current
    /// latency reservoir sample — `q = 0.5/0.95/0.99` are the p50/p95/p99
    /// the serve summary line prints.
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let mut lats = lock_unpoisoned(&self.inner).lat_sample.clone();
        lats.sort_unstable();
        Duration::from_micros(obs::percentile_u64(&lats, q))
    }

    fn pct(sorted: &[u64], p: f64) -> Duration {
        Duration::from_micros(obs::percentile_u64(sorted, p))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = lock_unpoisoned(&self.inner);
        let mut lats = g.lat_sample.clone();
        lats.sort_unstable();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let requests = self.requests.get();
        let batches = self.batches.get();
        MetricsSnapshot {
            requests,
            batches,
            mean_batch: if batches == 0 { 0.0 } else { requests as f64 / batches as f64 },
            p50_latency: Self::pct(&lats, 0.50),
            p95_latency: Self::pct(&lats, 0.95),
            p99_latency: Self::pct(&lats, 0.99),
            max_latency: if g.lat_seen == 0 { Duration::ZERO } else { Duration::from_micros(g.max_us) },
            throughput_rps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            shed: self.shed.get(),
            deadline_expired: self.deadline_expired.get(),
            engine_failed: self.engine_failed.get(),
            drain_rejected: self.drain_rejected.get(),
            retries: 0,
            sim_batches: self.sim_batches.get(),
            sim_cycles: self.sim_cycles.get(),
            sim_off_chip_accesses: self.sim_off_chip.get(),
            sim_on_chip_accesses: self.sim_on_chip.get(),
            sim_macs: self.sim_macs.get(),
            sim_joules: g.sim_joules,
            sim_seconds: g.sim_seconds,
            sim_gops: achieved_gops(self.sim_macs.get(), g.sim_seconds),
            sim_f_clk: g.sim_f_clk,
            sim_per_layer: g.sim_layers.clone(),
            canary: CanaryReport {
                sampled: self.canary_sampled.get(),
                bit_divergence: self.canary_bit_divergence.get(),
                counter_divergence: self.canary_counter_divergence.get(),
            },
            fault: FaultReport {
                injected: self.fault_injected.get(),
                detected: self.fault_detected.get(),
                corrected: self.fault_corrected.get(),
                reexecuted: self.fault_reexecuted.get(),
                quarantined: self.fault_quarantined.get(),
                hedged: self.fault_hedged.get(),
                hedge_wasted: self.fault_hedge_wasted.get(),
                hedge_won: self.fault_hedge_won.get(),
                stragglers_detected: self.fault_stragglers.get(),
                timing_quarantined: self.fault_timing_quarantined.get(),
            },
            queue_wait: self.queue_wait_us.snapshot(),
            service: self.service_us.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SimStats;

    #[test]
    fn percentiles_and_counts() {
        let m = ServeMetrics::new();
        m.record_batch(&[Duration::from_micros(100), Duration::from_micros(200)], None);
        m.record_batch(&[Duration::from_micros(300)], None);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert_eq!(s.max_latency, Duration::from_micros(300));
        assert_eq!(s.sim_batches, 0);
        assert_eq!(s.sim_gops, 0.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p95_latency, Duration::ZERO);
        assert_eq!(s.p99_latency, Duration::ZERO);
        assert_eq!(s.sim_cycles, 0);
        assert_eq!(s.canary, CanaryReport::default());
        assert_eq!(s.fault, FaultReport::default());
        assert_eq!(s.queue_wait.count, 0);
    }

    #[test]
    fn latency_reservoir_is_bounded_and_max_exact() {
        let m = ServeMetrics::new();
        let n = (LATENCY_RESERVOIR * 3) as u64;
        for i in 0..n {
            m.record_batch(&[Duration::from_micros(i + 1)], None);
        }
        let g = m.inner.lock().unwrap();
        assert_eq!(g.lat_sample.len(), LATENCY_RESERVOIR, "reservoir must not grow past cap");
        assert_eq!(g.lat_seen, n);
        drop(g);
        let s = m.snapshot();
        assert_eq!(s.requests, n);
        assert_eq!(s.max_latency, Duration::from_micros(n), "max is exact, not sampled");
        // Percentiles of a uniform ramp stay near the true values even
        // though 2/3 of the observations were sampled out.
        let p50 = s.p50_latency.as_micros() as f64;
        assert!((p50 - n as f64 / 2.0).abs() < n as f64 * 0.1, "p50 ≈ n/2, got {p50}");
        assert!(s.p50_latency <= s.p95_latency && s.p95_latency <= s.max_latency);
    }

    #[test]
    fn exact_quantiles_on_known_distribution() {
        // 1..=1000 µs fits wholly in the reservoir, so the nearest-rank
        // accessors are exact: p50 = 501 (round(999·0.5) = 500 → idx 500),
        // p95 = 950, p99 = 990.
        let m = ServeMetrics::new();
        for i in 1..=1000u64 {
            m.record_batch(&[Duration::from_micros(i)], None);
        }
        assert_eq!(m.latency_quantile(0.50), Duration::from_micros(501));
        assert_eq!(m.latency_quantile(0.95), Duration::from_micros(950));
        assert_eq!(m.latency_quantile(0.99), Duration::from_micros(990));
        assert_eq!(m.latency_quantile(1.0), Duration::from_micros(1000));
        let s = m.snapshot();
        assert_eq!(s.p50_latency, m.latency_quantile(0.50));
        assert_eq!(s.p95_latency, m.latency_quantile(0.95));
        assert_eq!(s.p99_latency, m.latency_quantile(0.99));
        assert!(s.p95_latency <= s.p99_latency && s.p99_latency <= s.max_latency);
    }

    fn cost_at(cycles: u64, macs: u64, f_clk: f64) -> BatchCost {
        let stats = SimStats {
            cycles,
            ext_input_reads: 10,
            weight_reads: 5,
            output_writes: 5,
            psum_buf_reads: 3,
            psum_buf_writes: 3,
            macs,
            ..Default::default()
        };
        BatchCost::from_stats(stats, f_clk, &crate::analytics::EnergyModel::paper())
    }

    fn cost(cycles: u64, macs: u64) -> BatchCost {
        cost_at(cycles, macs, 150.0e6)
    }

    #[test]
    fn sim_cost_accumulates() {
        let m = ServeMetrics::new();
        let c1 = cost(100, 400);
        let c2 = cost(50, 200);
        m.record_batch(&[Duration::from_micros(10)], Some(&c1));
        m.record_batch(&[Duration::from_micros(10)], Some(&c2));
        m.record_batch(&[Duration::from_micros(10)], None); // mixed traffic
        let s = m.snapshot();
        assert_eq!(s.batches, 3);
        assert_eq!(s.sim_batches, 2);
        assert_eq!(s.sim_cycles, 150);
        assert_eq!(s.sim_macs, 600);
        assert_eq!(s.sim_off_chip_accesses, 40);
        assert_eq!(s.sim_on_chip_accesses, 12);
        assert!((s.sim_joules - (c1.joules + c2.joules)).abs() < 1e-18);
        let gops = 2.0 * 600.0 * 150.0e6 / 150.0 / 1e9;
        assert!((s.sim_gops - gops).abs() < 1e-9);
    }

    #[test]
    fn counter_accumulation_saturates_near_u64_max() {
        // A soak run must peg counters at u64::MAX — never wrap, never
        // trip a debug overflow panic.
        let m = ServeMetrics::new();
        m.record_batch(&[Duration::from_micros(1)], Some(&cost(u64::MAX - 10, u64::MAX - 10)));
        m.record_batch(&[Duration::from_micros(1)], Some(&cost(100, 100)));
        let s = m.snapshot();
        assert_eq!(s.sim_cycles, u64::MAX);
        assert_eq!(s.sim_macs, u64::MAX);
        // off/on-chip sums were accumulated twice without wrapping
        assert_eq!(s.sim_off_chip_accesses, 40);
        // ... and a merge of two pegged snapshots stays pegged.
        let mut merged = s.clone();
        merged.merge(&s);
        assert_eq!(merged.sim_cycles, u64::MAX);
        assert_eq!(merged.sim_macs, u64::MAX);
        assert_eq!(merged.requests, 4);
    }

    #[test]
    fn snapshot_merge_sums_counters_and_recomputes_rates() {
        let m1 = ServeMetrics::new();
        let m2 = ServeMetrics::new();
        m1.record_batch(&[Duration::from_micros(100)], Some(&cost(100, 400)));
        m2.record_batch(
            &[Duration::from_micros(300), Duration::from_micros(50)],
            Some(&cost(300, 600)),
        );
        let (s1, s2) = (m1.snapshot(), m2.snapshot());
        let mut merged = s1.clone();
        merged.merge(&s2);
        assert_eq!(merged.requests, s1.requests + s2.requests);
        assert_eq!(merged.batches, s1.batches + s2.batches);
        assert_eq!(merged.sim_batches, s1.sim_batches + s2.sim_batches);
        assert_eq!(merged.sim_cycles, s1.sim_cycles + s2.sim_cycles);
        assert_eq!(merged.sim_macs, s1.sim_macs + s2.sim_macs);
        assert_eq!(
            merged.sim_off_chip_accesses,
            s1.sim_off_chip_accesses + s2.sim_off_chip_accesses
        );
        assert!((merged.sim_joules - (s1.sim_joules + s2.sim_joules)).abs() < 1e-18);
        assert_eq!(merged.max_latency, Duration::from_micros(300));
        assert!((merged.mean_batch - 1.5).abs() < 1e-9);
        let gops = 2.0 * merged.sim_macs as f64 * 150.0e6 / merged.sim_cycles as f64 / 1e9;
        assert!((merged.sim_gops - gops).abs() < 1e-9);
        // merging into a default snapshot is the identity
        let mut from_zero = MetricsSnapshot::default();
        from_zero.merge(&s1);
        assert_eq!(from_zero.sim_cycles, s1.sim_cycles);
        assert_eq!(from_zero.sim_f_clk, s1.sim_f_clk);
    }

    #[test]
    fn merge_with_empty_snapshot_is_identity() {
        let m = ServeMetrics::new();
        m.record_batch(
            &[Duration::from_micros(100), Duration::from_micros(200)],
            Some(&cost(100, 400).with_per_layer(vec![LayerCost {
                name: "L1".into(),
                cycles: 100,
                off_chip_accesses: 40,
                on_chip_accesses: 12,
                macs: 400,
            }])),
        );
        m.record_queue_service(&[Duration::from_micros(5)], Duration::from_micros(50));
        let s = m.snapshot();
        // s ∪ ∅ — every field unchanged.
        let mut a = s.clone();
        a.merge(&MetricsSnapshot::default());
        assert_eq!(a.requests, s.requests);
        assert_eq!(a.batches, s.batches);
        assert_eq!((a.p50_latency, a.p95_latency, a.p99_latency), (s.p50_latency, s.p95_latency, s.p99_latency));
        assert_eq!(a.max_latency, s.max_latency);
        assert_eq!(a.sim_cycles, s.sim_cycles);
        assert_eq!(a.sim_f_clk, s.sim_f_clk);
        assert_eq!(a.sim_per_layer.len(), s.sim_per_layer.len());
        assert_eq!(a.canary, s.canary);
        assert_eq!(a.queue_wait, s.queue_wait);
        assert_eq!(a.service, s.service);
        assert!((a.mean_batch - s.mean_batch).abs() < 1e-12);
        // ∅ ∪ s — same thing from the other side.
        let mut b = MetricsSnapshot::default();
        b.merge(&s);
        assert_eq!(b.requests, s.requests);
        assert_eq!(b.p99_latency, s.p99_latency);
        assert_eq!(b.queue_wait, s.queue_wait);
        assert_eq!(b.canary, s.canary);
    }

    #[test]
    fn zero_request_farm_does_not_skew_latency_aggregates() {
        // A farm that served nothing (all-zero percentiles, zero
        // batches) must not drag the merged percentiles down or distort
        // mean_batch/throughput.
        let busy = ServeMetrics::new();
        busy.record_batch(
            &[Duration::from_micros(400), Duration::from_micros(800)],
            None,
        );
        let idle = ServeMetrics::new();
        let mut merged = busy.snapshot();
        let before = merged.clone();
        merged.merge(&idle.snapshot());
        assert_eq!(merged.p50_latency, before.p50_latency);
        assert_eq!(merged.p95_latency, before.p95_latency);
        assert_eq!(merged.p99_latency, before.p99_latency);
        assert_eq!(merged.max_latency, before.max_latency);
        assert!((merged.mean_batch - before.mean_batch).abs() < 1e-12);
        assert_eq!(merged.requests, before.requests);
    }

    #[test]
    fn per_layer_costs_accumulate_and_merge_by_name() {
        let m1 = ServeMetrics::new();
        let m2 = ServeMetrics::new();
        let layer = |name: &str, cycles: u64| LayerCost {
            name: name.into(),
            cycles,
            off_chip_accesses: cycles * 2,
            on_chip_accesses: cycles / 2,
            macs: cycles * 10,
        };
        let c1 = cost(100, 400).with_per_layer(vec![layer("L1", 60), layer("L2", 40)]);
        let c2 = cost(50, 200).with_per_layer(vec![layer("L1", 30), layer("L2", 20)]);
        m1.record_batch(&[Duration::from_micros(1)], Some(&c1));
        m1.record_batch(&[Duration::from_micros(1)], Some(&c2));
        let s1 = m1.snapshot();
        assert_eq!(s1.sim_per_layer.len(), 2, "folded by name across batches");
        assert_eq!(s1.sim_per_layer[0].name, "L1");
        assert_eq!(s1.sim_per_layer[0].cycles, 90);
        assert_eq!(s1.sim_per_layer[1].cycles, 60);
        assert_eq!(s1.sim_per_layer[0].macs, 900);
        // Router-style snapshot merge folds the other farm's table in —
        // shared names dedup (L2 folds), new names append (L3).
        let c3 = cost(10, 40).with_per_layer(vec![layer("L2", 5), layer("L3", 5)]);
        m2.record_batch(&[Duration::from_micros(1)], Some(&c3));
        let mut merged = s1.clone();
        merged.merge(&m2.snapshot());
        assert_eq!(merged.sim_per_layer.len(), 3, "L2 deduped, L3 appended");
        assert_eq!(merged.sim_per_layer[1].cycles, 65, "L2 folded across farms");
        assert_eq!(merged.sim_per_layer[2].name, "L3");
        // cost-free batches leave the table untouched
        let plain = ServeMetrics::new();
        plain.record_batch(&[Duration::from_micros(1)], None);
        assert!(plain.snapshot().sim_per_layer.is_empty());
    }

    #[test]
    fn mixed_clock_merge_prices_each_farm_at_its_own_clock() {
        // A 150 MHz farm and a 300 MHz farm behind one router: the merged
        // GOPS must come from Σ simulated seconds, not Σ cycles priced at
        // one farm's clock.
        let slow = ServeMetrics::new();
        let fast = ServeMetrics::new();
        slow.record_batch(&[Duration::from_micros(1)], Some(&cost_at(100, 400, 150.0e6)));
        fast.record_batch(&[Duration::from_micros(1)], Some(&cost_at(100, 400, 300.0e6)));
        let mut merged = slow.snapshot();
        merged.merge(&fast.snapshot());
        let seconds = 100.0 / 150.0e6 + 100.0 / 300.0e6;
        assert!((merged.sim_seconds - seconds).abs() < 1e-18);
        let gops = 2.0 * 800.0 / seconds / 1e9;
        assert!((merged.sim_gops - gops).abs() < 1e-9, "got {}", merged.sim_gops);
        // the single-clock formula over summed cycles would be wrong here
        let naive = 2.0 * 800.0 * 150.0e6 / 200.0 / 1e9;
        assert!((merged.sim_gops - naive).abs() > 0.1);
    }

    #[test]
    fn canary_totals_flow_through_record_and_merge() {
        let m = ServeMetrics::new();
        let mut c = cost(10, 40);
        c.canary = CanaryReport { sampled: 8, bit_divergence: 1, counter_divergence: 0 };
        m.record_batch(&[Duration::from_micros(1)], Some(&c));
        m.record_batch(&[Duration::from_micros(1)], Some(&c));
        let s = m.snapshot();
        assert_eq!(s.canary.sampled, 16);
        assert_eq!(s.canary.bit_divergence, 2);
        let mut merged = s.clone();
        merged.merge(&s);
        assert_eq!(merged.canary.sampled, 32, "canary totals merge across farms");
    }

    #[test]
    fn fault_totals_flow_through_record_and_merge() {
        let m = ServeMetrics::new();
        let mut c = cost(10, 40);
        c.faults = FaultReport {
            injected: 5,
            detected: 5,
            corrected: 4,
            reexecuted: 6,
            quarantined: 1,
            hedged: 7,
            hedge_wasted: 3,
            hedge_won: 2,
            stragglers_detected: 4,
            timing_quarantined: 1,
        };
        m.record_batch(&[Duration::from_micros(1)], Some(&c));
        m.record_batch(&[Duration::from_micros(1)], Some(&c));
        let s = m.snapshot();
        assert_eq!(s.fault.injected, 10);
        assert_eq!(s.fault.detected, 10);
        assert_eq!(s.fault.corrected, 8);
        assert_eq!(s.fault.reexecuted, 12);
        assert_eq!(s.fault.quarantined, 2);
        assert_eq!(s.fault.hedged, 14);
        assert_eq!(s.fault.hedge_wasted, 6);
        assert_eq!(s.fault.hedge_won, 4);
        assert_eq!(s.fault.stragglers_detected, 8);
        assert_eq!(s.fault.timing_quarantined, 2);
        let mut merged = s.clone();
        merged.merge(&s);
        assert_eq!(merged.fault.detected, 20, "fault totals merge across farms");
        // fault-free batches leave everything zero
        let clean = ServeMetrics::new();
        clean.record_batch(&[Duration::from_micros(1)], Some(&cost(10, 40)));
        assert_eq!(clean.snapshot().fault, FaultReport::default());
    }

    #[test]
    fn queue_and_service_histograms_record_and_snapshot() {
        let m = ServeMetrics::new();
        m.record_queue_service(
            &[Duration::from_micros(3), Duration::from_micros(100)],
            Duration::from_micros(1000),
        );
        let s = m.snapshot();
        assert_eq!(s.queue_wait.count, 2);
        assert_eq!(s.queue_wait.sum, 103);
        assert_eq!(s.service.count, 1);
        assert_eq!(s.service.sum, 1000);
    }

    #[test]
    fn prometheus_rendering_exposes_all_families() {
        let m = ServeMetrics::new();
        let mut c = cost(100, 400).with_per_layer(vec![LayerCost {
            name: "SL1".into(),
            cycles: 100,
            off_chip_accesses: 40,
            on_chip_accesses: 12,
            macs: 400,
        }]);
        c.canary = CanaryReport { sampled: 2, bit_divergence: 0, counter_divergence: 0 };
        c.faults = FaultReport {
            injected: 3,
            detected: 3,
            corrected: 3,
            reexecuted: 3,
            hedged: 5,
            hedge_won: 1,
            stragglers_detected: 2,
            ..FaultReport::default()
        };
        m.record_batch(&[Duration::from_micros(100)], Some(&c));
        m.record_queue_service(&[Duration::from_micros(5)], Duration::from_micros(80));
        let text = m.snapshot().render_prometheus();
        assert!(text.contains("# TYPE trim_requests_total counter"));
        assert!(text.contains("trim_requests_total 1"));
        assert!(text.contains("trim_sim_cycles_total 100"));
        assert!(text.contains("trim_canary_sampled_total 2"));
        assert!(text.contains("trim_fault_detected_total 3"));
        assert!(text.contains("trim_fault_quarantined_total 0"));
        assert!(text.contains("trim_fault_hedged_total 5"));
        assert!(text.contains("trim_fault_hedge_won_total 1"));
        assert!(text.contains("trim_fault_stragglers_total 2"));
        assert!(text.contains("trim_fault_timing_quarantined_total 0"));
        assert!(text.contains("trim_latency_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("trim_queue_wait_us_count 1"));
        assert!(text.contains("trim_service_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("trim_sim_layer_cycles_total{layer=\"SL1\"} 100"));
        let json = m.snapshot().render_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"canary_sampled\":2"));
        assert!(json.contains("\"fault_injected\":3"));
        assert!(json.contains("\"fault_hedged\":5"));
        assert!(json.contains("\"fault_stragglers\":2"));
        assert!(json.contains("\"sim_cycles\":100"));
        assert!(!json.contains('\n'), "one line for the trajectory grep");
    }
}
