//! Serving metrics: counters, a bounded latency distribution, and the
//! cumulative simulated execution cost (cycles / memory accesses / joules)
//! reported by cost-carrying backends.
//!
//! Latencies are kept in a fixed-size **reservoir sample** (Vitter's
//! algorithm R, deterministic in-tree PRNG): under sustained load the
//! p50/p95 estimates stay meaningful while memory stays O(1) — the
//! previous unbounded `Vec` grew forever. `max_latency` is tracked exactly
//! outside the reservoir.

use super::backend::{BatchCost, LayerCost};
use crate::util::SplitMix64;
use std::sync::Mutex;
use std::time::Duration;

/// Reservoir capacity: enough for stable p50/p95 estimates, small enough
/// that a week of sustained load costs the same memory as a minute.
pub const LATENCY_RESERVOIR: usize = 4096;

/// Achieved simulated throughput in GOPs/s: `2·MACs / simulated seconds`.
/// Working from accumulated simulated *time* (each batch contributes
/// `cycles/f_clk`) rather than `Σcycles` priced at one clock keeps the
/// figure correct when farms with different clocks merge.
fn achieved_gops(macs: u64, sim_seconds: f64) -> f64 {
    if sim_seconds > 0.0 {
        2.0 * macs as f64 / sim_seconds / 1e9
    } else {
        0.0
    }
}

/// Point-in-time snapshot.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub max_latency: Duration,
    pub throughput_rps: f64,
    /// Batches that carried a simulated [`BatchCost`] (0 for PJRT/mock
    /// backends — all `sim_*` fields stay zero then).
    pub sim_batches: u64,
    /// Cumulative simulated engine cycles (each batch contributes its
    /// farm-aggregated wall-clock cycles: max over parallel shards, sum
    /// over sequential phases).
    pub sim_cycles: u64,
    /// Cumulative off-chip (DRAM-side) element accesses.
    pub sim_off_chip_accesses: u64,
    /// Cumulative on-chip (psum-buffer) element accesses.
    pub sim_on_chip_accesses: u64,
    /// Cumulative MACs.
    pub sim_macs: u64,
    /// Cumulative simulated energy (J).
    pub sim_joules: f64,
    /// Cumulative simulated engine time in seconds (Σ batch
    /// `cycles/f_clk` — well-defined even across mixed-clock farms).
    pub sim_seconds: f64,
    /// Achieved simulated throughput over everything served so far, in
    /// GOPs/s: `2·sim_macs/sim_seconds`.
    pub sim_gops: f64,
    /// Clock (Hz) of the most recent cost seen — display only; rate
    /// derivations use `sim_seconds`, not this. 0 until a cost is seen.
    pub sim_f_clk: f64,
    /// Cumulative per-layer cost breakdown, folded by layer name across
    /// every cost-carrying batch (empty when no backend attributes cost
    /// per layer) — the 2408.01254-style accounting `trim farm`/`trim
    /// serve` print as a table.
    pub sim_per_layer: Vec<LayerCost>,
}

impl MetricsSnapshot {
    /// Fold another farm's snapshot into this one (the [`super::Router`]
    /// merged view): countable fields **sum** (requests, batches, sim
    /// counters, joules, throughput), latency percentiles take the
    /// conservative **max** across farms, and derived rates (`mean_batch`,
    /// `sim_gops`) are recomputed from the merged totals.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.mean_batch =
            if self.batches == 0 { 0.0 } else { self.requests as f64 / self.batches as f64 };
        self.p50_latency = self.p50_latency.max(other.p50_latency);
        self.p95_latency = self.p95_latency.max(other.p95_latency);
        self.max_latency = self.max_latency.max(other.max_latency);
        self.throughput_rps += other.throughput_rps;
        self.sim_batches += other.sim_batches;
        self.sim_cycles += other.sim_cycles;
        self.sim_off_chip_accesses += other.sim_off_chip_accesses;
        self.sim_on_chip_accesses += other.sim_on_chip_accesses;
        self.sim_macs += other.sim_macs;
        self.sim_joules += other.sim_joules;
        self.sim_seconds += other.sim_seconds;
        if self.sim_f_clk == 0.0 {
            self.sim_f_clk = other.sim_f_clk;
        }
        for l in &other.sim_per_layer {
            LayerCost::fold_into(&mut self.sim_per_layer, l);
        }
        self.sim_gops = achieved_gops(self.sim_macs, self.sim_seconds);
    }
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    batches: u64,
    /// Fixed-size latency reservoir (µs) — see module docs.
    lat_sample: Vec<u64>,
    /// Latencies observed in total (≥ `lat_sample.len()`).
    lat_seen: u64,
    /// Exact maximum, tracked outside the reservoir.
    max_us: u64,
    rng: SplitMix64,
    started: Option<std::time::Instant>,
    sim_batches: u64,
    sim_cycles: u64,
    sim_off_chip: u64,
    sim_on_chip: u64,
    sim_macs: u64,
    sim_joules: f64,
    sim_seconds: f64,
    sim_f_clk: f64,
    sim_layers: Vec<LayerCost>,
}

impl Default for Inner {
    fn default() -> Self {
        Self {
            requests: 0,
            batches: 0,
            lat_sample: Vec::new(),
            lat_seen: 0,
            max_us: 0,
            rng: SplitMix64::new(0x5EED_CAFE),
            started: None,
            sim_batches: 0,
            sim_cycles: 0,
            sim_off_chip: 0,
            sim_on_chip: 0,
            sim_macs: 0,
            sim_joules: 0.0,
            sim_seconds: 0.0,
            sim_f_clk: 0.0,
            sim_layers: Vec::new(),
        }
    }
}

impl Inner {
    fn record_latency(&mut self, us: u64) {
        self.max_us = self.max_us.max(us);
        if self.lat_sample.len() < LATENCY_RESERVOIR {
            self.lat_sample.push(us);
        } else {
            // Algorithm R: item i (1-based) replaces a reservoir slot with
            // probability k/i, keeping the sample uniform over all seen.
            let j = self.rng.next_u64() % (self.lat_seen + 1);
            if (j as usize) < LATENCY_RESERVOIR {
                self.lat_sample[j as usize] = us;
            }
        }
        self.lat_seen += 1;
    }
}

/// Thread-safe metrics accumulator shared between the engine thread and
/// observers.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served batch: its per-request latencies plus the
    /// backend's [`BatchCost`] when it reported one.
    pub fn record_batch(&self, latencies: &[Duration], cost: Option<&BatchCost>) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(std::time::Instant::now);
        g.batches += 1;
        g.requests += latencies.len() as u64;
        for d in latencies {
            g.record_latency(d.as_micros() as u64);
        }
        if let Some(c) = cost {
            g.sim_batches += 1;
            g.sim_cycles += c.stats.cycles;
            g.sim_off_chip += c.stats.off_chip_accesses();
            g.sim_on_chip += c.stats.on_chip_accesses();
            g.sim_macs += c.stats.macs;
            g.sim_joules += c.joules;
            if c.f_clk > 0.0 {
                g.sim_seconds += c.stats.cycles as f64 / c.f_clk;
            }
            g.sim_f_clk = c.f_clk;
            for l in &c.per_layer {
                LayerCost::fold_into(&mut g.sim_layers, l);
            }
        }
    }

    fn pct(sorted: &[u64], p: f64) -> Duration {
        if sorted.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        Duration::from_micros(sorted[idx])
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut lats = g.lat_sample.clone();
        lats.sort_unstable();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
            p50_latency: Self::pct(&lats, 0.50),
            p95_latency: Self::pct(&lats, 0.95),
            max_latency: if g.lat_seen == 0 { Duration::ZERO } else { Duration::from_micros(g.max_us) },
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            sim_batches: g.sim_batches,
            sim_cycles: g.sim_cycles,
            sim_off_chip_accesses: g.sim_off_chip,
            sim_on_chip_accesses: g.sim_on_chip,
            sim_macs: g.sim_macs,
            sim_joules: g.sim_joules,
            sim_seconds: g.sim_seconds,
            sim_gops: achieved_gops(g.sim_macs, g.sim_seconds),
            sim_f_clk: g.sim_f_clk,
            sim_per_layer: g.sim_layers.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SimStats;

    #[test]
    fn percentiles_and_counts() {
        let m = ServeMetrics::new();
        m.record_batch(&[Duration::from_micros(100), Duration::from_micros(200)], None);
        m.record_batch(&[Duration::from_micros(300)], None);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert_eq!(s.max_latency, Duration::from_micros(300));
        assert_eq!(s.sim_batches, 0);
        assert_eq!(s.sim_gops, 0.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p95_latency, Duration::ZERO);
        assert_eq!(s.sim_cycles, 0);
    }

    #[test]
    fn latency_reservoir_is_bounded_and_max_exact() {
        let m = ServeMetrics::new();
        let n = (LATENCY_RESERVOIR * 3) as u64;
        for i in 0..n {
            m.record_batch(&[Duration::from_micros(i + 1)], None);
        }
        let g = m.inner.lock().unwrap();
        assert_eq!(g.lat_sample.len(), LATENCY_RESERVOIR, "reservoir must not grow past cap");
        assert_eq!(g.lat_seen, n);
        drop(g);
        let s = m.snapshot();
        assert_eq!(s.requests, n);
        assert_eq!(s.max_latency, Duration::from_micros(n), "max is exact, not sampled");
        // Percentiles of a uniform ramp stay near the true values even
        // though 2/3 of the observations were sampled out.
        let p50 = s.p50_latency.as_micros() as f64;
        assert!((p50 - n as f64 / 2.0).abs() < n as f64 * 0.1, "p50 ≈ n/2, got {p50}");
        assert!(s.p50_latency <= s.p95_latency && s.p95_latency <= s.max_latency);
    }

    fn cost_at(cycles: u64, macs: u64, f_clk: f64) -> BatchCost {
        let stats = SimStats {
            cycles,
            ext_input_reads: 10,
            weight_reads: 5,
            output_writes: 5,
            psum_buf_reads: 3,
            psum_buf_writes: 3,
            macs,
            ..Default::default()
        };
        BatchCost::from_stats(stats, f_clk, &crate::analytics::EnergyModel::paper())
    }

    fn cost(cycles: u64, macs: u64) -> BatchCost {
        cost_at(cycles, macs, 150.0e6)
    }

    #[test]
    fn sim_cost_accumulates() {
        let m = ServeMetrics::new();
        let c1 = cost(100, 400);
        let c2 = cost(50, 200);
        m.record_batch(&[Duration::from_micros(10)], Some(&c1));
        m.record_batch(&[Duration::from_micros(10)], Some(&c2));
        m.record_batch(&[Duration::from_micros(10)], None); // mixed traffic
        let s = m.snapshot();
        assert_eq!(s.batches, 3);
        assert_eq!(s.sim_batches, 2);
        assert_eq!(s.sim_cycles, 150);
        assert_eq!(s.sim_macs, 600);
        assert_eq!(s.sim_off_chip_accesses, 40);
        assert_eq!(s.sim_on_chip_accesses, 12);
        assert!((s.sim_joules - (c1.joules + c2.joules)).abs() < 1e-18);
        let gops = 2.0 * 600.0 * 150.0e6 / 150.0 / 1e9;
        assert!((s.sim_gops - gops).abs() < 1e-9);
    }

    #[test]
    fn snapshot_merge_sums_counters_and_recomputes_rates() {
        let m1 = ServeMetrics::new();
        let m2 = ServeMetrics::new();
        m1.record_batch(&[Duration::from_micros(100)], Some(&cost(100, 400)));
        m2.record_batch(
            &[Duration::from_micros(300), Duration::from_micros(50)],
            Some(&cost(300, 600)),
        );
        let (s1, s2) = (m1.snapshot(), m2.snapshot());
        let mut merged = s1.clone();
        merged.merge(&s2);
        assert_eq!(merged.requests, s1.requests + s2.requests);
        assert_eq!(merged.batches, s1.batches + s2.batches);
        assert_eq!(merged.sim_batches, s1.sim_batches + s2.sim_batches);
        assert_eq!(merged.sim_cycles, s1.sim_cycles + s2.sim_cycles);
        assert_eq!(merged.sim_macs, s1.sim_macs + s2.sim_macs);
        assert_eq!(
            merged.sim_off_chip_accesses,
            s1.sim_off_chip_accesses + s2.sim_off_chip_accesses
        );
        assert!((merged.sim_joules - (s1.sim_joules + s2.sim_joules)).abs() < 1e-18);
        assert_eq!(merged.max_latency, Duration::from_micros(300));
        assert!((merged.mean_batch - 1.5).abs() < 1e-9);
        let gops = 2.0 * merged.sim_macs as f64 * 150.0e6 / merged.sim_cycles as f64 / 1e9;
        assert!((merged.sim_gops - gops).abs() < 1e-9);
        // merging into a default snapshot is the identity
        let mut from_zero = MetricsSnapshot::default();
        from_zero.merge(&s1);
        assert_eq!(from_zero.sim_cycles, s1.sim_cycles);
        assert_eq!(from_zero.sim_f_clk, s1.sim_f_clk);
    }

    #[test]
    fn per_layer_costs_accumulate_and_merge_by_name() {
        let m1 = ServeMetrics::new();
        let m2 = ServeMetrics::new();
        let layer = |name: &str, cycles: u64| LayerCost {
            name: name.into(),
            cycles,
            off_chip_accesses: cycles * 2,
            on_chip_accesses: cycles / 2,
            macs: cycles * 10,
        };
        let c1 = cost(100, 400).with_per_layer(vec![layer("L1", 60), layer("L2", 40)]);
        let c2 = cost(50, 200).with_per_layer(vec![layer("L1", 30), layer("L2", 20)]);
        m1.record_batch(&[Duration::from_micros(1)], Some(&c1));
        m1.record_batch(&[Duration::from_micros(1)], Some(&c2));
        let s1 = m1.snapshot();
        assert_eq!(s1.sim_per_layer.len(), 2, "folded by name across batches");
        assert_eq!(s1.sim_per_layer[0].name, "L1");
        assert_eq!(s1.sim_per_layer[0].cycles, 90);
        assert_eq!(s1.sim_per_layer[1].cycles, 60);
        assert_eq!(s1.sim_per_layer[0].macs, 900);
        // Router-style snapshot merge folds the other farm's table in.
        let c3 = cost(10, 40).with_per_layer(vec![layer("L2", 5), layer("L3", 5)]);
        m2.record_batch(&[Duration::from_micros(1)], Some(&c3));
        let mut merged = s1.clone();
        merged.merge(&m2.snapshot());
        assert_eq!(merged.sim_per_layer.len(), 3);
        assert_eq!(merged.sim_per_layer[1].cycles, 65, "L2 folded across farms");
        assert_eq!(merged.sim_per_layer[2].name, "L3");
        // cost-free batches leave the table untouched
        let plain = ServeMetrics::new();
        plain.record_batch(&[Duration::from_micros(1)], None);
        assert!(plain.snapshot().sim_per_layer.is_empty());
    }

    #[test]
    fn mixed_clock_merge_prices_each_farm_at_its_own_clock() {
        // A 150 MHz farm and a 300 MHz farm behind one router: the merged
        // GOPS must come from Σ simulated seconds, not Σ cycles priced at
        // one farm's clock.
        let slow = ServeMetrics::new();
        let fast = ServeMetrics::new();
        slow.record_batch(&[Duration::from_micros(1)], Some(&cost_at(100, 400, 150.0e6)));
        fast.record_batch(&[Duration::from_micros(1)], Some(&cost_at(100, 400, 300.0e6)));
        let mut merged = slow.snapshot();
        merged.merge(&fast.snapshot());
        let seconds = 100.0 / 150.0e6 + 100.0 / 300.0e6;
        assert!((merged.sim_seconds - seconds).abs() < 1e-18);
        let gops = 2.0 * 800.0 / seconds / 1e9;
        assert!((merged.sim_gops - gops).abs() < 1e-9, "got {}", merged.sim_gops);
        // the single-clock formula over summed cycles would be wrong here
        let naive = 2.0 * 800.0 * 150.0e6 / 200.0 / 1e9;
        assert!((merged.sim_gops - naive).abs() > 0.1);
    }
}
