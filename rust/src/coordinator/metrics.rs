//! Serving metrics: counters + latency distribution.

use std::sync::Mutex;
use std::time::Duration;

/// Point-in-time snapshot.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub max_latency: Duration,
    pub throughput_rps: f64,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    latencies_us: Vec<u64>,
    started: Option<std::time::Instant>,
}

/// Thread-safe metrics accumulator shared between the engine thread and
/// observers.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served batch.
    pub fn record_batch(&self, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(std::time::Instant::now);
        g.batches += 1;
        g.requests += latencies.len() as u64;
        g.latencies_us.extend(latencies.iter().map(|d| d.as_micros() as u64));
    }

    fn pct(sorted: &[u64], p: f64) -> Duration {
        if sorted.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        Duration::from_micros(sorted[idx])
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut lats = g.latencies_us.clone();
        lats.sort_unstable();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
            p50_latency: Self::pct(&lats, 0.50),
            p95_latency: Self::pct(&lats, 0.95),
            max_latency: lats.last().copied().map(Duration::from_micros).unwrap_or_default(),
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counts() {
        let m = ServeMetrics::new();
        m.record_batch(&[Duration::from_micros(100), Duration::from_micros(200)]);
        m.record_batch(&[Duration::from_micros(300)]);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert_eq!(s.max_latency, Duration::from_micros(300));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p95_latency, Duration::ZERO);
    }
}
