//! Layer-3 coordinator: the runtime system that serves CNN inference over
//! a pluggable backend — compiled TrIM artifacts (PJRT), the simulated
//! engine farm ([`crate::scheduler::SimBackend`]), or a mock.
//!
//! The paper's contribution is the accelerator; the coordinator plays the
//! role of its host-side runtime, shaped like a miniature serving router
//! (vllm-project/router style): an ingress queue, a dynamic batcher, a
//! single engine thread that owns the PJRT client (executables are not
//! `Sync`), per-layer dispatch mirroring the engine's layer-serial
//! schedule, and metrics.
//!
//! Threads + channels only — this crate builds offline with no async
//! runtime; the blocking batcher with a deadline performs the same
//! time-or-size batching policy a tokio select-loop would.

pub mod backend;
pub mod batcher;
pub mod coordinator;
pub mod metrics;
pub mod request;

pub use backend::{make_backend, BackendKind, InferenceBackend, MockBackend, PjrtBackend};
pub use crate::scheduler::SimBackend;
pub use batcher::{Batcher, BatcherConfig};
pub use coordinator::{Coordinator, CoordinatorConfig};
pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use request::{InferenceRequest, InferenceResponse};
