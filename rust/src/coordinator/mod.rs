//! Layer-3 coordinator: the runtime system that serves CNN inference over
//! a pluggable backend — compiled TrIM artifacts (PJRT), the simulated
//! engine farm ([`crate::scheduler::SimBackend`]), or a mock.
//!
//! The paper's contribution is the accelerator; the coordinator plays the
//! role of its host-side runtime, shaped like a miniature serving router
//! (vllm-project/router style): an ingress queue, a dynamic batcher, a
//! single engine thread that owns the PJRT client (executables are not
//! `Sync`), per-layer dispatch mirroring the engine's layer-serial
//! schedule, and metrics.
//!
//! Execution cost is a first-class part of the serving API: every
//! [`InferenceBackend::infer_batch`] returns a [`BatchReport`] whose
//! optional [`BatchCost`] carries the farm-aggregated
//! [`crate::arch::SimStats`] (cycles = max over parallel shards,
//! accesses = sum) plus GOPS/joules derived via
//! [`crate::analytics::EnergyModel`]. The coordinator attributes that
//! cost per request ([`InferenceResponse::cost`]) and accumulates it in
//! [`ServeMetrics`], so `trim serve --backend sim` reports simulated
//! cycles, memory accesses and joules next to rps — the paper's Tables
//! I–II accounting, live at the serving boundary.
//!
//! Scale-out is the [`Router`]: one `submit`/`infer`/`metrics` ingress
//! over N coordinators (each its own farm, possibly heterogeneous), with
//! cost-aware dispatch, retry-with-backoff across farms, and a merged
//! metrics snapshot.
//!
//! Robustness is the production front door (ISSUE 7): ingress is a
//! **bounded** queue guarded by [`AdmissionControl`] (shed with
//! [`ServeError::Overloaded`] past `queue_cap` or the EWMA-cost budget),
//! the batcher is deadline-aware (requests carry a deadline budget;
//! batches close by earliest-deadline − estimated service cost; hopeless
//! requests reject up front as [`ServeError::DeadlineExceeded`]), failed
//! or panicked batches resolve as typed [`ServeError::EngineFailed`]
//! (retried by the router on the next-cheapest farm), and
//! [`Coordinator::shutdown`] / [`Router::drain`] provide graceful drain —
//! admission closes, in-flight work flushes, the post-deadline backlog
//! rejects as [`ServeError::Shutdown`], engine threads join. The
//! [`http::HttpServer`] puts a std-only HTTP/JSON face (`/infer`,
//! `/metrics`, `/healthz`) on all of it. Per-client fairness is a
//! token-bucket [`ClientQuota`] in front of admission (`--client-rps`),
//! and hardware-level fault tolerance ([`crate::fault`]) surfaces here
//! too: each batch's [`BatchCost::faults`] carries the farm's
//! detected/corrected/quarantined counters into [`ServeMetrics`], the
//! Prometheus export and the router-merged snapshot.
//!
//! Observability rides on [`crate::obs`]: every admission opens a
//! `serve.request` span (finished when the reply is sent), each executed
//! batch is a `serve.batch` span, the batcher emits `batch.formed`
//! events naming which bound closed the batch, and the router emits
//! `router.dispatch` events with the chosen farm and its EWMA score.
//! [`ServeMetrics`] separates queue-wait from service time in log₂
//! histograms, all counters saturate, and [`MetricsSnapshot`] (which
//! also carries the farm's shadow-canary divergence totals) renders as
//! Prometheus text or a single JSON trajectory line.
//!
//! Threads + channels only — this crate builds offline with no async
//! runtime; the blocking batcher with a deadline performs the same
//! time-or-size batching policy a tokio select-loop would.

pub mod admission;
pub mod backend;
pub mod batcher;
pub mod coordinator;
pub mod error;
pub mod http;
pub mod metrics;
pub mod request;
pub mod router;
pub mod testing;

pub use admission::{AdmissionConfig, AdmissionControl, ClientQuota, Ewma, EWMA_ALPHA};
pub use backend::{
    make_backend, BackendKind, BatchCost, BatchReport, InferenceBackend, LayerCost, MockBackend,
    PjrtBackend, SimCost,
};
pub use crate::fault::{FaultConfig, FaultModel, FaultReport};
pub use crate::obs::HistogramSnapshot;
pub use crate::scheduler::{CanaryConfig, CanaryReport, SimBackend};
pub use testing::FaultInjectingBackend;
pub use batcher::{Batcher, BatcherConfig};
pub use coordinator::{Coordinator, CoordinatorConfig};
pub use error::{ServeError, ServeResult};
pub use http::HttpServer;
pub use metrics::{MetricsSnapshot, ServeMetrics, LATENCY_RESERVOIR};
pub use request::{InferenceRequest, InferenceResponse};
pub use router::{RetryConfig, Router, RouterReply};
