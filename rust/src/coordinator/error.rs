//! Typed serving errors — the front door's error taxonomy.
//!
//! Every request submitted to the serving stack resolves with either an
//! [`super::InferenceResponse`] or one of these variants; the old
//! empty-logits failure sentinel is gone. The variants map one-to-one
//! onto the production failure modes of the request path:
//!
//! * [`ServeError::Overloaded`] — admission control shed the request
//!   before it entered the queue (bounded ingress full, or queue depth ×
//!   EWMA cost past the configured budget). Carries a `retry_after` hint
//!   derived from the current queue depth and the EWMA service time.
//! * [`ServeError::DeadlineExceeded`] — the request's deadline budget
//!   cannot be met: either it was already expired at submit, or the
//!   deadline-aware batcher determined at batch formation that even an
//!   immediate execution would miss it.
//! * [`ServeError::EngineFailed`] — the backend failed (or panicked on)
//!   the batch this request rode in. The [`super::Router`] retries these
//!   on the next-cheapest farm with capped exponential backoff.
//! * [`ServeError::Shutdown`] — the coordinator is draining; admission
//!   is closed and queued requests past the drain deadline are rejected.
//!
//! `ServeError` implements [`std::error::Error`], so it travels inside
//! [`anyhow::Error`] and callers recover the typed variant with
//! `err.downcast_ref::<ServeError>()`.

use std::time::Duration;

/// Per-request result type flowing back over the reply channel.
pub type ServeResult = Result<super::InferenceResponse, ServeError>;

/// Why a request could not be served (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control shed the request; retry after the hint.
    Overloaded { retry_after: Duration },
    /// The request's deadline budget cannot be met; `missed_by` is the
    /// estimated overshoot at the point of rejection.
    DeadlineExceeded { missed_by: Duration },
    /// The backend failed or panicked on this request's batch.
    EngineFailed { reason: String },
    /// The coordinator is draining / shut down; admission is closed.
    Shutdown,
}

impl ServeError {
    /// Stable short name, used in metrics details and HTTP error bodies.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Overloaded { .. } => "overloaded",
            Self::DeadlineExceeded { .. } => "deadline_exceeded",
            Self::EngineFailed { .. } => "engine_failed",
            Self::Shutdown => "shutdown",
        }
    }

    /// True when a retry (possibly on another farm) may succeed — the
    /// router's retry loop only acts on these.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Self::EngineFailed { .. })
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Overloaded { retry_after } => {
                write!(f, "overloaded: admission shed the request (retry after {retry_after:?})")
            }
            Self::DeadlineExceeded { missed_by } => {
                write!(f, "deadline exceeded: would miss the budget by ≈{missed_by:?}")
            }
            Self::EngineFailed { reason } => write!(f, "engine failed: {reason}"),
            Self::Shutdown => write!(f, "shutting down: admission is closed"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_display_are_stable() {
        let o = ServeError::Overloaded { retry_after: Duration::from_millis(5) };
        assert_eq!(o.kind(), "overloaded");
        assert!(o.to_string().contains("retry after"));
        let d = ServeError::DeadlineExceeded { missed_by: Duration::from_micros(10) };
        assert_eq!(d.kind(), "deadline_exceeded");
        let e = ServeError::EngineFailed { reason: "boom".into() };
        assert_eq!(e.kind(), "engine_failed");
        assert!(e.to_string().contains("boom"));
        assert_eq!(ServeError::Shutdown.kind(), "shutdown");
    }

    #[test]
    fn only_engine_failures_are_retryable() {
        assert!(ServeError::EngineFailed { reason: String::new() }.is_retryable());
        assert!(!ServeError::Shutdown.is_retryable());
        assert!(!ServeError::Overloaded { retry_after: Duration::ZERO }.is_retryable());
        assert!(!ServeError::DeadlineExceeded { missed_by: Duration::ZERO }.is_retryable());
    }

    #[test]
    fn travels_through_anyhow_and_downcasts() {
        let err: anyhow::Error = ServeError::Overloaded { retry_after: Duration::ZERO }.into();
        let back = err.downcast_ref::<ServeError>().expect("typed error must downcast");
        assert_eq!(back.kind(), "overloaded");
    }
}
