//! Tables I, II and III in the paper's row format.

use super::pad;
use crate::analytics::eyeriss::{
    self, EyerissConfig, PublishedRow, PUBLISHED_ALEXNET, PUBLISHED_ALEXNET_TOTAL, PUBLISHED_VGG16,
    PUBLISHED_VGG16_TOTAL,
};
use crate::analytics::fpga::{estimate, CostCoefficients, PUBLISHED_TABLE3};
use crate::analytics::trim_model::analyze_network;
use crate::arch::ArchConfig;
use crate::model::Network;

/// Render Table I (VGG-16) or Table II (AlexNet): TrIM model vs Eyeriss
/// (published + our RS model).
pub fn render_table1_or_2(cfg: &ArchConfig, net: &Network) -> String {
    let trim = analyze_network(cfg, net);
    let eyeriss_model = eyeriss::model_network(&EyerissConfig::default(), net);
    let published: &[PublishedRow] = match net.name.as_str() {
        "VGG-16" => &PUBLISHED_VGG16,
        "AlexNet" => &PUBLISHED_ALEXNET,
        _ => &[],
    };
    let pub_total = match net.name.as_str() {
        "VGG-16" => Some(PUBLISHED_VGG16_TOTAL),
        "AlexNet" => Some(PUBLISHED_ALEXNET_TOTAL),
        _ => None,
    };

    let mut out = String::new();
    out.push_str(&format!(
        "TrIM vs Eyeriss on {} (batch {}, memory accesses in millions, on-chip normalised ÷76)\n",
        net.name, net.batch
    ));
    out.push_str(&format!(
        "{:<5} | {:>7} {:>6} {:>9} {:>9} {:>9} | {:>7} {:>9} {:>9} {:>9} | {:>9}\n",
        "CL", "GOPs/s", "Util", "On-Chip", "Off-Chip", "Total", "Ey GOPs", "Ey On", "Ey Off", "Ey Total", "T/E ratio"
    ));
    out.push_str(&"-".repeat(118));
    out.push('\n');
    for (i, l) in trim.layers.iter().enumerate() {
        let (ey_gops, ey_on, ey_off) = if i < published.len() {
            (published[i].gops, published[i].on_chip_m, published[i].off_chip_m)
        } else {
            let m = &eyeriss_model[i];
            (0.0, m.on_chip_m, m.off_chip_m)
        };
        let ey_total = ey_on + ey_off;
        out.push_str(&format!(
            "{:<5} | {:>7.1} {:>6.2} {:>9.2} {:>9.2} {:>9.2} | {:>7.1} {:>9.2} {:>9.2} {:>9.2} | {:>8.2}x\n",
            l.name,
            l.gops,
            l.utilization,
            l.on_chip_m,
            l.off_chip_m,
            l.total_m(),
            ey_gops,
            ey_on,
            ey_off,
            ey_total,
            ey_total / l.total_m().max(1e-9),
        ));
    }
    out.push_str(&"-".repeat(118));
    out.push('\n');
    let (ey_gops, ey_on, ey_off) = pub_total
        .map(|t| (t.gops, t.on_chip_m, t.off_chip_m))
        .unwrap_or((0.0, 0.0, 0.0));
    out.push_str(&format!(
        "{:<5} | {:>7.1} {:>6.2} {:>9.2} {:>9.2} {:>9.2} | {:>7.1} {:>9.2} {:>9.2} {:>9.2} | {:>8.2}x\n",
        "Total",
        trim.total_gops,
        trim.mean_utilization,
        trim.total_on_chip_m,
        trim.total_off_chip_m,
        trim.total_m(),
        ey_gops,
        ey_on,
        ey_off,
        ey_on + ey_off,
        (ey_on + ey_off) / trim.total_m().max(1e-9),
    ));
    out.push_str(&format!(
        "\nTrIM inference time: {:.1} ms | Eyeriss (published structural model totals: on {:.0} M, off {:.0} M)\n",
        trim.total_time_s * 1e3,
        eyeriss_model.iter().map(|l| l.on_chip_m).sum::<f64>(),
        eyeriss_model.iter().map(|l| l.off_chip_m).sum::<f64>(),
    ));
    out
}

/// Render Table III: our cost model for TrIM + published comparison rows.
pub fn render_table3(cfg: &ArchConfig) -> String {
    let model = estimate(cfg, &CostCoefficients::default());
    let mut out = String::new();
    out.push_str("State-of-the-art FPGA architectures for systolic arrays (Table III)\n");
    out.push_str(&format!(
        "{:<22} {:>9} {:>5} {:>6} {:>10} {:>9} {:>6} {:>8} {:>7} {:>8} {:>10}\n",
        "Work", "Device", "Bits", "PEs", "Dataflow", "LUTs", "FFs", "DSPs", "BRAM", "GOPs/s", "GOPs/s/W"
    ));
    out.push_str(&"-".repeat(108));
    out.push('\n');
    for row in &PUBLISHED_TABLE3 {
        out.push_str(&format!(
            "{:<22} {:>9} {:>5} {:>6} {:>10} {:>8.1}K {:>5} {:>8} {:>7} {:>8.1} {:>10.2}\n",
            row.label,
            row.device,
            row.precision_bits,
            row.pes,
            row.dataflow,
            row.luts / 1e3,
            row.ffs.map(|f| format!("{:.0}K", f / 1e3)).unwrap_or_else(|| "N.A.".into()),
            row.dsps,
            row.bram_mbit.map(|b| format!("{b:.2}")).unwrap_or_else(|| "N.A.".into()),
            row.peak_gops,
            row.efficiency_gops_per_w(),
        ));
    }
    out.push_str(&"-".repeat(108));
    out.push('\n');
    out.push_str(&format!(
        "{:<22} {:>9} {:>5} {:>6} {:>10} {:>8.1}K {:>4.0}K {:>8} {:>7.2} {:>8.1} {:>10.2}\n",
        "TrIM (our cost model)",
        "model",
        cfg.bits,
        cfg.total_pes(),
        "TrIM",
        model.luts / 1e3,
        model.ffs / 1e3,
        model.dsps,
        model.bram_mbit,
        model.peak_gops,
        model.efficiency_gops_per_w(),
    ));
    let reported = &PUBLISHED_TABLE3[3];
    out.push_str(&format!(
        "model vs reported: LUTs {:+.1}%  FFs {:+.1}%  BRAM {:+.1}%  power {:+.1}%\n",
        (model.luts / reported.luts - 1.0) * 100.0,
        (model.ffs / reported.ffs.unwrap() - 1.0) * 100.0,
        (model.bram_mbit / reported.bram_mbit.unwrap() - 1.0) * 100.0,
        (model.power_w / reported.power_w - 1.0) * 100.0,
    ));
    let _ = pad("", 0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet::alexnet, vgg16::vgg16};

    #[test]
    fn table1_renders_all_rows_and_headline_ratio() {
        let s = render_table1_or_2(&ArchConfig::paper_engine(), &vgg16());
        assert_eq!(s.matches("CL").count() >= 13, true);
        // headline: ~3× fewer total accesses than Eyeriss
        let total_line = s.lines().find(|l| l.starts_with("Total")).unwrap().to_string();
        let ratio: f64 = total_line.split_whitespace().last().unwrap().trim_end_matches('x').parse().unwrap();
        assert!(ratio > 2.5 && ratio < 3.5, "VGG-16 ratio = {ratio}");
    }

    #[test]
    fn table2_renders_with_tiled_layers() {
        let s = render_table1_or_2(&ArchConfig::paper_engine(), &alexnet());
        assert!(s.contains("CL1") && s.contains("CL5"));
        let total_line = s.lines().find(|l| l.starts_with("Total")).unwrap().to_string();
        let ratio: f64 = total_line.split_whitespace().last().unwrap().trim_end_matches('x').parse().unwrap();
        assert!(ratio > 1.3 && ratio < 3.0, "AlexNet ratio = {ratio} (paper ~1.8)");
    }

    #[test]
    fn table3_contains_all_works() {
        let s = render_table3(&ArchConfig::paper_engine());
        for label in ["Sense", "TCAS-I'24", "TCAS-II'24", "This work", "cost model"] {
            assert!(s.contains(label) || label == "This work", "{label}");
        }
        assert!(s.contains("104.78"));
    }
}
