//! Renderers that regenerate the paper's tables and figures as text.
//!
//! Every renderer returns a `String` so the CLI, the examples and the
//! benchmark harness can share them; each prints the paper's published
//! value next to the model/simulation output with the deviation, so a
//! reader can audit the reproduction row by row.

pub mod figures;
pub mod tables;

pub use figures::{render_fig1, render_fig7};
pub use tables::{render_table1_or_2, render_table3};

/// Right-pad/align helper used by the renderers.
pub(crate) fn pad(s: &str, w: usize) -> String {
    format!("{s:>w$}")
}

/// Simple horizontal bar for ASCII figures.
pub(crate) fn bar(value: f64, max: f64, width: usize) -> String {
    let n = if max > 0.0 { ((value / max) * width as f64).round() as usize } else { 0 };
    "█".repeat(n.min(width))
}
