//! Figs. 1 and 7 as ASCII charts.

use super::bar;
use crate::analytics::design_space::{sweep, PAPER_GRID};
use crate::analytics::ops::profile_network;
use crate::arch::ArchConfig;
use crate::model::Network;

/// Fig. 1: VGG-16 per-CL memory requirements (ifmap + weight bars) and
/// operations (points).
pub fn render_fig1(net: &Network, bits: usize) -> String {
    let profiles = profile_network(net, bits);
    let max_mb = profiles.iter().map(|p| p.total_mb()).fold(0.0, f64::max);
    let mut out = String::new();
    out.push_str(&format!(
        "Fig. 1 — {} per-CL memory ({} bit) and operations\n",
        net.name, bits
    ));
    out.push_str(&format!(
        "{:<5} {:>9} {:>9} {:>9} {:>7}  {}\n",
        "CL", "ifmap MB", "wgt MB", "total MB", "GOPs", "memory"
    ));
    for p in &profiles {
        out.push_str(&format!(
            "{:<5} {:>9.2} {:>9.2} {:>9.2} {:>7.2}  {}\n",
            p.name,
            p.ifmap_mb,
            p.weight_mb,
            p.total_mb(),
            p.gops,
            bar(p.total_mb(), max_mb, 40),
        ));
    }
    let tot_mb: f64 = profiles.iter().map(|p| p.total_mb()).sum();
    let tot_gops: f64 = profiles.iter().map(|p| p.gops).sum();
    out.push_str(&format!("Total: {tot_mb:.1} MB, {tot_gops:.1} GOPs per inference\n"));
    out
}

/// Fig. 7: design-space sweep — (a) throughput + psum buffer size,
/// (b) I/O bandwidth, over P_N, P_M ∈ {1, 4, 8, 16, 24}.
pub fn render_fig7(base: &ArchConfig, net: &Network) -> String {
    let pts = sweep(base, net);
    let max_gops = pts.iter().map(|p| p.gops).fold(0.0, f64::max);
    let mut out = String::new();
    out.push_str(&format!(
        "Fig. 7 — design space on {} at {:.0} MHz (grid P_N, P_M ∈ {:?})\n",
        net.name,
        base.f_clk / 1e6,
        PAPER_GRID
    ));
    out.push_str("(a) throughput [GOPs/s] (bars) + psum buffer size [Mbit] (per P_N group)\n");
    for chunk in pts.chunks(PAPER_GRID.len()) {
        let p_n = chunk[0].p_n;
        out.push_str(&format!(
            "  P_N={:<2} (psum buffers {:>6.2} Mbit)\n",
            p_n, chunk[0].psum_buffer_mbit
        ));
        for p in chunk {
            out.push_str(&format!(
                "    P_M={:<2} {:>7.1} {}\n",
                p.p_m,
                p.gops,
                bar(p.gops, max_gops, 36)
            ));
        }
    }
    out.push_str("(b) I/O bandwidth [bits/cycle]\n");
    for chunk in pts.chunks(PAPER_GRID.len()) {
        out.push_str(&format!("  P_N={:<2}", chunk[0].p_n));
        for p in chunk {
            out.push_str(&format!("  P_M={}:{:>5}", p.p_m, p.io_bandwidth_bits));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::vgg16;

    #[test]
    fn fig1_mentions_all_layers_and_totals() {
        let s = render_fig1(&vgg16(), 8);
        assert!(s.contains("CL13"));
        assert!(s.contains("30.7 GOPs") || s.contains("30.6 GOPs") || s.contains("30.8 GOPs"));
    }

    #[test]
    fn fig7_contains_best_case() {
        let s = render_fig7(&ArchConfig::paper_engine(), &vgg16());
        assert!(s.contains("P_N=24"));
        // §IV best case ≈ 1243 GOPs/s
        assert!(s.contains("1243") || s.contains("124"), "{s}");
    }
}
