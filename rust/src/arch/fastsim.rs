//! The fast execution tier: bit-exact functional convolution + closed-form
//! [`SimStats`] synthesis (see [`super::config::ExecFidelity`]).
//!
//! ## Why it exists
//!
//! The register tier spins a full [`super::slice::SliceSim`] sweep per
//! (filter, channel-group, tile) task — ~262k sweeps for a VGG-16
//! CL13-sized layer — even though every counter it reports is a
//! closed-form function of the layer geometry: cycles follow eq. (2) of
//! the companion dataflow/modelling paper (arXiv 2408.01254) and the
//! access counters follow the Tables I–II formulas, facts the register
//! tier's own tests prove (`engine_cycles_follow_eq2`,
//! `reads_each_padded_element_once`,
//! `broadcast_counts_inputs_once_per_filter_group`). Separating numerics
//! from timing — the way 3D-TrIM (arXiv 2502.18983) separates fabric
//! behaviour from fabric count — makes a farmed engine fast enough to
//! serve full VGG-16/AlexNet layers at volume.
//!
//! ## Contract
//!
//! For every layer the register tier accepts, the fast tier returns
//! **bit-identical ofmaps** and **counter-identical [`SimStats`]** (all
//! nine fields, including `max_rsrb_occupancy` and
//! `peak_ext_inputs_per_cycle`). This is enforced by the property tests in
//! `tests/proptest_invariants.rs` across native, tiled (K > K_nat),
//! strided and `run_filter_range`-sharded paths.
//!
//! ## Numerics
//!
//! The register datapath computes each 2-D convolution with wrapping i32
//! products/psum-chain additions, truncates the slice adder-tree output to
//! i32, accumulates channel/tile contributions in i64 (core spatial sum,
//! engine psum buffers, §V tile psums) and truncates the final engine
//! accumulator to i32. Because every wrap/truncation is a reduction
//! mod 2³² and i64 addition is exact, the composition equals a single
//! direct convolution accumulated in i64 and truncated once at the end —
//! which is what [`conv_blocked`] computes, with the filter-block ×
//! channel × output-row loop nest of `python/compile/kernels/blocked.py`
//! (the engine's step structure, cache-blocked).
//!
//! ## Cycle model
//!
//! Native layers: the register tier measures, per computational step,
//! `P_N·K` weight-load cycles plus one slice sweep
//! (`K + H_O1·W_O1 + (K−1) + tree(K)`) plus the core adder tree
//! (`tree(max(|m_grp|, 2))`), and one engine pipeline fill `L_I` per
//! layer. Summing over the `⌈N/P_N⌉ × ⌈M/P_M⌉` step grid (the tail
//! channel group has its own tree latency) reproduces the measurement
//! exactly. Tiled layers overwrite cycles with the
//! [`super::control::plan_layer`] schedule total, as the register tier
//! does.

use super::adder_tree::AdderTree;
use super::config::ArchConfig;
use super::control::StepPlan;
use super::stats::SimStats;
use crate::golden::Tensor3;
use crate::model::{ConvLayer, KernelTiling};

/// Filter-block size of the blocked convolution (the `N_B` of
/// `blocked.py`): how many filters' i64 psum rows stay resident while one
/// input channel streams through.
const N_BLOCK: usize = 8;

/// Blocked direct convolution, bit-exact against the register tier's
/// datapath (wrapping-i32 products, i64 accumulation, single final
/// truncation — see the module docs). `input` is `[M][H_I][W_I]`,
/// `weights` flat `[N][M][K][K]`; returns `[N][H_O][W_O]`.
pub fn conv_blocked(layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> Tensor3 {
    assert_eq!(input.c, layer.m);
    assert_eq!(input.h, layer.h_i);
    assert_eq!(input.w, layer.w_i);
    assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
    let (k, m, n, stride, pad) = (layer.k, layer.m, layer.n, layer.stride, layer.pad);
    let kk = k * k;
    let (h_o, w_o) = (layer.h_o(), layer.w_o());
    let (hp, wp) = (layer.h_i + 2 * pad, layer.w_i + 2 * pad);

    // Materialise the padded ifmaps once (the engine's broadcast buffer);
    // the inner loops then index without bounds arithmetic.
    let mut padded = vec![0i32; m * hp * wp];
    for c in 0..m {
        for y in 0..layer.h_i {
            let src = &input.channel(c)[y * layer.w_i..(y + 1) * layer.w_i];
            let dst = &mut padded[(c * hp + y + pad) * wp + pad..];
            dst[..layer.w_i].copy_from_slice(src);
        }
    }

    let mut ofmaps = Tensor3::zeros(n, h_o, w_o);
    let mut acc = vec![0i64; N_BLOCK.min(n) * h_o * w_o];
    for f0 in (0..n).step_by(N_BLOCK) {
        let fb = N_BLOCK.min(n - f0);
        let acc = &mut acc[..fb * h_o * w_o];
        acc.fill(0);
        for c in 0..m {
            let chan = &padded[c * hp * wp..(c + 1) * hp * wp];
            for df in 0..fb {
                let kern = &weights[((f0 + df) * m + c) * kk..((f0 + df) * m + c + 1) * kk];
                let a = &mut acc[df * h_o * w_o..(df + 1) * h_o * w_o];
                for oy in 0..h_o {
                    let arow = &mut a[oy * w_o..(oy + 1) * w_o];
                    for r in 0..k {
                        let irow = &chan[(oy * stride + r) * wp..(oy * stride + r + 1) * wp];
                        for (s, &wv) in kern[r * k..(r + 1) * k].iter().enumerate() {
                            if wv == 0 {
                                continue;
                            }
                            // i32×i32 products never overflow i64; the
                            // accumulation wraps mod 2⁶⁴, which preserves
                            // the final mod-2³² truncation exactly (and
                            // matches the register datapath under extreme
                            // operands without a debug-overflow panic).
                            let wv = wv as i64;
                            if stride == 1 {
                                // contiguous tap row: vectorisable AXPY
                                for (av, &x) in arow.iter_mut().zip(&irow[s..s + w_o]) {
                                    *av = av.wrapping_add(x as i64 * wv);
                                }
                            } else {
                                for (ox, av) in arow.iter_mut().enumerate() {
                                    *av = av.wrapping_add(irow[ox * stride + s] as i64 * wv);
                                }
                            }
                        }
                    }
                }
            }
        }
        // single truncation, as the engine accumulator drains (mod 2³²)
        for (i, &v) in acc.iter().enumerate() {
            ofmaps.data[f0 * h_o * w_o + i] = v as i32;
        }
    }
    ofmaps
}

/// Synthesize the complete [`SimStats`] of a register-tier
/// [`super::engine::EngineSim`] layer run from the layer geometry and the
/// [`StepPlan`] — no simulation. Counter-exact for every field (see the
/// module docs for the derivations; validated by property tests).
pub fn analytic_stats(cfg: &ArchConfig, layer: &ConvLayer, plan: &StepPlan) -> SimStats {
    let k = layer.k;
    let (hp, wp) = (layer.h_i + 2 * layer.pad, layer.w_i + 2 * layer.pad);
    let (h_o, w_o) = (layer.h_o(), layer.w_o());
    // stride-1 sweep grid the array always walks (§V decimation)
    let (h_o1, w_o1) = (hp - k + 1, wp - k + 1);
    let sweep = (h_o1 * w_o1) as u64;
    let ofm_per_filter = (h_o * w_o) as u64;
    let ofm = layer.n as u64 * ofm_per_filter;
    let mut s = SimStats { output_writes: ofm, ..SimStats::default() };

    if k <= cfg.k {
        // --- native path: one slice per (filter, channel) pair ---
        let n_groups = layer.n.div_ceil(cfg.p_n) as u64;
        let m_groups = layer.m.div_ceil(cfg.p_m);
        let slice_cycles = (2 * k - 1) as u64 + sweep + AdderTree::latency_for(k) as u64;
        // per-step cycles vary only through the tail channel group's core
        // tree fan-in
        let mut group_cycles = 0u64;
        for mi in 0..m_groups {
            let m_i = if mi + 1 == m_groups { layer.m - mi * cfg.p_m } else { cfg.p_m };
            group_cycles += plan.weight_load_cycles
                + slice_cycles
                + AdderTree::latency_for(m_i.max(2)) as u64;
        }
        s.cycles = cfg.pipeline_latency() + n_groups * group_cycles;
        // broadcast: the padded ifmap is read once per filter group
        s.ext_input_reads = n_groups * (layer.m * hp * wp) as u64;
        s.weight_reads = layer.weight_elems();
        s.macs = layer.weight_elems() * sweep;
        if m_groups > 1 {
            // temporal accumulation (Fig. 6): one write per group, one
            // read-modify-write per group after the first, per filter
            s.psum_buf_writes = m_groups as u64 * ofm;
            s.psum_buf_reads = (m_groups as u64 - 1) * ofm;
        }
        s.peak_ext_inputs_per_cycle = (2 * k - 1) as u64; // eq. (4) warm-up skew
        s.max_rsrb_occupancy = wp as u64; // one padded ifmap row
    } else {
        // --- tiled path (§V): T shifted K_nat×K_nat tasks per kernel ---
        let k_nat = cfg.k;
        let t = KernelTiling::new(k, k_nat).num_tiles() as u64;
        // shifted sub-view dims every tile sweeps
        let (hs, ws) = (hp - k + k_nat, wp - k + k_nat);
        s.cycles = plan.total_cycles;
        // broadcast: the shifted view is read once per filter pass
        s.ext_input_reads = layer.n as u64 * (hs * ws) as u64;
        let tasks = (layer.n * layer.m) as u64 * t;
        s.weight_reads = tasks * (k_nat * k_nat) as u64;
        s.macs = tasks * (k_nat * k_nat) as u64 * sweep;
        // channel groups beyond P_M spill through the psum buffers
        let spills = ((layer.m - 1) / cfg.p_m) as u64;
        s.psum_buf_reads = layer.n as u64 * spills * ofm_per_filter;
        s.psum_buf_writes = s.psum_buf_reads;
        s.peak_ext_inputs_per_cycle = (2 * k_nat - 1) as u64;
        s.max_rsrb_occupancy = ws as u64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::control::plan_layer;
    use crate::arch::EngineSim;
    use crate::golden::conv3d_i32;

    fn rand_tensor(c: usize, h: usize, w: usize, seed: i32) -> Tensor3 {
        Tensor3::from_fn(c, h, w, |ci, y, x| {
            ((ci as i32 * 131 + y as i32 * 31 + x as i32 * 7 + seed) % 251) - 125
        })
    }

    fn rand_weights(n: usize, m: usize, k: usize, seed: i32) -> Vec<i32> {
        (0..n * m * k * k).map(|i| ((i as i32 * 37 + seed) % 15) - 7).collect()
    }

    #[test]
    fn blocked_conv_matches_golden() {
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0), (9, 3, 17, 11, 2, 0)]
        {
            let layer = ConvLayer::new("b", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 7);
            let weights = rand_weights(n, m, k, 3);
            assert_eq!(
                conv_blocked(&layer, &input, &weights),
                conv3d_i32(&input, &weights, n, k, stride, pad),
                "hw={hw} k={k} m={m} n={n} s={stride} p={pad}"
            );
        }
    }

    #[test]
    fn blocked_conv_matches_register_datapath_under_overflow() {
        // Large magnitudes force the register tier's wrapping-i32 psum
        // chain to wrap; the i64-accumulate + truncate fast path must land
        // on the same bits.
        let layer = ConvLayer::new("ov", 8, 3, 3, 2, 1, 1);
        let input = Tensor3::from_fn(3, 8, 8, |c, y, x| {
            (c as i32 + 1) * 600_000_000 - (y * 8 + x) as i32 * 30_000_000
        });
        let weights: Vec<i32> =
            (0..2 * 3 * 9).map(|i| 1_000_000_000 - (i as i32 % 5) * 450_000_000).collect();
        let cfg = ArchConfig::small(3, 2, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        assert_eq!(conv_blocked(&layer, &input, &weights), reg.ofmaps);
    }

    #[test]
    fn analytic_stats_match_register_native_multi_group() {
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let cfg = ArchConfig::small(3, 2, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        assert_eq!(analytic_stats(&cfg, &layer, &plan), reg.stats);
    }

    #[test]
    fn analytic_stats_match_register_tiled_strided() {
        let layer = ConvLayer::new("t11", 31, 11, 2, 3, 4, 0);
        let input = rand_tensor(2, 31, 31, 17);
        let weights = rand_weights(3, 2, 11, 19);
        let cfg = ArchConfig::small(3, 4, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        assert_eq!(analytic_stats(&cfg, &layer, &plan), reg.stats);
    }
}
