//! The fast execution tier: bit-exact functional convolution + closed-form
//! [`SimStats`] synthesis (see [`super::config::ExecFidelity`]).
//!
//! ## Why it exists
//!
//! The register tier spins a full [`super::slice::SliceSim`] sweep per
//! (filter, channel-group, tile) task — ~262k sweeps for a VGG-16
//! CL13-sized layer — even though every counter it reports is a
//! closed-form function of the layer geometry: cycles follow eq. (2) of
//! the companion dataflow/modelling paper (arXiv 2408.01254) and the
//! access counters follow the Tables I–II formulas, facts the register
//! tier's own tests prove (`engine_cycles_follow_eq2`,
//! `reads_each_padded_element_once`,
//! `broadcast_counts_inputs_once_per_filter_group`). Separating numerics
//! from timing — the way 3D-TrIM (arXiv 2502.18983) separates fabric
//! behaviour from fabric count — makes a farmed engine fast enough to
//! serve full VGG-16/AlexNet layers at volume.
//!
//! ## Contract
//!
//! For every layer the register tier accepts, the fast tier returns
//! **bit-identical ofmaps** and **counter-identical [`SimStats`]** (all
//! nine fields, including `max_rsrb_occupancy` and
//! `peak_ext_inputs_per_cycle`). This is enforced by the property tests in
//! `tests/proptest_invariants.rs` across native, tiled (K > K_nat),
//! strided and `run_filter_range`-sharded paths.
//!
//! ## Numerics
//!
//! The register datapath computes each 2-D convolution with wrapping i32
//! products/psum-chain additions, truncates the slice adder-tree output to
//! i32, accumulates channel/tile contributions in i64 (core spatial sum,
//! engine psum buffers, §V tile psums) and truncates the final engine
//! accumulator to i32. Because every wrap/truncation is a reduction
//! mod 2³² and i64 addition is exact, the composition equals a single
//! direct convolution accumulated in i64 and truncated once at the end —
//! which is what [`conv_blocked`] computes, with the filter-block ×
//! channel × output-row loop nest of `python/compile/kernels/blocked.py`
//! (the engine's step structure, cache-blocked).
//!
//! ## Cycle model
//!
//! Native layers: the register tier measures, per computational step,
//! `P_N·K` weight-load cycles plus one slice sweep
//! (`K + H_O1·W_O1 + (K−1) + tree(K)`) plus the core adder tree
//! (`tree(max(|m_grp|, 2))`), and one engine pipeline fill `L_I` per
//! layer. Summing over the `⌈N/P_N⌉ × ⌈M/P_M⌉` step grid (the tail
//! channel group has its own tree latency) reproduces the measurement
//! exactly. Tiled layers overwrite cycles with the
//! [`super::control::plan_layer`] schedule total, as the register tier
//! does.

use super::adder_tree::AdderTree;
use super::config::ArchConfig;
use super::control::{plan_layer, StepPlan};
use super::stats::SimStats;
use crate::golden::Tensor3;
use crate::model::{ConvLayer, KernelTiling};
use std::ops::Range;
use std::sync::Arc;

/// Filter-block size of the blocked convolution (the `N_B` of
/// `blocked.py`): how many filters' i64 psum rows stay resident while one
/// input channel streams through.
const N_BLOCK: usize = 8;

/// Geometry of a materialised padded ifmap (the part of [`ConvScratch`]'s
/// cache key that is not the input tensor's identity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PadGeom {
    m: usize,
    h_i: usize,
    w_i: usize,
    pad: usize,
}

impl PadGeom {
    fn of(layer: &ConvLayer) -> Self {
        Self { m: layer.m, h_i: layer.h_i, w_i: layer.w_i, pad: layer.pad }
    }
}

/// Reusable fast-tier working set: the padded-ifmap materialisation (the
/// engine's broadcast buffer) plus the i64 accumulator arena of the
/// blocked convolution.
///
/// This is what makes the fast tier **allocation-free on the hot path**:
/// one scratch, owned by an [`super::engine::EngineSim`], serves every
/// layer/shard/step that engine runs. The two buffers are `resize`d in
/// place (capacity is kept across calls), and the padded ifmap is keyed on
/// the input tensor's `Arc` identity + pad geometry, so all shards and
/// filter-block steps of one batch input share a **single** padded-input
/// materialisation — a row shard computes its `oy0..oy1` band straight out
/// of the resident full padded ifmap instead of re-padding (or slab-
/// copying) the input per shard. The held `Arc` keeps the input alive
/// while it is cached, so a pointer match can never be a stale
/// reallocation.
///
/// `fills`/`hits` count (re)materialisations vs cache reuses — the
/// observability hook the allocation-reuse tests pin.
#[derive(Default)]
pub struct ConvScratch {
    padded: Vec<i32>,
    acc: Vec<i64>,
    /// Identity of the input whose padded ifmap is resident.
    held: Option<(Arc<Tensor3>, PadGeom)>,
    fills: u64,
    hits: u64,
    /// Per-microkernel-arm invocation counts, one per (channel,
    /// filter-in-block) dispatch: `[k3, unit, strided]` (saturating).
    arms: [u64; 3],
}

impl ConvScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Times the padded ifmap was (re)materialised.
    pub fn fills(&self) -> u64 {
        self.fills
    }

    /// Times a call found the right padded ifmap already resident.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cumulative microkernel-arm invocations `[k3, unit, strided]` —
    /// one count per (channel, filter) inner dispatch, the unit the
    /// `sim_hotpath` bench prices.
    pub fn microkernel_arms(&self) -> [u64; 3] {
        self.arms
    }

    /// Address of the padded-ifmap buffer (stable across cache hits —
    /// pinned by the pointer-identity test).
    pub fn padded_ptr(&self) -> *const i32 {
        self.padded.as_ptr()
    }

    /// Blocked convolution of output rows `rows`, reusing the resident
    /// padded ifmap when `input` is the same `Arc` (same tensor, same pad
    /// geometry) as the previous call — the batch-level input reuse of
    /// ROADMAP §Two-tier engine.
    pub fn conv_rows_shared(
        &mut self,
        layer: &ConvLayer,
        input: &Arc<Tensor3>,
        weights: &[i32],
        rows: Range<usize>,
    ) -> Tensor3 {
        let geom = PadGeom::of(layer);
        let resident = matches!(&self.held, Some((held, g)) if Arc::ptr_eq(held, input) && *g == geom);
        if resident {
            self.hits += 1;
        } else {
            // Invalidate the key *before* filling: a farm worker survives
            // job panics (catch_unwind keeps this scratch alive), so if
            // fill_padded panics mid-fill the stale key must not alias the
            // half-overwritten buffer on a later call.
            self.held = None;
            fill_padded(&mut self.padded, layer, input);
            self.held = Some((Arc::clone(input), geom));
            self.fills += 1;
        }
        conv_rows_from_padded(layer, &self.padded, weights, rows, &mut self.acc, &mut self.arms)
    }

    /// Blocked convolution of output rows `rows` for a caller that holds
    /// only a reference: always re-materialises the padded ifmap (no safe
    /// identity to key on) but still reuses both buffers' capacity.
    pub fn conv_rows(
        &mut self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        rows: Range<usize>,
    ) -> Tensor3 {
        self.held = None;
        fill_padded(&mut self.padded, layer, input);
        self.fills += 1;
        conv_rows_from_padded(layer, &self.padded, weights, rows, &mut self.acc, &mut self.arms)
    }
}

/// Materialise the padded ifmaps (the engine's broadcast buffer) into
/// `padded`, reusing its capacity; the inner conv loops then index without
/// bounds arithmetic.
fn fill_padded(padded: &mut Vec<i32>, layer: &ConvLayer, input: &Tensor3) {
    let (hp, wp) = (layer.h_i + 2 * layer.pad, layer.w_i + 2 * layer.pad);
    padded.clear();
    padded.resize(layer.m * hp * wp, 0);
    for c in 0..layer.m {
        for y in 0..layer.h_i {
            let src = &input.channel(c)[y * layer.w_i..(y + 1) * layer.w_i];
            let dst = &mut padded[(c * hp + y + layer.pad) * wp + layer.pad..];
            dst[..layer.w_i].copy_from_slice(src);
        }
    }
}

/// The blocked-conv loop nest over output rows `[rows.start, rows.end)` of
/// `layer`, reading the already-materialised full padded ifmap. Returns
/// `[N][rows.len()][W_O]`. `acc` is the caller's i64 arena (resized in
/// place, zeroed per filter block).
///
/// The per-(filter, channel) inner work is dispatched once, outside the
/// row loops, to one of three `w_o`-contiguous microkernels:
/// [`conv_taps_k3`] (the paper-native K = 3 / stride 1 serving hot path,
/// all three taps of a kernel row fused into one unit-stride pass),
/// [`conv_taps_unit`] (generic K at stride 1) and [`conv_taps_strided`]
/// (sweep-and-decimate geometries). All three accumulate with wrapping
/// i64 adds, which are associative/commutative mod 2⁶⁴ — so the tap
/// reordering cannot change the final mod-2³² truncation, and the
/// microkernels stay bit-exact vs the register oracle by construction
/// (property-tested in tests/proptest_invariants.rs).
fn conv_rows_from_padded(
    layer: &ConvLayer,
    padded: &[i32],
    weights: &[i32],
    rows: Range<usize>,
    acc: &mut Vec<i64>,
    arms: &mut [u64; 3],
) -> Tensor3 {
    assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
    assert!(rows.start < rows.end && rows.end <= layer.h_o(), "bad output-row range {rows:?}");
    let (k, m, n, stride) = (layer.k, layer.m, layer.n, layer.stride);
    let kk = k * k;
    let w_o = layer.w_o();
    let b_h = rows.len();
    let (hp, wp) = (layer.h_i + 2 * layer.pad, layer.w_i + 2 * layer.pad);
    debug_assert_eq!(padded.len(), m * hp * wp);

    let mut ofmaps = Tensor3::zeros(n, b_h, w_o);
    acc.clear();
    acc.resize(N_BLOCK.min(n) * b_h * w_o, 0);
    for f0 in (0..n).step_by(N_BLOCK) {
        let fb = N_BLOCK.min(n - f0);
        let acc = &mut acc[..fb * b_h * w_o];
        acc.fill(0);
        for c in 0..m {
            let chan = &padded[c * hp * wp..(c + 1) * hp * wp];
            for df in 0..fb {
                let kern = &weights[((f0 + df) * m + c) * kk..((f0 + df) * m + c + 1) * kk];
                let a = &mut acc[df * b_h * w_o..(df + 1) * b_h * w_o];
                if stride == 1 && k == 3 {
                    arms[0] = arms[0].saturating_add(1);
                    conv_taps_k3(a, chan, kern, rows.clone(), wp, w_o);
                } else if stride == 1 {
                    arms[1] = arms[1].saturating_add(1);
                    conv_taps_unit(a, chan, kern, rows.clone(), wp, w_o, k);
                } else {
                    arms[2] = arms[2].saturating_add(1);
                    conv_taps_strided(a, chan, kern, rows.clone(), wp, w_o, k, stride);
                }
            }
        }
        // single truncation, as the engine accumulator drains (mod 2³²)
        for (i, &v) in acc.iter().enumerate() {
            ofmaps.data[f0 * b_h * w_o + i] = v as i32;
        }
    }
    ofmaps
}

/// K = 3, stride 1 — the paper's native geometry and the serving hot
/// path. The three taps of each kernel row are fused into a single
/// unit-stride pass over the padded input row, so every input element is
/// loaded once per kernel row (not once per tap); the i32→i64 widening
/// of the taps is hoisted out of the inner loop; and `x0/x1/x2` are
/// fixed-length `w_o` sub-slices of the same row, so the bounds checks
/// fold away and the loop autovectorizes (widening multiply-accumulate)
/// on stable Rust with no dependencies. All-zero kernel rows skip the
/// pass (bit-exact either way: the skipped terms are zero).
// The indexed loop (rather than a 4-deep iterator zip) is the form LLVM
// reliably turns into one vectorised pass over the four streams.
// lint: hot-path
#[allow(clippy::needless_range_loop)]
#[inline]
fn conv_taps_k3(a: &mut [i64], chan: &[i32], kern: &[i32], rows: Range<usize>, wp: usize, w_o: usize) {
    for (by, oy) in rows.enumerate() {
        let arow = &mut a[by * w_o..(by + 1) * w_o];
        for r in 0..3 {
            let kr = &kern[r * 3..r * 3 + 3];
            if kr[0] == 0 && kr[1] == 0 && kr[2] == 0 {
                continue;
            }
            let (w0, w1, w2) = (kr[0] as i64, kr[1] as i64, kr[2] as i64);
            // w_o = wp − 2 here, so the row slice is exactly wp long.
            let irow = &chan[(oy + r) * wp..(oy + r) * wp + w_o + 2];
            let (x0, x1, x2) = (&irow[..w_o], &irow[1..w_o + 1], &irow[2..w_o + 2]);
            for i in 0..w_o {
                arow[i] = arow[i]
                    .wrapping_add(x0[i] as i64 * w0)
                    .wrapping_add(x1[i] as i64 * w1)
                    .wrapping_add(x2[i] as i64 * w2);
            }
        }
    }
}

/// Generic K at stride 1: per-tap AXPY, unit-stride over the padded row
/// with the tap's widened weight hoisted; zero taps skip their pass.
// lint: hot-path
#[inline]
fn conv_taps_unit(a: &mut [i64], chan: &[i32], kern: &[i32], rows: Range<usize>, wp: usize, w_o: usize, k: usize) {
    for (by, oy) in rows.enumerate() {
        let arow = &mut a[by * w_o..(by + 1) * w_o];
        for r in 0..k {
            // w_o = wp − k + 1, so the row slice is exactly wp long.
            let irow = &chan[(oy + r) * wp..(oy + r) * wp + w_o + k - 1];
            for (s, &wv) in kern[r * k..(r + 1) * k].iter().enumerate() {
                if wv == 0 {
                    continue;
                }
                let wv = wv as i64;
                for (av, &x) in arow.iter_mut().zip(&irow[s..s + w_o]) {
                    *av = av.wrapping_add(x as i64 * wv);
                }
            }
        }
    }
}

/// Strided fallback (sweep-and-decimate geometries, e.g. AlexNet CL1):
/// per-tap gather at `stride`-spaced columns.
// lint: hot-path
#[allow(clippy::too_many_arguments)]
#[inline]
fn conv_taps_strided(
    a: &mut [i64],
    chan: &[i32],
    kern: &[i32],
    rows: Range<usize>,
    wp: usize,
    w_o: usize,
    k: usize,
    stride: usize,
) {
    for (by, oy) in rows.enumerate() {
        let arow = &mut a[by * w_o..(by + 1) * w_o];
        for r in 0..k {
            let irow = &chan[(oy * stride + r) * wp..(oy * stride + r + 1) * wp];
            for (s, &wv) in kern[r * k..(r + 1) * k].iter().enumerate() {
                if wv == 0 {
                    continue;
                }
                let wv = wv as i64;
                for (ox, av) in arow.iter_mut().enumerate() {
                    *av = av.wrapping_add(irow[ox * stride + s] as i64 * wv);
                }
            }
        }
    }
}

/// Blocked direct convolution, bit-exact against the register tier's
/// datapath (wrapping-i32 products, i64 accumulation, single final
/// truncation — see the module docs). `input` is `[M][H_I][W_I]`,
/// `weights` flat `[N][M][K][K]`; returns `[N][H_O][W_O]`.
///
/// Standalone convenience over a throwaway [`ConvScratch`]; the serving
/// hot path goes through the [`super::engine::EngineSim`]-owned scratch
/// instead, which keeps the padded ifmap and accumulator arena alive
/// across layers, shards and steps.
pub fn conv_blocked(layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> Tensor3 {
    assert_eq!(input.c, layer.m);
    assert_eq!(input.h, layer.h_i);
    assert_eq!(input.w, layer.w_i);
    ConvScratch::new().conv_rows(layer, input, weights, 0..layer.h_o())
}

/// Synthesize the complete [`SimStats`] of a register-tier
/// [`super::engine::EngineSim`] layer run from the layer geometry and the
/// [`StepPlan`] — no simulation. Counter-exact for every field (see the
/// module docs for the derivations; validated by property tests).
pub fn analytic_stats(cfg: &ArchConfig, layer: &ConvLayer, plan: &StepPlan) -> SimStats {
    let k = layer.k;
    let (hp, wp) = (layer.h_i + 2 * layer.pad, layer.w_i + 2 * layer.pad);
    let (h_o, w_o) = (layer.h_o(), layer.w_o());
    // stride-1 sweep grid the array always walks (§V decimation)
    let (h_o1, w_o1) = (hp - k + 1, wp - k + 1);
    let sweep = (h_o1 * w_o1) as u64;
    let ofm_per_filter = (h_o * w_o) as u64;
    let ofm = layer.n as u64 * ofm_per_filter;
    let mut s = SimStats { output_writes: ofm, ..SimStats::default() };

    if k <= cfg.k {
        // --- native path: one slice per (filter, channel) pair ---
        let n_groups = layer.n.div_ceil(cfg.p_n) as u64;
        let m_groups = layer.m.div_ceil(cfg.p_m);
        let slice_cycles = (2 * k - 1) as u64 + sweep + AdderTree::latency_for(k) as u64;
        // per-step cycles vary only through the tail channel group's core
        // tree fan-in
        let mut group_cycles = 0u64;
        for mi in 0..m_groups {
            let m_i = if mi + 1 == m_groups { layer.m - mi * cfg.p_m } else { cfg.p_m };
            group_cycles += plan.weight_load_cycles
                + slice_cycles
                + AdderTree::latency_for(m_i.max(2)) as u64;
        }
        s.cycles = cfg.pipeline_latency() + n_groups * group_cycles;
        // broadcast: the padded ifmap is read once per filter group
        s.ext_input_reads = n_groups * (layer.m * hp * wp) as u64;
        s.weight_reads = layer.weight_elems();
        s.macs = layer.weight_elems() * sweep;
        if m_groups > 1 {
            // temporal accumulation (Fig. 6): one write per group, one
            // read-modify-write per group after the first, per filter
            s.psum_buf_writes = m_groups as u64 * ofm;
            s.psum_buf_reads = (m_groups as u64 - 1) * ofm;
        }
        s.peak_ext_inputs_per_cycle = (2 * k - 1) as u64; // eq. (4) warm-up skew
        s.max_rsrb_occupancy = wp as u64; // one padded ifmap row
    } else {
        // --- tiled path (§V): T shifted K_nat×K_nat tasks per kernel ---
        let k_nat = cfg.k;
        let t = KernelTiling::new(k, k_nat).num_tiles() as u64;
        // shifted sub-view dims every tile sweeps
        let (hs, ws) = (hp - k + k_nat, wp - k + k_nat);
        s.cycles = plan.total_cycles;
        // broadcast: the shifted view is read once per filter pass
        s.ext_input_reads = layer.n as u64 * (hs * ws) as u64;
        let tasks = (layer.n * layer.m) as u64 * t;
        s.weight_reads = tasks * (k_nat * k_nat) as u64;
        s.macs = tasks * (k_nat * k_nat) as u64 * sweep;
        // channel groups beyond P_M spill through the psum buffers
        let spills = ((layer.m - 1) / cfg.p_m) as u64;
        s.psum_buf_reads = layer.n as u64 * spills * ofm_per_filter;
        s.psum_buf_writes = s.psum_buf_reads;
        s.peak_ext_inputs_per_cycle = (2 * k_nat - 1) as u64;
        s.max_rsrb_occupancy = ws as u64;
    }
    s
}

/// Row-band variant of [`analytic_stats`]: the complete [`SimStats`] of
/// computing only output rows `rows` of `layer` — the counters the fast
/// tier of [`super::engine::EngineSim::run_row_range`] reports for a
/// proper sub-range.
///
/// A row band is exactly the band's slab run as an ordinary layer
/// ([`ConvLayer::row_band`]): `pad = 0`, ifmap = the slab of padded rows
/// `[rows.start·stride, (rows.end−1)·stride + K)` — so the band's
/// counters are [`analytic_stats`] of that synthetic layer, which is also
/// precisely what the register tier measures for the band. Off-chip input
/// reads therefore count the band's **whole slab including halo rows**:
/// summed over the bands of a [`crate::scheduler::ShardPlan`] they equal
/// the single-engine reads plus exactly the inter-band halo duplication,
/// while MACs/output/psum counters partition the single-engine counters
/// exactly on stride-1 layers (strided layers sweep-and-decimate, so
/// bands skip the sweep rows between bands and their MAC sum is
/// correspondingly *smaller* — pinned by the farm property tests).
///
/// Full-range caveat: for `rows == 0..H_O` this still prices the band's
/// slab of `(H_O−1)·stride + K` rows, whereas `run_row_range` degenerates
/// to a whole-layer run that reads the entire padded ifmap — on strided
/// layers the whole-layer run additionally pays the decimation-leftover
/// rows (`H_P mod stride`-ish tail the sweep walks but no band needs).
/// The engine short-circuits before ever pricing a full range as a band,
/// so the two only differ if you call this helper with the full range
/// yourself.
pub fn analytic_stats_rows(cfg: &ArchConfig, layer: &ConvLayer, rows: &Range<usize>) -> SimStats {
    let band = layer.row_band(rows);
    let plan = plan_layer(cfg, &band);
    analytic_stats(cfg, &band, &plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::control::plan_layer;
    use crate::arch::EngineSim;
    use crate::golden::conv3d_i32;

    fn rand_tensor(c: usize, h: usize, w: usize, seed: i32) -> Tensor3 {
        Tensor3::from_fn(c, h, w, |ci, y, x| {
            ((ci as i32 * 131 + y as i32 * 31 + x as i32 * 7 + seed) % 251) - 125
        })
    }

    fn rand_weights(n: usize, m: usize, k: usize, seed: i32) -> Vec<i32> {
        (0..n * m * k * k).map(|i| ((i as i32 * 37 + seed) % 15) - 7).collect()
    }

    #[test]
    fn blocked_conv_matches_golden() {
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0), (9, 3, 17, 11, 2, 0)]
        {
            let layer = ConvLayer::new("b", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 7);
            let weights = rand_weights(n, m, k, 3);
            assert_eq!(
                conv_blocked(&layer, &input, &weights),
                conv3d_i32(&input, &weights, n, k, stride, pad),
                "hw={hw} k={k} m={m} n={n} s={stride} p={pad}"
            );
        }
    }

    #[test]
    fn k3_microkernel_zero_row_skip_stays_exact() {
        // All-zero kernel rows hit the fused K=3 microkernel's skip path;
        // whole-zero kernels and mixed kernels must still be bit-exact.
        let layer = ConvLayer::new("z", 9, 3, 2, 3, 1, 1);
        let input = rand_tensor(2, 9, 9, 91);
        let mut weights = rand_weights(3, 2, 3, 93);
        for fc in 0..3 * 2 {
            for s in 3..6 {
                weights[fc * 9 + s] = 0; // middle row of every kernel
            }
        }
        for w in weights.iter_mut().take(9) {
            *w = 0; // the whole first kernel
        }
        assert_eq!(
            conv_blocked(&layer, &input, &weights),
            conv3d_i32(&input, &weights, 3, 3, 1, 1)
        );
    }

    #[test]
    fn blocked_conv_matches_register_datapath_under_overflow() {
        // Large magnitudes force the register tier's wrapping-i32 psum
        // chain to wrap; the i64-accumulate + truncate fast path must land
        // on the same bits.
        let layer = ConvLayer::new("ov", 8, 3, 3, 2, 1, 1);
        let input = Tensor3::from_fn(3, 8, 8, |c, y, x| {
            (c as i32 + 1) * 600_000_000 - (y * 8 + x) as i32 * 30_000_000
        });
        let weights: Vec<i32> =
            (0..2 * 3 * 9).map(|i| 1_000_000_000 - (i as i32 % 5) * 450_000_000).collect();
        let cfg = ArchConfig::small(3, 2, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        assert_eq!(conv_blocked(&layer, &input, &weights), reg.ofmaps);
    }

    #[test]
    fn analytic_stats_match_register_native_multi_group() {
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let cfg = ArchConfig::small(3, 2, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        assert_eq!(analytic_stats(&cfg, &layer, &plan), reg.stats);
    }

    #[test]
    fn analytic_stats_match_register_tiled_strided() {
        let layer = ConvLayer::new("t11", 31, 11, 2, 3, 4, 0);
        let input = rand_tensor(2, 31, 31, 17);
        let weights = rand_weights(3, 2, 11, 19);
        let cfg = ArchConfig::small(3, 4, 2);
        let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        assert_eq!(analytic_stats(&cfg, &layer, &plan), reg.stats);
    }

    #[test]
    fn conv_rows_slices_the_full_conv() {
        // Every contiguous band of conv_rows must equal the matching rows
        // of the whole-layer conv, native and tiled, strided and padded.
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 4usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0)]
        {
            let layer = ConvLayer::new("rb", hw, k, m, n, stride, pad);
            let input = Arc::new(rand_tensor(m, hw, hw, 29));
            let weights = rand_weights(n, m, k, 31);
            let whole = conv_blocked(&layer, &input, &weights);
            let (h_o, w_o) = (layer.h_o(), layer.w_o());
            let mid = h_o / 2;
            let mut scratch = ConvScratch::new();
            for rows in [0..mid.max(1), mid.min(h_o - 1)..h_o] {
                let band = scratch.conv_rows_shared(&layer, &input, &weights, rows.clone());
                assert_eq!((band.c, band.h, band.w), (n, rows.len(), w_o));
                for f in 0..n {
                    assert_eq!(
                        band.channel(f),
                        &whole.channel(f)[rows.start * w_o..rows.end * w_o],
                        "k={k} f={f} rows={rows:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn scratch_pads_once_per_shared_input() {
        let layer = ConvLayer::new("sc", 9, 3, 3, 4, 1, 1);
        let input = Arc::new(rand_tensor(3, 9, 9, 55));
        let weights = rand_weights(4, 3, 3, 57);
        let mut scratch = ConvScratch::new();
        let _ = scratch.conv_rows_shared(&layer, &input, &weights, 0..4);
        let ptr = scratch.padded_ptr();
        let _ = scratch.conv_rows_shared(&layer, &input, &weights, 4..9);
        let _ = scratch.conv_rows_shared(&layer, &input, &weights, 0..9);
        assert_eq!((scratch.fills(), scratch.hits()), (1, 2), "one materialisation, two reuses");
        assert_eq!(scratch.padded_ptr(), ptr, "padded buffer identity is stable across hits");
        // A different tensor (even with identical contents) must re-fill.
        let other = Arc::new(rand_tensor(3, 9, 9, 55));
        let _ = scratch.conv_rows_shared(&layer, &other, &weights, 0..9);
        assert_eq!(scratch.fills(), 2, "new input identity re-materialises");
    }

    #[test]
    fn microkernel_arm_counts_follow_dispatch() {
        // One dispatch per (channel, filter) pair: M·N per whole-layer
        // call, attributed to the arm the (k, stride) geometry selects.
        let mut scratch = ConvScratch::new();
        let l3 = ConvLayer::new("a3", 9, 3, 2, 3, 1, 1); // K=3 s=1 → fused arm
        let i3 = Arc::new(rand_tensor(2, 9, 9, 5));
        let w3 = rand_weights(3, 2, 3, 7);
        let _ = scratch.conv_rows_shared(&l3, &i3, &w3, 0..l3.h_o());
        assert_eq!(scratch.microkernel_arms(), [6, 0, 0]);
        let l5 = ConvLayer::new("a5", 12, 5, 1, 2, 1, 2); // K=5 s=1 → unit arm
        let i5 = Arc::new(rand_tensor(1, 12, 12, 9));
        let w5 = rand_weights(2, 1, 5, 11);
        let _ = scratch.conv_rows_shared(&l5, &i5, &w5, 0..l5.h_o());
        assert_eq!(scratch.microkernel_arms(), [6, 2, 0]);
        let ls = ConvLayer::new("as", 9, 3, 1, 1, 2, 0); // strided arm
        let is_ = Arc::new(rand_tensor(1, 9, 9, 13));
        let ws = rand_weights(1, 1, 3, 15);
        let _ = scratch.conv_rows_shared(&ls, &is_, &ws, 0..ls.h_o());
        assert_eq!(scratch.microkernel_arms(), [6, 2, 1]);
    }

    #[test]
    fn scratch_invalidates_held_key_if_fill_panics() {
        // A farm worker survives job panics (catch_unwind) with its
        // scratch alive, so a fill that dies mid-materialisation must not
        // leave the old cache key pointing at the clobbered buffer.
        let layer = ConvLayer::new("pz", 9, 3, 3, 4, 1, 1);
        let good = Arc::new(rand_tensor(3, 9, 9, 11));
        let weights = rand_weights(4, 3, 3, 13);
        let mut scratch = ConvScratch::new();
        let expect = scratch.conv_rows_shared(&layer, &good, &weights, 0..9);
        // A layer whose M exceeds the resident input's channels makes
        // fill_padded panic after it has already resized/overwritten the
        // padded buffer.
        let wide = ConvLayer::new("pzw", 9, 3, 5, 4, 1, 1);
        let bad_weights = rand_weights(4, 5, 3, 13);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scratch.conv_rows_shared(&wide, &good, &bad_weights, 0..9)
        }));
        assert!(r.is_err(), "channel-mismatched input must panic in fill_padded");
        // The good input must re-materialise (no stale-key cache hit on
        // the half-overwritten buffer) and stay bit-exact.
        let again = scratch.conv_rows_shared(&layer, &good, &weights, 0..9);
        assert_eq!(again, expect, "post-panic reuse must not read a clobbered buffer");
        assert_eq!(scratch.fills(), 2, "the failed fill invalidated the held key");
    }

    #[test]
    fn analytic_stats_rows_match_register_band_run() {
        // The band's analytic counters equal the register tier run on the
        // band's slab layer — native multi-group and tiled strided.
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (31, 11, 2, 3, 4, 0)]
        {
            let layer = ConvLayer::new("bs", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 61);
            let weights = rand_weights(n, m, k, 63);
            let cfg = ArchConfig::small(3, 2, 2);
            let h_o = layer.h_o();
            let rows = 1..h_o - 1;
            let reg = EngineSim::new(cfg).run_row_range(&layer, &input, &weights, rows.clone());
            assert_eq!(analytic_stats_rows(&cfg, &layer, &rows), reg.stats, "k={k}");
        }
    }
}
