//! The TrIM Engine (Fig. 6): `P_N` cores on broadcast inputs, per-core
//! psum buffers + accumulators for temporal accumulation over the
//! `⌈M/P_M⌉` channel groups, and the shared control logic.
//!
//! The engine executes real convolutional layers: its numeric output is
//! validated bit-exactly against [`crate::golden::conv3d_i32`] (including
//! the tiled large-kernel path of §V), while its cycle accounting follows
//! the control plan of [`super::control`] (eq. (2)) and its psum-buffer
//! access counters feed the memory-access model of Tables I–II.
//!
//! An engine runs at one of two [`ExecFidelity`] tiers. The **register**
//! tier below is the measurement oracle: it steps every PE register. The
//! **fast** tier ([`super::fastsim`]) produces the identical
//! [`EngineRunResult`] — ofmaps bit-for-bit, stats counter-for-counter —
//! from a blocked direct convolution plus the closed-form counter model,
//! at a small fraction of the wall-clock cost. New code should default to
//! fast and reach for [`EngineSim::new`] (register) only to validate.

use super::config::{ArchConfig, ExecFidelity};
use super::control::{plan_layer, StepPlan};
use super::core::CoreSim;
use super::fastsim::{self, ConvScratch};
use super::slice::{InputView, SliceSim};
use super::stats::SimStats;
use crate::fault::FaultInjector;
use crate::golden::Tensor3;
use crate::model::{ConvLayer, KernelTiling};
use std::cell::RefCell;
use std::ops::Range;
use std::sync::Arc;

/// Result of running one layer on the engine.
#[derive(Debug, Clone)]
pub struct EngineRunResult {
    /// Accumulated ofmaps, `[N][H_O][W_O]` (engine accumulator precision).
    pub ofmaps: Tensor3,
    pub stats: SimStats,
    pub plan: StepPlan,
}

/// Engine-level simulator.
pub struct EngineSim {
    cfg: ArchConfig,
    fidelity: ExecFidelity,
    /// Fast-tier working set (padded ifmap + accumulator arena), reused
    /// across every layer/shard/step this engine runs so the hot path
    /// performs no per-call allocation and at most one padded-input
    /// materialisation per batch input (see [`ConvScratch`]). `RefCell`:
    /// an engine is owned by exactly one farm worker thread.
    scratch: RefCell<ConvScratch>,
    /// Seeded chaos-testing hook ([`crate::fault`]). `None` in normal
    /// operation: the per-run cost of the disabled path is one `Option`
    /// branch per terminal result site.
    fault: Option<FaultInjector>,
}

impl EngineSim {
    /// A register-tier (cycle-accurate) engine — the validation oracle.
    pub fn new(cfg: ArchConfig) -> Self {
        Self::with_fidelity(cfg, ExecFidelity::Register)
    }

    /// A fast-tier engine: identical results, closed-form counters.
    pub fn fast(cfg: ArchConfig) -> Self {
        Self::with_fidelity(cfg, ExecFidelity::Fast)
    }

    pub fn with_fidelity(cfg: ArchConfig, fidelity: ExecFidelity) -> Self {
        Self { cfg, fidelity, scratch: RefCell::new(ConvScratch::new()), fault: None }
    }

    /// Attach a seeded fault injector: every execution's ofmaps pass
    /// through [`FaultInjector::maybe_corrupt`] keyed on the *effective*
    /// layer (sub-layer / row-band names included), so each (engine,
    /// shard) pair draws independently and deterministically.
    pub fn with_fault(mut self, fault: FaultInjector) -> Self {
        self.fault = Some(fault);
        self
    }

    /// Corrupt `ofmaps` in place if the chaos plan says this (engine,
    /// effective layer) execution suffers an upset. No-op when no
    /// injector is attached.
    #[inline]
    fn apply_fault(&self, layer: &ConvLayer, ofmaps: &mut Tensor3) {
        if let Some(f) = &self.fault {
            f.maybe_corrupt(layer, ofmaps);
        }
    }

    /// `(fills, hits, padded-buffer address)` of the fast tier's
    /// [`ConvScratch`] — observability for the allocation-reuse tests.
    pub fn scratch_stats(&self) -> (u64, u64, usize) {
        let s = self.scratch.borrow();
        (s.fills(), s.hits(), s.padded_ptr() as usize)
    }

    /// Cumulative microkernel-arm invocation counts of the fast tier's
    /// [`ConvScratch`], `[k3, unit, strided]` — all zero on a register
    /// engine, whose datapath never touches the blocked conv. Farm
    /// workers publish per-job deltas of these into the farm registry.
    pub fn microkernel_arms(&self) -> [u64; 3] {
        self.scratch.borrow().microkernel_arms()
    }

    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    pub fn fidelity(&self) -> ExecFidelity {
        self.fidelity
    }

    /// Per-group entry point for the farm scheduler ([`crate::scheduler`]):
    /// run only the filters `[filters.start, filters.end)` of `layer`.
    ///
    /// `weights` is still the FULL `[N][M][K][K]` tensor of the layer; the
    /// engine slices out the range itself. The returned ofmaps hold
    /// `filters.end − filters.start` channels, in filter order — because
    /// every filter is computed independently (one core per filter, private
    /// psum buffer), the result is bit-identical to the corresponding
    /// channel range of a whole-layer [`EngineSim::run_layer`] run, and the
    /// per-range stats partition the whole-layer access counts exactly.
    ///
    /// Shard boundaries should be aligned to multiples of `P_N` (the
    /// paper's filter-group size — the outer loop of eq. (2)) so that
    /// splitting never adds partially-filled filter groups; the planner in
    /// [`crate::scheduler::plan_filter_shards`] guarantees this.
    pub fn run_filter_range(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        filters: Range<usize>,
    ) -> EngineRunResult {
        // Thin wrapper over the 2-D tile entry point (full row range) so
        // the 1-D and 2-D shard paths cannot drift apart.
        self.run_shard(layer, input, weights, filters, 0..layer.h_o())
    }

    /// [`EngineSim::run_filter_range`] for callers that hold the input
    /// behind an `Arc`: on the fast tier the shard reuses the
    /// engine-resident padded-input materialisation instead of re-padding
    /// per call. Results are identical to the borrowed variant.
    pub fn run_filter_range_shared(
        &self,
        layer: &ConvLayer,
        input: &Arc<Tensor3>,
        weights: &[i32],
        filters: Range<usize>,
    ) -> EngineRunResult {
        self.run_shard_shared(layer, input, weights, filters, 0..layer.h_o())
    }

    /// Row-band entry point for the spatial shard axis
    /// ([`crate::scheduler::plan_row_shards`]): run all `N` filters of
    /// `layer` over output rows `[rows.start, rows.end)` only.
    ///
    /// The band is executed as the ordinary layer [`ConvLayer::row_band`]
    /// describes — `pad = 0` over the band's explicitly-padded input slab
    /// (halo rows included) — so the returned ofmaps (`[N][rows.len()][W_O]`,
    /// bit-identical to the corresponding rows of a whole-layer run) and
    /// stats are equal across fidelity tiers by the same property that
    /// makes whole layers equal. The register tier materialises the slab
    /// (it is the slow oracle); the fast tier computes the band straight
    /// out of the engine-resident full padded ifmap, copying nothing.
    pub fn run_row_range(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        rows: Range<usize>,
    ) -> EngineRunResult {
        self.row_range_impl(layer, input, None, weights, rows)
    }

    /// [`EngineSim::run_row_range`] for `Arc`-held inputs: fast-tier
    /// bands of the same input share one padded-input materialisation
    /// (see [`ConvScratch`]).
    pub fn run_row_range_shared(
        &self,
        layer: &ConvLayer,
        input: &Arc<Tensor3>,
        weights: &[i32],
        rows: Range<usize>,
    ) -> EngineRunResult {
        self.row_range_impl(layer, input, Some(input), weights, rows)
    }

    /// 2-D shard entry point for the hybrid (filter-group × row-band)
    /// axis ([`crate::scheduler::plan_hybrid_shards`]): run only filters
    /// `[filters.start, filters.end)` over output rows
    /// `[rows.start, rows.end)` of `layer`.
    ///
    /// This is the composition of [`EngineSim::run_filter_range`] and
    /// [`EngineSim::run_row_range`] — the filter slice first (filters are
    /// independent), then the row band of the resulting sub-layer — so
    /// every guarantee of the two 1-D entry points composes: the returned
    /// ofmaps (`[filters.len()][rows.len()][W_O]`) are bit-identical to
    /// the corresponding block of a whole-layer run on both fidelity
    /// tiers, and the stats are the analytic band counters of the filter
    /// sub-layer (halo-aware slab reads, as for pure row bands). Full
    /// ranges degenerate to the matching 1-D (or whole-layer) paths.
    pub fn run_shard(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        filters: Range<usize>,
        rows: Range<usize>,
    ) -> EngineRunResult {
        assert!(filters.start < filters.end && filters.end <= layer.n, "bad filter range {filters:?}");
        assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
        if filters == (0..layer.n) {
            return self.run_row_range(layer, input, weights, rows);
        }
        let (sub, w0, w1) = filter_sub_layer(layer, &filters);
        self.run_row_range(&sub, input, &weights[w0..w1], rows)
    }

    /// [`EngineSim::run_shard`] for `Arc`-held inputs (the farm's dispatch
    /// path): on the fast tier every shard of the same input — across both
    /// grid axes — reuses the engine-resident padded-input materialisation
    /// (the filter sub-layer shares the parent's pad geometry, so the
    /// [`ConvScratch`] cache key matches across filter splits too).
    pub fn run_shard_shared(
        &self,
        layer: &ConvLayer,
        input: &Arc<Tensor3>,
        weights: &[i32],
        filters: Range<usize>,
        rows: Range<usize>,
    ) -> EngineRunResult {
        assert!(filters.start < filters.end && filters.end <= layer.n, "bad filter range {filters:?}");
        assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
        if filters == (0..layer.n) {
            return self.run_row_range_shared(layer, input, weights, rows);
        }
        let (sub, w0, w1) = filter_sub_layer(layer, &filters);
        self.run_row_range_shared(&sub, input, &weights[w0..w1], rows)
    }

    fn row_range_impl(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        shared: Option<&Arc<Tensor3>>,
        weights: &[i32],
        rows: Range<usize>,
    ) -> EngineRunResult {
        assert!(rows.start < rows.end && rows.end <= layer.h_o(), "bad output-row range {rows:?}");
        assert_eq!(input.c, layer.m);
        assert_eq!(input.h, layer.h_i);
        assert_eq!(input.w, layer.w_i);
        assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
        if rows == (0..layer.h_o()) {
            return match shared {
                Some(a) => self.run_layer_shared(layer, a, weights),
                None => self.run_layer(layer, input, weights),
            };
        }
        let band = layer.row_band(&rows);
        match self.fidelity {
            ExecFidelity::Fast => {
                // One band + plan materialisation serves both the plan
                // field and the analytic counters (analytic_stats_rows is
                // exactly analytic_stats over this band/plan pair — don't
                // rebuild them on the per-shard hot path).
                let plan = plan_layer(&self.cfg, &band);
                let stats = fastsim::analytic_stats(&self.cfg, &band, &plan);
                let mut scratch = self.scratch.borrow_mut();
                let mut ofmaps = match shared {
                    Some(a) => scratch.conv_rows_shared(layer, a, weights, rows),
                    None => scratch.conv_rows(layer, input, weights, rows),
                };
                drop(scratch);
                self.apply_fault(&band, &mut ofmaps);
                EngineRunResult { ofmaps, stats, plan }
            }
            ExecFidelity::Register => {
                // Materialise the band's explicitly-padded slab and step
                // it register by register as a normal pad-0 layer.
                let slab_rows = layer.band_input_rows(&rows);
                let wp = layer.w_i + 2 * layer.pad;
                let mut slab = Tensor3::zeros(layer.m, slab_rows.len(), wp);
                for c in 0..layer.m {
                    for (sy, py) in slab_rows.clone().enumerate() {
                        // padded row `py` holds unpadded row `py − pad`
                        // (zero outside the ifmap)
                        if py >= layer.pad && py < layer.pad + layer.h_i {
                            let y = py - layer.pad;
                            let src = &input.channel(c)[y * layer.w_i..(y + 1) * layer.w_i];
                            let at = (c * slab_rows.len() + sy) * wp + layer.pad;
                            slab.data[at..at + layer.w_i].copy_from_slice(src);
                        }
                    }
                }
                self.run_layer(&band, &slab, weights)
            }
        }
    }

    /// Run a full convolutional layer: `input` is `[M][H][W]`, `weights`
    /// is flat `[N][M][K][K]`. Dispatches on the engine's fidelity tier,
    /// then (register tier) on the native vs tiled kernel path.
    pub fn run_layer(&self, layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> EngineRunResult {
        assert_eq!(input.c, layer.m);
        assert_eq!(input.h, layer.h_i);
        assert_eq!(input.w, layer.w_i);
        assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
        match self.fidelity {
            ExecFidelity::Fast => self.run_fast(layer, input, None, weights),
            ExecFidelity::Register => {
                if layer.k <= self.cfg.k {
                    self.run_native(layer, input, weights)
                } else {
                    self.run_tiled(layer, input, weights)
                }
            }
        }
    }

    /// [`EngineSim::run_layer`] for `Arc`-held inputs: on the fast tier
    /// the padded-input materialisation is keyed on the input's identity
    /// and reused across the calls that share it (the register tier has no
    /// scratch and simply delegates).
    pub fn run_layer_shared(&self, layer: &ConvLayer, input: &Arc<Tensor3>, weights: &[i32]) -> EngineRunResult {
        assert_eq!(input.c, layer.m);
        assert_eq!(input.h, layer.h_i);
        assert_eq!(input.w, layer.w_i);
        assert_eq!(weights.len(), layer.n * layer.m * layer.k * layer.k);
        match self.fidelity {
            ExecFidelity::Fast => self.run_fast(layer, input, Some(input), weights),
            ExecFidelity::Register => self.run_layer(layer, input, weights),
        }
    }

    /// Fast tier: blocked functional convolution + closed-form stats
    /// ([`super::fastsim`]), through the engine-owned [`ConvScratch`].
    /// Identical [`EngineRunResult`] to the register paths below, enforced
    /// by property tests.
    fn run_fast(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        shared: Option<&Arc<Tensor3>>,
        weights: &[i32],
    ) -> EngineRunResult {
        let plan = plan_layer(&self.cfg, layer);
        let rows = 0..layer.h_o();
        let mut scratch = self.scratch.borrow_mut();
        let mut ofmaps = match shared {
            Some(a) => scratch.conv_rows_shared(layer, a, weights, rows),
            None => scratch.conv_rows(layer, input, weights, rows),
        };
        drop(scratch);
        let stats = fastsim::analytic_stats(&self.cfg, layer, &plan);
        self.apply_fault(layer, &mut ofmaps);
        EngineRunResult { ofmaps, stats, plan }
    }

    /// Native path: K ≤ K_nat. Steps iterate ⌈N/P_N⌉ filter groups ×
    /// ⌈M/P_M⌉ channel groups; each core owns one filter; psum buffers
    /// accumulate across channel groups.
    fn run_native(&self, layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> EngineRunResult {
        let cfg = &self.cfg;
        let plan = plan_layer(cfg, layer);
        let k = layer.k;
        let kk = k * k;
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        let mut stats = SimStats::default();
        let mut ofmaps = Tensor3::zeros(layer.n, h_o, w_o);
        // One psum buffer per core (Fig. 6).
        let mut psum_buf: Vec<Vec<i64>> = vec![vec![0i64; h_o * w_o]; cfg.p_n];
        let w_im = (layer.w_i + 2 * layer.pad).max(cfg.k + 1);

        let filters: Vec<usize> = (0..layer.n).collect();
        let channels: Vec<usize> = (0..layer.m).collect();
        let m_groups: Vec<&[usize]> = channels.chunks(cfg.p_m).collect();
        // Long-lived cores: each slice resets its registers/RSRBs/scratch
        // in place per step instead of being reallocated (§Perf).
        let mut cores: Vec<CoreSim> =
            (0..cfg.p_n.min(layer.n)).map(|_| CoreSim::new(cfg.k, cfg.p_m, w_im)).collect();

        for n_grp in filters.chunks(cfg.p_n) {
            for (mi, m_grp) in m_groups.iter().enumerate() {
                // --- weight-load phase: P_N · K cycles (eq. (2)) ---
                stats.cycles += plan.weight_load_cycles;
                // --- compute phase (cores in parallel on broadcast inputs)
                let mut step_cycles = 0u64;
                for (ci, &f) in n_grp.iter().enumerate() {
                    let core = &mut cores[ci];
                    let chans: Vec<&[i32]> = m_grp.iter().map(|&c| input.channel(c)).collect();
                    let kerns: Vec<&[i32]> =
                        m_grp.iter().map(|&c| &weights[(f * layer.m + c) * kk..(f * layer.m + c + 1) * kk]).collect();
                    let r = core.run_step(&chans, layer.h_i, layer.w_i, &kerns, layer.pad, layer.stride, ci == 0);
                    // cores run concurrently: take one core's cycles
                    step_cycles = step_cycles.max(r.stats.cycles);
                    let mut s = r.stats;
                    s.cycles = 0;
                    stats.merge(&s);
                    // --- temporal accumulation into the psum buffer ---
                    let buf = &mut psum_buf[ci];
                    if mi == 0 {
                        buf.copy_from_slice(&r.partial);
                        stats.psum_buf_writes += if m_groups.len() > 1 { buf.len() as u64 } else { 0 };
                    } else {
                        for (b, &p) in buf.iter_mut().zip(r.partial.iter()) {
                            *b += p;
                        }
                        stats.psum_buf_reads += buf.len() as u64;
                        stats.psum_buf_writes += buf.len() as u64;
                    }
                    if mi == m_groups.len() - 1 {
                        // final: quantised activations leave the engine
                        // (drained with the last accumulation — counted as
                        // output writes, not extra buffer reads; matches
                        // the (2·m_steps − 1) accounting of Tables I–II)
                        for (i, &v) in buf.iter().enumerate() {
                            ofmaps.data[f * h_o * w_o + i] = v as i32;
                        }
                        stats.output_writes += buf.len() as u64;
                    }
                }
                stats.cycles += step_cycles;
            }
        }
        stats.cycles += cfg.pipeline_latency();
        self.apply_fault(layer, &mut ofmaps);
        EngineRunResult { ofmaps, stats, plan }
    }

    /// Tiled path (§V): kernels with K > K_nat are split into 3×3 tiles;
    /// each (channel, tile) pair is a slice task convolving a shifted view
    /// of the padded ifmap at stride 1, decimated by the layer stride; the
    /// engine accumulates tile psums on top of the channel accumulation.
    fn run_tiled(&self, layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> EngineRunResult {
        let cfg = &self.cfg;
        let plan = plan_layer(cfg, layer);
        let k = layer.k;
        let kk = k * k;
        let k_nat = cfg.k;
        let tiling = KernelTiling::new(k, k_nat);
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        let hp = layer.h_i + 2 * layer.pad;
        let wp = layer.w_i + 2 * layer.pad;
        // Shifted sub-view height/width so every tile sweeps the same
        // stride-1 grid as the full kernel.
        let hs = hp - k + k_nat;
        let ws = wp - k + k_nat;
        let mut stats = SimStats::default();
        let mut ofmaps = Tensor3::zeros(layer.n, h_o, w_o);
        let w_im = ws.max(cfg.k + 1);

        // Materialise the padded input once (the broadcast buffer).
        let mut padded = Tensor3::zeros(layer.m, hp, wp);
        for c in 0..layer.m {
            for y in 0..layer.h_i {
                for x in 0..layer.w_i {
                    padded.set(c, y + layer.pad, x + layer.pad, input.get(c, y, x));
                }
            }
        }

        // One long-lived slice simulator serves every (channel, tile) task
        // (reset in place per pass), fed through shifted zero-tailed window
        // views of the padded ifmap instead of per-task copies (§Perf).
        let mut slice = SliceSim::new(k_nat, w_im);
        for f in 0..layer.n {
            let mut acc = vec![0i64; h_o * w_o];
            let mut first_task = true;
            for c in 0..layer.m {
                let kern = &weights[(f * layer.m + c) * kk..(f * layer.m + c + 1) * kk];
                for tile in &tiling.tiles {
                    let tw = tiling.extract_tile_weights(kern, tile);
                    // shifted strided view of the padded channel
                    let view =
                        InputView::window(padded.channel(c), hp, wp, tile.row0, tile.col0, hs, ws);
                    let r = slice.run_conv_view(&view, &tw, layer.stride);
                    debug_assert_eq!((r.h_o, r.w_o), (h_o, w_o));
                    let mut s = r.stats;
                    // Broadcast: the padded ifmap is read once per filter
                    // pass, not once per tile — count reads for the first
                    // (channel, tile) task only; cycles are per the plan.
                    if !first_task {
                        s.ext_input_reads = 0;
                    }
                    s.cycles = 0;
                    s.output_writes = 0;
                    stats.merge(&s);
                    first_task = false;
                    for (i, &v) in r.output.iter().enumerate() {
                        acc[i] += v as i64;
                    }
                }
                // tile psums accumulate spatially/at the top level each
                // step; channel groups beyond P_M go through psum buffers
                if (c + 1) % cfg.p_m == 0 && c + 1 < layer.m {
                    stats.psum_buf_reads += acc.len() as u64;
                    stats.psum_buf_writes += acc.len() as u64;
                }
            }
            for (i, &v) in acc.iter().enumerate() {
                ofmaps.data[f * h_o * w_o + i] = v as i32;
            }
            stats.output_writes += acc.len() as u64;
        }
        // Timing comes from the control plan (the per-task sims above run
        // logically in parallel across slices/cores).
        stats.cycles = plan.total_cycles;
        self.apply_fault(layer, &mut ofmaps);
        EngineRunResult { ofmaps, stats, plan }
    }
}

/// The sub-layer computing filters `[filters.start, filters.end)` of
/// `layer`, plus the flat-weight range it reads.
fn filter_sub_layer(layer: &ConvLayer, filters: &Range<usize>) -> (ConvLayer, usize, usize) {
    let kk = layer.k * layer.k;
    let sub = ConvLayer {
        name: format!("{}[f{}..{}]", layer.name, filters.start, filters.end),
        n: filters.end - filters.start,
        ..layer.clone()
    };
    (sub, filters.start * layer.m * kk, filters.end * layer.m * kk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv3d_i32;

    fn rand_tensor(c: usize, h: usize, w: usize, seed: i32) -> Tensor3 {
        Tensor3::from_fn(c, h, w, |ci, y, x| ((ci as i32 * 131 + y as i32 * 31 + x as i32 * 7 + seed) % 251) - 125)
    }

    fn rand_weights(n: usize, m: usize, k: usize, seed: i32) -> Vec<i32> {
        (0..n * m * k * k).map(|i| ((i as i32 * 37 + seed) % 15) - 7).collect()
    }

    #[test]
    fn native_layer_matches_golden_multi_group() {
        // M=5 > P_M=2 forces 3 channel groups; N=5 > P_N=2 forces 3 filter
        // groups — exercises the psum buffers and the control loops.
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let cfg = ArchConfig::small(3, 2, 2);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let golden = conv3d_i32(&input, &weights, 5, 3, 1, 1);
        assert_eq!(r.ofmaps, golden);
        assert!(r.stats.psum_buf_reads > 0 && r.stats.psum_buf_writes > 0);
    }

    #[test]
    fn native_single_group_skips_psum_buffer() {
        let layer = ConvLayer::new("t", 8, 3, 2, 2, 1, 1);
        let input = rand_tensor(2, 8, 8, 5);
        let weights = rand_weights(2, 2, 3, 7);
        let cfg = ArchConfig::small(3, 4, 4);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        // M ≤ P_M and N ≤ P_N: pure spatial accumulation, no buffer traffic
        // (Fig. 6: "the accumulation logic is required only when P_N < N").
        assert_eq!(r.stats.psum_buf_reads, 0);
        assert_eq!(r.stats.psum_buf_writes, 0);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 2, 3, 1, 1));
    }

    #[test]
    fn tiled_5x5_matches_golden() {
        let layer = ConvLayer::new("t5", 12, 5, 3, 4, 1, 2);
        let input = rand_tensor(3, 12, 12, 9);
        let weights = rand_weights(4, 3, 5, 13);
        let cfg = ArchConfig::small(3, 2, 2);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 4, 5, 1, 2));
        assert_eq!(r.plan.tiles, 4);
    }

    #[test]
    fn tiled_strided_11x11_matches_golden() {
        // AlexNet-CL1-like (scaled down): 11×11 kernel, stride 4, no pad.
        let layer = ConvLayer::new("t11", 31, 11, 2, 3, 4, 0);
        let input = rand_tensor(2, 31, 31, 17);
        let weights = rand_weights(3, 2, 11, 19);
        let cfg = ArchConfig::small(3, 4, 2);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 3, 11, 4, 0));
        assert_eq!(r.plan.tiles, 16);
    }

    #[test]
    fn filter_range_partitions_whole_layer_run() {
        // N=5 on P_N=2 → groups {0,1},{2,3},{4}; split ranges on the group
        // boundary and check both numerics and stats partition exactly.
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let cfg = ArchConfig::small(3, 2, 2);
        let sim = EngineSim::new(cfg);
        let whole = sim.run_layer(&layer, &input, &weights);
        let lo = sim.run_filter_range(&layer, &input, &weights, 0..2);
        let hi = sim.run_filter_range(&layer, &input, &weights, 2..5);
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        assert_eq!(lo.ofmaps.data[..], whole.ofmaps.data[..2 * h_o * w_o]);
        assert_eq!(hi.ofmaps.data[..], whole.ofmaps.data[2 * h_o * w_o..]);
        // Access counters partition (the farm's sum-merge conserves them).
        assert_eq!(lo.stats.ext_input_reads + hi.stats.ext_input_reads, whole.stats.ext_input_reads);
        assert_eq!(lo.stats.macs + hi.stats.macs, whole.stats.macs);
        assert_eq!(lo.stats.output_writes + hi.stats.output_writes, whole.stats.output_writes);
        assert_eq!(lo.stats.psum_buf_reads + hi.stats.psum_buf_reads, whole.stats.psum_buf_reads);
        // Parallel time: the larger shard is strictly faster than the whole.
        assert!(lo.stats.cycles.max(hi.stats.cycles) < whole.stats.cycles);
    }

    #[test]
    fn filter_range_tiled_path_matches_golden_slice() {
        let layer = ConvLayer::new("t5", 12, 5, 3, 4, 1, 2);
        let input = rand_tensor(3, 12, 12, 9);
        let weights = rand_weights(4, 3, 5, 13);
        let sim = EngineSim::new(ArchConfig::small(3, 2, 2));
        let golden = conv3d_i32(&input, &weights, 4, 5, 1, 2);
        let r = sim.run_filter_range(&layer, &input, &weights, 1..3);
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        assert_eq!(r.ofmaps.data[..], golden.data[h_o * w_o..3 * h_o * w_o]);
    }

    #[test]
    fn engine_cycles_follow_eq2() {
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let cfg = ArchConfig::small(3, 2, 2);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        let plan = plan_layer(&cfg, &layer);
        // Engine-measured cycles = eq. (2) + the slice/core pipeline
        // overheads the analytical model folds into L_I. Allow the
        // per-step pipeline fill as slack.
        let per_step_overhead = 3 + cfg.k as u64 + 5; // tree + skew + core tree
        assert!(r.stats.cycles >= plan.total_cycles);
        assert!(r.stats.cycles <= plan.total_cycles + plan.steps * per_step_overhead + 16,
            "engine {} vs plan {}", r.stats.cycles, plan.total_cycles);
    }

    #[test]
    fn broadcast_counts_inputs_once_per_filter_group() {
        // N=4 filters on P_N=2 cores → 2 filter groups; M=2 ≤ P_M.
        let layer = ConvLayer::new("t", 8, 3, 2, 4, 1, 1);
        let input = rand_tensor(2, 8, 8, 23);
        let weights = rand_weights(4, 2, 3, 29);
        let cfg = ArchConfig::small(3, 2, 2);
        let r = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
        // padded reads = M × 10 × 10 per filter group × 2 groups
        assert_eq!(r.stats.ext_input_reads, 2 * 10 * 10 * 2);
    }

    #[test]
    fn fast_tier_equals_register_tier_native_and_tiled() {
        // The two tiers must agree on ofmaps AND every stats counter —
        // the broad randomized sweep lives in tests/proptest_invariants.rs;
        // this pins the three canonical geometries.
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0)]
        {
            let layer = ConvLayer::new("ft", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 41);
            let weights = rand_weights(n, m, k, 43);
            let cfg = ArchConfig::small(3, 2, 2);
            let reg = EngineSim::new(cfg).run_layer(&layer, &input, &weights);
            let fast = EngineSim::fast(cfg).run_layer(&layer, &input, &weights);
            assert_eq!(fast.ofmaps, reg.ofmaps, "k={k}: ofmaps");
            assert_eq!(fast.stats, reg.stats, "k={k}: stats");
            assert_eq!(fast.plan.total_cycles, reg.plan.total_cycles, "k={k}: plan");
        }
    }

    #[test]
    fn row_range_partitions_whole_layer_both_tiers() {
        // Bands of run_row_range must reproduce the matching ofmap rows of
        // a whole-layer run bit-for-bit on both tiers, with identical
        // per-band stats across tiers, for native/tiled/strided layers.
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0)]
        {
            let layer = ConvLayer::new("rr", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 71);
            let weights = rand_weights(n, m, k, 73);
            let cfg = ArchConfig::small(3, 2, 2);
            let reg = EngineSim::new(cfg);
            let fast = EngineSim::fast(cfg);
            let whole = fast.run_layer(&layer, &input, &weights);
            let (h_o, w_o) = (layer.h_o(), layer.w_o());
            let mid = h_o / 2;
            for rows in [0..mid, mid..h_o] {
                let rf = fast.run_row_range(&layer, &input, &weights, rows.clone());
                let rr = reg.run_row_range(&layer, &input, &weights, rows.clone());
                assert_eq!(rf.ofmaps, rr.ofmaps, "k={k} rows={rows:?}: ofmaps fast vs register");
                assert_eq!(rf.stats, rr.stats, "k={k} rows={rows:?}: stats fast vs register");
                assert_eq!((rf.ofmaps.c, rf.ofmaps.h, rf.ofmaps.w), (n, rows.len(), w_o));
                for f in 0..n {
                    assert_eq!(
                        rf.ofmaps.channel(f),
                        &whole.ofmaps.channel(f)[rows.start * w_o..rows.end * w_o],
                        "k={k} f={f} rows={rows:?}: band vs whole-layer rows"
                    );
                }
                assert!(rf.stats.cycles < whole.stats.cycles, "a proper band is faster");
            }
            // Full range degenerates to the whole-layer run, stats included.
            let full = fast.run_row_range(&layer, &input, &weights, 0..h_o);
            assert_eq!(full.ofmaps, whole.ofmaps);
            assert_eq!(full.stats, whole.stats);
        }
    }

    #[test]
    fn shared_row_bands_reuse_one_padded_materialisation() {
        // The acceptance hook for "no per-shard padded-input allocation":
        // consecutive row bands of the same Arc input on one fast engine
        // fill the scratch once and keep the buffer address stable.
        let layer = ConvLayer::new("sh", 12, 3, 3, 5, 1, 1);
        let input = std::sync::Arc::new(rand_tensor(3, 12, 12, 81));
        let weights = rand_weights(5, 3, 3, 83);
        let sim = EngineSim::fast(ArchConfig::small(3, 2, 2));
        let whole = sim.run_layer_shared(&layer, &input, &weights);
        let (fills0, _, ptr0) = sim.scratch_stats();
        assert_eq!(fills0, 1, "whole-layer run materialises the padded input once");
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        let bands = [0..4, 4..8, 8..h_o];
        for rows in bands.clone() {
            let band = sim.run_row_range_shared(&layer, &input, &weights, rows.clone());
            for f in 0..layer.n {
                assert_eq!(
                    band.ofmaps.channel(f),
                    &whole.ofmaps.channel(f)[rows.start * w_o..rows.end * w_o]
                );
            }
        }
        let (fills, hits, ptr) = sim.scratch_stats();
        assert_eq!(fills, 1, "row shards must not re-materialise the padded input");
        assert_eq!(hits, bands.len() as u64, "every band reuses the resident ifmap");
        assert_eq!(ptr, ptr0, "padded buffer identity is stable across shards");
        // The register tier has no scratch to exercise: its shared call is
        // pure delegation and still bit-matches the fast band.
        let reg = EngineSim::new(ArchConfig::small(3, 2, 2));
        let rr = reg.run_row_range_shared(&layer, &input, &weights, 0..4);
        let rf = sim.run_row_range_shared(&layer, &input, &weights, 0..4);
        assert_eq!(rr.ofmaps, rf.ofmaps);
        assert_eq!(rr.stats, rf.stats);
    }

    #[test]
    fn shard_tile_partitions_whole_layer_both_tiers() {
        // A filter-range × row-band tile (the hybrid shard unit) equals
        // the matching block of a whole-layer run on both fidelity tiers,
        // with tier-identical stats, for native/tiled/strided layers.
        for (hw, k, m, n, stride, pad) in
            [(10usize, 3usize, 5usize, 5usize, 1usize, 1usize), (12, 5, 3, 4, 1, 2), (31, 11, 2, 3, 4, 0)]
        {
            let layer = ConvLayer::new("tile", hw, k, m, n, stride, pad);
            let input = rand_tensor(m, hw, hw, 87);
            let weights = rand_weights(n, m, k, 89);
            let cfg = ArchConfig::small(3, 2, 2);
            let reg = EngineSim::new(cfg);
            let fast = EngineSim::fast(cfg);
            let whole = fast.run_layer(&layer, &input, &weights);
            let (h_o, w_o) = (layer.h_o(), layer.w_o());
            let filters = 0..(n / 2).max(1);
            let rows = (h_o / 2).min(h_o - 1)..h_o;
            let tf = fast.run_shard(&layer, &input, &weights, filters.clone(), rows.clone());
            let tr = reg.run_shard(&layer, &input, &weights, filters.clone(), rows.clone());
            assert_eq!(tf.ofmaps, tr.ofmaps, "k={k}: tile ofmaps fast vs register");
            assert_eq!(tf.stats, tr.stats, "k={k}: tile stats fast vs register");
            assert_eq!((tf.ofmaps.c, tf.ofmaps.h, tf.ofmaps.w), (filters.len(), rows.len(), w_o));
            for (df, f) in filters.clone().enumerate() {
                assert_eq!(
                    tf.ofmaps.channel(df),
                    &whole.ofmaps.channel(f)[rows.start * w_o..rows.end * w_o],
                    "k={k} f={f}: tile vs whole-layer block"
                );
            }
            // degenerate full ranges fall back to the whole-layer path
            let full = fast.run_shard(&layer, &input, &weights, 0..n, 0..h_o);
            assert_eq!(full.ofmaps, whole.ofmaps);
            assert_eq!(full.stats, whole.stats);
        }
    }

    #[test]
    fn fast_tier_filter_range_partitions_like_register() {
        let layer = ConvLayer::new("t", 10, 3, 5, 5, 1, 1);
        let input = rand_tensor(5, 10, 10, 3);
        let weights = rand_weights(5, 5, 3, 11);
        let sim = EngineSim::fast(ArchConfig::small(3, 2, 2));
        let whole = sim.run_layer(&layer, &input, &weights);
        let lo = sim.run_filter_range(&layer, &input, &weights, 0..2);
        let hi = sim.run_filter_range(&layer, &input, &weights, 2..5);
        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        assert_eq!(lo.ofmaps.data[..], whole.ofmaps.data[..2 * h_o * w_o]);
        assert_eq!(hi.ofmaps.data[..], whole.ofmaps.data[2 * h_o * w_o..]);
        assert_eq!(lo.stats.macs + hi.stats.macs, whole.stats.macs);
        assert_eq!(lo.stats.output_writes + hi.stats.output_writes, whole.stats.output_writes);
        assert!(lo.stats.cycles.max(hi.stats.cycles) < whole.stats.cycles);
    }
}
