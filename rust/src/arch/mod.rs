//! Cycle-accurate structural model of the TrIM hardware hierarchy.
//!
//! Fidelity contract:
//!
//! * **Slice level** ([`slice::SliceSim`]) is register-accurate: every PE
//!   input/weight/psum/pass register, every RSRB stage and the adder-tree
//!   pipeline are stepped cycle by cycle; data reaches the multiplier only
//!   through the structural paths of Fig. 3 (external port, right-neighbour
//!   pass register, or RSRB dispatch bus). The slice's numerics, cycle
//!   counts, external-read counts and per-cycle peak input bandwidth are
//!   all *measured*, not computed from formulas.
//! * **Core/Engine level** ([`core`], [`engine`]) compose slice simulations
//!   per computational step and model the core adder tree, the engine psum
//!   buffers and the control FSM with per-step cycle accounting identical
//!   to eq. (2) (weight-load phase `P_N·K`, compute phase `H_O·W_O`,
//!   pipeline latency `L_I`). Psum-buffer reads/writes are counted exactly.
//!
//! The [`control`] module holds the step scheduler shared with the
//! analytical models (including the large-kernel tiling policy of §V).
//!
//! [`fastsim`] is the second execution tier: the same engine results
//! (ofmaps bit-exact, stats counter-exact — property-tested) synthesized
//! from a blocked functional convolution plus the closed-form counter
//! model, selected via [`ExecFidelity`] on [`EngineSim`]. The register
//! tier described above remains the oracle the fast tier is validated
//! against.

pub mod adder_tree;
pub mod config;
pub mod control;
pub mod engine;
pub mod fastsim;
pub mod pe;
pub mod rsrb;
pub mod slice;
pub mod stats;

#[allow(clippy::module_inception)]
pub mod core;

pub use config::{ArchConfig, ExecFidelity};
pub use engine::EngineSim;
pub use slice::SliceSim;
pub use stats::SimStats;
