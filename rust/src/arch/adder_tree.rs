//! Pipelined binary adder trees (slice-level K-input and core-level
//! P_M-input reductions, Figs. 3 and 5).

/// A pipelined binary adder tree with one register per stage and an output
/// register. Values inserted at cycle `t` emerge `latency()` cycles later.
///
/// The simulator models the pipeline as a shift queue of stage results —
/// numerically the reduction is exact; timing-wise each `step` advances one
/// clock.
#[derive(Debug, Clone)]
pub struct AdderTree {
    fan_in: usize,
    /// In-flight sums, one slot per pipeline stage (front = oldest);
    /// a deque so `step` is O(1) (perf: see EXPERIMENTS.md §Perf).
    pipeline: std::collections::VecDeque<Option<i64>>,
    adds: u64,
}

impl AdderTree {
    pub fn new(fan_in: usize) -> Self {
        assert!(fan_in >= 1);
        let stages = Self::stages_for(fan_in);
        Self { fan_in, pipeline: std::iter::repeat_n(None, stages + 1).collect(), adds: 0 }
    }

    /// `⌈log2(fan_in)⌉` reduction stages (paper §III-A).
    pub fn stages_for(fan_in: usize) -> usize {
        (fan_in as f64).log2().ceil() as usize
    }

    /// Pipeline latency of a `fan_in`-input tree without constructing one:
    /// reduction stages plus the output register. Used by the core's
    /// per-step cycle accounting and the fast tier's closed-form cycle
    /// model ([`super::fastsim`]) — `AdderTree::new(n).latency()` for any
    /// `n`, as a pure function.
    pub fn latency_for(fan_in: usize) -> usize {
        Self::stages_for(fan_in) + 1
    }

    /// Stages + output register.
    pub fn latency(&self) -> usize {
        self.pipeline.len()
    }

    /// Flush all in-flight state (fresh pass) without reallocating the
    /// stage queue.
    pub fn reset(&mut self) {
        for slot in self.pipeline.iter_mut() {
            *slot = None;
        }
        self.adds = 0;
    }

    /// Clock the tree: feed `inputs` (or None for a bubble), get the value
    /// that reaches the output register this cycle (if any).
    pub fn step(&mut self, inputs: Option<&[i32]>) -> Option<i64> {
        let entering = inputs.map(|xs| {
            assert_eq!(xs.len(), self.fan_in);
            self.adds += (self.fan_in - 1) as u64;
            xs.iter().map(|&v| v as i64).sum::<i64>()
        });
        let out = self.pipeline.pop_front().expect("pipeline never empty");
        self.pipeline.push_back(entering);
        out
    }

    /// Flush remaining in-flight values (end of a pass).
    pub fn drain(&mut self) -> Vec<i64> {
        let mut out = vec![];
        for _ in 0..self.latency() {
            if let Some(v) = self.step(None) {
                out.push(v);
            }
        }
        out
    }

    pub fn adds(&self) -> u64 {
        self.adds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k3_latency_matches_paper() {
        // ⌈log2 3⌉ = 2 stages + output register = 3-cycle latency.
        let t = AdderTree::new(3);
        assert_eq!(t.latency(), 3);
    }

    #[test]
    fn latency_for_matches_constructed_tree() {
        for n in 1..=32 {
            assert_eq!(AdderTree::latency_for(n), AdderTree::new(n).latency(), "fan-in {n}");
        }
    }

    #[test]
    fn reset_flushes_in_flight_values() {
        let mut t = AdderTree::new(3);
        t.step(Some(&[1, 2, 3]));
        t.reset();
        assert_eq!(t.drain(), Vec::<i64>::new());
        assert_eq!(t.adds(), 0);
        // still usable after a reset
        t.step(Some(&[4, 5, 6]));
        assert_eq!(t.drain(), vec![15]);
    }

    #[test]
    fn values_emerge_in_order_after_latency() {
        let mut t = AdderTree::new(3);
        assert_eq!(t.step(Some(&[1, 2, 3])), None);
        assert_eq!(t.step(Some(&[4, 5, 6])), None);
        assert_eq!(t.step(None), None);
        assert_eq!(t.step(None), Some(6)); // 1+2+3 after 3 cycles
        assert_eq!(t.step(None), Some(15));
        assert_eq!(t.step(None), None); // bubble propagated
    }

    #[test]
    fn drain_returns_in_flight() {
        let mut t = AdderTree::new(4);
        t.step(Some(&[1, 1, 1, 1]));
        t.step(Some(&[2, 2, 2, 2]));
        assert_eq!(t.drain(), vec![4, 8]);
    }

    #[test]
    fn core_tree_p24() {
        // ⌈log2 24⌉ = 5 reduction stages; the paper pipelines these as 3
        // physical stages at the core level — the *functional* latency we
        // model is the conservative fully-pipelined one.
        assert_eq!(AdderTree::stages_for(24), 5);
    }
}
