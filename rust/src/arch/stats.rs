//! Cycle and memory-access counters collected by the simulators.



/// Counters accumulated during a simulation run.
///
/// "External" counters are DRAM-side (off-chip) in the paper's accounting;
/// `psum_buf_*` are the engine's on-chip global buffer (the only on-chip
/// *memory* TrIM uses — RSRBs and PE registers are registers, which the
/// paper does not count as memory accesses).
///
/// Counters are either *measured* (register tier) or *synthesized* from
/// the closed-form model of [`super::fastsim`] (fast tier); the two are
/// equal field-for-field, so downstream consumers (farm aggregation,
/// serving metrics, the Tables I–II reports) never need to know which
/// tier produced them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Total clock cycles simulated.
    pub cycles: u64,
    /// External (off-chip) ifmap element reads, padding included — the
    /// padded border is exactly the paper's "1.8 % overhead" for 3×3/224².
    pub ext_input_reads: u64,
    /// External weight element reads.
    pub weight_reads: u64,
    /// Output activations written off-chip.
    pub output_writes: u64,
    /// Engine psum-buffer element reads (temporal accumulation).
    pub psum_buf_reads: u64,
    /// Engine psum-buffer element writes.
    pub psum_buf_writes: u64,
    /// MACs actually performed by PEs (incl. zero-padded tile positions).
    pub macs: u64,
    /// Maximum external input elements consumed in any single cycle by one
    /// slice (the eq. (4) peak: 2K−1, i.e. 5 for K = 3).
    pub peak_ext_inputs_per_cycle: u64,
    /// Maximum RSRB occupancy observed (must stay ≤ W_IM).
    pub max_rsrb_occupancy: u64,
}

impl SimStats {
    /// Merge counters from a sub-simulation (peak fields take max).
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles = self.cycles.max(other.cycles);
        self.ext_input_reads += other.ext_input_reads;
        self.weight_reads += other.weight_reads;
        self.output_writes += other.output_writes;
        self.psum_buf_reads += other.psum_buf_reads;
        self.psum_buf_writes += other.psum_buf_writes;
        self.macs += other.macs;
        self.peak_ext_inputs_per_cycle = self.peak_ext_inputs_per_cycle.max(other.peak_ext_inputs_per_cycle);
        self.max_rsrb_occupancy = self.max_rsrb_occupancy.max(other.max_rsrb_occupancy);
    }

    /// Merge counters from a sub-simulation that runs *sequentially* after
    /// the current one (cycles add instead of max).
    pub fn merge_sequential(&mut self, other: &SimStats) {
        let cycles = self.cycles + other.cycles;
        self.merge(other);
        self.cycles = cycles;
    }

    /// Total off-chip accesses (reads + writes).
    pub fn off_chip_accesses(&self) -> u64 {
        self.ext_input_reads + self.weight_reads + self.output_writes
    }

    /// Total on-chip memory accesses.
    pub fn on_chip_accesses(&self) -> u64 {
        self.psum_buf_reads + self.psum_buf_writes
    }

    /// Achieved throughput in ops/s at clock `f_clk`.
    pub fn ops_per_s(&self, f_clk: f64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        2.0 * self.macs as f64 * f_clk / self.cycles as f64
    }

    /// Input-read overhead relative to the theoretical minimum of reading
    /// each (unpadded) ifmap element exactly once.
    pub fn input_read_overhead(&self, min_reads: u64) -> f64 {
        self.ext_input_reads as f64 / min_reads as f64 - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_semantics() {
        let mut a = SimStats { cycles: 10, ext_input_reads: 5, peak_ext_inputs_per_cycle: 3, ..Default::default() };
        let b = SimStats { cycles: 7, ext_input_reads: 2, peak_ext_inputs_per_cycle: 5, ..Default::default() };
        let mut seq = a;
        a.merge(&b);
        assert_eq!(a.cycles, 10); // parallel: max
        assert_eq!(a.ext_input_reads, 7);
        assert_eq!(a.peak_ext_inputs_per_cycle, 5);
        seq.merge_sequential(&b);
        assert_eq!(seq.cycles, 17); // sequential: sum
    }

    #[test]
    fn overhead_math() {
        let s = SimStats { ext_input_reads: 51076, ..Default::default() };
        let ovh = s.input_read_overhead(224 * 224);
        assert!((ovh - 0.01794).abs() < 1e-4, "padding overhead = {ovh}");
    }
}
