//! Reconfigurable Shift Register Buffer (Fig. 4).
//!
//! An RSRB carries the row-wise overlap between vertically adjacent sliding
//! windows: elements retired by the left edge of PE row *i+1* re-emerge one
//! output row later at PE row *i* (the diagonal movement). Physically it is
//! `W_IM` shift registers split into sub-buffers (SBs) of lengths `L_sb`; a
//! multiplexer taps the K-register group matching the *current* ifmap width,
//! which is what makes the slice agnostic to ifmap size at run time.
//!
//! The simulator models the RSRB as a tapped delay line: `push` is the
//! shift-in from the row above's retiring pass register; `pop` reads the
//! mux output (the slice pops K times back-to-back for the K-wide group
//! dispatched at an output-row boundary). Occupancy is tracked so the test
//! suite can assert the structural capacity bound (`≤ W_IM`) and measure
//! the tap position a given layer requires.

use std::collections::VecDeque;

/// Sub-buffer segmentation of an RSRB. The paper leaves `L_sb` "generic or
/// customized"; the default segmentation uses power-of-two SBs so any tap
/// in `[K, W_IM]` is reachable with ⌈log2(W_IM)⌉ mux inputs.
#[derive(Debug, Clone)]
pub struct SubBufferPlan {
    /// Lengths of the sub-buffers, outermost (shift-in side) first.
    pub lengths: Vec<usize>,
}

impl SubBufferPlan {
    /// Power-of-two plan covering total capacity `w_im`: SB lengths
    /// 1, 1, 2, 4, 8, ... — every prefix sum in `[1, w_im]` is reachable
    /// within one SB granule of the target.
    pub fn pow2(w_im: usize) -> Self {
        let mut lengths = vec![];
        let mut total = 0usize;
        let mut next = 1usize;
        while total < w_im {
            let l = next.min(w_im - total);
            lengths.push(l);
            total += l;
            next = (next * 2).max(1);
        }
        Self { lengths }
    }

    /// Number of mux inputs (= number of SB boundaries that can be tapped).
    pub fn mux_ways(&self) -> usize {
        self.lengths.len()
    }

    /// The smallest reachable tap ≥ `want` (prefix-sum granularity).
    pub fn tap_for(&self, want: usize) -> Option<usize> {
        let mut sum = 0;
        for &l in &self.lengths {
            sum += l;
            if sum >= want {
                return Some(sum);
            }
        }
        None
    }
}

/// One RSRB instance (delay-line model with occupancy accounting).
#[derive(Debug, Clone)]
pub struct Rsrb {
    fifo: VecDeque<i32>,
    capacity: usize,
    max_occupancy: usize,
    pushes: u64,
    pops: u64,
}

impl Rsrb {
    pub fn new(capacity: usize) -> Self {
        Self { fifo: VecDeque::with_capacity(capacity), capacity, max_occupancy: 0, pushes: 0, pops: 0 }
    }

    /// Clear contents and counters for a fresh pass, keeping the allocated
    /// capacity (the slice reuses its RSRBs across `run_conv` calls instead
    /// of reallocating them — EXPERIMENTS.md §Perf).
    pub fn reset(&mut self) {
        self.fifo.clear();
        self.max_occupancy = 0;
        self.pushes = 0;
        self.pops = 0;
    }

    /// Shift one element in from the PE row above's retiring pass register.
    #[inline]
    pub fn push(&mut self, v: i32) {
        self.fifo.push_back(v);
        self.pushes += 1;
        if self.fifo.len() > self.max_occupancy {
            self.max_occupancy = self.fifo.len();
        }
        debug_assert!(
            self.fifo.len() <= self.capacity,
            "RSRB overflow: occupancy {} > W_IM {}",
            self.fifo.len(),
            self.capacity
        );
    }

    /// Mux output: one element for the steady-state rightmost-PE dispatch.
    /// The K-wide group dispatch at an output-row boundary ("the leftmost
    /// K inputs" of the tapped SB, Fig. 4) is K back-to-back pops — kept
    /// element-wise so the slice's hot loop stays allocation-free
    /// (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn pop(&mut self) -> i32 {
        self.pops += 1;
        self.fifo.pop_front().expect("RSRB underflow: diagonal dispatch with empty buffer")
    }

    pub fn occupancy(&self) -> usize {
        self.fifo.len()
    }

    pub fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    pub fn pops(&self) -> u64 {
        self.pops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_occupancy() {
        let mut b = Rsrb::new(8);
        for v in 0..5 {
            b.push(v);
        }
        assert_eq!(b.occupancy(), 5);
        assert_eq!(b.pop(), 0);
        assert_eq!((0..3).map(|_| b.pop()).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(b.max_occupancy(), 5);
        assert_eq!(b.pushes(), 5);
        assert_eq!(b.pops(), 4);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn underflow_panics() {
        Rsrb::new(4).pop();
    }

    #[test]
    fn reset_clears_state_and_counters() {
        let mut b = Rsrb::new(8);
        for v in 0..5 {
            b.push(v);
        }
        b.pop();
        b.reset();
        assert_eq!(b.occupancy(), 0);
        assert_eq!(b.max_occupancy(), 0);
        assert_eq!((b.pushes(), b.pops()), (0, 0));
        b.push(42);
        assert_eq!(b.pop(), 42);
    }

    #[test]
    fn pow2_plan_covers_all_taps() {
        let plan = SubBufferPlan::pow2(226);
        assert_eq!(plan.lengths.iter().sum::<usize>(), 226);
        // A 14-wide VGG layer (padded 16) must have a nearby tap.
        let tap = plan.tap_for(16).unwrap();
        assert!(tap >= 16 && tap <= 32, "tap = {tap}");
        // Full-width tap exists.
        assert_eq!(plan.tap_for(226), Some(226));
        // Mux stays small.
        assert!(plan.mux_ways() <= 10);
    }
}
