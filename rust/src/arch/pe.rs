//! The TrIM Processing Element (detail box of Fig. 3).
//!
//! A PE holds four registers — input, weight, psum-out and the pass
//! register forwarding its current input to the left neighbour — plus two
//! cascaded multiplexers selecting the multiplier operand among the
//! external input `I_ext`, the diagonal dispatch `I_D` (from an RSRB) and
//! the right-neighbour input `I_R`.



/// Multiplexer selection for the PE input operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputSel {
    /// External input from the periphery (`I_ext`).
    Ext,
    /// Diagonal dispatch from the RSRB below (`I_D`).
    Diag,
    /// Right neighbour's pass register (`I_R`).
    Right,
}

/// One processing element. All registers are `i32`, wide enough for the
/// paper's maximum datapath width (30 bits at B = 8, K = 3, M ≤ 512).
#[derive(Debug, Clone, Copy, Default)]
pub struct Pe {
    /// Weight register (stationary during the whole convolution).
    pub weight: i32,
    /// Input register = pass register: the operand used this cycle,
    /// visible to the left neighbour next cycle.
    pub input: i32,
    /// Psum output register (result of this cycle's MAC).
    pub psum: i32,
}

impl Pe {
    /// Weight-load phase: shift the weight register down the column
    /// (returns the previous weight, which moves to the row below).
    #[inline]
    pub fn shift_weight(&mut self, from_above: i32) -> i32 {
        std::mem::replace(&mut self.weight, from_above)
    }

    /// Compute phase: latch `operand` (already mux-selected by the control
    /// logic) and perform the MAC against the psum arriving from the row
    /// above. Returns the new psum value (also latched in `self.psum`).
    #[inline]
    pub fn mac(&mut self, operand: i32, psum_from_above: i32) -> i32 {
        self.input = operand;
        self.psum = operand.wrapping_mul(self.weight).wrapping_add(psum_from_above);
        self.psum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_shift_chain() {
        let mut top = Pe::default();
        let mut bottom = Pe::default();
        // cycle 1: kernel row 1 enters the top
        let spill = top.shift_weight(10);
        bottom.shift_weight(spill);
        // cycle 2: kernel row 0 enters the top, row 1 moves down
        let spill = top.shift_weight(20);
        bottom.shift_weight(spill);
        assert_eq!(top.weight, 20);
        assert_eq!(bottom.weight, 10);
    }

    #[test]
    fn mac_accumulates_from_above() {
        let mut pe = Pe { weight: 3, ..Default::default() };
        assert_eq!(pe.mac(5, 100), 115);
        assert_eq!(pe.input, 5); // pass register visible to left neighbour
        assert_eq!(pe.psum, 115);
    }
}
