//! Control logic: the computational-step scheduler (Fig. 6's "Control
//! Logic"), including the large-kernel tiling policy of §V.
//!
//! The schedule determines eq. (2)'s cycle count and the PE-utilisation
//! column of Tables I–II:
//!
//! * **native layers** (`K ≤ K_nat`): `⌈N/P_N⌉·⌈M/P_M⌉` steps of
//!   `P_N·K + H_O·W_O` cycles (weight-load + compute phases);
//! * **tiled layers, few tiles** (`T ≤ P_N`, e.g. AlexNet's 5×5 → T = 4):
//!   the T tile-groups of one filter occupy T cooperating cores and their
//!   psums are combined at the engine level, so only `⌊P_N/T⌋` filters run
//!   concurrently (AlexNet CL2: 4 of 7 cores busy → the paper's 0.57
//!   utilisation);
//! * **tiled layers, many tiles** (`T > P_N`, e.g. 11×11 → T = 16): the
//!   `M·T` (channel, tile) tasks of one filter are packed across slices of
//!   `⌈M·T/P_M⌉` cooperating cores ("different slices may cooperate with
//!   each other to manage large kernel sizes", §I).
//!
//! Strided layers sweep every stride-1 window position and decimate
//! (§V's AlexNet CL1 behaviour), so their compute phase costs
//! `(H_P−K+1)·(W_P−K+1)` cycles per step instead of `H_O·W_O`.
//!
//! **Known deviation** (documented in EXPERIMENTS.md): for AlexNet CL1 the
//! paper reports 2.13 GOPs/s, implying an almost fully serialised tile
//! schedule; our packing is more aggressive (~19 GOPs/s). The qualitative
//! result is unchanged — CL1 is the only layer where Eyeriss beats TrIM.
//!
//! [`StepPlan`] carries eq. (2)'s *analytical* cycle count (`total_cycles`
//! folds the per-step pipeline overheads into `L_I`, as the paper does);
//! the fast tier's [`super::fastsim::analytic_stats`] extends this plan to
//! the register-measured counters — same step grid, plus the explicit
//! slice-skew and adder-tree latencies each measured step pays.

use super::config::ArchConfig;
use crate::model::{ConvLayer, KernelTiling};


/// The per-layer execution plan (schedule + eq. (2) timing).
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// Tiles per kernel (1 for native layers).
    pub tiles: usize,
    /// Filters processed concurrently.
    pub filters_parallel: usize,
    /// Cores cooperating on one filter (1 for native layers).
    pub cores_per_filter: usize,
    /// Filter-group steps: `⌈N / filters_parallel⌉`.
    pub filter_steps: u64,
    /// Channel-group steps: `⌈M / P_M⌉` (1 when channels are packed with
    /// tiles inside the filter's cooperating cores).
    pub m_steps: u64,
    /// Total computational steps.
    pub steps: u64,
    /// Weight-load cycles per step (`P_N · K`).
    pub weight_load_cycles: u64,
    /// Compute-phase cycles per step (stride-1 sweep positions).
    pub sweep_cycles: u64,
    /// eq. (2): `L_I + steps · (weight_load + sweep)`.
    pub total_cycles: u64,
    /// Steady-state slice occupancy (the tables' "PE Util." column).
    pub utilization: f64,
}

impl StepPlan {
    /// Execution time at the configured clock.
    pub fn time_s(&self, cfg: &ArchConfig) -> f64 {
        self.total_cycles as f64 / cfg.f_clk
    }

    /// Achieved throughput for `layer` (eq. (1) ops over eq. (2) time).
    pub fn gops(&self, cfg: &ArchConfig, layer: &ConvLayer) -> f64 {
        layer.ops() as f64 / self.time_s(cfg) / 1e9
    }
}

/// Build the execution plan for `layer` on `cfg`.
pub fn plan_layer(cfg: &ArchConfig, layer: &ConvLayer) -> StepPlan {
    let k_nat = cfg.k;
    let (p_n, p_m) = (cfg.p_n, cfg.p_m);
    let hp = layer.h_i + 2 * layer.pad;
    let wp = layer.w_i + 2 * layer.pad;

    let tiling = KernelTiling::new(layer.k, k_nat);
    let t = tiling.num_tiles();

    // Stride-1 sweep positions (== H_O·W_O for stride-1 layers).
    let sweep = ((hp - layer.k + 1) * (wp - layer.k + 1)) as u64;
    let weight_load = (p_n * k_nat) as u64;

    let (filters_parallel, cores_per_filter, m_steps, util);
    if t == 1 {
        // Native: one slice per (filter, channel) pair.
        filters_parallel = p_n.min(layer.n);
        cores_per_filter = 1;
        m_steps = layer.m.div_ceil(p_m) as u64;
        util = (layer.m.min(p_m) as f64 / p_m as f64) * (layer.n.min(p_n) as f64 / p_n as f64);
    } else if t <= p_n {
        // Few tiles: T cores cooperate per filter (paper's 5×5 policy).
        filters_parallel = (p_n / t).max(1);
        cores_per_filter = t;
        m_steps = layer.m.div_ceil(p_m) as u64;
        let cores_used = (filters_parallel * t).min(p_n);
        util = (cores_used as f64 / p_n as f64) * (layer.m.min(p_m) as f64 / p_m as f64);
    } else {
        // Many tiles: (channel, tile) tasks packed across slices.
        let tasks_per_filter = layer.m * t;
        let cpf = tasks_per_filter.div_ceil(p_m);
        if cpf <= p_n {
            filters_parallel = (p_n / cpf).max(1);
            cores_per_filter = cpf;
            m_steps = 1;
            let slices_used = filters_parallel * tasks_per_filter;
            util = slices_used as f64 / (p_n * p_m) as f64;
        } else {
            filters_parallel = 1;
            cores_per_filter = cpf;
            m_steps = cpf.div_ceil(p_n) as u64; // sequential rounds
            util = 1.0;
        }
    }

    let filter_steps = layer.n.div_ceil(filters_parallel) as u64;
    let steps = filter_steps * m_steps;
    let total_cycles = cfg.pipeline_latency() + steps * (weight_load + sweep);

    StepPlan {
        tiles: t,
        filters_parallel,
        cores_per_filter,
        filter_steps,
        m_steps,
        steps,
        weight_load_cycles: weight_load,
        sweep_cycles: sweep,
        total_cycles,
        utilization: util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet::alexnet, vgg16::vgg16};

    fn paper_cfg() -> ArchConfig {
        ArchConfig::paper_engine()
    }

    /// Table I: per-layer GOPs/s of the paper's engine on VGG-16.
    #[test]
    fn vgg16_gops_match_table1() {
        let cfg = paper_cfg();
        let expect = [51.8, 368.0, 387.0, 387.0, 396.0, 432.0, 432.0, 422.0, 422.0, 422.0, 389.0, 389.0, 389.0];
        for (l, &e) in vgg16().layers.iter().zip(&expect) {
            let plan = plan_layer(&cfg, l);
            let g = plan.gops(&cfg, l);
            assert!((g - e).abs() / e < 0.01, "{}: got {g:.1}, paper {e}", l.name);
        }
    }

    /// Table I: PE utilisation column.
    #[test]
    fn vgg16_utilization_matches_table1() {
        let cfg = paper_cfg();
        let net = vgg16();
        let u1 = plan_layer(&cfg, &net.layers[0]).utilization;
        assert!((u1 - 0.125).abs() < 0.01, "CL1 util = {u1}"); // paper: 0.13
        for l in &net.layers[1..] {
            let u = plan_layer(&cfg, l).utilization;
            assert!((u - 1.0).abs() < 1e-9, "{} util = {u}", l.name);
        }
    }

    /// §V: VGG-16 sustained throughput 391 GOPs/s, 78.6 ms/inference,
    /// mean utilisation 93 %.
    #[test]
    fn vgg16_totals_match_section5() {
        let cfg = paper_cfg();
        let net = vgg16();
        let total_time: f64 = net.layers.iter().map(|l| plan_layer(&cfg, l).time_s(&cfg)).sum();
        let gops = net.total_ops() as f64 / total_time / 1e9;
        assert!((total_time * 1e3 - 78.6).abs() < 1.0, "time = {:.1} ms", total_time * 1e3);
        assert!((gops - 391.0).abs() < 5.0, "throughput = {gops:.0} GOPs/s");
        let mean_util: f64 =
            net.layers.iter().map(|l| plan_layer(&cfg, l).utilization).sum::<f64>() / 13.0;
        assert!((mean_util - 0.93).abs() < 0.01, "mean util = {mean_util:.3}");
    }

    /// Table II: AlexNet CL2 (5×5 → 4 tile-groups on 4 of 7 cores).
    #[test]
    fn alexnet_cl2_matches_table2() {
        let cfg = paper_cfg();
        let net = alexnet();
        let cl2 = &net.layers[1];
        let plan = plan_layer(&cfg, cl2);
        assert_eq!(plan.tiles, 4);
        assert_eq!(plan.cores_per_filter, 4);
        assert_eq!(plan.filters_parallel, 1);
        assert!((plan.utilization - 4.0 / 7.0).abs() < 1e-9); // paper: 0.57
        let g = plan.gops(&cfg, cl2);
        assert!((g - 179.0).abs() / 179.0 < 0.03, "CL2 = {g:.0} GOPs/s (paper 179)");
    }

    /// Table II: AlexNet CL3-5 (native 3×3 layers) match exactly.
    #[test]
    fn alexnet_native_layers_match_table2() {
        let cfg = paper_cfg();
        let net = alexnet();
        let expect = [390.0, 402.0, 399.0];
        for (l, &e) in net.layers[2..].iter().zip(&expect) {
            let g = plan_layer(&cfg, l).gops(&cfg, l);
            assert!((g - e).abs() / e < 0.01, "{}: {g:.0} vs paper {e}", l.name);
        }
    }

    /// AlexNet CL1: 16 tiles > P_N — our packing spreads (channel, tile)
    /// tasks across slices; the paper's (underspecified) schedule is far
    /// more serial. Documented deviation: we check the qualitative shape —
    /// CL1 is TrIM's worst layer and loses to Eyeriss (51.1 GOPs/s).
    #[test]
    fn alexnet_cl1_is_the_weak_spot() {
        let cfg = paper_cfg();
        let net = alexnet();
        let cl1 = &net.layers[0];
        let plan = plan_layer(&cfg, cl1);
        assert_eq!(plan.tiles, 16);
        let g = plan.gops(&cfg, cl1);
        assert!(g < 51.1, "CL1 {g:.1} GOPs/s must lose to Eyeriss's 51.1");
        let others: f64 = net.layers[1..]
            .iter()
            .map(|l| plan_layer(&cfg, l).gops(&cfg, l))
            .fold(f64::INFINITY, f64::min);
        assert!(g < others, "CL1 must be the slowest layer");
    }

    #[test]
    fn eq2_structure_native() {
        // eq. (2): NC = L_I + ⌈N/P_N⌉·⌈M/P_M⌉·(P_N·K + H_O·W_O)
        let cfg = paper_cfg();
        let l = ConvLayer::new("x", 56, 3, 128, 256, 1, 1);
        let p = plan_layer(&cfg, &l);
        assert_eq!(p.steps, 37 * 6);
        assert_eq!(p.weight_load_cycles, 21);
        assert_eq!(p.sweep_cycles, 56 * 56);
        assert_eq!(p.total_cycles, 9 + 222 * (21 + 3136));
    }
}
