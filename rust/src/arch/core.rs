//! The TrIM Core (Fig. 5): `P_M` slices in lockstep plus a pipelined adder
//! tree that spatially accumulates the slice outputs.
//!
//! Fidelity: the slices themselves are register-accurate ([`SliceSim`]);
//! the core combines their (cycle-aligned) output streams through the
//! adder-tree model. All slices of a core run the *same* schedule on
//! different (ifmap, kernel) pairs, so their cycle counts are identical and
//! the core's cycle count is that of one slice plus the tree latency —
//! exactly the paper's "3 stages for the adder tree at the core level".

use super::adder_tree::AdderTree;
use super::slice::SliceSim;
use super::stats::SimStats;

/// Result of one core pass (one filter over ≤ P_M channels).
#[derive(Debug, Clone)]
pub struct CoreRunResult {
    /// Spatially accumulated partial ofmap (`core_out` of Fig. 5), i64 to
    /// hold the `2B+K+⌈log2 K⌉+⌈log2 P_M⌉`-bit core output.
    pub partial: Vec<i64>,
    pub h_o: usize,
    pub w_o: usize,
    pub stats: SimStats,
}

/// One TrIM core: `p_m` slice simulators + the spatial adder tree.
pub struct CoreSim {
    p_m: usize,
    slices: Vec<SliceSim>,
}

impl CoreSim {
    pub fn new(k: usize, p_m: usize, w_im: usize) -> Self {
        Self { p_m, slices: (0..p_m).map(|_| SliceSim::new(k, w_im)).collect() }
    }

    pub fn p_m(&self) -> usize {
        self.p_m
    }

    /// Run one computational step for one filter: convolve `channels`
    /// (each an `h×w` ifmap slice) with the matching `kernels` (each
    /// `k×k`), then reduce across slices.
    ///
    /// `count_ext_reads = false` models the engine-level input broadcast:
    /// only one core per engine pays the external ifmap reads (Fig. 6 —
    /// "the memory bandwidth is fully utilized by reading inputs once and
    /// broadcasting them to the different cores").
    #[allow(clippy::too_many_arguments)]
    pub fn run_step(
        &mut self,
        channels: &[&[i32]],
        h: usize,
        w: usize,
        kernels: &[&[i32]],
        pad: usize,
        stride: usize,
        count_ext_reads: bool,
    ) -> CoreRunResult {
        assert!(!channels.is_empty() && channels.len() <= self.p_m);
        assert_eq!(channels.len(), kernels.len());

        let mut stats = SimStats::default();
        let mut slice_outputs = Vec::with_capacity(channels.len());
        let mut h_o = 0;
        let mut w_o = 0;
        for (idx, (ch, kern)) in channels.iter().zip(kernels.iter()).enumerate() {
            let r = self.slices[idx].run_conv(ch, h, w, kern, pad, stride);
            let mut s = r.stats;
            if !count_ext_reads {
                s.ext_input_reads = 0;
                // weights are per-core (not broadcast): keep weight_reads.
            }
            // Slices run in parallel: cycles take the max (they're equal),
            // access counters add.
            s.output_writes = 0; // slice outputs stay on-chip (tree input)
            stats.merge(&s);
            h_o = r.h_o;
            w_o = r.w_o;
            slice_outputs.push(r.output);
        }

        // Spatial reduction. Numerically this is an exact sum; timing-wise
        // it adds the pipelined tree latency once per step — taken from
        // [`AdderTree::latency_for`] rather than a throwaway tree instance.
        let mut partial = vec![0i64; h_o * w_o];
        for out in &slice_outputs {
            for (i, &v) in out.iter().enumerate() {
                partial[i] += v as i64;
            }
        }
        stats.cycles += AdderTree::latency_for(slice_outputs.len().max(2)) as u64;

        CoreRunResult { partial, h_o, w_o, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::{conv3d_i32, Tensor3};

    #[test]
    fn core_step_equals_multichannel_golden() {
        let (m, h, w, k) = (4usize, 12usize, 10usize, 3usize);
        let input = Tensor3::from_fn(m, h, w, |c, y, x| ((c * 31 + y * 7 + x * 3) % 23) as i32 - 11);
        let weights: Vec<i32> = (0..m * k * k).map(|i| (i as i32 % 9) - 4).collect();

        let golden = conv3d_i32(&input, &weights, 1, k, 1, 1);

        let mut core = CoreSim::new(k, m, w + 2);
        let chans: Vec<&[i32]> = (0..m).map(|c| input.channel(c)).collect();
        let kerns: Vec<&[i32]> = (0..m).map(|c| &weights[c * k * k..(c + 1) * k * k]).collect();
        let r = core.run_step(&chans, h, w, &kerns, 1, 1, true);

        let got: Vec<i32> = r.partial.iter().map(|&v| v as i32).collect();
        assert_eq!(got, golden.data);
    }

    #[test]
    fn broadcast_suppresses_ext_reads() {
        let (h, w, k) = (8usize, 8usize, 3usize);
        let ifmap: Vec<i32> = (0..h * w).map(|i| i as i32).collect();
        let kern = vec![1i32; 9];
        let mut core = CoreSim::new(k, 1, w + 2);
        let a = core.run_step(&[&ifmap], h, w, &[&kern], 1, 1, true);
        let b = core.run_step(&[&ifmap], h, w, &[&kern], 1, 1, false);
        assert!(a.stats.ext_input_reads > 0);
        assert_eq!(b.stats.ext_input_reads, 0);
        assert_eq!(a.partial, b.partial);
    }
}
