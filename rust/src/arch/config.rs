//! Architecture configuration (the free parameters of Figs. 3–6) and the
//! execution-fidelity tier selection.

/// Which execution tier an [`crate::arch::EngineSim`] runs.
///
/// Both tiers produce **identical** results — same ofmaps bit-for-bit,
/// same [`crate::arch::SimStats`] counter-for-counter (property-tested in
/// `tests/proptest_invariants.rs`); they differ only in how those results
/// are obtained, and therefore in wall-clock cost:
///
/// * [`ExecFidelity::Register`] steps every PE register, RSRB stage and
///   adder-tree pipeline cycle by cycle — the measurement oracle.
/// * [`ExecFidelity::Fast`] computes ofmaps with a blocked direct
///   convolution and synthesizes the counters from the closed-form model
///   of [`crate::arch::fastsim`] (eq. (2) + the Tables I–II access
///   formulas) — the serving tier, orders of magnitude faster per layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecFidelity {
    /// Functional fast path + analytical counters (the farm default).
    #[default]
    Fast,
    /// Cycle-accurate register simulation (the validation oracle).
    Register,
}

impl ExecFidelity {
    /// CLI-facing name (`--fidelity fast|register`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Fast => "fast",
            Self::Register => "register",
        }
    }
}

impl std::fmt::Display for ExecFidelity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

impl std::str::FromStr for ExecFidelity {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fast" | "functional" => Ok(Self::Fast),
            "register" | "cycle" | "rtl" => Ok(Self::Register),
            other => Err(anyhow::anyhow!("unknown fidelity {other:?} (expected fast|register)")),
        }
    }
}

/// Parameters of a TrIM engine instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchConfig {
    /// Native kernel size of a slice (K). The paper's engine: 3.
    pub k: usize,
    /// Slices per core (P_M — parallel ifmaps).
    pub p_m: usize,
    /// Cores per engine (P_N — parallel filters/ofmaps).
    pub p_n: usize,
    /// Operand precision B in bits (8 in the paper).
    pub bits: usize,
    /// Clock frequency in Hz (150 MHz in the paper).
    pub f_clk: f64,
    /// RSRB capacity: width of the largest (padded) ifmap, `W_IM`.
    /// 226 for VGG-16 (224 + 2·pad).
    pub w_im: usize,
    /// Psum buffer capacity per core, in activations (`H_OM × W_OM`);
    /// 224·224 in the paper (worst case = first two VGG-16 layers).
    pub psum_buf_depth: usize,
}

impl ArchConfig {
    /// The paper's FPGA implementation: P_N = 7 cores × P_M = 24 slices of
    /// 3×3 PEs = 1512 PEs @ 150 MHz, 8-bit operands.
    pub fn paper_engine() -> Self {
        Self { k: 3, p_m: 24, p_n: 7, bits: 8, f_clk: 150.0e6, w_im: 226, psum_buf_depth: 224 * 224 }
    }

    /// A reduced engine for fast cycle-accurate engine tests.
    pub fn small(k: usize, p_m: usize, p_n: usize) -> Self {
        Self { k, p_m, p_n, bits: 8, f_clk: 150.0e6, w_im: 64, psum_buf_depth: 64 * 64 }
    }

    /// Total PE count: `P_N · P_M · K²`.
    pub fn total_pes(&self) -> usize {
        self.p_n * self.p_m * self.k * self.k
    }

    /// Peak throughput in ops/s: every PE does one MAC (2 ops) per cycle.
    /// Paper: 1512 PEs · 2 · 150 MHz = 453.6 GOPs/s.
    pub fn peak_ops_per_s(&self) -> f64 {
        self.total_pes() as f64 * 2.0 * self.f_clk
    }

    /// Engine pipeline latency L_I in cycles. Paper §V: 9 stages
    /// (5 slice + 3 core adder tree + 1 engine accumulator).
    pub fn pipeline_latency(&self) -> u64 {
        let slice = (self.k as u64 - 1) + 1 + (self.k as f64).log2().ceil() as u64; // skew+MAC+tree
        let core = 3; // paper's pipelined core tree depth for P_M = 24
        slice + core + 1
    }

    /// I/O bandwidth requirement, eq. (4): `(P_M·(2K−1) + P_N)·B` bits per
    /// cycle. For K = 3 this is the paper's `(P_M·5 + P_N)·B`.
    pub fn io_bandwidth_bits(&self) -> u64 {
        ((self.p_m * (2 * self.k - 1) + self.p_n) * self.bits) as u64
    }

    /// Psum-buffer size in bits, eq. (3): `P_N · H_OM·W_OM · 32`.
    pub fn psum_buffer_bits(&self) -> u64 {
        (self.p_n * self.psum_buf_depth) as u64 * 32
    }
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::paper_engine()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_engine_headlines() {
        let c = ArchConfig::paper_engine();
        assert_eq!(c.total_pes(), 1512);
        assert!((c.peak_ops_per_s() / 1e9 - 453.6).abs() < 1e-9);
        assert_eq!(c.pipeline_latency(), 9); // 3+1+2 slice, 3 core, 1 engine
        // eq. (4): (24·5 + 7)·8 = 1016 bits/cycle, "rounded to 1024" in §V.
        assert_eq!(c.io_bandwidth_bits(), 1016);
        // eq. (3): 7 · 224² · 32 = 11.24 Mb — just above the XCZU7EV's 11 Mb,
        // the paper's stated BRAM constraint (10.21 Mb used after synthesis).
        assert!((c.psum_buffer_bits() as f64 / 1e6 - 11.24) < 0.3);
    }

    #[test]
    fn fidelity_parses_and_defaults_fast() {
        assert_eq!("fast".parse::<ExecFidelity>().unwrap(), ExecFidelity::Fast);
        assert_eq!("register".parse::<ExecFidelity>().unwrap(), ExecFidelity::Register);
        assert!("quick".parse::<ExecFidelity>().is_err());
        assert_eq!(ExecFidelity::default(), ExecFidelity::Fast);
        assert_eq!(ExecFidelity::Register.to_string(), "register");
    }
}
