//! Architecture configuration (the free parameters of Figs. 3–6).



/// Parameters of a TrIM engine instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchConfig {
    /// Native kernel size of a slice (K). The paper's engine: 3.
    pub k: usize,
    /// Slices per core (P_M — parallel ifmaps).
    pub p_m: usize,
    /// Cores per engine (P_N — parallel filters/ofmaps).
    pub p_n: usize,
    /// Operand precision B in bits (8 in the paper).
    pub bits: usize,
    /// Clock frequency in Hz (150 MHz in the paper).
    pub f_clk: f64,
    /// RSRB capacity: width of the largest (padded) ifmap, `W_IM`.
    /// 226 for VGG-16 (224 + 2·pad).
    pub w_im: usize,
    /// Psum buffer capacity per core, in activations (`H_OM × W_OM`);
    /// 224·224 in the paper (worst case = first two VGG-16 layers).
    pub psum_buf_depth: usize,
}

impl ArchConfig {
    /// The paper's FPGA implementation: P_N = 7 cores × P_M = 24 slices of
    /// 3×3 PEs = 1512 PEs @ 150 MHz, 8-bit operands.
    pub fn paper_engine() -> Self {
        Self { k: 3, p_m: 24, p_n: 7, bits: 8, f_clk: 150.0e6, w_im: 226, psum_buf_depth: 224 * 224 }
    }

    /// A reduced engine for fast cycle-accurate engine tests.
    pub fn small(k: usize, p_m: usize, p_n: usize) -> Self {
        Self { k, p_m, p_n, bits: 8, f_clk: 150.0e6, w_im: 64, psum_buf_depth: 64 * 64 }
    }

    /// Total PE count: `P_N · P_M · K²`.
    pub fn total_pes(&self) -> usize {
        self.p_n * self.p_m * self.k * self.k
    }

    /// Peak throughput in ops/s: every PE does one MAC (2 ops) per cycle.
    /// Paper: 1512 PEs · 2 · 150 MHz = 453.6 GOPs/s.
    pub fn peak_ops_per_s(&self) -> f64 {
        self.total_pes() as f64 * 2.0 * self.f_clk
    }

    /// Engine pipeline latency L_I in cycles. Paper §V: 9 stages
    /// (5 slice + 3 core adder tree + 1 engine accumulator).
    pub fn pipeline_latency(&self) -> u64 {
        let slice = (self.k as u64 - 1) + 1 + (self.k as f64).log2().ceil() as u64; // skew+MAC+tree
        let core = 3; // paper's pipelined core tree depth for P_M = 24
        slice + core + 1
    }

    /// I/O bandwidth requirement, eq. (4): `(P_M·(2K−1) + P_N)·B` bits per
    /// cycle. For K = 3 this is the paper's `(P_M·5 + P_N)·B`.
    pub fn io_bandwidth_bits(&self) -> u64 {
        ((self.p_m * (2 * self.k - 1) + self.p_n) * self.bits) as u64
    }

    /// Psum-buffer size in bits, eq. (3): `P_N · H_OM·W_OM · 32`.
    pub fn psum_buffer_bits(&self) -> u64 {
        (self.p_n * self.psum_buf_depth) as u64 * 32
    }
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::paper_engine()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_engine_headlines() {
        let c = ArchConfig::paper_engine();
        assert_eq!(c.total_pes(), 1512);
        assert!((c.peak_ops_per_s() / 1e9 - 453.6).abs() < 1e-9);
        assert_eq!(c.pipeline_latency(), 9); // 3+1+2 slice, 3 core, 1 engine
        // eq. (4): (24·5 + 7)·8 = 1016 bits/cycle, "rounded to 1024" in §V.
        assert_eq!(c.io_bandwidth_bits(), 1016);
        // eq. (3): 7 · 224² · 32 = 11.24 Mb — just above the XCZU7EV's 11 Mb,
        // the paper's stated BRAM constraint (10.21 Mb used after synthesis).
        assert!((c.psum_buffer_bits() as f64 / 1e6 - 11.24) < 0.3);
    }
}
