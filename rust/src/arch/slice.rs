//! Register-accurate simulation of one TrIM Slice (Fig. 3).
//!
//! ## Reconstructed schedule
//!
//! The slice computes a 2-D `K×K` convolution at one output per cycle.
//! PE rows are skewed by one cycle by the vertical psum register chain:
//! row `i` processes output index `s` at compute cycle `s + i`. Inputs
//! reach the multiplier of `PE[i][j]` only through the structural paths of
//! Fig. 3:
//!
//! * **vertical / external** (`I_ext`, blue): the bottom row's new element
//!   each cycle, the K-wide window load of every row at an output-row
//!   start, and the warm-up feeds of the upper rows during the first
//!   output row;
//! * **horizontal** (`I_R`, red): the right neighbour's pass register —
//!   the column-overlap reuse between horizontally adjacent windows;
//! * **diagonal** (`I_D`, brown): the RSRB dispatch — elements retired by
//!   the left edge of row `i+1` re-emerge one output row later at row `i`
//!   (the row-overlap reuse between vertically adjacent windows).
//!
//! Consequences, all *measured* by this model and asserted in tests:
//!
//! * every element of the **padded** ifmap is read from outside exactly
//!   once → the read overhead for a 3×3 convolution over 224×224 with
//!   pad 1 is 226²/224² − 1 = **1.79 %**, the paper's "negligible 1.8 %
//!   overhead" (§II);
//! * the peak external-input bandwidth of one slice is **2K−1 = 5**
//!   elements in one cycle (warm-up skew), the `P_M·5·B` term of eq. (4);
//! * each RSRB buffers at most one padded ifmap row (≤ `W_IM`), matching
//!   the paper's RSRB sizing;
//! * compute cycles are `H_O·W_O` plus the pipeline fill of
//!   `(K−1) + ⌈log2 K⌉ + 1`, matching eq. (2)'s per-step term.

use super::adder_tree::AdderTree;
use super::pe::InputSel;
use super::rsrb::Rsrb;
use super::stats::SimStats;

/// Result of one slice pass.
#[derive(Debug, Clone)]
pub struct SliceRunResult {
    /// Row-major `h_o × w_o` ofmap (stride applied).
    pub output: Vec<i32>,
    pub h_o: usize,
    pub w_o: usize,
    pub stats: SimStats,
}

/// Register-accurate slice simulator.
///
/// PE registers are stored struct-of-arrays (one flat `K×K` vector per
/// register class) so the per-cycle MAC loop vectorises — the [`super::pe::Pe`]
/// struct documents the per-PE view; the simulation state is the same
/// registers laid out for the simulator's hot loop (EXPERIMENTS.md §Perf).
///
/// The simulator is reusable: all state (registers, RSRBs, adder tree,
/// per-row scratch) is reset in place at the start of every pass, so a
/// slice owned by a long-lived core performs no allocations across steps
/// beyond the returned ofmap (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct SliceSim {
    k: usize,
    w_im: usize,
    /// Weight registers, row-major `K×K`.
    pe_weight: Vec<i32>,
    /// Input/pass registers.
    pe_input: Vec<i32>,
    /// Psum output registers.
    pe_psum: Vec<i32>,
    rsrbs: Vec<Rsrb>, // K−1 buffers; rsrbs[i] feeds row i, fed by row i+1
    /// Slice-level adder tree, reset per pass.
    tree: AdderTree,
    // --- per-pass scratch, reset in place (allocation-free hot loop) ---
    row_vals: Vec<i32>,
    tree_buf: Vec<i32>,
    row_oy: Vec<usize>,
    row_ox: Vec<usize>,
    out1: Vec<i32>,
}

/// Zero-padded read-only view of an ifmap, or a shifted window into a
/// larger row-major buffer.
///
/// The window form is the §V tiled path's *strided view*: tile
/// `(row0, col0)` of a large kernel convolves the padded ifmap shifted by
/// its origin, and positions past the buffer edge read as zero. Passing the
/// view to [`SliceSim::run_conv_view`] replaces the per-(channel, tile)
/// sub-ifmap copies the engine used to materialise (EXPERIMENTS.md §Perf).
pub struct InputView<'a> {
    data: &'a [i32],
    /// Underlying buffer dimensions (row pitch = `src_w`).
    src_h: usize,
    src_w: usize,
    /// Window origin inside the buffer.
    y0: usize,
    x0: usize,
    /// Window dimensions — the `h × w` ifmap the slice convolves.
    h: usize,
    w: usize,
    /// Zero padding around the window.
    pad: usize,
}

impl<'a> InputView<'a> {
    /// View an entire `h × w` ifmap with `pad` zeros on each border.
    pub fn whole(data: &'a [i32], h: usize, w: usize, pad: usize) -> Self {
        assert_eq!(data.len(), h * w);
        Self { data, src_h: h, src_w: w, y0: 0, x0: 0, h, w, pad }
    }

    /// An `h × w` window at `(y0, x0)` inside an `src_h × src_w` buffer,
    /// unpadded; window positions beyond the buffer read as zero (the
    /// zero tail a shifted tile view sweeps at the right/bottom edges).
    pub fn window(
        data: &'a [i32],
        src_h: usize,
        src_w: usize,
        y0: usize,
        x0: usize,
        h: usize,
        w: usize,
    ) -> Self {
        assert_eq!(data.len(), src_h * src_w);
        assert!(y0 < src_h && x0 < src_w, "window origin outside the buffer");
        Self { data, src_h, src_w, y0, x0, h, w, pad: 0 }
    }

    /// Padded dimensions.
    fn hp(&self) -> usize {
        self.h + 2 * self.pad
    }
    fn wp(&self) -> usize {
        self.w + 2 * self.pad
    }
    /// Read padded coordinate (y, x) — zero outside the real region.
    #[inline]
    fn get(&self, y: usize, x: usize) -> i32 {
        let yy = y as isize - self.pad as isize;
        let xx = x as isize - self.pad as isize;
        if yy < 0 || xx < 0 || yy >= self.h as isize || xx >= self.w as isize {
            return 0;
        }
        let sy = yy as usize + self.y0;
        let sx = xx as usize + self.x0;
        if sy >= self.src_h || sx >= self.src_w {
            0
        } else {
            self.data[sy * self.src_w + sx]
        }
    }
}

impl SliceSim {
    /// A slice with native kernel size `k` and RSRB capacity `w_im`
    /// (the largest padded ifmap width it must handle).
    pub fn new(k: usize, w_im: usize) -> Self {
        assert!(k >= 2, "a 1×1 'array' has no triangular movement");
        Self {
            k,
            w_im,
            pe_weight: vec![0; k * k],
            pe_input: vec![0; k * k],
            pe_psum: vec![0; k * k],
            rsrbs: (0..k - 1).map(|_| Rsrb::new(w_im)).collect(),
            tree: AdderTree::new(k),
            row_vals: vec![0; k],
            tree_buf: vec![0; k],
            row_oy: vec![0; k],
            row_ox: vec![0; k],
            out1: Vec::new(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Weight-load phase (§III-A): kernel rows enter the top row as groups
    /// of K per cycle — last kernel row first — and shift down; after K
    /// cycles PE row `i` holds kernel row `i`. Counts K cycles and K
    /// weight reads per cycle.
    fn load_weights(&mut self, weights: &[i32], stats: &mut SimStats) {
        let k = self.k;
        assert_eq!(weights.len(), k * k);
        for cycle in 0..k {
            let incoming_row = k - 1 - cycle; // kernel row entering the top
            for j in 0..k {
                let mut carry = weights[incoming_row * k + j];
                for i in 0..k {
                    carry = std::mem::replace(&mut self.pe_weight[i * k + j], carry);
                }
            }
            stats.weight_reads += k as u64;
            stats.cycles += 1;
        }
    }

    /// Run one `K×K` convolution over an `h×w` ifmap with the given zero
    /// padding and stride (see [`SliceSim::run_conv_view`]).
    pub fn run_conv(
        &mut self,
        ifmap: &[i32],
        h: usize,
        w: usize,
        weights: &[i32],
        pad: usize,
        stride: usize,
    ) -> SliceRunResult {
        self.run_conv_view(&InputView::whole(ifmap, h, w, pad), weights, stride)
    }

    /// Run one `K×K` convolution over the ifmap described by `view`
    /// (a whole padded ifmap, or a shifted tile window — see
    /// [`InputView`]). Stride > 1 is executed the way §V describes for
    /// AlexNet: the array streams every stride-1 position and the control
    /// logic decimates the outputs (the cycle count reflects the full
    /// stride-1 sweep — TrIM's known inefficiency on strided layers).
    pub fn run_conv_view(&mut self, view: &InputView, weights: &[i32], stride: usize) -> SliceRunResult {
        let k = self.k;
        let (hp, wp) = (view.hp(), view.wp());
        assert!(hp >= k && wp >= k, "ifmap smaller than kernel");
        let h_o1 = hp - k + 1; // stride-1 output grid
        let w_o1 = wp - k + 1;
        assert!(w_o1 >= k, "output width below K breaks the RSRB schedule");
        assert!(wp <= self.w_im, "padded ifmap wider than W_IM: reconfigure the slice");

        let mut stats = SimStats::default();
        // fresh state per pass — everything reset in place, nothing
        // reallocated (EXPERIMENTS.md §Perf)
        self.pe_weight.fill(0);
        self.pe_input.fill(0);
        self.pe_psum.fill(0);
        for b in &mut self.rsrbs {
            b.reset();
        }
        self.tree.reset();
        self.row_oy.fill(0);
        self.row_ox.fill(0);
        self.out1.clear();
        self.out1.reserve(h_o1 * w_o1);

        self.load_weights(weights, &mut stats);

        let total_steps = h_o1 * w_o1;
        let compute_cycles = total_steps + (k - 1); // last row's skew

        for c in 0..compute_cycles {
            let mut ext_this_cycle = 0u64;
            // rows updated bottom-up so psum/pass registers read pre-update
            for i in (0..k).rev() {
                if c < i || c - i >= total_steps {
                    continue; // row idle (fill/drain of the skew)
                }
                let oy = self.row_oy[i];
                let ox = self.row_ox[i];
                self.row_ox[i] += 1;
                if self.row_ox[i] == w_o1 {
                    self.row_ox[i] = 0;
                    self.row_oy[i] += 1;
                }
                let y = oy + i; // padded ifmap row this PE row consumes

                // --- input mux selection (control logic of Fig. 6);
                // I_ext when the bottom row or warm-up, I_D (RSRB) for the
                // upper rows, I_R (right neighbour) for the pass chain ---
                let ext_row = i == k - 1 || oy == 0;
                if ox == 0 {
                    // output-row start: K-wide window load
                    if ext_row {
                        for j in 0..k {
                            self.row_vals[j] = view.get(y, j); // I_ext
                        }
                        ext_this_cycle += k as u64;
                    } else {
                        for j in 0..k {
                            self.row_vals[j] = self.rsrbs[i].pop(); // I_D bus
                        }
                        debug_assert!(
                            (0..k).all(|j| self.row_vals[j] == view.get(y, j)),
                            "RSRB replay mismatch at row {i} oy {oy}"
                        );
                    }
                } else {
                    // steady state: one new element at the right edge,
                    // everything else shifts from the right neighbour.
                    self.row_vals[..k - 1].copy_from_slice(&self.pe_input[i * k + 1..i * k + k]); // I_R
                    if ext_row {
                        self.row_vals[k - 1] = view.get(y, ox + k - 1); // I_ext
                        ext_this_cycle += 1;
                    } else {
                        let popped = self.rsrbs[i].pop(); // I_D
                        debug_assert_eq!(popped, view.get(y, ox + k - 1), "RSRB replay row {i} ({oy},{ox})");
                        self.row_vals[k - 1] = popped;
                    }
                }
                let _ = InputSel::Right; // selections are implied by the schedule

                // --- MAC + pass-register update (vectorised: one MAC per
                // PE of the row against the row-above psum registers) ---
                let base = i * k;
                self.pe_input[base..base + k].copy_from_slice(&self.row_vals[..k]);
                if i == 0 {
                    for j in 0..k {
                        self.pe_psum[j] = self.row_vals[j].wrapping_mul(self.pe_weight[j]);
                    }
                } else {
                    for j in 0..k {
                        self.pe_psum[base + j] = self.row_vals[j]
                            .wrapping_mul(self.pe_weight[base + j])
                            .wrapping_add(self.pe_psum[base - k + j]);
                    }
                }
                stats.macs += k as u64;

                // --- diagonal forwarding: retire to the RSRB below ---
                if i > 0 {
                    self.rsrbs[i - 1].push(self.row_vals[0]);
                    if ox == w_o1 - 1 {
                        // end-of-row flush: the last K−1 columns drain out
                        for j in 1..k {
                            let v = self.row_vals[j];
                            self.rsrbs[i - 1].push(v);
                        }
                    }
                }
            }

            // --- adder tree fed by the bottom row's registered psums ---
            let out = if c >= k - 1 && c - (k - 1) < total_steps {
                self.tree_buf.copy_from_slice(&self.pe_psum[(k - 1) * k..]);
                self.tree.step(Some(&self.tree_buf))
            } else {
                self.tree.step(None)
            };
            if let Some(v) = out {
                self.out1.push(v as i32);
            }

            stats.cycles += 1;
            if ext_this_cycle > stats.peak_ext_inputs_per_cycle {
                stats.peak_ext_inputs_per_cycle = ext_this_cycle;
            }
            stats.ext_input_reads += ext_this_cycle;
        }
        for v in self.tree.drain() {
            self.out1.push(v as i32);
        }
        stats.cycles += self.tree.latency() as u64; // output-register drain
        stats.max_rsrb_occupancy =
            self.rsrbs.iter().map(|b| b.max_occupancy() as u64).max().unwrap_or(0);
        assert_eq!(self.out1.len(), total_steps);

        // stride decimation (control logic; no extra cycles — the sweep
        // above already paid the full stride-1 cost)
        let h_o = (hp - k) / stride + 1;
        let w_o = (wp - k) / stride + 1;
        let mut output = Vec::with_capacity(h_o * w_o);
        for oy in 0..h_o {
            for ox in 0..w_o {
                output.push(self.out1[(oy * stride) * w_o1 + ox * stride]);
            }
        }
        stats.output_writes += output.len() as u64;
        SliceRunResult { output, h_o, w_o, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv2d_i32;

    fn check(h: usize, w: usize, k: usize, pad: usize, stride: usize) -> SimStats {
        let ifmap: Vec<i32> = (0..h * w).map(|i| (i as i32 * 31 + 7) % 251).collect();
        let weights: Vec<i32> = (0..k * k).map(|i| (i as i32 % 7) - 3).collect();
        let golden = conv2d_i32(&ifmap, h, w, &weights, k, stride, pad);
        let mut slice = SliceSim::new(k, w + 2 * pad);
        let r = slice.run_conv(&ifmap, h, w, &weights, pad, stride);
        assert_eq!(r.output, golden, "slice != golden for {h}x{w} k{k} p{pad} s{stride}");
        r.stats
    }

    #[test]
    fn matches_golden_3x3_same() {
        check(16, 16, 3, 1, 1);
    }

    #[test]
    fn matches_golden_3x3_valid() {
        check(12, 9, 3, 0, 1);
    }

    #[test]
    fn matches_golden_5x5() {
        check(14, 14, 5, 2, 1);
    }

    #[test]
    fn matches_golden_2x2() {
        check(8, 10, 2, 0, 1);
    }

    #[test]
    fn matches_golden_stride2() {
        check(13, 13, 3, 1, 2);
    }

    #[test]
    fn matches_golden_stride4_k11_like_alexnet_tile() {
        check(31, 31, 3, 0, 4);
    }

    #[test]
    fn reads_each_padded_element_once() {
        let s = check(20, 20, 3, 1, 1);
        assert_eq!(s.ext_input_reads, 22 * 22);
        // paper's §II claim at full scale is exercised in rust/tests/.
    }

    #[test]
    fn peak_bandwidth_is_2k_minus_1() {
        let s = check(10, 10, 3, 1, 1);
        assert_eq!(s.peak_ext_inputs_per_cycle, 5); // eq. (4)'s "5" for K=3
        let s = check(16, 16, 5, 2, 1);
        assert_eq!(s.peak_ext_inputs_per_cycle, 9); // 2K−1 generalisation
    }

    #[test]
    fn cycle_count_matches_eq2_per_step_term() {
        let (h, k, pad) = (18usize, 3usize, 1usize);
        let s = check(h, h, k, pad, 1);
        let h_o = h; // same conv
        let fill = (k - 1) as u64; // row skew
        let tree = AdderTree::new(k).latency() as u64;
        assert_eq!(s.cycles, k as u64 + (h_o * h_o) as u64 + fill + tree);
    }

    #[test]
    fn rsrb_occupancy_bounded_by_one_padded_row() {
        let s = check(24, 24, 3, 1, 1);
        assert!(s.max_rsrb_occupancy <= 26, "occ = {}", s.max_rsrb_occupancy);
    }

    #[test]
    #[should_panic(expected = "W_IM")]
    fn too_wide_ifmap_panics() {
        let ifmap = vec![0i32; 40 * 40];
        SliceSim::new(3, 32).run_conv(&ifmap, 40, 40, &[0; 9], 1, 1);
    }

    #[test]
    fn reused_slice_matches_fresh_slice() {
        // A long-lived slice (reset in place) must reproduce a fresh
        // slice's output AND stats bit-for-bit, across differing
        // geometries in sequence.
        let mut reused = SliceSim::new(3, 32);
        for (h, w, pad, stride) in [(10usize, 12usize, 1usize, 1usize), (8, 8, 0, 2), (12, 9, 1, 1)] {
            let ifmap: Vec<i32> = (0..h * w).map(|i| (i as i32 * 29 + 3) % 251 - 120).collect();
            let weights: Vec<i32> = (0..9).map(|i| (i as i32 % 7) - 3).collect();
            let a = reused.run_conv(&ifmap, h, w, &weights, pad, stride);
            let b = SliceSim::new(3, 32).run_conv(&ifmap, h, w, &weights, pad, stride);
            assert_eq!(a.output, b.output, "{h}x{w} p{pad} s{stride}");
            assert_eq!(a.stats, b.stats, "{h}x{w} p{pad} s{stride}");
        }
    }

    #[test]
    fn windowed_view_equals_materialised_window() {
        // The tiled path's strided view: an (hs × ws) window at (y0, x0)
        // inside a larger buffer must convolve exactly like the explicitly
        // materialised (zero-tailed) copy.
        let (src_h, src_w) = (14usize, 15usize);
        let buf: Vec<i32> = (0..src_h * src_w).map(|i| (i as i32 * 13 + 1) % 101 - 50).collect();
        let weights: Vec<i32> = (0..9).map(|i| (i as i32 % 5) - 2).collect();
        let (y0, x0, hs, ws) = (2usize, 3usize, 13usize, 13usize); // overhangs the buffer edge
        let mut sub = vec![0i32; hs * ws];
        for y in 0..hs {
            for x in 0..ws {
                let (sy, sx) = (y0 + y, x0 + x);
                if sy < src_h && sx < src_w {
                    sub[y * ws + x] = buf[sy * src_w + sx];
                }
            }
        }
        for stride in [1usize, 2] {
            let view = InputView::window(&buf, src_h, src_w, y0, x0, hs, ws);
            let a = SliceSim::new(3, 32).run_conv_view(&view, &weights, stride);
            let b = SliceSim::new(3, 32).run_conv(&sub, hs, ws, &weights, 0, stride);
            assert_eq!(a.output, b.output, "stride {stride}");
            assert_eq!(a.stats, b.stats, "stride {stride}");
        }
    }
}
