//! Hardware fault injection and ABFT (algorithm-based fault tolerance)
//! checksum detection.
//!
//! Deployed FPGAs suffer transient upsets — bit flips in PE MAC
//! results, shift-register (RSRB) corruption, and bad ifmap/weight
//! reads — that a functional simulator would otherwise serve as wrong
//! logits. This module provides both halves of the defence:
//!
//! * [`FaultConfig`] / [`FaultInjector`]: a deterministic, seeded fault
//!   plan. Whether a given (engine, shard) execution is corrupted is a
//!   pure function of `(seed, engine, effective layer signature)`, so a
//!   re-execution of the same shard on a *different* engine gets an
//!   independent draw while a retry on the same engine deterministically
//!   reproduces the fault. Zero-cost when disabled: the engine hook is a
//!   single `Option` test.
//! * [`AbftChecker`]: per-shard output checksums. For each filter the
//!   true output sum equals `Σ_{c,r,q} w[f,c,r,q] · T[c,r,q]` where
//!   `T[c,r,q]` is the sum of the input samples that tap `(r,q)` touches
//!   over the shard's output rows — the classic ABFT column-checksum
//!   identity specialised to strided, padded convolution. `T` is an O(1)
//!   rectangle query on stride-phase-decimated summed-area tables, so
//!   the whole check costs O(input) to build once per layer plus
//!   O(output + N·M·K²) per shard: noise next to the O(N·M·K²·H_o·W_o)
//!   convolution itself. The identity is exact in wrapping `i64`
//!   arithmetic, so every merged shard is verified, not sampled, with no
//!   false positives.

use std::ops::Range;
use std::sync::Arc;

use crate::golden::Tensor3;
use crate::model::ConvLayer;
use crate::obs::Counter;

/// Which hardware structure the injected upsets model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultModel {
    /// Transient single-bit flip in one PE MAC result.
    Pe,
    /// Stuck-at-1 upset in a shift-register buffer: an OR mask smeared
    /// across one output row (the RSRB feeds a whole row of PEs).
    Rsrb,
    /// Corrupted ifmap/weight read: a constant additive error folded
    /// into every output of one filter.
    Mem,
    /// Gray failure: the engine still answers *correctly* but late — a
    /// seeded deterministic per-(engine, shard) sleep stretches the
    /// shard's service time past its analytic budget.
    Slow,
    /// Gray failure: the shard never completes. The worker parks until
    /// its hedge duplicate wins (cancel flag) or the farm shuts down.
    Hang,
}

impl FaultModel {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultModel::Pe => "pe",
            FaultModel::Rsrb => "rsrb",
            FaultModel::Mem => "mem",
            FaultModel::Slow => "slow",
            FaultModel::Hang => "hang",
        }
    }

    /// Timing models delay or withhold output; they never corrupt
    /// values, so ABFT checksums stay clean under them by construction.
    pub fn is_timing(&self) -> bool {
        matches!(self, FaultModel::Slow | FaultModel::Hang)
    }
}

impl std::fmt::Display for FaultModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for FaultModel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pe" => Ok(FaultModel::Pe),
            "rsrb" => Ok(FaultModel::Rsrb),
            "mem" => Ok(FaultModel::Mem),
            "slow" => Ok(FaultModel::Slow),
            "hang" => Ok(FaultModel::Hang),
            other => Err(format!("unknown fault model '{other}' (expected pe|rsrb|mem|slow|hang)")),
        }
    }
}

/// Seeded fault-injection plan. `rate` is the per-(engine, shard)
/// probability that the shard's output is corrupted; `0.0` disables
/// injection entirely.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    pub rate: f64,
    pub seed: u64,
    pub model: FaultModel,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self { rate: 0.0, seed: 0xFA17_5EED, model: FaultModel::Pe }
    }
}

impl FaultConfig {
    pub fn disabled() -> Self {
        Self::default()
    }

    pub fn new(rate: f64, seed: u64, model: FaultModel) -> Self {
        Self { rate, seed, model }
    }

    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Deterministic Bernoulli draw keyed by `key`: fires with
    /// probability `rate` under this plan's seed. The farm keys its
    /// draws by (engine, shard signature); coarser harnesses — e.g. the
    /// [`crate::coordinator::testing`] backend double — key by call
    /// index. Same plan + same key → same verdict, always.
    pub fn draw(&self, key: u64) -> bool {
        self.enabled() && unit_f64(mix(mix(self.seed, key), 0x5EED_CA11)) < self.rate
    }

    /// Timing-chaos draw for one (engine, shard) execution. Returns the
    /// gray failure to stage, or `None` when the model is a value model,
    /// the plan is disabled, or the draw does not fire. Deterministic:
    /// the same (seed, engine, layer, shard) always yields the same
    /// verdict, so a hedge duplicate picked up by a *different* engine
    /// gets an independent draw while a retry on the same engine
    /// reproduces the stall. Zero-cost when disabled (one branch).
    pub fn timing_fault(
        &self,
        engine: usize,
        layer: &ConvLayer,
        filters: &Range<usize>,
        rows: &Range<usize>,
    ) -> Option<TimingFault> {
        if !self.enabled() || !self.model.is_timing() {
            return None;
        }
        let mut key = fault_key(self.seed, engine, layer);
        key = mix(key, ((filters.start as u64) << 32) | filters.end as u64);
        key = mix(key, ((rows.start as u64) << 32) | rows.end as u64);
        if unit_f64(key) >= self.rate {
            return None;
        }
        match self.model {
            FaultModel::Slow => {
                // Independent stream so changing the rate never changes
                // *how slow* a firing draw is: 2–8 ms, far past any
                // tiny-workload shard budget yet cheap in tests.
                let micros = 2_000 + mix(key, 0x510_DEAD) % 6_000;
                Some(TimingFault::Slow { micros })
            }
            _ => Some(TimingFault::Hang),
        }
    }
}

/// A staged gray failure for one (engine, shard) execution, drawn by
/// [`FaultConfig::timing_fault`]. The scheduler (not the engine) applies
/// it: the value pipeline — and therefore the ABFT checksum — is
/// untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimingFault {
    /// Sleep `micros` before executing the shard (answer is late but
    /// correct).
    Slow { micros: u64 },
    /// Never complete: park until cancelled or shut down.
    Hang,
}

/// SplitMix64-finalizer mixing step (same constants as
/// [`crate::util::SplitMix64`]), used to key fault draws.
#[inline]
fn mix(mut h: u64, v: u64) -> u64 {
    h ^= v;
    h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 32;
    h
}

/// Deterministic key for one (engine, effective-layer) execution. The
/// effective layer name already encodes the shard (`run_shard_shared`
/// names sub-layers `"{name}[f{a}..{b}]"` / `"{name}[r{a}..{b}]"`), so
/// the key uniquely identifies a shard regardless of work-stealing
/// order.
fn fault_key(seed: u64, engine: usize, layer: &ConvLayer) -> u64 {
    let mut h = mix(seed, engine as u64);
    for b in layer.name.as_bytes() {
        h = mix(h, *b as u64);
    }
    h = mix(h, layer.h_i as u64);
    h = mix(h, layer.w_i as u64);
    h = mix(h, ((layer.k as u64) << 32) | layer.stride as u64);
    h = mix(h, ((layer.pad as u64) << 32) | layer.m as u64);
    mix(h, layer.n as u64)
}

#[inline]
fn unit_f64(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Per-engine fault injector, attached to an `EngineSim` when chaos
/// testing is enabled. Each call site passes the effective layer it just
/// executed plus the produced ofmaps; corruption is applied in place.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    cfg: FaultConfig,
    engine: usize,
    injected: Arc<Counter>,
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig, engine: usize, injected: Arc<Counter>) -> Self {
        Self { cfg, engine, injected }
    }

    pub fn config(&self) -> FaultConfig {
        self.cfg
    }

    pub fn engine(&self) -> usize {
        self.engine
    }

    /// Number of fault events that actually corrupted output so far.
    pub fn injected(&self) -> u64 {
        self.injected.get()
    }

    /// Corrupt `ofmaps` in place iff this (engine, layer) execution
    /// draws a fault. Returns `true` when at least one output element
    /// actually changed (a stuck-at-1 mask over already-set bits is
    /// benign and is not counted as injected).
    pub fn maybe_corrupt(&self, layer: &ConvLayer, ofmaps: &mut Tensor3) -> bool {
        // Timing models are handled by the scheduler (sleep/park around
        // the execution) and never touch output values.
        if !self.cfg.enabled() || self.cfg.model.is_timing() || ofmaps.data.is_empty() {
            return false;
        }
        let key = fault_key(self.cfg.seed, self.engine, layer);
        if unit_f64(key) >= self.cfg.rate {
            return false;
        }
        // Derive the corruption parameters from an independent stream so
        // changing the rate never changes *which* corruption fires.
        let mut rng = crate::util::SplitMix64::new(mix(key, 0xC0DE_D00D));
        let changed = match self.cfg.model {
            FaultModel::Pe => corrupt_pe(&mut rng, ofmaps),
            FaultModel::Rsrb => corrupt_rsrb(&mut rng, ofmaps),
            FaultModel::Mem => corrupt_mem(&mut rng, ofmaps),
            // Unreachable (guarded above), but keep the match total.
            FaultModel::Slow | FaultModel::Hang => 0,
        };
        if changed > 0 {
            self.injected.inc();
            true
        } else {
            false
        }
    }
}

/// Single-bit flip in one output element (one PE's MAC result).
fn corrupt_pe(rng: &mut crate::util::SplitMix64, ofmaps: &mut Tensor3) -> u64 {
    let idx = (rng.next_u64() % ofmaps.data.len() as u64) as usize;
    let bit = (rng.next_u64() % 32) as u32;
    ofmaps.data[idx] ^= 1i32 << bit;
    1
}

/// Stuck-at-1 OR mask across one output row of one filter. Only bits
/// below the sign bit are stuck so every flipped element strictly
/// increases — the per-filter sum delta can never cancel to zero.
fn corrupt_rsrb(rng: &mut crate::util::SplitMix64, ofmaps: &mut Tensor3) -> u64 {
    let f = (rng.next_u64() % ofmaps.c as u64) as usize;
    let y = (rng.next_u64() % ofmaps.h as u64) as usize;
    let mask = 1i32 << (rng.next_u64() % 31) as u32;
    let start = (f * ofmaps.h + y) * ofmaps.w;
    let mut changed = 0u64;
    for v in &mut ofmaps.data[start..start + ofmaps.w] {
        if *v & mask == 0 {
            *v |= mask;
            changed += 1;
        }
    }
    changed
}

/// Constant additive error over one filter's whole output channel,
/// modelling a corrupted weight/ifmap read folded into every MAC that
/// consumed it. The delta is non-zero so every element changes.
fn corrupt_mem(rng: &mut crate::util::SplitMix64, ofmaps: &mut Tensor3) -> u64 {
    let f = (rng.next_u64() % ofmaps.c as u64) as usize;
    let mut delta = (rng.next_u64() % 255) as i32 - 127;
    if delta == 0 {
        delta = 1;
    }
    let plane = ofmaps.h * ofmaps.w;
    let start = f * plane;
    for v in &mut ofmaps.data[start..start + plane] {
        *v = v.wrapping_add(delta);
    }
    plane as u64
}

/// Aggregated fault-tolerance counters, shaped like `CanaryReport` so
/// they flow through the same snapshot/merge/delta plumbing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Fault events that actually corrupted engine output.
    pub injected: u64,
    /// ABFT checksum mismatches (or worker failures) observed at merge.
    pub detected: u64,
    /// Shards healed to a bit-exact result via re-execution.
    pub corrected: u64,
    /// Re-execution attempts dispatched.
    pub reexecuted: u64,
    /// Engines quarantined after crossing the failure threshold.
    pub quarantined: u64,
    /// Hedge duplicates injected for shards past their service budget.
    pub hedged: u64,
    /// Hedge losers: duplicate completions discarded at the merge point.
    pub hedge_wasted: u64,
    /// Shards whose *winning* result came from the hedge duplicate.
    pub hedge_won: u64,
    /// Distinct shards observed past their analytic service budget.
    pub stragglers_detected: u64,
    /// Engines quarantined for persistent straggling (timing, not value).
    pub timing_quarantined: u64,
}

impl FaultReport {
    pub fn merge(&mut self, other: &FaultReport) {
        self.injected = self.injected.saturating_add(other.injected);
        self.detected = self.detected.saturating_add(other.detected);
        self.corrected = self.corrected.saturating_add(other.corrected);
        self.reexecuted = self.reexecuted.saturating_add(other.reexecuted);
        self.quarantined = self.quarantined.saturating_add(other.quarantined);
        self.hedged = self.hedged.saturating_add(other.hedged);
        self.hedge_wasted = self.hedge_wasted.saturating_add(other.hedge_wasted);
        self.hedge_won = self.hedge_won.saturating_add(other.hedge_won);
        self.stragglers_detected = self.stragglers_detected.saturating_add(other.stragglers_detected);
        self.timing_quarantined = self.timing_quarantined.saturating_add(other.timing_quarantined);
    }

    /// Counters accrued since `prev` (both must be cumulative totals).
    pub fn delta_since(&self, prev: &FaultReport) -> FaultReport {
        FaultReport {
            injected: self.injected.saturating_sub(prev.injected),
            detected: self.detected.saturating_sub(prev.detected),
            corrected: self.corrected.saturating_sub(prev.corrected),
            reexecuted: self.reexecuted.saturating_sub(prev.reexecuted),
            quarantined: self.quarantined.saturating_sub(prev.quarantined),
            hedged: self.hedged.saturating_sub(prev.hedged),
            hedge_wasted: self.hedge_wasted.saturating_sub(prev.hedge_wasted),
            hedge_won: self.hedge_won.saturating_sub(prev.hedge_won),
            stragglers_detected: self.stragglers_detected.saturating_sub(prev.stragglers_detected),
            timing_quarantined: self.timing_quarantined.saturating_sub(prev.timing_quarantined),
        }
    }

    pub fn is_clean(&self) -> bool {
        self.detected == 0 && self.quarantined == 0 && self.timing_quarantined == 0
    }
}

/// Engine health as tracked by the self-healing farm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineHealth {
    Healthy,
    /// At least one fault attributed, below the quarantine threshold.
    Suspect,
    /// Straggler strikes dominate: the engine answers correctly but
    /// late relative to the analytic cycle model.
    Slow,
    /// Crossed the threshold; receives no further work.
    Quarantined,
}

impl EngineHealth {
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineHealth::Healthy => "healthy",
            EngineHealth::Suspect => "suspect",
            EngineHealth::Slow => "slow",
            EngineHealth::Quarantined => "quarantined",
        }
    }
}

/// One detected checksum violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AbftMismatch {
    /// Absolute filter index (in the full layer's filter space).
    pub filter: usize,
    pub expected: i64,
    pub actual: i64,
}

/// Summed-area table over one stride-phase decimation of one input
/// channel: entry `(a, b)` covers input sample `(py + a·s, px + b·s)`.
struct PhaseSat {
    rows: usize,
    cols: usize,
    /// `(rows+1) × (cols+1)` inclusive prefix, wrapping `i64`.
    sat: Vec<i64>,
}

impl PhaseSat {
    fn build(input: &Tensor3, c: usize, py: usize, px: usize, s: usize) -> Self {
        let rows = if py < input.h { (input.h - py).div_ceil(s) } else { 0 };
        let cols = if px < input.w { (input.w - px).div_ceil(s) } else { 0 };
        let mut sat = vec![0i64; (rows + 1) * (cols + 1)];
        let pitch = cols + 1;
        for a in 0..rows {
            let mut row_acc = 0i64;
            for b in 0..cols {
                row_acc = row_acc.wrapping_add(input.get(c, py + a * s, px + b * s) as i64);
                sat[(a + 1) * pitch + (b + 1)] = sat[a * pitch + (b + 1)].wrapping_add(row_acc);
            }
        }
        Self { rows, cols, sat }
    }

    /// Sum over `a ∈ [a0, a1) × b ∈ [b0, b1)` (clamped to the table).
    fn rect(&self, a0: isize, a1: isize, b0: isize, b1: isize) -> i64 {
        let a0 = a0.clamp(0, self.rows as isize) as usize;
        let a1 = a1.clamp(0, self.rows as isize) as usize;
        let b0 = b0.clamp(0, self.cols as isize) as usize;
        let b1 = b1.clamp(0, self.cols as isize) as usize;
        if a0 >= a1 || b0 >= b1 {
            return 0;
        }
        let p = self.cols + 1;
        self.sat[a1 * p + b1]
            .wrapping_sub(self.sat[a0 * p + b1])
            .wrapping_sub(self.sat[a1 * p + b0])
            .wrapping_add(self.sat[a0 * p + b0])
    }
}

/// Per-layer ABFT checker. Built once per `(layer, input)` at the
/// farm's shard-merge point; `check` then verifies each merged shard
/// against the filter-sum identity in O(output + N·M·K²).
pub struct AbftChecker {
    k: usize,
    stride: usize,
    pad: usize,
    m: usize,
    h_o: usize,
    w_o: usize,
    /// `m × stride × stride` phase tables, indexed `(c·s + py)·s + px`.
    sats: Vec<PhaseSat>,
}

impl AbftChecker {
    pub fn new(layer: &ConvLayer, input: &Tensor3) -> Self {
        assert_eq!(
            (input.c, input.h, input.w),
            (layer.m, layer.h_i, layer.w_i),
            "ABFT checker input does not match layer {}",
            layer.name
        );
        let s = layer.stride;
        let mut sats = Vec::with_capacity(layer.m * s * s);
        for c in 0..layer.m {
            for py in 0..s {
                for px in 0..s {
                    sats.push(PhaseSat::build(input, c, py, px, s));
                }
            }
        }
        Self {
            k: layer.k,
            stride: s,
            pad: layer.pad,
            m: layer.m,
            h_o: layer.h_o(),
            w_o: layer.w_o(),
            sats,
        }
    }

    /// Tap sums `T[c, r, q]` for output rows `[rows)` over the full
    /// output width: the sum of every input sample that kernel tap
    /// `(r, q)` multiplies across those output positions.
    fn tap_sums(&self, rows: &Range<usize>) -> Vec<i64> {
        let s = self.stride as isize;
        let k = self.k;
        let mut taps = vec![0i64; self.m * k * k];
        for r in 0..k {
            let dy = r as isize - self.pad as isize;
            let py = dy.rem_euclid(s) as usize;
            let off_y = (dy - py as isize) / s;
            let a0 = rows.start as isize + off_y;
            let a1 = rows.end as isize + off_y;
            for q in 0..k {
                let dx = q as isize - self.pad as isize;
                let px = dx.rem_euclid(s) as usize;
                let off_x = (dx - px as isize) / s;
                let b0 = off_x;
                let b1 = self.w_o as isize + off_x;
                for c in 0..self.m {
                    let sat = &self.sats[(c * self.stride + py) * self.stride + px];
                    taps[(c * k + r) * k + q] = sat.rect(a0, a1, b0, b1);
                }
            }
        }
        taps
    }

    /// Verify a shard's ofmap block (filters `filters`, output rows
    /// `rows`, full width) against the checksum identity. `weights` is
    /// the full layer's `[N][M][K][K]` tensor. Returns the first
    /// mismatching filter, or `None` when every checksum holds.
    pub fn check(
        &self,
        weights: &[i32],
        filters: &Range<usize>,
        rows: &Range<usize>,
        ofmaps: &Tensor3,
    ) -> Option<AbftMismatch> {
        debug_assert_eq!(ofmaps.c, filters.len());
        debug_assert_eq!(ofmaps.h, rows.len());
        debug_assert_eq!(ofmaps.w, self.w_o);
        let taps = self.tap_sums(rows);
        let kk = self.k * self.k;
        let plane = ofmaps.h * ofmaps.w;
        for (i, f) in filters.clone().enumerate() {
            let mut expected = 0i64;
            let w_f = &weights[f * self.m * kk..(f + 1) * self.m * kk];
            for (w, t) in w_f.iter().zip(taps.iter()) {
                expected = expected.wrapping_add((*w as i64).wrapping_mul(*t));
            }
            let mut actual = 0i64;
            for v in &ofmaps.data[i * plane..(i + 1) * plane] {
                actual = actual.wrapping_add(*v as i64);
            }
            if actual != expected {
                return Some(AbftMismatch { filter: f, expected, actual });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv3d_i32;
    use crate::util::SplitMix64;

    fn random_input(m: usize, h: usize, w: usize, seed: u64) -> Tensor3 {
        let mut rng = SplitMix64::new(seed);
        Tensor3::from_fn(m, h, w, |_, _, _| rng.range_i32(-9, 9))
    }

    fn random_weights(n: usize, m: usize, k: usize, seed: u64) -> Vec<i32> {
        SplitMix64::new(seed).vec_i32(n * m * k * k, -4, 8)
    }

    /// Extract the `[filters) × [rows) × full-width` block of a full
    /// ofmap tensor, exactly as a farm shard would produce it.
    fn shard_block(full: &Tensor3, filters: &Range<usize>, rows: &Range<usize>) -> Tensor3 {
        Tensor3::from_fn(filters.len(), rows.len(), full.w, |f, y, x| {
            full.get(filters.start + f, rows.start + y, x)
        })
    }

    fn geometries() -> Vec<ConvLayer> {
        vec![
            ConvLayer::new("g-s1", 8, 3, 3, 4, 1, 0),
            ConvLayer::new("g-s1-pad", 9, 3, 2, 5, 1, 1),
            ConvLayer::new("g-s2-pad", 11, 3, 3, 4, 2, 1),
            ConvLayer::new("g-s2", 10, 3, 2, 3, 2, 0),
            ConvLayer::new("g-k5", 12, 5, 2, 3, 1, 2),
            ConvLayer::new("g-s3", 13, 3, 2, 4, 3, 1),
        ]
    }

    #[test]
    fn abft_accepts_golden_output_across_geometries() {
        for layer in geometries() {
            let input = random_input(layer.m, layer.h_i, layer.w_i, 7);
            let weights = random_weights(layer.n, layer.m, layer.k, 11);
            let full = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);
            let checker = AbftChecker::new(&layer, &input);
            let all_f = 0..layer.n;
            let all_r = 0..layer.h_o();
            assert_eq!(
                checker.check(&weights, &all_f, &all_r, &full),
                None,
                "false positive on {}",
                layer.name
            );
        }
    }

    #[test]
    fn abft_accepts_golden_shard_blocks() {
        for layer in geometries() {
            let input = random_input(layer.m, layer.h_i, layer.w_i, 23);
            let weights = random_weights(layer.n, layer.m, layer.k, 29);
            let full = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);
            let checker = AbftChecker::new(&layer, &input);
            let h_o = layer.h_o();
            // Filter shard, row shard, and a joint (hybrid-style) block.
            let cases = vec![
                (1..layer.n, 0..h_o),
                (0..layer.n, h_o / 2..h_o),
                (0..1, 1..h_o.max(2) - 1),
            ];
            for (filters, rows) in cases {
                if filters.is_empty() || rows.is_empty() {
                    continue;
                }
                let block = shard_block(&full, &filters, &rows);
                assert_eq!(
                    checker.check(&weights, &filters, &rows, &block),
                    None,
                    "false positive on {} shard f{filters:?} r{rows:?}",
                    layer.name
                );
            }
        }
    }

    #[test]
    fn abft_detects_every_fault_model() {
        let layer = ConvLayer::new("chaos", 11, 3, 3, 4, 2, 1);
        let input = random_input(layer.m, layer.h_i, layer.w_i, 41);
        let weights = random_weights(layer.n, layer.m, layer.k, 43);
        let full = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);
        let checker = AbftChecker::new(&layer, &input);
        let filters = 0..layer.n;
        let rows = 0..layer.h_o();
        for model in [FaultModel::Pe, FaultModel::Rsrb, FaultModel::Mem] {
            let inj = FaultInjector::new(
                FaultConfig::new(1.0, 77, model),
                0,
                Arc::new(Counter::new()),
            );
            let mut block = shard_block(&full, &filters, &rows);
            assert!(inj.maybe_corrupt(&layer, &mut block), "{model} did not fire at rate 1");
            assert_eq!(inj.injected(), 1);
            let miss = checker.check(&weights, &filters, &rows, &block);
            assert!(miss.is_some(), "{model} corruption escaped the checksum");
        }
    }

    #[test]
    fn fault_draws_are_deterministic_and_engine_keyed() {
        let layer = ConvLayer::new("det", 9, 3, 2, 3, 1, 1);
        let input = random_input(layer.m, layer.h_i, layer.w_i, 5);
        let weights = random_weights(layer.n, layer.m, layer.k, 6);
        let full = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);
        let cfg = FaultConfig::new(1.0, 99, FaultModel::Pe);
        let corrupt_on = |engine: usize| {
            let inj = FaultInjector::new(cfg, engine, Arc::new(Counter::new()));
            let mut t = full.clone();
            inj.maybe_corrupt(&layer, &mut t);
            t
        };
        // Same engine → identical corruption; different engine →
        // an independent draw (at rate 1 both fire, differently).
        assert_eq!(corrupt_on(0), corrupt_on(0));
        assert_ne!(corrupt_on(0), corrupt_on(1));
        // Rate 0 is a no-op and counts nothing.
        let off = FaultInjector::new(FaultConfig::disabled(), 0, Arc::new(Counter::new()));
        let mut t = full.clone();
        assert!(!off.maybe_corrupt(&layer, &mut t));
        assert_eq!(t, full);
        assert_eq!(off.injected(), 0);
    }

    #[test]
    fn fault_rate_is_respected_in_aggregate() {
        let cfg = FaultConfig::new(0.25, 1234, FaultModel::Mem);
        let mut fired = 0usize;
        let total = 400usize;
        for i in 0..total {
            let layer = ConvLayer::new(&format!("agg{i}"), 8, 3, 2, 2, 1, 1);
            let inj = FaultInjector::new(cfg, i % 4, Arc::new(Counter::new()));
            // Zero tensor: the mem model always changes every element.
            let mut t = Tensor3::zeros(2, 6, 4);
            if inj.maybe_corrupt(&layer, &mut t) {
                fired += 1;
            }
        }
        let frac = fired as f64 / total as f64;
        assert!(
            (0.15..=0.35).contains(&frac),
            "rate 0.25 produced empirical rate {frac} ({fired}/{total})"
        );
    }

    #[test]
    fn report_merge_and_delta() {
        let mut a = FaultReport {
            injected: 3,
            detected: 2,
            corrected: 2,
            reexecuted: 4,
            quarantined: 0,
            hedged: 2,
            hedge_wasted: 1,
            hedge_won: 1,
            stragglers_detected: 2,
            timing_quarantined: 0,
        };
        let b = FaultReport {
            injected: 1,
            detected: 1,
            corrected: 0,
            reexecuted: 1,
            quarantined: 1,
            hedged: 1,
            hedge_wasted: 0,
            hedge_won: 1,
            stragglers_detected: 1,
            timing_quarantined: 1,
        };
        a.merge(&b);
        assert_eq!(
            a,
            FaultReport {
                injected: 4,
                detected: 3,
                corrected: 2,
                reexecuted: 5,
                quarantined: 1,
                hedged: 3,
                hedge_wasted: 1,
                hedge_won: 2,
                stragglers_detected: 3,
                timing_quarantined: 1,
            }
        );
        let prev = FaultReport {
            injected: 2,
            detected: 1,
            corrected: 1,
            reexecuted: 2,
            hedged: 1,
            stragglers_detected: 1,
            ..FaultReport::default()
        };
        let d = a.delta_since(&prev);
        assert_eq!(
            d,
            FaultReport {
                injected: 2,
                detected: 2,
                corrected: 1,
                reexecuted: 3,
                quarantined: 1,
                hedged: 2,
                hedge_wasted: 1,
                hedge_won: 2,
                stragglers_detected: 2,
                timing_quarantined: 1,
            }
        );
        assert!(!a.is_clean());
        assert!(FaultReport::default().is_clean());
        // Timing quarantine alone degrades the report.
        let slow = FaultReport { timing_quarantined: 1, ..FaultReport::default() };
        assert!(!slow.is_clean());
        // Hedging without quarantine is still clean: wasted work, not
        // wrong answers.
        let hedgy = FaultReport {
            hedged: 5,
            hedge_wasted: 3,
            hedge_won: 2,
            stragglers_detected: 5,
            ..FaultReport::default()
        };
        assert!(hedgy.is_clean());
    }

    #[test]
    fn fault_model_round_trips_from_str() {
        for m in [FaultModel::Pe, FaultModel::Rsrb, FaultModel::Mem, FaultModel::Slow, FaultModel::Hang] {
            assert_eq!(m.as_str().parse::<FaultModel>(), Ok(m));
        }
        assert!("cosmic".parse::<FaultModel>().is_err());
    }

    #[test]
    fn timing_models_never_corrupt_values() {
        let layer = ConvLayer::new("timing", 9, 3, 2, 3, 1, 1);
        let input = random_input(layer.m, layer.h_i, layer.w_i, 5);
        let weights = random_weights(layer.n, layer.m, layer.k, 6);
        let full = conv3d_i32(&input, &weights, layer.n, layer.k, layer.stride, layer.pad);
        for model in [FaultModel::Slow, FaultModel::Hang] {
            let inj = FaultInjector::new(
                FaultConfig::new(1.0, 77, model),
                0,
                Arc::new(Counter::new()),
            );
            let mut t = full.clone();
            assert!(!inj.maybe_corrupt(&layer, &mut t), "{model} corrupted values");
            assert_eq!(t, full);
            assert_eq!(inj.injected(), 0);
        }
    }

    #[test]
    fn timing_fault_draws_are_deterministic_shard_keyed_and_rate_bounded() {
        let layer = ConvLayer::new("tdraw", 16, 3, 3, 8, 1, 1);
        let cfg = FaultConfig::new(1.0, 42, FaultModel::Slow);
        let d0 = cfg.timing_fault(0, &layer, &(0..8), &(0..14));
        // Same key → same verdict (and the same sleep length).
        assert_eq!(d0, cfg.timing_fault(0, &layer, &(0..8), &(0..14)));
        match d0 {
            Some(TimingFault::Slow { micros }) => {
                assert!((2_000..8_000).contains(&micros), "sleep {micros}µs out of range")
            }
            other => panic!("rate-1 slow draw did not fire: {other:?}"),
        }
        // Hang model fires as Hang.
        let hang = FaultConfig::new(1.0, 42, FaultModel::Hang);
        assert_eq!(hang.timing_fault(3, &layer, &(0..8), &(0..14)), Some(TimingFault::Hang));
        // Value models and disabled plans never stage timing faults.
        let pe = FaultConfig::new(1.0, 42, FaultModel::Pe);
        assert_eq!(pe.timing_fault(0, &layer, &(0..8), &(0..14)), None);
        assert_eq!(
            FaultConfig::new(0.0, 42, FaultModel::Hang).timing_fault(0, &layer, &(0..8), &(0..14)),
            None
        );
        // Aggregate rate over many distinct (engine, shard) keys.
        let sparse = FaultConfig::new(0.25, 1234, FaultModel::Slow);
        let mut fired = 0usize;
        let total = 400usize;
        for i in 0..total {
            let l = ConvLayer::new(&format!("tagg{i}"), 8, 3, 2, 2, 1, 1);
            if sparse.timing_fault(i % 4, &l, &(0..8), &(0..6)).is_some() {
                fired += 1;
            }
        }
        let frac = fired as f64 / total as f64;
        assert!(
            (0.15..=0.35).contains(&frac),
            "rate 0.25 produced empirical timing rate {frac} ({fired}/{total})"
        );
        // Shard-keyed: different filter ranges draw independently (at
        // rate ~0.5 over 64 shards, at least one pair must differ).
        let half = FaultConfig::new(0.5, 9, FaultModel::Slow);
        let verdicts: Vec<bool> =
            (0..64).map(|f| half.timing_fault(0, &layer, &(f..f + 1), &(0..14)).is_some()).collect();
        assert!(verdicts.iter().any(|v| *v) && verdicts.iter().any(|v| !*v));
    }

    #[test]
    fn config_draw_is_deterministic_and_rate_bounded() {
        let cfg = FaultConfig::new(0.25, 99, FaultModel::Pe);
        let fired = (0..4000u64).filter(|&k| cfg.draw(k)).count();
        assert_eq!(fired, (0..4000u64).filter(|&k| cfg.draw(k)).count(), "same key → same verdict");
        let frac = fired as f64 / 4000.0;
        assert!((0.15..=0.35).contains(&frac), "empirical rate {frac} too far from 0.25");
        assert!(!FaultConfig::disabled().draw(7), "disabled plans never fire");
        assert!((0..64u64).all(|k| FaultConfig::new(1.0, 3, FaultModel::Mem).draw(k)));
    }
}
