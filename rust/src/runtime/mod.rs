//! PJRT runtime: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`), compile them on the CPU PJRT client and
//! execute them from the coordinator's hot path. Python is never involved
//! at run time.
//!
//! The PJRT client needs the `xla` crate, which is not available in the
//! offline build environment, so the real client lives behind the `pjrt`
//! cargo feature (see Cargo.toml). Without the feature an API-compatible
//! stub is compiled instead: artifact/manifest parsing still works, but
//! `Runtime` construction returns a descriptive error, which the serving
//! layer turns into a fallback onto the simulated engine farm
//! ([`crate::scheduler`]).

pub mod artifact;

#[cfg(feature = "pjrt")]
pub mod client;

#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{LoadedModule, Runtime};
