//! PJRT runtime: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`), compile them on the CPU PJRT client and
//! execute them from the coordinator's hot path. Python is never involved
//! at run time.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{LoadedModule, Runtime};
