//! Artifact manifest: the shape contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! Format (one line per artifact):
//! ```text
//! # trim-sa artifact manifest v1
//! artifact <name> file=<rel-path> inputs=i32:3x32x32[,i32:...] outputs=i32:10
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Element type + shape of one runtime tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Only `i32` is used by the current artifacts (uint8 activations are
    /// carried as int32 at the boundary — see python/compile/model.py).
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Parse `"i32:3x32x32"`.
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, dims) = s.split_once(':').ok_or_else(|| anyhow!("bad tensor spec {s:?}"))?;
        let shape = dims
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        if shape.iter().any(|&d| d == 0) {
            bail!("zero dim in {s:?}");
        }
        Ok(Self { dtype: dtype.to_string(), shape })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Shape as i64 (what `Literal::reshape` wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for unit testing).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = vec![];
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap_or_default();
            if tag != "artifact" {
                bail!("line {}: unknown tag {tag:?}", lno + 1);
            }
            let name = parts.next().ok_or_else(|| anyhow!("line {}: missing name", lno + 1))?;
            let mut file = None;
            let mut inputs = None;
            let mut output = None;
            for kv in parts {
                let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("line {}: bad kv {kv:?}", lno + 1))?;
                match k {
                    "file" => file = Some(dir.join(v)),
                    "inputs" => {
                        inputs = Some(v.split(',').map(TensorSpec::parse).collect::<Result<Vec<_>>>()?)
                    }
                    "outputs" => output = Some(TensorSpec::parse(v)?),
                    _ => bail!("line {}: unknown key {k:?}", lno + 1),
                }
            }
            artifacts.push(ArtifactSpec {
                name: name.to_string(),
                file: file.ok_or_else(|| anyhow!("{name}: missing file"))?,
                inputs: inputs.ok_or_else(|| anyhow!("{name}: missing inputs"))?,
                output: output.ok_or_else(|| anyhow!("{name}: missing outputs"))?,
            });
        }
        Ok(Self { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# trim-sa artifact manifest v1
artifact block0 file=block0.hlo.txt inputs=i32:3x32x32 outputs=i32:16x16x16
artifact conv file=c.hlo.txt inputs=i32:2x8x8,i32:3x2x3x3 outputs=i32:3x8x8
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let c = m.get("conv").unwrap();
        assert_eq!(c.inputs.len(), 2);
        assert_eq!(c.inputs[1].shape, vec![3, 2, 3, 3]);
        assert_eq!(c.output.elems(), 3 * 8 * 8);
        assert_eq!(c.file, PathBuf::from("/a/c.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("artifact x file=f", PathBuf::new()).is_err()); // no io
        assert!(Manifest::parse("widget x", PathBuf::new()).is_err());
        assert!(TensorSpec::parse("i32:0x3").is_err());
        assert!(TensorSpec::parse("3x3").is_err());
    }

    #[test]
    fn tensor_spec_helpers() {
        let t = TensorSpec::parse("i32:4x5x6").unwrap();
        assert_eq!(t.elems(), 120);
        assert_eq!(t.dims_i64(), vec![4, 5, 6]);
    }
}
