//! Offline stub of the PJRT client (compiled when the `pjrt` feature is
//! disabled — see runtime/mod.rs and Cargo.toml).
//!
//! Keeps the whole `runtime` API surface compiling without the `xla`
//! crate: manifest parsing is untouched, but actually constructing a
//! [`Runtime`] fails with an error explaining how to get PJRT execution
//! (enable the feature) or how to serve without it (`--backend sim`).

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::{bail, Result};

/// Stub of one compiled artifact. Never constructed (a stub [`Runtime`]
/// cannot be built), but the type keeps call-site signatures identical to
/// the real client.
pub struct LoadedModule {
    pub spec: ArtifactSpec,
}

impl LoadedModule {
    /// Always fails: there is no executable behind the stub.
    pub fn run_i32(&self, _inputs: &[&[i32]]) -> Result<Vec<i32>> {
        bail!(
            "{}: PJRT execution not compiled in (enable the `pjrt` cargo feature)",
            self.spec.name
        )
    }
}

/// Stub runtime: construction always fails with a descriptive error.
pub struct Runtime {}

impl Runtime {
    /// Parse the manifest, then report that PJRT execution is unavailable.
    /// Parsing first preserves the real client's error for a missing
    /// artifacts directory (the more actionable message).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        bail!(
            "cannot compile artifacts from {:?}: PJRT execution not compiled in \
             (the `xla` crate is gated behind the `pjrt` cargo feature; \
             serve through the simulator instead: `trim serve --backend sim`)",
            manifest.dir
        )
    }

    /// Backend identification (mirrors the real client's API).
    pub fn platform(&self) -> String {
        "pjrt-unavailable".to_string()
    }

    pub fn module(&self, name: &str) -> Result<&LoadedModule> {
        bail!("module {name:?} unavailable: PJRT execution not compiled in")
    }

    pub fn module_names(&self) -> Vec<&str> {
        Vec::new()
    }
}
