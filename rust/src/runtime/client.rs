//! PJRT client wrapper: HLO text → compiled executable → execution.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts take int32 tensors and
//! return a 1-tuple (lowered with `return_tuple=True`).

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// One compiled artifact.
pub struct LoadedModule {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModule {
    /// Execute with flat int32 buffers (one per declared input).
    /// Returns the flat int32 output.
    pub fn run_i32(&self, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("{}: expected {} inputs, got {}", self.spec.name, self.spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, tspec) in inputs.iter().zip(&self.spec.inputs) {
            if buf.len() != tspec.elems() {
                bail!("{}: input size {} != spec {:?}", self.spec.name, buf.len(), tspec.shape);
            }
            literals.push(xla::Literal::vec1(buf).reshape(&tspec.dims_i64())?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // return_tuple=True on the jax side
        let values = out.to_vec::<i32>()?;
        if values.len() != self.spec.output.elems() {
            bail!("{}: output size {} != spec {:?}", self.spec.name, values.len(), self.spec.output.shape);
        }
        Ok(values)
    }
}

/// The runtime: a PJRT CPU client plus all compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    modules: HashMap<String, LoadedModule>,
}

impl Runtime {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Load and compile from a parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut modules = HashMap::new();
        for spec in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", spec.name))?;
            modules.insert(spec.name.clone(), LoadedModule { spec: spec.clone(), exe });
        }
        Ok(Self { client, modules })
    }

    /// Backend identification (e.g. "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn module(&self, name: &str) -> Result<&LoadedModule> {
        self.modules.get(name).with_context(|| format!("module {name:?} not loaded"))
    }

    pub fn module_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.modules.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}
