//! `obs` — std-only observability primitives for the serving stack.
//!
//! Two halves, both allocation-light and lock-cheap enough for the farm
//! hot path:
//!
//! * **Tracer** — a span/event tracer with monotonic microsecond
//!   timestamps (relative to the tracer's epoch), parent-linked span IDs
//!   allocated from one atomic, a bounded ring-buffer sink (oldest
//!   events are dropped and counted, never blocking the producer) and a
//!   JSON-lines export (`trim trace`). A process-global instance is
//!   available via [`tracer()`]; unit tests construct their own.
//! * **Metrics registry** — saturating [`Counter`]s, [`Gauge`]s and
//!   log₂-bucketed [`Histogram`]s, optionally grouped in a name-keyed
//!   [`Registry`] with get-or-create semantics so hot paths resolve an
//!   `Arc` handle once and never touch the map again.
//!   [`crate::coordinator::ServeMetrics`] builds on these types instead
//!   of keeping its own ad-hoc `u64` fields.
//!
//! Everything here is `std`-only (the crate builds offline) and every
//! accumulation saturates — a soak run must degrade to a pegged counter,
//! not a wrap or a debug-build panic.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Counters / gauges / histograms
// ---------------------------------------------------------------------------

/// Monotonic saturating counter (never wraps, even at `u64::MAX`).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Add `n`, saturating at `u64::MAX`.
    pub fn add(&self, n: u64) {
        // `fetch_update` with a total closure never yields `Err`.
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_add(n))
            });
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed gauge (queue depths, in-flight counts).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add `delta` (may be negative), saturating at the i64 limits.
    pub fn add(&self, delta: i64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_add(delta))
            });
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets: bucket `0` holds the value `0`, bucket
/// `i ≥ 1` holds values `v` with `floor(log2(v)) == i - 1`, i.e.
/// `v ∈ [2^(i-1), 2^i - 1]`. Bucket 64 holds `v ≥ 2^63`.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// Bucket index for a recorded value (log₂ bucketing).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the last bucket).
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Lock-free log₂-bucketed histogram of `u64` samples.
///
/// All fields saturate; `record` is three relaxed atomic RMWs, cheap
/// enough for per-request and per-shard call sites.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn record(&self, v: u64) {
        let _ = self
            .count
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| {
                Some(c.saturating_add(1))
            });
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                Some(s.saturating_add(v))
            });
        let _ = self.buckets[bucket_index(v)].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |b| Some(b.saturating_add(1)),
        );
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable copy of a [`Histogram`], mergeable across farms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HISTOGRAM_BUCKETS],
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0,
            buckets: [0; HISTOGRAM_BUCKETS],
        }
    }
}

impl HistogramSnapshot {
    /// Bucket-wise saturating merge.
    pub fn merge(&mut self, other: &Self) {
        self.count = self.count.saturating_add(other.count);
        self.sum = self.sum.saturating_add(other.sum);
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b = b.saturating_add(*o);
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the `q`-quantile sample
    /// (`q ∈ [0, 1]`); 0 for an empty histogram. Resolution is a factor
    /// of 2 — use the latency reservoir for exact serving quantiles.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(*b);
            if seen > rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(HISTOGRAM_BUCKETS - 1)
    }
}

/// Exact nearest-rank percentile over an already-sorted slice
/// (`q ∈ [0, 1]`); 0 for an empty slice.
pub fn percentile_u64(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct RegState {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

/// Name-keyed metric registry with get-or-create semantics.
///
/// Hot paths call `counter(name)` once at setup and keep the returned
/// `Arc` handle; the map lock is never taken per event. Each
/// [`crate::scheduler::EngineFarm`] owns one registry for its engine /
/// injector / scratch telemetry.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<RegState>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RegState> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.lock()
                .counters
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Counter::new())),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.lock()
                .gauges
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Gauge::new())),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.lock()
                .histograms
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Current value of a counter (0 if it was never created).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.lock().counters.get(name).map_or(0, |c| c.get())
    }

    /// Current value of a gauge (0 if it was never created).
    pub fn gauge_value(&self, name: &str) -> i64 {
        self.lock().gauges.get(name).map_or(0, |g| g.get())
    }

    /// Sorted `(name, value)` pairs of every registered counter.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.lock()
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Prometheus-style text exposition of every registered metric,
    /// sorted by name. Names are sanitised to `[a-zA-Z0-9_:]`.
    pub fn render_prometheus(&self) -> String {
        let state = self.lock();
        let mut out = String::new();
        for (name, c) in &state.counters {
            let n = sanitize_metric_name(name);
            let _ = writeln!(out, "# TYPE {n} counter\n{n} {}", c.get());
        }
        for (name, g) in &state.gauges {
            let n = sanitize_metric_name(name);
            let _ = writeln!(out, "# TYPE {n} gauge\n{n} {}", g.get());
        }
        for (name, h) in &state.histograms {
            let n = sanitize_metric_name(name);
            let snap = h.snapshot();
            let _ = writeln!(out, "# TYPE {n} histogram");
            let mut cum = 0u64;
            for (i, b) in snap.buckets.iter().enumerate() {
                if *b == 0 {
                    continue;
                }
                cum = cum.saturating_add(*b);
                let _ = writeln!(out, "{n}_bucket{{le=\"{}\"}} {cum}", bucket_upper_bound(i));
            }
            let _ = writeln!(out, "{n}_bucket{{le=\"+Inf\"}} {}", snap.count);
            let _ = writeln!(out, "{n}_sum {}\n{n}_count {}", snap.sum, snap.count);
        }
        out
    }
}

/// Map a dotted metric name onto the Prometheus charset.
pub fn sanitize_metric_name(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Tracer
// ---------------------------------------------------------------------------

/// One completed span or instant event in the ring buffer.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Microseconds since the tracer's epoch (span start for spans).
    pub ts_us: u64,
    /// `"span"` or `"event"`.
    pub kind: &'static str,
    pub name: &'static str,
    /// Span id (0 for instant events, which have no identity).
    pub id: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Span duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Free-form `key=value` payload (may be empty).
    pub detail: String,
}

/// An open span handle returned by [`Tracer::begin`]; pass it back to
/// [`Tracer::finish`] (possibly from another thread — the handle is
/// `Send`) to record the completed span.
#[derive(Debug)]
pub struct Span {
    id: u64,
    parent: u64,
    name: &'static str,
    start: Instant,
}

impl Span {
    /// Span id, for linking child spans/events.
    pub fn id(&self) -> u64 {
        self.id
    }
}

#[derive(Debug)]
struct Ring {
    buf: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

/// Capacity of the process-global tracer returned by [`tracer()`].
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// Span/event tracer with a bounded ring sink.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    next_id: AtomicU64,
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new(capacity: usize) -> Self {
        Self {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            ring: Mutex::new(Ring {
                buf: VecDeque::with_capacity(capacity.min(1024)),
                cap: capacity.max(1),
                dropped: 0,
            }),
        }
    }

    /// Open a span. `parent` is the id of the enclosing span (0 = root).
    pub fn begin(&self, name: &'static str, parent: u64) -> Span {
        Span {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            parent,
            name,
            start: Instant::now(),
        }
    }

    /// Close a span with no payload.
    pub fn finish(&self, span: Span) {
        self.finish_with(span, String::new());
    }

    /// Close a span with a `key=value` payload.
    pub fn finish_with(&self, span: Span, detail: String) {
        let ev = TraceEvent {
            ts_us: span.start.duration_since(self.epoch).as_micros() as u64,
            kind: "span",
            name: span.name,
            id: span.id,
            parent: span.parent,
            dur_us: span.start.elapsed().as_micros() as u64,
            detail,
        };
        self.push(ev);
    }

    /// Record an instant event under `parent` (0 = root).
    pub fn event(&self, name: &'static str, parent: u64, detail: String) {
        let ev = TraceEvent {
            ts_us: self.epoch.elapsed().as_micros() as u64,
            kind: "event",
            name,
            id: 0,
            parent,
            dur_us: 0,
            detail,
        };
        self.push(ev);
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap_or_else(PoisonError::into_inner);
        if ring.buf.len() >= ring.cap {
            ring.buf.pop_front();
            ring.dropped = ring.dropped.saturating_add(1);
        }
        ring.buf.push_back(ev);
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.ring
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .buf
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted from the ring since construction / last clear.
    pub fn dropped(&self) -> u64 {
        self.ring
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .dropped
    }

    pub fn clear(&self) {
        let mut ring = self.ring.lock().unwrap_or_else(PoisonError::into_inner);
        ring.buf.clear();
        ring.dropped = 0;
    }

    /// Copy of the buffered events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.ring
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .buf
            .iter()
            .cloned()
            .collect()
    }

    /// One JSON object per line, oldest event first.
    pub fn export_json_lines(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            let _ = writeln!(
                out,
                "{{\"ts_us\":{},\"kind\":\"{}\",\"name\":\"{}\",\"id\":{},\"parent\":{},\"dur_us\":{},\"detail\":\"{}\"}}",
                ev.ts_us,
                ev.kind,
                ev.name,
                ev.id,
                ev.parent,
                ev.dur_us,
                escape_json(&ev.detail),
            );
        }
        out
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(DEFAULT_TRACE_CAPACITY)
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Process-global tracer (ring capacity [`DEFAULT_TRACE_CAPACITY`]).
/// The serving stack records into this instance; `trim trace` exports it.
pub fn tracer() -> &'static Tracer {
    static GLOBAL: OnceLock<Tracer> = OnceLock::new();
    GLOBAL.get_or_init(Tracer::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_saturates_at_max() {
        let c = Counter::new();
        c.add(u64::MAX - 1);
        c.add(5);
        assert_eq!(c.get(), u64::MAX);
        c.inc();
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn gauge_tracks_depth() {
        let g = Gauge::new();
        g.add(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper_bound(1), 1);
        assert_eq!(bucket_upper_bound(3), 7);
        assert_eq!(bucket_upper_bound(64), u64::MAX);

        let h = Histogram::new();
        for v in [0u64, 1, 3, 4, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 1108);
        assert_eq!(s.buckets[0], 1); // 0
        assert_eq!(s.buckets[1], 1); // 1
        assert_eq!(s.buckets[2], 1); // 3
        assert_eq!(s.buckets[3], 1); // 4
        assert_eq!(s.buckets[7], 1); // 100 ∈ [64,127]
        assert_eq!(s.buckets[10], 1); // 1000 ∈ [512,1023]
        // quantile returns bucket upper bounds
        assert_eq!(s.quantile(0.0), 0);
        assert_eq!(s.quantile(1.0), 1023);
    }

    #[test]
    fn histogram_snapshot_merge_saturates() {
        let a = Histogram::new();
        a.record(5);
        let mut sa = a.snapshot();
        let mut sb = HistogramSnapshot {
            count: u64::MAX,
            sum: u64::MAX,
            ..Default::default()
        };
        sb.buckets[bucket_index(5)] = u64::MAX;
        sa.merge(&sb);
        assert_eq!(sa.count, u64::MAX);
        assert_eq!(sa.sum, u64::MAX);
        assert_eq!(sa.buckets[bucket_index(5)], u64::MAX);
    }

    #[test]
    fn percentile_is_exact_nearest_rank() {
        let sorted: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_u64(&sorted, 0.0), 1);
        assert_eq!(percentile_u64(&sorted, 0.5), 51); // round(99*0.5)=50 → idx 50
        assert_eq!(percentile_u64(&sorted, 0.95), 95); // round(99*0.95)=94
        assert_eq!(percentile_u64(&sorted, 0.99), 99); // round(99*0.99)=98
        assert_eq!(percentile_u64(&sorted, 1.0), 100);
        assert_eq!(percentile_u64(&[], 0.5), 0);
    }

    #[test]
    fn registry_get_or_create_returns_same_handle() {
        let reg = Registry::new();
        let a = reg.counter("farm.engine0.jobs");
        let b = reg.counter("farm.engine0.jobs");
        a.add(3);
        b.add(4);
        assert_eq!(reg.counter_value("farm.engine0.jobs"), 7);
        assert_eq!(reg.counter_value("nonexistent"), 0);
        reg.gauge("depth").set(9);
        assert_eq!(reg.gauge_value("depth"), 9);
    }

    #[test]
    fn registry_prometheus_rendering() {
        let reg = Registry::new();
        reg.counter("farm.jobs").add(12);
        reg.gauge("injector.depth").set(3);
        reg.histogram("busy.us").record(100);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE farm_jobs counter"));
        assert!(text.contains("farm_jobs 12"));
        assert!(text.contains("# TYPE injector_depth gauge"));
        assert!(text.contains("injector_depth 3"));
        assert!(text.contains("busy_us_count 1"));
        assert!(text.contains("busy_us_sum 100"));
        assert!(text.contains("busy_us_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn tracer_links_parents_and_bounds_ring() {
        let t = Tracer::new(4);
        let root = t.begin("serve.request", 0);
        let child = t.begin("serve.batch", root.id());
        t.event("batch.formed", child.id(), "size=4".into());
        let child_id = child.id();
        t.finish(child);
        t.finish_with(root, "class=3".into());
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].name, "batch.formed");
        assert_eq!(evs[0].parent, child_id);
        assert_eq!(evs[1].name, "serve.batch");
        assert_eq!(evs[2].name, "serve.request");
        assert!(evs[2].id < evs[1].id, "ids allocate monotonically");
        // overflow the 4-slot ring
        for _ in 0..10 {
            t.event("tick", 0, String::new());
        }
        assert_eq!(t.len(), 4);
        assert!(t.dropped() >= 9);
        let json = t.export_json_lines();
        assert_eq!(json.lines().count(), 4);
        assert!(json.contains("\"name\":\"tick\""));
        t.clear();
        assert!(t.is_empty());
    }

    #[test]
    fn trace_timestamps_are_monotonic_and_json_escapes() {
        let t = Tracer::new(16);
        t.event("a", 0, "x=\"quoted\"\nnext".into());
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.event("b", 0, String::new());
        let evs = t.events();
        assert!(evs[1].ts_us >= evs[0].ts_us);
        let json = t.export_json_lines();
        assert!(json.contains("\\\"quoted\\\""));
        assert!(json.contains("\\n"));
    }

    #[test]
    fn global_tracer_is_a_singleton() {
        let a = tracer() as *const Tracer;
        let b = tracer() as *const Tracer;
        assert_eq!(a, b);
    }
}
