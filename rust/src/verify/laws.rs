//! The conservation laws themselves: Tables I–II access counters and the
//! PR-4/PR-5 halo formula, reimplemented **from the layer geometry alone**.
//!
//! This file deliberately duplicates the closed forms of
//! [`crate::arch::fastsim::analytic_stats`] instead of calling them: the
//! checker's value is that two independently written derivations of the
//! paper's counter model (Tables I–II of arXiv 2408.10243, the halo
//! algebra of the row/hybrid shard axes) must agree on every point of the
//! design space. A bug in either derivation — or in the planner geometry
//! they both consume — surfaces as a named [`super::Violation`] instead
//! of silently skewing the bench trajectory.
//!
//! Derivations (stride-1 row split into `g_r` bands, `K ≤ K_nat`):
//! every band reads its input slab of `rows + K − 1` padded rows once per
//! filter group, so summed band reads are
//! `⌈N/P_N⌉ · M · W_P · (H_O + g_r·(K−1))` against the unsharded
//! `⌈N/P_N⌉ · M · W_P · (H_O + K − 1)` — the difference is exactly
//! `(g_r − 1)(K − 1)` duplicated halo rows. Tiled layers (`K > K_nat`)
//! read the shifted `(H_S × W_S)` view once per filter, giving the same
//! shape with `K_nat − 1` in place of `K − 1`. Filter splits duplicate
//! nothing (the groups of a `P_N`-aligned split partition the group
//! loop), which is why the halo depends only on the row-split count.

use crate::arch::{ArchConfig, SimStats};
use crate::model::{ConvLayer, KernelTiling};
use std::ops::Range;

/// Closed-form counters for the piece of `layer` covering `filters`
/// contiguous filters × output rows `rows` — cycles excluded (timing is a
/// bound in [`super::check_point`], not a conservation law).
///
/// `rows == 0..H_O` prices the whole padded ifmap (the engine
/// short-circuits a full range to a whole-layer run); a proper band
/// prices its slab of `(rows − 1)·stride + K` input rows, halo included.
pub fn expected_counters(
    arch: &ArchConfig,
    layer: &ConvLayer,
    filters: usize,
    rows: &Range<usize>,
) -> SimStats {
    let k = layer.k;
    let (hp, wp) = (layer.h_i + 2 * layer.pad, layer.w_i + 2 * layer.pad);
    let h_o = layer.h_o();
    let w_o = layer.w_o();
    let full = *rows == (0..h_o);
    let slab_h = if full { hp } else { (rows.len() - 1) * layer.stride + k };
    let n_i = filters as u64;
    let out_cells = n_i * (rows.len() * w_o) as u64;
    // The array always walks the stride-1 sweep grid of its input slab
    // and decimates (§V), so MACs price sweep positions, not outputs.
    let sweep1 = ((slab_h - k + 1) * (wp - k + 1)) as u64;
    let mut s = SimStats { output_writes: out_cells, ..SimStats::default() };
    if k <= arch.k {
        // Native: the slab is broadcast once per P_N-filter group.
        let groups = filters.div_ceil(arch.p_n) as u64;
        s.ext_input_reads = groups * (layer.m * slab_h * wp) as u64;
        s.weight_reads = n_i * (layer.m * k * k) as u64;
        s.macs = s.weight_reads * sweep1;
        let m_groups = layer.m.div_ceil(arch.p_m) as u64;
        if m_groups > 1 {
            // Temporal accumulation: one write per channel group, one
            // read back per group after the first, per output cell.
            s.psum_buf_writes = m_groups * out_cells;
            s.psum_buf_reads = (m_groups - 1) * out_cells;
        }
        s.peak_ext_inputs_per_cycle = (2 * k - 1) as u64;
        s.max_rsrb_occupancy = wp as u64;
    } else {
        // Tiled (§V): T shifted K_nat×K_nat tasks per kernel; the
        // shifted sub-view is read once per filter pass.
        let k_nat = arch.k;
        let t = KernelTiling::new(k, k_nat).num_tiles() as u64;
        let (hs, ws) = (slab_h - k + k_nat, wp - k + k_nat);
        s.ext_input_reads = n_i * (hs * ws) as u64;
        s.weight_reads = n_i * layer.m as u64 * t * (k_nat * k_nat) as u64;
        s.macs = s.weight_reads * sweep1;
        let spills = ((layer.m - 1) / arch.p_m) as u64;
        s.psum_buf_reads = n_i * spills * (rows.len() * w_o) as u64;
        s.psum_buf_writes = s.psum_buf_reads;
        s.peak_ext_inputs_per_cycle = (2 * k_nat - 1) as u64;
        s.max_rsrb_occupancy = ws as u64;
    }
    s
}

/// Exact inter-band halo duplication for a stride-1 layer split into
/// `g_r` row bands (any filter-split count): summed shard input reads
/// minus the unsharded reads. `None` for strided layers, whose bands
/// *skip* sweep rows between bands instead of duplicating them — there
/// the per-shard law stays exact but the aggregate is an inequality.
pub fn expected_halo_reads(arch: &ArchConfig, layer: &ConvLayer, g_r: usize) -> Option<u64> {
    if layer.stride != 1 {
        return None;
    }
    let wp = layer.w_i + 2 * layer.pad;
    let dup_bands = (g_r - 1) as u64;
    Some(if layer.k <= arch.k {
        (layer.n.div_ceil(arch.p_n) * layer.m * wp) as u64 * dup_bands * (layer.k - 1) as u64
    } else {
        (layer.n * (wp - layer.k + arch.k)) as u64 * dup_bands * (arch.k - 1) as u64
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_range_matches_band_union_on_stride1() {
        // Native stride-1: two bands' counters sum to the whole layer's
        // plus exactly one halo seam, straight from the closed forms.
        let arch = ArchConfig::small(3, 2, 2);
        let l = ConvLayer::new("t", 10, 3, 4, 6, 1, 1);
        let h_o = l.h_o();
        let whole = expected_counters(&arch, &l, l.n, &(0..h_o));
        let lo = expected_counters(&arch, &l, l.n, &(0..h_o / 2));
        let hi = expected_counters(&arch, &l, l.n, &(h_o / 2..h_o));
        assert_eq!(lo.output_writes + hi.output_writes, whole.output_writes);
        assert_eq!(lo.macs + hi.macs, whole.macs);
        let halo = expected_halo_reads(&arch, &l, 2).unwrap();
        assert_eq!(lo.ext_input_reads + hi.ext_input_reads, whole.ext_input_reads + halo);
    }

    #[test]
    fn strided_bands_never_exceed_whole_macs() {
        let arch = ArchConfig::small(3, 2, 2);
        let l = ConvLayer::new("s", 13, 3, 2, 3, 2, 1);
        let h_o = l.h_o();
        let whole = expected_counters(&arch, &l, l.n, &(0..h_o));
        let lo = expected_counters(&arch, &l, l.n, &(0..h_o / 2));
        let hi = expected_counters(&arch, &l, l.n, &(h_o / 2..h_o));
        assert!(lo.macs + hi.macs <= whole.macs, "decimated bands skip sweep rows");
        assert_eq!(lo.output_writes + hi.output_writes, whole.output_writes);
        assert!(expected_halo_reads(&arch, &l, 2).is_none());
    }
}
