//! Static invariant verification (`trim check`): prove the shard planner
//! and the closed-form counter model consistent over the whole design
//! space **without running a single convolution**.
//!
//! Four invariant families, checked per `(layer geometry × shard mode ×
//! engine count)` point:
//!
//! * **Coverage** — every `(filter, output row)` cell of the layer is
//!   owned by exactly one shard (none dropped, none double-counted);
//!   filter splits are `P_N`-group aligned; the grid dims, group counts
//!   and planner bookkeeping are self-consistent.
//! * **Halo conservation** — each shard's off-chip input reads match the
//!   independent slab formula in [`laws`], and on stride-1 layers the
//!   shard sum equals the unsharded reads plus *exactly* the
//!   [`laws::expected_halo_reads`] inter-band duplication.
//! * **Cycle bound** — no shard prices more cycles than the unsharded
//!   layer, and the plan [`ShardMode::Auto`] picks never has a worse
//!   [`ShardPlan::speedup_bound`] than the axes it rejected.
//! * **Counter conservation** — the fast tier's analytic counters agree
//!   with the independently re-derived Tables I–II identities, per shard
//!   and in aggregate (outputs partition exactly; weight reads duplicate
//!   exactly once per row band; MACs partition on stride-1 and can only
//!   shrink under decimation).
//!
//! [`check_plan`]/[`check_stats`] are also called (debug builds) at
//! shard-merge time in `scheduler/farm.rs`, so the same laws guard the
//! dynamic path for free. [`self_test`] corrupts a known-good plan and
//! stats vector and demands named violations — CI proof that the checker
//! *can* fail.

pub mod laws;

use crate::arch::control::plan_layer;
use crate::arch::fastsim::{analytic_stats, analytic_stats_rows};
use crate::arch::{ArchConfig, SimStats};
use crate::model::ConvLayer;
use crate::scheduler::{
    plan_filter_shards, plan_hybrid_shards, plan_row_shards, plan_shards, Shard, ShardMode,
    ShardPlan,
};
use std::fmt;

/// The invariant family a [`Violation`] belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Law {
    /// Exact-cover / alignment / planner-bookkeeping laws.
    Coverage,
    /// Off-chip input reads vs the slab + halo closed forms.
    HaloConservation,
    /// Shard cycles vs the unsharded bound; Auto plan consistency.
    CycleBound,
    /// Tables I–II counter identities, per shard and aggregate.
    CounterConservation,
}

impl Law {
    /// Stable kebab-case name (the per-violation report and JSON line).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Coverage => "coverage",
            Self::HaloConservation => "halo-conservation",
            Self::CycleBound => "cycle-bound",
            Self::CounterConservation => "counter-conservation",
        }
    }
}

impl fmt::Display for Law {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// One failed law check, carrying everything needed to file it.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Layer + engine geometry, e.g. `cl1 12x12 k3 s1 p1 m3 n16 | P_N=2 P_M=2 K_nat=3`.
    pub geometry: String,
    /// Shard mode (or plan axis) the point was checked under.
    pub mode: String,
    /// Engine count of the point.
    pub engines: usize,
    /// Which invariant family failed.
    pub law: Law,
    /// What the law demanded.
    pub expected: String,
    /// What the planner/model produced.
    pub got: String,
    /// Which specific identity failed, and where.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] {} mode={} engines={}: {} — expected {}, got {}",
            self.law, self.geometry, self.mode, self.engines, self.detail, self.expected, self.got
        )
    }
}

/// Render the geometry tag shared by every violation of one point.
pub fn geometry_tag(arch: &ArchConfig, layer: &ConvLayer) -> String {
    format!(
        "{} {}x{} k{} s{} p{} m{} n{} | P_N={} P_M={} K_nat={}",
        layer.name, layer.h_i, layer.w_i, layer.k, layer.stride, layer.pad, layer.m, layer.n,
        arch.p_n, arch.p_m, arch.k
    )
}

/// Check accumulator: counts every law evaluated, records the failures.
struct Ctx {
    geometry: String,
    mode: String,
    engines: usize,
    checks: u64,
    out: Vec<Violation>,
}

impl Ctx {
    fn new(arch: &ArchConfig, layer: &ConvLayer, mode: &str, engines: usize) -> Self {
        Self {
            geometry: geometry_tag(arch, layer),
            mode: mode.to_string(),
            engines,
            checks: 0,
            out: Vec::new(),
        }
    }

    fn law(
        &mut self,
        law: Law,
        ok: bool,
        expected: impl fmt::Display,
        got: impl fmt::Display,
        detail: impl fmt::Display,
    ) {
        self.checks += 1;
        if !ok {
            self.out.push(Violation {
                geometry: self.geometry.clone(),
                mode: self.mode.clone(),
                engines: self.engines,
                law,
                expected: expected.to_string(),
                got: got.to_string(),
                detail: detail.to_string(),
            });
        }
    }

    fn eq_u64(&mut self, law: Law, expected: u64, got: u64, detail: impl fmt::Display) {
        self.law(law, expected == got, expected, got, detail);
    }
}

/// Structural Coverage laws of one [`ShardPlan`] (no counters involved).
/// Returns the violations; empty means the plan partitions the layer.
pub fn check_plan(
    arch: &ArchConfig,
    layer: &ConvLayer,
    engines: usize,
    plan: &ShardPlan,
) -> Vec<Violation> {
    let mut ctx = Ctx::new(arch, layer, plan.axis.as_str(), engines);
    check_plan_in(&mut ctx, arch, layer, engines, plan);
    ctx.out
}

fn check_plan_in(ctx: &mut Ctx, arch: &ArchConfig, layer: &ConvLayer, engines: usize, plan: &ShardPlan) {
    let h_o = layer.h_o();
    let c = Law::Coverage;
    ctx.eq_u64(c, (plan.grid.0 * plan.grid.1) as u64, plan.shards.len() as u64, "grid dims × == shard count");
    ctx.law(c, plan.shards.len() <= engines, format!("≤ {engines}"), plan.shards.len(), "shards within engine budget");
    ctx.eq_u64(c, h_o as u64, plan.rows as u64, "plan.rows == H_O");
    ctx.eq_u64(c, layer.n.div_ceil(arch.p_n) as u64, plan.filter_groups as u64, "plan.filter_groups == ⌈N/P_N⌉");
    ctx.eq_u64(c, arch.p_n as u64, plan.p_n as u64, "plan.p_n == engine P_N");
    let mut covered = vec![0u32; layer.n * h_o];
    for (i, s) in plan.shards.iter().enumerate() {
        let at = format!("shard {i}");
        ctx.eq_u64(c, i as u64, s.index as u64, format!("{at}: index matches position"));
        ctx.law(c, !s.filters.is_empty() && !s.rows.is_empty(), "non-empty ranges", format!("filters {:?} rows {:?}", s.filters, s.rows), format!("{at}: empty shard"));
        ctx.law(c, s.filters.end <= layer.n && s.rows.end <= h_o, format!("within 0..{} × 0..{h_o}", layer.n), format!("filters {:?} rows {:?}", s.filters, s.rows), format!("{at}: out of bounds"));
        let aligned = s.filters.start % arch.p_n == 0 && (s.filters.end % arch.p_n == 0 || s.filters.end == layer.n);
        ctx.law(c, aligned, "P_N-group-aligned boundaries", format!("{:?}", s.filters), format!("{at}: filter split alignment"));
        ctx.eq_u64(c, s.filters.len().div_ceil(arch.p_n) as u64, s.groups as u64, format!("{at}: groups == ⌈|filters|/P_N⌉"));
        for f in s.filters.clone() {
            for r in s.rows.clone() {
                if let Some(cell) = covered.get_mut(f * h_o + r) {
                    *cell += 1;
                }
            }
        }
    }
    let dropped = covered.iter().filter(|&&v| v == 0).count();
    let doubled = covered.iter().filter(|&&v| v > 1).count();
    ctx.eq_u64(c, 0, dropped as u64, "output cells owned by no shard (dropped)");
    ctx.eq_u64(c, 0, doubled as u64, "output cells owned by >1 shard (double-counted)");
}

/// The analytic per-shard counters the fast tier would report for
/// `shard` of `layer` — the model side of [`check_stats`].
pub fn analytic_shard_stats(arch: &ArchConfig, layer: &ConvLayer, shard: &Shard) -> SimStats {
    let sub = ConvLayer {
        name: format!("{}[f{}..{}]", layer.name, shard.filters.start, shard.filters.end),
        n: shard.filters.len(),
        ..layer.clone()
    };
    if shard.rows == (0..layer.h_o()) {
        // A full row range is a whole-layer run, never priced as a band
        // (mirrors the engine's short-circuit).
        analytic_stats(arch, &sub, &plan_layer(arch, &sub))
    } else {
        analytic_stats_rows(arch, &sub, &shard.rows)
    }
}

/// Halo + counter conservation of per-shard [`SimStats`] against the
/// independent closed forms in [`laws`] — per shard and in aggregate.
/// `per_shard[i]` must be the stats of `plan.shards[i]` (the farm's
/// merge-time ordering). Cycles are not a conservation law and are
/// ignored here; see [`check_point`] for the cycle bound.
pub fn check_stats(
    arch: &ArchConfig,
    layer: &ConvLayer,
    plan: &ShardPlan,
    per_shard: &[SimStats],
) -> Vec<Violation> {
    let mut ctx = Ctx::new(arch, layer, plan.axis.as_str(), plan.shards.len());
    check_stats_in(&mut ctx, arch, layer, plan, per_shard);
    ctx.out
}

fn check_stats_in(
    ctx: &mut Ctx,
    arch: &ArchConfig,
    layer: &ConvLayer,
    plan: &ShardPlan,
    per_shard: &[SimStats],
) {
    ctx.eq_u64(
        Law::CounterConservation,
        plan.shards.len() as u64,
        per_shard.len() as u64,
        "one stats entry per shard",
    );
    let mut sum = SimStats::default();
    for (s, got) in plan.shards.iter().zip(per_shard) {
        let exp = laws::expected_counters(arch, layer, s.filters.len(), &s.rows);
        let at = format!("shard {} (filters {:?} rows {:?})", s.index, s.filters, s.rows);
        ctx.eq_u64(Law::HaloConservation, exp.ext_input_reads, got.ext_input_reads, format!("{at}: slab input reads"));
        ctx.eq_u64(Law::CounterConservation, exp.weight_reads, got.weight_reads, format!("{at}: weight reads"));
        ctx.eq_u64(Law::CounterConservation, exp.output_writes, got.output_writes, format!("{at}: output writes"));
        ctx.eq_u64(Law::CounterConservation, exp.macs, got.macs, format!("{at}: MACs"));
        ctx.eq_u64(Law::CounterConservation, exp.psum_buf_reads, got.psum_buf_reads, format!("{at}: psum reads"));
        ctx.eq_u64(Law::CounterConservation, exp.psum_buf_writes, got.psum_buf_writes, format!("{at}: psum writes"));
        ctx.eq_u64(Law::CounterConservation, exp.peak_ext_inputs_per_cycle, got.peak_ext_inputs_per_cycle, format!("{at}: eq. (4) peak"));
        ctx.eq_u64(Law::CounterConservation, exp.max_rsrb_occupancy, got.max_rsrb_occupancy, format!("{at}: RSRB occupancy"));
        sum.ext_input_reads += got.ext_input_reads;
        sum.weight_reads += got.weight_reads;
        sum.output_writes += got.output_writes;
        sum.macs += got.macs;
    }
    let whole = laws::expected_counters(arch, layer, layer.n, &(0..layer.h_o()));
    ctx.eq_u64(Law::CounterConservation, whole.output_writes, sum.output_writes, "aggregate: output writes partition the layer exactly");
    ctx.eq_u64(
        Law::CounterConservation,
        whole.weight_reads * plan.grid.1 as u64,
        sum.weight_reads,
        "aggregate: weights are re-read once per row band",
    );
    if layer.stride == 1 {
        ctx.eq_u64(Law::CounterConservation, whole.macs, sum.macs, "aggregate: stride-1 MACs partition the layer exactly");
    } else {
        ctx.law(
            Law::CounterConservation,
            sum.macs <= whole.macs,
            format!("≤ {}", whole.macs),
            sum.macs,
            "aggregate: decimated bands can only shrink the sweep",
        );
    }
    if let Some(halo) = laws::expected_halo_reads(arch, layer, plan.grid.1) {
        ctx.eq_u64(
            Law::HaloConservation,
            whole.ext_input_reads + halo,
            sum.ext_input_reads,
            "aggregate: shard reads == unsharded reads + exact halo duplication",
        );
    }
}

/// Result of checking one design-space point.
#[derive(Debug, Clone)]
pub struct PointReport {
    /// Total law evaluations performed.
    pub checks: u64,
    /// The failures (empty for a healthy point).
    pub violations: Vec<Violation>,
}

/// Verify all four invariant families for one `(layer, mode, engines)`
/// point on `arch`, planning with the real planner and pricing shards
/// with the real fast-tier model — no convolution executed.
/// `mode` must be a per-layer mode (not [`ShardMode::LayerPipeline`]).
pub fn check_point(
    arch: &ArchConfig,
    layer: &ConvLayer,
    engines: usize,
    mode: ShardMode,
) -> PointReport {
    let plan = plan_shards(arch, layer, engines, mode);
    let mut ctx = Ctx::new(arch, layer, mode.as_str(), engines);
    check_plan_in(&mut ctx, arch, layer, engines, &plan);
    let per_shard: Vec<SimStats> =
        plan.shards.iter().map(|s| analytic_shard_stats(arch, layer, s)).collect();
    check_stats_in(&mut ctx, arch, layer, &plan, &per_shard);

    // Cycle-bound sanity: the whole-layer analytic model bounds every
    // shard from above (a shard is a sub-problem), and Auto never keeps
    // a plan with a worse bound than an axis it rejected.
    let whole = analytic_stats(arch, layer, &plan_layer(arch, layer));
    let cycles_max = per_shard.iter().map(|s| s.cycles).max().unwrap_or(0);
    ctx.law(
        Law::CycleBound,
        cycles_max <= whole.cycles,
        format!("≤ {}", whole.cycles),
        cycles_max,
        "max shard cycles within the unsharded cycle count",
    );
    if mode == ShardMode::Auto {
        let chosen = plan.speedup_bound();
        let bf = plan_filter_shards(arch, layer, engines).speedup_bound();
        let br = plan_row_shards(arch, layer, engines).speedup_bound();
        let bh = plan_hybrid_shards(arch, layer, engines).speedup_bound();
        // Auto takes the better pure axis, and the grid only when
        // *strictly* better — so the chosen bound dominates both axes
        // exactly and the grid up to the planner's strictness epsilon.
        ctx.law(
            Law::CycleBound,
            chosen + 1e-6 >= bf.max(br) && chosen + 1e-6 >= bh - 1e-9,
            format!("≥ max(filters {bf:.3}, rows {br:.3}, hybrid-ε {bh:.3})"),
            format!("{chosen:.3}"),
            "Auto speedup_bound consistent with the rejected axes",
        );
    }
    PointReport { checks: ctx.checks, violations: ctx.out }
}

/// Summary of a design-space sweep.
#[derive(Debug, Clone)]
pub struct SweepSummary {
    /// `(layer × arch × mode × engines)` points checked.
    pub points: usize,
    /// Total law evaluations across all points.
    pub checks: u64,
    /// Every violation found (empty on a healthy tree).
    pub violations: Vec<Violation>,
}

/// The swept design space: layer geometries covering native/tiled ×
/// unit/strided × padded/unpadded shapes, engine configs spanning the
/// Fig. 7 parallelism grid, all four per-layer shard modes, and farm
/// sizes from 1 to 16 engines. `full` is the CI `--sweep` grid
/// (≥ 200 points); the quick grid is a strict subset for local runs.
pub fn sweep_design_space(full: bool) -> SweepSummary {
    let layers = [
        ConvLayer::new("cl1", 24, 3, 3, 16, 1, 1),
        ConvLayer::new("cl2", 16, 3, 8, 16, 1, 1),
        ConvLayer::new("deep", 8, 3, 16, 32, 1, 1),
        ConvLayer::new("k5", 14, 5, 3, 6, 1, 2),
        ConvLayer::new("k7", 12, 7, 2, 4, 1, 0),
        ConvLayer::new("alex", 31, 11, 2, 6, 4, 0),
        ConvLayer::new("s2", 13, 3, 3, 5, 2, 1),
    ];
    let archs = [
        ArchConfig::small(3, 2, 2),
        ArchConfig::small(3, 4, 4),
        ArchConfig::paper_engine(),
    ];
    let modes = [ShardMode::FilterShards, ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto];
    let engine_counts: &[usize] = if full { &[1, 2, 4, 8, 16] } else { &[1, 4, 8] };
    let (layers, archs): (&[ConvLayer], &[ArchConfig]) =
        if full { (&layers, &archs) } else { (&layers[..4], &archs[..1]) };

    let mut summary = SweepSummary { points: 0, checks: 0, violations: Vec::new() };
    for layer in layers {
        for arch in archs {
            for &mode in &modes {
                for &engines in engine_counts {
                    let r = check_point(arch, layer, engines, mode);
                    summary.points += 1;
                    summary.checks += r.checks;
                    summary.violations.extend(r.violations);
                }
            }
        }
    }
    summary
}

/// Corrupt a plan by dropping its last shard (a lost row band / filter
/// split) — [`check_plan`] must report dropped Coverage cells.
pub fn corrupt_drop_shard(plan: &mut ShardPlan) {
    plan.shards.pop();
}

/// Corrupt a row plan by extending a band into its neighbour (the
/// double-counted-halo failure) — [`check_plan`] must report
/// double-counted Coverage cells.
pub fn corrupt_overlap_rows(plan: &mut ShardPlan) {
    if plan.shards.len() >= 2 {
        plan.shards[0].rows.end += 1;
    }
}

/// Prove the checker can fail: corrupt a known-good plan and stats
/// vector in the three seeded ways and demand each is rejected with the
/// right named law. Run by `trim check` on every invocation, so a
/// vacuously-green checker fails CI.
pub fn self_test() -> Result<(), String> {
    let arch = ArchConfig::small(3, 2, 2);
    let layer = ConvLayer::new("selftest", 16, 3, 3, 8, 1, 1);
    let engines = 4;

    let expect = |name: &str, law: Law, v: &[Violation]| -> Result<(), String> {
        if v.iter().any(|x| x.law == law) {
            Ok(())
        } else {
            Err(format!("{name}: corrupted input was NOT rejected with a {law} violation"))
        }
    };

    let mut dropped = plan_row_shards(&arch, &layer, engines);
    corrupt_drop_shard(&mut dropped);
    expect("dropped row band", Law::Coverage, &check_plan(&arch, &layer, engines, &dropped))?;

    let mut overlapped = plan_row_shards(&arch, &layer, engines);
    corrupt_overlap_rows(&mut overlapped);
    expect("overlapping bands", Law::Coverage, &check_plan(&arch, &layer, engines, &overlapped))?;

    let plan = plan_row_shards(&arch, &layer, engines);
    let mut stats: Vec<SimStats> =
        plan.shards.iter().map(|s| analytic_shard_stats(&arch, &layer, s)).collect();
    stats[0].ext_input_reads += 1; // a double-counted halo element
    expect("inflated halo reads", Law::HaloConservation, &check_stats(&arch, &layer, &plan, &stats))?;

    let mut stats2: Vec<SimStats> =
        plan.shards.iter().map(|s| analytic_shard_stats(&arch, &layer, s)).collect();
    stats2[1].macs = stats2[1].macs.wrapping_sub(1);
    expect("skewed MAC counter", Law::CounterConservation, &check_stats(&arch, &layer, &plan, &stats2))?;

    // And the uncorrupted point must be clean, or the fixtures are stale.
    let healthy = check_point(&arch, &layer, engines, ShardMode::Auto);
    if !healthy.violations.is_empty() {
        return Err(format!(
            "self-test fixture is not clean: {}",
            healthy.violations[0]
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_is_clean() {
        let s = sweep_design_space(false);
        assert!(s.points >= 48, "quick grid shrank: {} points", s.points);
        assert!(
            s.violations.is_empty(),
            "quick sweep found violations: {}",
            s.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("; ")
        );
    }

    #[test]
    fn self_test_catches_seeded_corruption() {
        self_test().unwrap();
    }

    #[test]
    fn full_sweep_covers_acceptance_floor() {
        let s = sweep_design_space(true);
        assert!(s.points >= 200, "full sweep has only {} points", s.points);
        assert!(
            s.violations.is_empty(),
            "full sweep found violations: {}",
            s.violations.iter().take(5).map(|v| v.to_string()).collect::<Vec<_>>().join("; ")
        );
    }
}
