//! Golden (oracle) models: plain direct convolutions used to validate the
//! cycle-accurate simulator and the PJRT-executed artifacts bit-exactly.

mod conv;

pub use conv::{conv2d_i32, conv3d_i32, Tensor3};
