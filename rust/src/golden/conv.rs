//! Direct integer convolutions (the correctness oracle).
//!
//! All math is `i32` (the paper's datapath never exceeds 30 bits for
//! B = 8, K = 3, M ≤ 512 — see `model::quant::DatapathBits`).

/// Minimal row-major `[C][H][W]` tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tensor3 {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<i32>,
}

impl Tensor3 {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w, data: vec![0; c * h * w] }
    }

    pub fn from_fn(c: usize, h: usize, w: usize, mut f: impl FnMut(usize, usize, usize) -> i32) -> Self {
        let mut data = Vec::with_capacity(c * h * w);
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    data.push(f(ci, y, x));
                }
            }
        }
        Self { c, h, w, data }
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> i32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: i32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Channel slice as a row-major `[H][W]` view.
    pub fn channel(&self, c: usize) -> &[i32] {
        &self.data[c * self.h * self.w..(c + 1) * self.h * self.w]
    }
}

/// 2-D direct convolution of a single `h×w` channel with a single `k×k`
/// kernel (row-major slices), zero padding `pad`, stride `stride`.
/// Returns the row-major `h_o × w_o` output.
pub fn conv2d_i32(input: &[i32], h: usize, w: usize, weights: &[i32], k: usize, stride: usize, pad: usize) -> Vec<i32> {
    assert_eq!(input.len(), h * w);
    assert_eq!(weights.len(), k * k);
    let h_o = (h + 2 * pad - k) / stride + 1;
    let w_o = (w + 2 * pad - k) / stride + 1;
    let mut out = vec![0i32; h_o * w_o];
    for oy in 0..h_o {
        for ox in 0..w_o {
            let mut acc = 0i32;
            for r in 0..k {
                let iy = (oy * stride + r) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                let row = &input[iy as usize * w..(iy as usize + 1) * w];
                for c in 0..k {
                    let ix = (ox * stride + c) as isize - pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    acc += row[ix as usize] * weights[r * k + c];
                }
            }
            out[oy * w_o + ox] = acc;
        }
    }
    out
}

/// 3-D (multi-channel, multi-filter) direct convolution:
/// `input` is `[M][H][W]`, `weights` is `[N][M][K][K]` (flat, row-major),
/// output is `[N][H_O][W_O]`.
pub fn conv3d_i32(
    input: &Tensor3,
    weights: &[i32],
    n: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Tensor3 {
    let m = input.c;
    assert_eq!(weights.len(), n * m * k * k);
    let h_o = (input.h + 2 * pad - k) / stride + 1;
    let w_o = (input.w + 2 * pad - k) / stride + 1;
    let mut out = Tensor3::zeros(n, h_o, w_o);
    for fi in 0..n {
        for ci in 0..m {
            let kern = &weights[(fi * m + ci) * k * k..(fi * m + ci + 1) * k * k];
            let partial = conv2d_i32(input.channel(ci), input.h, input.w, kern, k, stride, pad);
            for (i, v) in partial.iter().enumerate() {
                out.data[fi * h_o * w_o + i] += v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel() {
        // 3×3 kernel with centre 1 and pad 1 reproduces the input.
        let h = 5;
        let w = 4;
        let input: Vec<i32> = (0..h * w).map(|i| i as i32).collect();
        let mut k = vec![0i32; 9];
        k[4] = 1;
        let out = conv2d_i32(&input, h, w, &k, 3, 1, 1);
        assert_eq!(out, input);
    }

    #[test]
    fn hand_computed_2x2() {
        // input 3×3 = [[1,2,3],[4,5,6],[7,8,9]], kernel 2×2 = [[1,0],[0,1]]
        let input = vec![1, 2, 3, 4, 5, 6, 7, 8, 9];
        let k = vec![1, 0, 0, 1];
        let out = conv2d_i32(&input, 3, 3, &k, 2, 1, 0);
        assert_eq!(out, vec![1 + 5, 2 + 6, 4 + 8, 5 + 9]);
    }

    #[test]
    fn stride_2_downsamples() {
        let input: Vec<i32> = vec![1; 16];
        let k = vec![1; 4];
        let out = conv2d_i32(&input, 4, 4, &k, 2, 2, 0);
        assert_eq!(out, vec![4; 4]);
    }

    #[test]
    fn multichannel_sums_channels() {
        let input = Tensor3::from_fn(2, 3, 3, |c, y, x| (c as i32 + 1) * (y * 3 + x) as i32);
        // One filter, both kernels are centre-1 3×3.
        let mut w = vec![0i32; 2 * 9];
        w[4] = 1;
        w[13] = 1;
        let out = conv3d_i32(&input, &w, 1, 3, 1, 1);
        // out = ch0 + ch1 = 3 × (y·3+x)
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(out.get(0, y, x), 3 * (y * 3 + x) as i32);
            }
        }
    }

    #[test]
    fn padding_zeroes_outside() {
        let input = vec![1i32; 4];
        let k = vec![1i32; 9];
        let out = conv2d_i32(&input, 2, 2, &k, 3, 1, 1);
        assert_eq!(out.len(), 4);
        assert_eq!(out, vec![4, 4, 4, 4]); // each window sees all four ones
    }
}
