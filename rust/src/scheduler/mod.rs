//! Engine-farm scheduler: shard CNN work across a pool of simulated TrIM
//! engines and serve inference from it.
//!
//! The paper scales throughput by replicating compute *inside* one engine
//! (`P_N` cores, Fig. 6); its 3D-TrIM follow-up scales further by stacking
//! whole TrIM fabrics. This module is that next level of the hierarchy in
//! software:
//!
//! * [`shard`] — the planner: split a [`crate::model::ConvLayer`] into
//!   independent filter shards on the paper's own `P_N`-filter group
//!   boundaries (the `⌈N/P_N⌉` outer loop of eq. (2)), into contiguous
//!   output-row bands (the spatial axis that saturates the farm on
//!   CL1-class layers — [`plan_row_shards`]), into a 2-D filter × row
//!   grid for farms bigger than either single axis
//!   ([`plan_hybrid_shards`]), per-layer whichever bounds best
//!   ([`ShardMode::Auto`]), or assign whole layers of a network to
//!   engines ([`ShardMode`]).
//! * [`farm`] — [`EngineFarm`]: worker threads, each wrapping one
//!   cycle-accurate [`crate::arch::EngineSim`], stealing jobs from one
//!   shared injector queue; bit-exact ofmap reassembly, named-engine
//!   errors for panicked jobs, and [`crate::arch::SimStats`] aggregation
//!   (cycles = max over parallel shards, accesses = sum) so the
//!   Tables I–II accounting stays meaningful at farm scale. Every
//!   merged shard is verified against the [`crate::fault`] ABFT
//!   checksum identity; detected faults re-execute on a different
//!   engine, repeat offenders are quarantined and later layers replan
//!   over the survivors.
//! * [`backend`] — [`SimBackend`]: a [`crate::coordinator::InferenceBackend`]
//!   that serves batched requests straight from the farm, with zero PJRT
//!   artifacts (`trim serve --backend sim`).

pub mod backend;
pub mod farm;
pub mod shard;

pub use backend::{SimBackend, SimNetSpec};
pub use farm::{
    CanaryConfig, CanaryReport, EngineFarm, EngineHealthMap, FarmConfig, FarmRunResult,
    FirstWins, Injector, PipelineRunResult, PipelineStage,
};
pub use shard::{
    plan_filter_shards, plan_filter_shards_weighted, plan_hybrid_shards, plan_row_shards,
    plan_row_shards_weighted, plan_shards, plan_shards_weighted, Shard, ShardAxis, ShardMode,
    ShardPlan,
};
