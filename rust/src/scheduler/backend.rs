//! [`SimBackend`]: serve CNN inference straight from the simulated engine
//! farm — no PJRT, no artifacts, no Python. `trim serve --backend sim`.
//!
//! The backend owns an [`EngineFarm`] and a small quantised CNN
//! ([`SimNetSpec`]) whose weights are generated deterministically, so any
//! two processes (and the golden reference path) agree bit-exactly on
//! every logit. Batches are executed in one of the farm's modes:
//!
//! * [`ShardMode::FilterShards`] / [`ShardMode::Spatial`] /
//!   [`ShardMode::Hybrid`] / [`ShardMode::Auto`] — layer-serial over the
//!   batch (the same weight-resident order as
//!   [`crate::coordinator::PjrtBackend`]), each layer sharded across
//!   engines along the chosen axis (filters, output rows, the 2-D
//!   filter × row grid, or the per-layer best of the three);
//! * [`ShardMode::LayerPipeline`] — the batch streams through the layer
//!   chain with one engine per stage.
//!
//! All produce identical logits (property-tested); they differ only in
//! how the work is spread over the farm.

use super::farm::{CanaryConfig, CanaryReport, EngineFarm, FarmConfig, PipelineStage};
use super::shard::ShardMode;
use crate::analytics::EnergyModel;
use crate::arch::{ArchConfig, ExecFidelity, SimStats};
use crate::coordinator::{BatchCost, BatchReport, InferenceBackend, LayerCost};
use crate::fault::{FaultConfig, FaultReport};
use crate::golden::{conv3d_i32, Tensor3};
use crate::model::quant::Requant;
use crate::model::ConvLayer;
use crate::util::SplitMix64;
use anyhow::{bail, Result};
use std::sync::Arc;

/// The workload a [`SimBackend`] serves: a chain of conv layers plus the
/// head that turns the last activations into logits (per-class global sum
/// pooling — class `k` pools ofmap channel `k`).
#[derive(Debug, Clone)]
pub struct SimNetSpec {
    /// Input shape `(C, H, W)`; requests carry `C·H·W` flat int32 values.
    pub input: (usize, usize, usize),
    /// Layer chain; layer `i+1`'s ifmap shape must equal layer `i`'s
    /// ofmap shape, and the last layer's `N` must equal `classes`.
    pub layers: Vec<ConvLayer>,
    /// Power-of-two re-quantisation shift applied after every layer
    /// (activations stay 8-bit between layers, like the paper's datapath).
    pub requant_shift: u32,
    /// Number of classes (= channels of the last layer).
    pub classes: usize,
    /// Seed for the deterministic weight generator.
    pub weight_seed: u64,
}

impl SimNetSpec {
    /// The default serving workload: a 3-layer, 10-class quantised CNN on
    /// 3×16×16 images — small enough that a cycle-accurate farm serves
    /// ~100-request workloads in seconds, big enough to exercise filter
    /// grouping, striding and the psum buffers.
    pub fn tiny() -> Self {
        let layers = vec![
            ConvLayer::new("SL1", 16, 3, 3, 8, 1, 1),  // 3×16×16 → 8×16×16
            ConvLayer::new("SL2", 16, 3, 8, 8, 2, 1),  // 8×16×16 → 8×8×8
            ConvLayer::new("SL3", 8, 3, 8, 10, 1, 1),  // 8×8×8  → 10×8×8
        ];
        Self { input: (3, 16, 16), layers, requant_shift: 6, classes: 10, weight_seed: 0x7215 }
    }

    /// A CL1-class serving workload: one wide-spatial, filter-starved
    /// layer (3 → 10 filters over 120×120 — the geometry class of VGG-16
    /// CL1, where `⌈N/P_N⌉` filter groups cannot occupy a big farm but
    /// `H_O` rows can). This is the workload `benches/farm_scaling.rs`
    /// sweeps the shard axes over. On 8 narrow (`P_N = 1`) engines the
    /// filter axis is capped at `10/2 = 5×` while the spatial axis bounds
    /// `8×`; at 16 engines *both* single axes fall short (filters 10×,
    /// rows `120/8 = 15×`) and only the 2×8 hybrid grid reaches `16×` —
    /// the shape the hybrid-sharding acceptance gate pins.
    pub fn cl1_class() -> Self {
        let layers = vec![
            ConvLayer::new("WL1", 120, 3, 3, 10, 1, 1), // 3×120×120 → 10×120×120
        ];
        Self { input: (3, 120, 120), layers, requant_shift: 6, classes: 10, weight_seed: 0xC11 }
    }

    /// Deterministic weights for layer `idx` of this spec.
    pub fn layer_weights(&self, idx: usize) -> Vec<i32> {
        let l = &self.layers[idx];
        let mut rng = SplitMix64::new(self.weight_seed.wrapping_add(idx as u64).wrapping_mul(0x9E37));
        rng.vec_i32(l.weight_elems() as usize, -4, 8)
    }

    fn validate(&self) {
        assert!(!self.layers.is_empty(), "SimNetSpec needs at least one layer");
        let (c, h, w) = self.input;
        assert_eq!((self.layers[0].m, self.layers[0].h_i, self.layers[0].w_i), (c, h, w));
        for (a, b) in self.layers.iter().zip(self.layers.iter().skip(1)) {
            assert_eq!(a.n, b.m, "{} → {}: channel mismatch", a.name, b.name);
            assert_eq!((a.h_o(), a.w_o()), (b.h_i, b.w_i), "{} → {}: shape mismatch", a.name, b.name);
        }
        assert_eq!(self.layers.last().unwrap().n, self.classes, "last layer must have `classes` filters");
    }
}

/// Inference backend that runs entirely on the simulated engine farm.
///
/// Because the farm is a simulator, every batch comes back with a
/// [`BatchCost`]: the farm-aggregated [`SimStats`] of the batch plus the
/// derived GOPS/joules — the Tables I–II accounting, priced by
/// [`EnergyModel`], surfaced through the serving API.
pub struct SimBackend {
    farm: EngineFarm,
    spec: SimNetSpec,
    weights: Vec<Arc<Vec<i32>>>,
    mode: ShardMode,
    requant: Requant,
    energy: EnergyModel,
    /// Cumulative canary totals already attributed to earlier batches —
    /// `infer_batch` reports per-batch *deltas* so the serving metrics
    /// (which sum batch costs) end up with the true totals.
    last_canary: CanaryReport,
    /// Cumulative fault totals already attributed to earlier batches
    /// (same delta scheme as `last_canary`).
    last_fault: FaultReport,
    /// infer_batch calls observed (exposed for batching assertions).
    pub calls: u64,
}

impl SimBackend {
    /// Default backend: the [`SimNetSpec::tiny`] workload on `engines`
    /// narrow engines (`P_N = 1`, so every engine count up to ~8 gets its
    /// own filter groups to shard).
    pub fn new(engines: usize) -> Self {
        Self::with_spec(engines, ArchConfig::small(3, 2, 1), SimNetSpec::tiny(), ShardMode::FilterShards)
    }

    /// Full control over the farm and workload (fast-tier engines — the
    /// farm default; see [`SimBackend::with_fidelity`] for the oracle).
    pub fn with_spec(engines: usize, arch: ArchConfig, spec: SimNetSpec, mode: ShardMode) -> Self {
        Self::with_fidelity(engines, arch, spec, mode, ExecFidelity::Fast)
    }

    /// Full control including the engines' execution tier. Both tiers
    /// serve bit-identical logits; `Register` trades orders of magnitude
    /// of throughput for cycle-by-cycle engine observability.
    pub fn with_fidelity(
        engines: usize,
        arch: ArchConfig,
        spec: SimNetSpec,
        mode: ShardMode,
        fidelity: ExecFidelity,
    ) -> Self {
        Self::with_canary(engines, arch, spec, mode, fidelity, CanaryConfig::default())
    }

    /// Full control including the farm's shadow-execution canary: a
    /// `canary.sample_rate` fraction of the sharded-path shards are
    /// re-executed on a `Register`-fidelity oracle off the hot path, and
    /// each batch's [`BatchCost::canary`] carries the divergence delta
    /// observed since the previous batch. The pipeline mode never
    /// samples (its inputs are consumed by the stage workers).
    pub fn with_canary(
        engines: usize,
        arch: ArchConfig,
        spec: SimNetSpec,
        mode: ShardMode,
        fidelity: ExecFidelity,
        canary: CanaryConfig,
    ) -> Self {
        Self::with_chaos(engines, arch, spec, mode, fidelity, canary, FaultConfig::disabled())
    }

    /// Full control including the farm's fault-injection plan. When
    /// `chaos.enabled()`, each engine deterministically corrupts a
    /// `chaos.rate` fraction of its shard results; the farm's ABFT
    /// checksum catches them at merge time and the self-healing loop
    /// re-executes / quarantines, so the *served* logits stay bit-exact.
    /// Each batch's [`BatchCost::faults`] carries the fault activity
    /// observed since the previous batch.
    pub fn with_chaos(
        engines: usize,
        arch: ArchConfig,
        spec: SimNetSpec,
        mode: ShardMode,
        fidelity: ExecFidelity,
        canary: CanaryConfig,
        chaos: FaultConfig,
    ) -> Self {
        Self::with_farm_config(
            FarmConfig::with_fidelity(engines, arch, fidelity).with_canary(canary).with_chaos(chaos),
            spec,
            mode,
        )
    }

    /// Fullest control: hand the farm configuration over verbatim —
    /// hedging (`FarmConfig::with_hedge`), the analytic safety valve,
    /// probation cooldowns, chaos, canary. The other constructors are
    /// sugar over this; the serving CLI uses it to wire
    /// `--hedge-factor`/`--straggler-threshold` through.
    pub fn with_farm_config(cfg: FarmConfig, spec: SimNetSpec, mode: ShardMode) -> Self {
        spec.validate();
        let farm = EngineFarm::new(cfg);
        let weights = (0..spec.layers.len()).map(|i| Arc::new(spec.layer_weights(i))).collect();
        let requant = Requant::new(spec.requant_shift, 8);
        Self {
            farm,
            spec,
            weights,
            mode,
            requant,
            energy: EnergyModel::paper(),
            last_canary: CanaryReport::default(),
            last_fault: FaultReport::default(),
            calls: 0,
        }
    }

    /// The underlying farm — its [`crate::obs::Registry`] telemetry and
    /// canary totals are read through here (`trim farm` summary).
    pub fn farm(&self) -> &EngineFarm {
        &self.farm
    }

    pub fn mode(&self) -> ShardMode {
        self.mode
    }

    pub fn engines(&self) -> usize {
        self.farm.engines()
    }

    /// The energy model used to price [`BatchCost::joules`].
    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    fn image_tensor(&self, image: &[i32]) -> Tensor3 {
        let (c, h, w) = self.spec.input;
        Tensor3 { c, h, w, data: image.to_vec() }
    }

    /// Per-class global sum pooling over the last activations.
    fn head(&self, act: &Tensor3) -> Vec<i32> {
        (0..self.spec.classes)
            .map(|k| act.channel(k).iter().map(|&v| v as i64).sum::<i64>() as i32)
            .collect()
    }

    fn requant_inplace(&self, t: &mut Tensor3) {
        for v in t.data.iter_mut() {
            *v = self.requant.apply(*v as i64) as i32;
        }
    }

    /// Layer-serial forward of one image, every layer sharded across the
    /// farm along `self.mode`'s axis (the weight-resident order of the
    /// PJRT backend). Weights stay behind their cached `Arc`s — nothing is
    /// copied per request except the incoming image. Returns the logits
    /// plus one shard-reduced [`SimStats`] per layer (cycles = max over
    /// the layer's parallel shards, accesses = sum); the layers run
    /// sequentially, so folding them with `merge_sequential` gives the
    /// image's aggregate.
    fn forward_sharded(&self, image: &[i32]) -> Result<(Vec<i32>, Vec<SimStats>)> {
        let mut act = Arc::new(self.image_tensor(image));
        let mut per_layer = Vec::with_capacity(self.spec.layers.len());
        for (layer, weights) in self.spec.layers.iter().zip(&self.weights) {
            let mut r = self.farm.run_layer_shared(layer, act, Arc::clone(weights), self.mode)?;
            per_layer.push(r.stats);
            self.requant_inplace(&mut r.ofmaps);
            act = Arc::new(r.ofmaps);
        }
        Ok((self.head(&act), per_layer))
    }

    fn pipeline_stages(&self) -> Vec<PipelineStage> {
        self.spec
            .layers
            .iter()
            .zip(&self.weights)
            .map(|(layer, weights)| PipelineStage {
                layer: layer.clone(),
                weights: Arc::clone(weights),
                requant: Some(self.requant),
            })
            .collect()
    }

    /// Golden-model reference (no farm, no simulator): the logits this
    /// backend must produce for `image`. Used by the tests to pin the
    /// serving path to the golden convolution oracle.
    pub fn reference_logits(&self, image: &[i32]) -> Vec<i32> {
        let mut act = self.image_tensor(image);
        for (layer, weights) in self.spec.layers.iter().zip(&self.weights) {
            let mut out = conv3d_i32(&act, weights, layer.n, layer.k, layer.stride, layer.pad);
            self.requant_inplace(&mut out);
            act = out;
        }
        self.head(&act)
    }
}

impl InferenceBackend for SimBackend {
    fn input_len(&self) -> usize {
        let (c, h, w) = self.spec.input;
        c * h * w
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchReport> {
        self.calls += 1;
        let expect = self.input_len();
        for img in images {
            if img.len() != expect {
                bail!("sim backend: image length {} != expected {}", img.len(), expect);
            }
        }
        let f_clk = self.farm.arch().f_clk;
        let (outputs, stats, per_layer) = match self.mode {
            ShardMode::LayerPipeline => {
                let stages = self.pipeline_stages();
                let inputs: Vec<Tensor3> = images.iter().map(|img| self.image_tensor(img)).collect();
                let r = self.farm.run_pipeline(&stages, inputs)?;
                // PipelineRunResult already reduces across engines
                // (cycles = max over parallel engines, accesses = sum);
                // the per-stage breakdown is the per-layer cost table.
                let per_layer = self
                    .spec
                    .layers
                    .iter()
                    .zip(&r.per_stage)
                    .map(|(l, s)| LayerCost::from_stats(l.name.as_str(), s))
                    .collect();
                (r.outputs.iter().map(|t| self.head(t)).collect(), r.stats, per_layer)
            }
            // Filter, spatial, hybrid or auto axis: images run back to
            // back through the farm; per-image stats (already
            // shard-reduced per layer) add cycles, and each layer's
            // contributions fold into the per-layer cost table.
            ShardMode::FilterShards | ShardMode::Spatial | ShardMode::Hybrid | ShardMode::Auto => {
                let mut stats = SimStats::default();
                let mut per_layer: Vec<LayerCost> = self
                    .spec
                    .layers
                    .iter()
                    .map(|l| LayerCost { name: l.name.clone(), ..LayerCost::default() })
                    .collect();
                let mut outputs = Vec::with_capacity(images.len());
                for img in images {
                    let (logits, layer_stats) = self.forward_sharded(img)?;
                    for (acc, s) in per_layer.iter_mut().zip(&layer_stats) {
                        acc.add_stats(s);
                        stats.merge_sequential(s);
                    }
                    outputs.push(logits);
                }
                (outputs, stats, per_layer)
            }
        };
        // Attribute the canary activity observed since the last batch to
        // this one. Drain first so every shard this batch submitted has
        // been checked — the oracle is slow, but it only re-runs the
        // sampled fraction.
        let canary = if self.farm.canary_enabled() {
            self.farm.canary_drain();
            let total = self.farm.canary_report();
            let delta = total.delta_since(&self.last_canary);
            self.last_canary = total;
            delta
        } else {
            CanaryReport::default()
        };
        // Fault counters are updated synchronously at shard-merge time, so
        // no drain is needed: everything this batch merged is in the totals.
        let faults = {
            let total = self.farm.fault_report();
            let delta = total.delta_since(&self.last_fault);
            self.last_fault = total;
            delta
        };
        Ok(BatchReport::with_cost(
            outputs,
            BatchCost::from_stats(stats, f_clk, &self.energy)
                .with_per_layer(per_layer)
                .with_canary(canary)
                .with_faults(faults),
        ))
    }

    fn describe(&self) -> String {
        format!(
            "sim[{} engines, {:?}, {} fidelity, {} layers, {} classes]",
            self.farm.engines(),
            self.mode,
            self.farm.fidelity(),
            self.spec.layers.len(),
            self.spec.classes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image(seed: u64, len: usize) -> Vec<i32> {
        SplitMix64::new(seed).vec_i32(len, 0, 256)
    }

    #[test]
    fn both_modes_match_the_golden_reference() {
        let mut sharded = SimBackend::new(2);
        let mut piped = SimBackend::with_spec(
            2,
            ArchConfig::small(3, 2, 1),
            SimNetSpec::tiny(),
            ShardMode::LayerPipeline,
        );
        let len = sharded.input_len();
        let imgs: Vec<Vec<i32>> = (0..3).map(|i| image(100 + i, len)).collect();
        let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let expect: Vec<Vec<i32>> = imgs.iter().map(|v| sharded.reference_logits(v)).collect();
        let rs = sharded.infer_batch(&refs).unwrap();
        let rp = piped.infer_batch(&refs).unwrap();
        assert_eq!(rs.outputs, expect);
        assert_eq!(rp.outputs, expect);
        // Both modes report a priced batch cost, and since they execute
        // the same layers on the same images, the work counters agree —
        // only the wall-cycle reduction differs between the modes.
        let (cs, cp) = (rs.cost.unwrap(), rp.cost.unwrap());
        assert!(cs.stats.cycles > 0 && cp.stats.cycles > 0);
        assert_eq!(cs.stats.macs, cp.stats.macs, "same MACs either way");
        assert_eq!(cs.stats.ext_input_reads, cp.stats.ext_input_reads);
        assert_eq!(cs.stats.output_writes, cp.stats.output_writes);
        assert!(cs.joules > 0.0 && cp.joules > 0.0);
    }

    #[test]
    fn spatial_hybrid_and_auto_modes_match_the_golden_reference() {
        let mut by_mode: Vec<SimBackend> = [ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto]
            .into_iter()
            .map(|m| SimBackend::with_spec(3, ArchConfig::small(3, 2, 1), SimNetSpec::tiny(), m))
            .collect();
        let len = by_mode[0].input_len();
        let imgs: Vec<Vec<i32>> = (0..2).map(|i| image(700 + i, len)).collect();
        let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let expect: Vec<Vec<i32>> = imgs.iter().map(|v| by_mode[0].reference_logits(v)).collect();
        for b in by_mode.iter_mut() {
            let mode = b.mode();
            let r = b.infer_batch(&refs).unwrap();
            assert_eq!(r.outputs, expect, "{mode:?} logits vs golden");
            let cost = r.cost.expect("sharded sim batches carry cost");
            assert!(cost.stats.cycles > 0 && cost.joules > 0.0, "{mode:?}");
        }
    }

    #[test]
    fn cl1_class_spec_is_filter_starved() {
        // The bench workload's defining property: on 8 narrow engines the
        // filter axis bounds 5× while rows bound 8× — Auto must pick rows.
        use crate::scheduler::shard::{plan_shards, ShardAxis};
        let spec = SimNetSpec::cl1_class();
        spec.validate();
        let arch = ArchConfig::small(3, 2, 2); // P_N = 2 → 5 filter groups
        let plan = plan_shards(&arch, &spec.layers[0], 8, ShardMode::Auto);
        assert_eq!(plan.axis, ShardAxis::Rows);
        assert!((plan.speedup_bound() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn batch_cost_carries_per_layer_breakdown() {
        // Layer-serial modes: the per-layer table names every spec layer
        // in order and sums exactly to the batch totals (layers and
        // images are sequential, so cycles partition too).
        let mut b = SimBackend::with_spec(3, ArchConfig::small(3, 2, 1), SimNetSpec::tiny(), ShardMode::Auto);
        let len = b.input_len();
        let imgs: Vec<Vec<i32>> = (0..2).map(|i| image(900 + i, len)).collect();
        let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let cost = b.infer_batch(&refs).unwrap().cost.unwrap();
        let names: Vec<&str> = cost.per_layer.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["SL1", "SL2", "SL3"]);
        assert!(cost.per_layer.iter().all(|l| l.cycles > 0 && l.macs > 0));
        assert_eq!(cost.per_layer.iter().map(|l| l.cycles).sum::<u64>(), cost.stats.cycles);
        assert_eq!(cost.per_layer.iter().map(|l| l.macs).sum::<u64>(), cost.stats.macs);
        assert_eq!(
            cost.per_layer.iter().map(|l| l.off_chip_accesses).sum::<u64>(),
            cost.stats.off_chip_accesses()
        );
        assert_eq!(
            cost.per_layer.iter().map(|l| l.on_chip_accesses).sum::<u64>(),
            cost.stats.on_chip_accesses()
        );

        // Pipeline mode: same per-layer work counters; cycles sum to the
        // total *work*, which is ≥ the parallel wall-clock of the batch.
        let mut p = SimBackend::with_spec(
            2,
            ArchConfig::small(3, 2, 1),
            SimNetSpec::tiny(),
            ShardMode::LayerPipeline,
        );
        let pcost = p.infer_batch(&refs).unwrap().cost.unwrap();
        assert_eq!(pcost.per_layer.len(), 3);
        assert_eq!(pcost.per_layer.iter().map(|l| l.macs).sum::<u64>(), pcost.stats.macs);
        assert_eq!(
            pcost.per_layer.iter().map(|l| l.macs).sum::<u64>(),
            cost.per_layer.iter().map(|l| l.macs).sum::<u64>(),
            "same work either way"
        );
        assert!(pcost.per_layer.iter().map(|l| l.cycles).sum::<u64>() >= pcost.stats.cycles);
    }

    #[test]
    fn rejects_wrong_image_length() {
        let mut b = SimBackend::new(1);
        let img = vec![0i32; 5];
        assert!(b.infer_batch(&[&img]).is_err());
    }

    #[test]
    fn describe_names_the_farm() {
        let b = SimBackend::new(3);
        assert!(b.describe().contains("3 engines"));
        assert!(b.describe().contains("fast fidelity"), "got {}", b.describe());
        assert_eq!(b.engines(), 3);
    }

    #[test]
    fn full_rate_canary_reads_zero_divergence_on_tiny() {
        // The acceptance gate: shadow-executing *every* shard of the tiny
        // workload on the register oracle finds no bit or counter
        // divergence — the two tiers really are exact twins in serving.
        let mut b = SimBackend::with_canary(
            2,
            ArchConfig::small(3, 2, 1),
            SimNetSpec::tiny(),
            ShardMode::Auto,
            ExecFidelity::Fast,
            CanaryConfig::sampled(1.0),
        );
        let len = b.input_len();
        let imgs: Vec<Vec<i32>> = (0..2).map(|i| image(2500 + i, len)).collect();
        let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let r1 = b.infer_batch(&refs).unwrap();
        let c1 = r1.cost.unwrap().canary;
        assert!(c1.sampled > 0, "rate 1.0 must sample every shard");
        assert!(c1.is_clean(), "fast tier diverged from the oracle: {c1:?}");
        // deltas: a second batch reports only its own samples
        let r2 = b.infer_batch(&refs).unwrap();
        let c2 = r2.cost.unwrap().canary;
        assert_eq!(c2.sampled, c1.sampled, "same batch shape → same per-batch sample count");
        assert!(c2.is_clean());
        // farm-level totals accumulate across both batches
        assert_eq!(b.farm().canary_report().sampled, c1.sampled + c2.sampled);
        // logits still match the golden reference with the canary on
        let expect: Vec<Vec<i32>> = imgs.iter().map(|v| b.reference_logits(v)).collect();
        assert_eq!(r1.outputs, expect);
    }

    #[test]
    fn canary_off_batch_reports_are_unchanged() {
        // canary-off costs carry an all-zero CanaryReport, so reports stay
        // comparable across canary-on/off deployments.
        let mut b = SimBackend::new(2);
        let img = image(41, b.input_len());
        let cost = b.infer_batch(&[&img]).unwrap().cost.unwrap();
        assert_eq!(cost.canary, CanaryReport::default());
        assert!(!b.farm().canary_enabled());
        // Likewise chaos-off: all-zero FaultReport, chaos disabled.
        assert_eq!(cost.faults, FaultReport::default());
        assert!(!b.farm().chaos_enabled());
    }

    #[test]
    fn chaos_backend_serves_golden_logits_and_reports_fault_deltas() {
        // Faults injected into the farm are detected, healed and
        // attributed per batch — while the *served* logits stay golden.
        // Fault draws are keyed on (seed, engine, shard signature), so a
        // shard whose draw fires on *every* engine deterministically
        // exhausts its retries (a typed error, never a wrong answer).
        // Which engine first runs a shard is a work-stealing race, so per
        // batch only the invariants hold, not an exact count — the test
        // scans seeds until one yields a fully healed batch (rate 0.3 on
        // 4 engines ≈ 90% of seeds).
        use crate::fault::{FaultConfig, FaultModel};
        let mut healed = false;
        for seed in 0..16u64 {
            let mut b = SimBackend::with_chaos(
                4,
                ArchConfig::small(3, 2, 1),
                SimNetSpec::tiny(),
                ShardMode::FilterShards,
                ExecFidelity::Fast,
                CanaryConfig::default(),
                FaultConfig::new(0.3, seed, FaultModel::Pe),
            );
            let len = b.input_len();
            let imgs: Vec<Vec<i32>> = (0..2).map(|i| image(3100 + i, len)).collect();
            let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
            let expect: Vec<Vec<i32>> = imgs.iter().map(|v| b.reference_logits(v)).collect();
            match b.infer_batch(&refs) {
                Ok(r) => {
                    assert_eq!(r.outputs, expect, "healed chaos batch must serve golden logits");
                    let f = r.cost.unwrap().faults;
                    assert_eq!(f.detected, f.injected, "ABFT catches every injected corruption");
                    assert_eq!(f.reexecuted, f.detected);
                    // Per-batch deltas sum to the farm-level totals.
                    assert_eq!(b.farm().fault_report(), f);
                    if f.injected > 0 {
                        assert!(f.corrected > 0, "a healed faulty batch corrected something");
                        healed = true;
                        break;
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    assert!(
                        msg.contains("ABFT checksum mismatch") && msg.contains("attempts"),
                        "chaos failures must be typed: {msg}"
                    );
                }
            }
        }
        assert!(healed, "no seed in 0..16 produced a healed faulty batch");
    }

    #[test]
    fn register_fidelity_backend_serves_identical_logits() {
        let mut fast = SimBackend::new(2);
        let mut reg = SimBackend::with_fidelity(
            2,
            ArchConfig::small(3, 2, 1),
            SimNetSpec::tiny(),
            ShardMode::FilterShards,
            ExecFidelity::Register,
        );
        assert!(reg.describe().contains("register fidelity"));
        let len = fast.input_len();
        let imgs: Vec<Vec<i32>> = (0..2).map(|i| image(400 + i, len)).collect();
        let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
        // Whole-report equality: identical logits AND identical BatchCost
        // (the fast tier's counters are exact vs the register oracle).
        assert_eq!(fast.infer_batch(&refs).unwrap(), reg.infer_batch(&refs).unwrap());
    }
}
