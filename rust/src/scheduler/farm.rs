//! The engine farm: a pool of worker threads, each wrapping one
//! cycle-accurate [`EngineSim`], plus the dispatch/merge logic that makes
//! the pool behave like one big accelerator.
//!
//! Distribution strategies (see [`super::shard::ShardMode`]):
//!
//! * **filter shards** — [`EngineFarm::run_layer`] splits a layer's
//!   filters across engines on `P_N`-group boundaries (the planner of
//!   [`super::shard`]) and reassembles the ofmaps bit-exactly. This is the
//!   multi-fabric scaling of the 3D-TrIM follow-up: every fabric sees the
//!   same broadcast inputs and owns a disjoint set of filters.
//! * **spatial (row) shards** — split the layer's *output rows* instead:
//!   each engine runs all `N` filters over a contiguous row band
//!   ([`super::shard::plan_row_shards`]), reading its input slab including
//!   the halo rows shared with neighbouring bands. This is the axis that
//!   saturates the farm on CL1-class layers whose few filter groups leave
//!   filter sharding starved.
//! * **hybrid grid** — cut both dimensions at once
//!   ([`super::shard::plan_hybrid_shards`]): each shard is a filter-range
//!   × row-band tile, so farms bigger than either single axis keep
//!   scaling; `Auto` picks the best of the three axes per layer.
//! * **layer pipeline** — [`EngineFarm::run_pipeline`] streams a batch of
//!   images through a layer chain, each (image, stage) pair an
//!   independent job (contrast with Chain-NN's serial chain, where one
//!   fabric owns the whole network).
//!
//! **Dispatch is work-stealing**, not static assignment: every job goes
//! into one shared injector queue ([`Injector`], std-only
//! `Mutex<VecDeque>` + `Condvar`) and idle workers pop whatever is next,
//! so one slow band no longer idles the rest of the pool while its
//! pre-assigned neighbour queues up. Results are bit-identical regardless
//! of which engine runs which shard (shards are self-contained and the
//! merge below writes disjoint ranges keyed by the shard, not the
//! worker) — property-tested against a static single-engine baseline in
//! tests/scheduler_farm.rs. A job that panics inside a worker is caught
//! ([`std::panic::catch_unwind`]) and surfaced to the dispatching caller
//! as a named-engine [`anyhow::Error`] instead of deadlocking the reply
//! channel; the worker and its engine survive for subsequent jobs.
//!
//! Stats follow the Tables I–II accounting: counters of parallel shards
//! **sum** (every access really happens — a row band's off-chip input
//! reads count its whole slab, halo rows included) while cycles take the
//! **max** (shards run concurrently); within one engine, sequential jobs
//! add their cycles. Both reductions reuse [`SimStats::merge`] /
//! [`SimStats::merge_sequential`].
//!
//! **Observability.** Every farm owns an [`crate::obs::Registry`]
//! ([`EngineFarm::registry`]): per-engine job/busy/idle/steal counters,
//! an injector queue-depth gauge, and farm-wide scratch fill/hit and
//! per-microkernel-arm invocation totals harvested from each engine
//! after every job. Layer runs and per-shard executions record
//! parent-linked spans into the global [`crate::obs::tracer`].
//!
//! **Shadow-execution canary.** With [`CanaryConfig::sample_rate`] > 0
//! the farm keeps one extra `Register`-fidelity engine off the hot path
//! and re-executes a deterministic sample of completed shards on it,
//! comparing the fast tier's ofmaps (bit-exactness) and [`SimStats`]
//! (counter-exactness) against the cycle-accurate oracle. Divergence is
//! *published as a metric* ([`EngineFarm::canary_report`], flowing into
//! `MetricsSnapshot` and merged across farms by the Router) instead of
//! failing a test — production canarying of the simulator itself.
//!
//! **Gray-failure tolerance.** A shard that answers *late or never*
//! stalls the merge just as surely as a wrong answer — `cycles = max
//! over shards` means one gray-failed engine caps farm throughput.
//! Because execution is deterministic and bit-exact, duplicate
//! execution carries no correctness risk, so the farm hedges: every
//! dispatched shard gets a **service budget** from the closed-form
//! eq. (2) cycle estimate ([`crate::verify::analytic_shard_stats`])
//! × the fleet's observed wall-µs-per-analytic-cycle EWMA
//! ([`EngineHealthMap`]); a shard still outstanding past
//! [`FarmConfig::hedge_factor`] × budget is re-injected through the
//! same work-stealing injector and the **first** result wins
//! ([`FirstWins`]: the merge-once claim doubles as the cancel flag the
//! loser observes — model-checked in tests/loom_models.rs). Late
//! arrivals are discarded (`hedge_wasted`) and attributed as timing
//! strikes; engines crossing [`FarmConfig::straggler_threshold`]
//! quarantine with a [`EngineHealth::Slow`] cause, and quarantined
//! engines come back on **probation** after a cooldown (one clean shard
//! restores them, one fault re-quarantines with the cooldown doubled).
//! The same health map feeds cost-proportional shard sizing: once the
//! fleet's slowdown skew passes a gate, plans come from
//! [`plan_shards_weighted`] (slow engines get proportionally smaller
//! filter-groups/row-bands and are soft-banned from above-median
//! shards) — the heterogeneous-farm hook.

use super::shard::{plan_shards, plan_shards_weighted, ShardMode, ShardPlan};
use crate::arch::engine::EngineRunResult;
use crate::arch::{ArchConfig, EngineSim, ExecFidelity, SimStats};
use crate::coordinator::ServeError;
use crate::fault::{AbftChecker, EngineHealth, FaultConfig, FaultInjector, FaultReport, TimingFault};
use crate::golden::Tensor3;
use crate::model::quant::Requant;
use crate::model::ConvLayer;
use crate::obs::{self, Counter, Gauge, Registry};
use crate::util::sync::{
    lock_unpoisoned, AtomicBool, AtomicU64, Condvar, Mutex, MutexGuard, Ordering, PoisonError,
};
use crate::util::SplitMix64;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shadow-execution canary configuration: re-run a sampled fraction of
/// completed shards on a `Register`-fidelity engine off the hot path and
/// publish bit/counter divergence as metrics.
#[derive(Debug, Clone, Copy)]
pub struct CanaryConfig {
    /// Fraction of completed shards to shadow-execute (`0.0` disables
    /// the canary entirely — no thread, no overhead; `1.0` samples every
    /// shard deterministically).
    pub sample_rate: f64,
    /// Seed of the deterministic sampling PRNG (rates strictly between
    /// 0 and 1 draw one uniform per shard).
    pub seed: u64,
    /// Test hook: flip the low bit of the first ofmap element of the
    /// *copy fed to the canary* (served results are untouched), so tests
    /// can prove a diverging fast tier is caught and counted.
    #[doc(hidden)]
    pub perturb: bool,
}

impl CanaryConfig {
    /// Canary at `sample_rate`, default seed, no perturbation.
    pub fn sampled(sample_rate: f64) -> Self {
        Self { sample_rate, ..Default::default() }
    }

    pub fn enabled(&self) -> bool {
        self.sample_rate > 0.0
    }
}

impl Default for CanaryConfig {
    fn default() -> Self {
        Self { sample_rate: 0.0, seed: 0x5EED_CA9A, perturb: false }
    }
}

/// Cumulative canary totals (all saturating). `Default` is all-zero,
/// which is also what a canary-less farm reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CanaryReport {
    /// Shards shadow-executed on the register oracle.
    pub sampled: u64,
    /// Samples whose ofmaps were not bit-identical to the oracle's.
    pub bit_divergence: u64,
    /// Samples whose [`SimStats`] differed from the oracle's.
    pub counter_divergence: u64,
}

impl CanaryReport {
    /// Saturating element-wise accumulation (Router-side merge).
    pub fn merge(&mut self, other: &Self) {
        self.sampled = self.sampled.saturating_add(other.sampled);
        self.bit_divergence = self.bit_divergence.saturating_add(other.bit_divergence);
        self.counter_divergence = self.counter_divergence.saturating_add(other.counter_divergence);
    }

    /// Element-wise `self - prev` (saturating), for per-batch deltas
    /// against a cumulative report.
    pub fn delta_since(&self, prev: &Self) -> Self {
        Self {
            sampled: self.sampled.saturating_sub(prev.sampled),
            bit_divergence: self.bit_divergence.saturating_sub(prev.bit_divergence),
            counter_divergence: self.counter_divergence.saturating_sub(prev.counter_divergence),
        }
    }

    /// No divergence observed (vacuously true with zero samples).
    pub fn is_clean(&self) -> bool {
        self.bit_divergence == 0 && self.counter_divergence == 0
    }
}

/// Farm-level configuration.
#[derive(Debug, Clone, Copy)]
pub struct FarmConfig {
    /// Number of simulated TrIM engines (worker threads).
    pub engines: usize,
    /// Architecture of every engine in the pool (homogeneous farm).
    pub arch: ArchConfig,
    /// Execution tier of every engine. The farm defaults to
    /// [`ExecFidelity::Fast`] — identical results (bit-exact ofmaps,
    /// counter-exact stats), orders of magnitude more layer throughput;
    /// pick [`ExecFidelity::Register`] to run the cycle-accurate oracle.
    pub fidelity: ExecFidelity,
    /// Shadow-execution canary (off by default).
    pub canary: CanaryConfig,
    /// Seeded hardware fault injection ([`crate::fault`], disabled by
    /// default). Non-zero rates attach a [`FaultInjector`] to every
    /// worker engine — the chaos-testing mode behind `--chaos`.
    pub chaos: FaultConfig,
    /// Self-healing: maximum re-executions of one shard after a
    /// detected fault (ABFT checksum mismatch or worker panic) before
    /// the layer run fails with a typed error.
    pub max_retries: u32,
    /// Self-healing: an engine with this many attributed faults is
    /// quarantined — banned from all future jobs, with subsequent
    /// layers replanned over the surviving engines. The last live
    /// engine is never quarantined.
    pub quarantine_after: u32,
    /// Hedged re-execution: a shard outstanding past `hedge_factor ×`
    /// its analytic service budget is re-injected for another engine
    /// and the first bit-exact result wins. `0.0` disables hedging
    /// (the library default — serving paths opt in via
    /// `--hedge-factor`); single-engine farms never hedge.
    pub hedge_factor: f64,
    /// Timing strikes (late arrivals past budget) before an engine is
    /// quarantined with the [`EngineHealth::Slow`] cause.
    pub straggler_threshold: u32,
    /// Floor of the whole-layer safety valve: a layer run that has not
    /// completed by `max(valve_floor, valve_multiplier × analytic
    /// estimate)` fails with a typed [`ServeError::EngineFailed`]
    /// instead of blocking forever. The default floor keeps the old
    /// 300 s ceiling for cold farms (no µs-per-cycle EWMA yet to scale
    /// the analytic estimate); tests and benches tighten it via
    /// [`FarmConfig::with_valve`].
    pub valve_floor: Duration,
    /// Multiplier of the valve's analytic component (see `valve_floor`).
    pub valve_multiplier: f64,
    /// Cooldown before a quarantined engine is released on probation
    /// (one clean shard restores it; one fault re-quarantines it with
    /// the cooldown doubled). Long by default so short-lived test farms
    /// keep PR 9's never-returns semantics.
    pub probation_cooldown: Duration,
}

impl FarmConfig {
    pub fn new(engines: usize, arch: ArchConfig) -> Self {
        Self {
            engines,
            arch,
            fidelity: ExecFidelity::Fast,
            canary: CanaryConfig::default(),
            chaos: FaultConfig::default(),
            max_retries: 3,
            quarantine_after: 3,
            hedge_factor: 0.0,
            straggler_threshold: 3,
            valve_floor: Duration::from_secs(300),
            valve_multiplier: 8.0,
            probation_cooldown: Duration::from_secs(60),
        }
    }

    pub fn with_fidelity(engines: usize, arch: ArchConfig, fidelity: ExecFidelity) -> Self {
        Self { fidelity, ..Self::new(engines, arch) }
    }

    /// Builder: enable the shadow-execution canary.
    pub fn with_canary(mut self, canary: CanaryConfig) -> Self {
        self.canary = canary;
        self
    }

    /// Builder: enable seeded fault injection (chaos testing).
    pub fn with_chaos(mut self, chaos: FaultConfig) -> Self {
        self.chaos = chaos;
        self
    }

    /// Builder: tune the self-healing policy.
    pub fn with_heal(mut self, max_retries: u32, quarantine_after: u32) -> Self {
        self.max_retries = max_retries;
        self.quarantine_after = quarantine_after.max(1);
        self
    }

    /// Builder: enable hedged re-execution of stragglers.
    pub fn with_hedge(mut self, hedge_factor: f64, straggler_threshold: u32) -> Self {
        self.hedge_factor = hedge_factor.max(0.0);
        self.straggler_threshold = straggler_threshold.max(1);
        self
    }

    /// Builder: tune the layer-run safety valve.
    pub fn with_valve(mut self, floor: Duration, multiplier: f64) -> Self {
        self.valve_floor = floor;
        self.valve_multiplier = multiplier.max(1.0);
        self
    }

    /// Builder: tune the quarantine-probation cooldown.
    pub fn with_probation(mut self, cooldown: Duration) -> Self {
        self.probation_cooldown = cooldown;
        self
    }
}

impl Default for FarmConfig {
    fn default() -> Self {
        Self::new(4, ArchConfig::paper_engine())
    }
}

/// The first-result-wins rendezvous of one hedged shard: a single
/// atomic flag whose `claim()` both guards the merge (exactly one
/// caller wins) **and** is the cancel signal losers observe — there is
/// no window where a result has merged but a duplicate still believes
/// it is wanted, because they are the same bit. Workers poll
/// [`FirstWins::is_cancelled`] at pickup (drop the duplicate unrun) and
/// inside timing-chaos stalls (abandon the straggle). Model-checked in
/// tests/loom_models.rs: no lost result, no double-merge, the loser
/// always observes the winner's claim.
#[derive(Debug, Default)]
pub struct FirstWins {
    won: AtomicBool,
}

impl FirstWins {
    pub fn new() -> Self {
        Self::default()
    }

    /// Claim the merge. Returns true for exactly one caller across all
    /// twins of the shard; every subsequent `is_cancelled` observes it.
    pub fn claim(&self) -> bool {
        !self.won.swap(true, Ordering::AcqRel)
    }

    /// Whether some twin already claimed the merge (the loser's view).
    pub fn is_cancelled(&self) -> bool {
        self.won.load(Ordering::Acquire)
    }
}

/// One unit of work for a worker: a filter-range × row-band tile of one
/// layer (either range may be the full dimension — the engine's
/// [`EngineSim::run_shard_shared`] degenerates to the matching 1-D or
/// whole-layer path), plus an optional output re-quantisation (used
/// between pipeline stages).
struct Job {
    layer: ConvLayer,
    input: Arc<Tensor3>,
    weights: Arc<Vec<i32>>,
    filters: Range<usize>,
    rows: Range<usize>,
    requant: Option<Requant>,
    tag: u64,
    /// Span id of the dispatching layer/pipeline run (0 = root), so the
    /// worker's per-shard span links back across the thread boundary.
    trace_parent: u64,
    /// Bit mask of engines that must not run this job: quarantined
    /// engines plus — on a re-execution — every engine that already
    /// produced a faulty result for this shard. A banned worker hands
    /// the job back to the injector. Engine ids ≥ 64 are never banned
    /// (see [`engine_bit`]).
    banned: u64,
    /// Shared first-result-wins flag of this shard (all twins of one
    /// tag clone the same `Arc`). Claimed by the merge loop; observed
    /// by workers as the cancel signal.
    cancel: Arc<FirstWins>,
    /// Whether this job is a hedged duplicate (latency accounting: its
    /// service time is measured from the hedge push, not the layer
    /// start).
    hedge: bool,
    reply: Sender<JobDone>,
}

/// The `banned`-mask bit of one engine. Ids past the mask width can
/// never be banned — the mask degrades to "retry anywhere", which is
/// safe (a re-execution merely loses the different-engine guarantee).
#[inline]
fn engine_bit(id: usize) -> u64 {
    if id < 64 {
        1u64 << id
    } else {
        0
    }
}

struct JobDone {
    tag: u64,
    /// Worker that executed (or failed) the job.
    engine: usize,
    filters: Range<usize>,
    rows: Range<usize>,
    /// Whether this reply came from a hedged duplicate.
    hedged: bool,
    /// `Err(panic message)` when the job panicked inside the worker.
    result: std::result::Result<EngineRunResult, String>,
}

/// The shared work-stealing injector: every worker pops from one queue,
/// so idle engines steal whatever shard is next instead of waiting on a
/// static per-worker assignment. std-only by design (the crate builds
/// offline): a `Mutex<VecDeque<T>>` plus a `Condvar` workers park on —
/// both from [`crate::util::sync`], so `--cfg loom` builds swap in
/// loom's model-checked primitives and tests/loom_models.rs explores
/// every push/pop/shutdown interleaving (no lost job, no double pop).
/// Generic over the job type for exactly that reason: the farm
/// instantiates it with [`Job`], the models with plain integers.
pub struct Injector<T> {
    state: Mutex<InjectorState<T>>,
    ready: Condvar,
    /// Live queue-depth gauge (`injector.depth` in the farm registry),
    /// updated under the state lock on every push/pop.
    depth: Arc<Gauge>,
}

struct InjectorState<T> {
    jobs: VecDeque<T>,
    shutdown: bool,
}

impl<T> Injector<T> {
    /// New empty injector publishing its depth through `depth`.
    pub fn new(depth: Arc<Gauge>) -> Self {
        Self {
            state: Mutex::new(InjectorState { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
            depth,
        }
    }

    /// Jobs run *outside* the lock (the guard is dropped before the
    /// engine starts), so a panicking job cannot poison the queue — but
    /// stay robust to poisoning anyway rather than propagating it.
    fn lock(&self) -> MutexGuard<'_, InjectorState<T>> {
        lock_unpoisoned(&self.state)
    }

    /// Enqueue jobs and wake exactly as many workers as there is new
    /// work for — the pipeline path pushes one job per stage completion,
    /// and waking the whole pool to pop a single job is a thundering
    /// herd.
    pub fn push(&self, jobs: impl IntoIterator<Item = T>) {
        let mut st = self.lock();
        let before = st.jobs.len();
        st.jobs.extend(jobs);
        let added = st.jobs.len() - before;
        self.depth.set(st.jobs.len() as i64);
        drop(st);
        match added {
            0 => {}
            1 => self.ready.notify_one(),
            _ => self.ready.notify_all(),
        }
    }

    /// Block until a job is available (steal it) or the farm shuts down
    /// (`None`). The queue drains before shutdown takes effect, so
    /// already-dispatched work always gets a reply. The returned flag is
    /// true when the job was already queued on arrival (a "steal" — the
    /// worker never parked for it).
    pub fn next_job(&self) -> Option<(T, bool)> {
        let mut st = self.lock();
        let mut waited = false;
        loop {
            if let Some(job) = st.jobs.pop_front() {
                self.depth.set(st.jobs.len() as i64);
                return Some((job, !waited));
            }
            if st.shutdown {
                return None;
            }
            waited = true;
            st = self.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Flag shutdown and wake every parked worker. Queued jobs still
    /// drain first — `next_job` returns `None` only on an empty queue.
    pub fn shutdown(&self) {
        self.lock().shutdown = true;
        self.ready.notify_all();
    }

    /// Whether shutdown has been flagged. A worker holding a job it is
    /// banned from re-runs the decision on this: once the farm is
    /// draining no caller is waiting, so the job is discarded instead of
    /// re-pushed (re-pushing from the last surviving worker would
    /// otherwise cycle forever and wedge the join).
    pub fn is_shutdown(&self) -> bool {
        self.lock().shutdown
    }
}

/// Best-effort rendering of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-worker metric handles, resolved once from the farm registry at
/// spawn time so the hot loop never touches the registry map. Job/busy/
/// idle/steal counters are per-engine; scratch and microkernel totals
/// are farm-wide (every worker adds its deltas to the shared counters).
struct WorkerTelemetry {
    jobs: Arc<Counter>,
    busy_us: Arc<Counter>,
    idle_us: Arc<Counter>,
    steals: Arc<Counter>,
    scratch_fills: Arc<Counter>,
    scratch_hits: Arc<Counter>,
    mk_k3: Arc<Counter>,
    mk_unit: Arc<Counter>,
    mk_strided: Arc<Counter>,
}

fn worker_loop(
    id: usize,
    engine: EngineSim,
    injector: Arc<Injector<Job>>,
    tel: WorkerTelemetry,
    chaos: FaultConfig,
) {
    // The engine's scratch/microkernel counters are cumulative over its
    // lifetime; publish per-job deltas into the farm-wide counters.
    let (mut prev_fills, mut prev_hits, _) = engine.scratch_stats();
    let mut prev_arms = engine.microkernel_arms();
    loop {
        let parked = Instant::now();
        let Some((job, stolen)) = injector.next_job() else { break };
        if job.banned & engine_bit(id) != 0 {
            // Quarantined for this job (or it already faulted here):
            // hand it back for another engine and yield briefly so the
            // re-push doesn't spin against an otherwise-idle pool. If
            // the farm is draining instead, discard — no caller waits,
            // and re-pushing could cycle against the shutdown join.
            if !injector.is_shutdown() {
                injector.push([job]);
                std::thread::sleep(Duration::from_micros(50));
            }
            continue;
        }
        if job.cancel.is_cancelled() {
            // A twin of this shard already merged — drop the duplicate
            // unrun (no reply: the merge loop stopped waiting on this
            // tag the moment it claimed the winner).
            continue;
        }
        // Timing chaos (gray failures): deterministically keyed on
        // (engine, layer, shard), so a hedged duplicate on another
        // engine draws independently. `Slow` straggles in cancellable
        // 200 µs steps; `Hang` never executes — it parks until the
        // hedge winner cancels it or the farm drains.
        if let Some(tf) = chaos.timing_fault(id, &job.layer, &job.filters, &job.rows) {
            let abandoned = match tf {
                TimingFault::Slow { micros } => {
                    let wake = Instant::now() + Duration::from_micros(micros);
                    let mut cancelled = false;
                    while Instant::now() < wake {
                        if job.cancel.is_cancelled() || injector.is_shutdown() {
                            cancelled = true;
                            break;
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    cancelled
                }
                TimingFault::Hang => {
                    while !job.cancel.is_cancelled() && !injector.is_shutdown() {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    true
                }
            };
            if abandoned {
                // The straggle was cancelled (or the farm is draining):
                // reply with a typed marker so the merge loop can
                // attribute the timing strike to this engine. A merged
                // tag never retries on this Err — the claim happened
                // first.
                let _ = job.reply.send(JobDone {
                    tag: job.tag,
                    engine: id,
                    filters: job.filters.clone(),
                    rows: job.rows.clone(),
                    hedged: job.hedge,
                    result: Err("straggling under timing chaos; cancelled".to_string()),
                });
                continue;
            }
        }
        tel.idle_us.add(parked.elapsed().as_micros() as u64);
        if stolen {
            tel.steals.inc();
        }
        let span = obs::tracer().begin("farm.shard", job.trace_parent);
        let started = Instant::now();
        // Catch panics so a poisoned job (bad geometry, corrupt weights)
        // surfaces as a named-engine error at the dispatch site instead
        // of silently dropping the reply sender and stranding the caller;
        // the worker — and its engine with the resident ConvScratch —
        // survives for subsequent jobs.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // The `_shared` entry point lets the engine's fast tier key
            // its padded-input materialisation on the Arc'd input
            // identity, across both grid axes.
            let mut result = engine.run_shard_shared(
                &job.layer,
                &job.input,
                &job.weights,
                job.filters.clone(),
                job.rows.clone(),
            );
            if let Some(q) = job.requant {
                for v in result.ofmaps.data.iter_mut() {
                    *v = q.apply(*v as i64) as i32;
                }
            }
            result
        }));
        tel.busy_us.add(started.elapsed().as_micros() as u64);
        tel.jobs.inc();
        let (fills, hits, _) = engine.scratch_stats();
        tel.scratch_fills.add(fills.saturating_sub(prev_fills));
        tel.scratch_hits.add(hits.saturating_sub(prev_hits));
        (prev_fills, prev_hits) = (fills, hits);
        let arms = engine.microkernel_arms();
        tel.mk_k3.add(arms[0].saturating_sub(prev_arms[0]));
        tel.mk_unit.add(arms[1].saturating_sub(prev_arms[1]));
        tel.mk_strided.add(arms[2].saturating_sub(prev_arms[2]));
        prev_arms = arms;
        let result = outcome.map_err(|p| panic_message(p.as_ref()));
        obs::tracer().finish_with(
            span,
            format!("engine={id} tag={} ok={}", job.tag, result.is_ok()),
        );
        // Receiver may have given up (caller bailed on an earlier
        // failure, or the farm dropped mid-run) — ignore.
        let _ = job.reply.send(JobDone {
            tag: job.tag,
            engine: id,
            filters: job.filters.clone(),
            rows: job.rows.clone(),
            hedged: job.hedge,
            result,
        });
    }
}

// ---------------------------------------------------------------------------
// Shadow-execution canary
// ---------------------------------------------------------------------------

/// A completed fast-tier shard queued for shadow re-execution.
struct CanaryJob {
    layer: ConvLayer,
    input: Arc<Tensor3>,
    weights: Arc<Vec<i32>>,
    filters: Range<usize>,
    rows: Range<usize>,
    /// The fast tier's result as served (or deliberately perturbed by
    /// the test hook) — what the oracle's re-execution is compared to.
    fast_ofmaps: Tensor3,
    fast_stats: SimStats,
}

#[derive(Clone)]
struct CanaryCounters {
    sampled: Arc<Counter>,
    bit_divergence: Arc<Counter>,
    counter_divergence: Arc<Counter>,
    /// Jobs submitted but not yet judged — lets tests and shutdown wait
    /// for the (asynchronous, off-hot-path) canary to catch up.
    pending: Arc<AtomicU64>,
}

struct Canary {
    cfg: CanaryConfig,
    tx: Sender<CanaryJob>,
    rng: Mutex<SplitMix64>,
    counters: CanaryCounters,
    worker: Option<JoinHandle<()>>,
}

impl Canary {
    /// Deterministic sampling decision: rate ≥ 1 samples everything
    /// without consuming randomness; otherwise draw one uniform in
    /// [0, 1) from the seeded PRNG.
    fn should_sample(&self) -> bool {
        if self.cfg.sample_rate >= 1.0 {
            return true;
        }
        let mut rng = lock_unpoisoned(&self.rng);
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        u < self.cfg.sample_rate
    }

    fn submit(&self, job: CanaryJob) {
        self.counters.pending.fetch_add(1, Ordering::AcqRel);
        if self.tx.send(job).is_err() {
            // Canary thread is gone; don't leave drain() waiting.
            self.counters.pending.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// The canary thread: re-run each sampled shard on the `Register`
/// oracle and count bit/counter divergence from the served fast result.
fn canary_loop(engine: EngineSim, rx: Receiver<CanaryJob>, counters: CanaryCounters) {
    while let Ok(job) = rx.recv() {
        let span = obs::tracer().begin("canary.shard", 0);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_shard_shared(
                &job.layer,
                &job.input,
                &job.weights,
                job.filters.clone(),
                job.rows.clone(),
            )
        }));
        counters.sampled.inc();
        let (bit_div, counter_div) = match outcome {
            Ok(oracle) => (
                oracle.ofmaps != job.fast_ofmaps,
                oracle.stats != job.fast_stats,
            ),
            // The oracle panicked where the fast tier succeeded: that is
            // maximal divergence, not an error to swallow.
            Err(_) => (true, true),
        };
        if bit_div {
            counters.bit_divergence.inc();
        }
        if counter_div {
            counters.counter_divergence.inc();
        }
        obs::tracer().finish_with(
            span,
            format!("bit_div={bit_div} counter_div={counter_div}"),
        );
        counters.pending.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Write one shard's `[filters.len()][rows.len()][W_O]` ofmap block into
/// the whole-layer `[N][H_O][W_O]` tensor: per covered filter, the band's
/// rows land at their interleaved offsets (contiguous whole-channel copy
/// when the shard covers all rows).
fn stitch(dst: &mut [i32], src: &[i32], filters: &Range<usize>, rows: &Range<usize>, h_o: usize, w_o: usize) {
    let b_h = rows.len();
    for (df, f) in filters.clone().enumerate() {
        let block = &src[df * b_h * w_o..(df + 1) * b_h * w_o];
        let at = (f * h_o + rows.start) * w_o;
        dst[at..at + b_h * w_o].copy_from_slice(block);
    }
}

/// Result of one farmed layer run (filter-, row- or hybrid-shard mode).
#[derive(Debug, Clone)]
pub struct FarmRunResult {
    /// Reassembled ofmaps `[N][H_O][W_O]` — bit-identical to a
    /// single-engine [`EngineSim::run_layer`] of the same layer.
    pub ofmaps: Tensor3,
    /// Aggregate stats: cycles = max over shards, accesses/MACs = sum.
    /// Filter shards partition the single-engine counters exactly; row
    /// bands (and the row dimension of hybrid tiles) additionally count
    /// their halo input rows (each band reads its whole slab), so summed
    /// off-chip input reads exceed the single-engine count by exactly the
    /// inter-band halo duplication — which depends only on the row-split
    /// count `plan.grid.1`, not on the filter splits.
    pub stats: SimStats,
    /// Per-shard stats, indexed like `plan.shards`.
    pub per_shard: Vec<SimStats>,
    /// The shard assignment that produced this result.
    pub plan: ShardPlan,
}

/// One stage of a layer pipeline: a layer, its weights, and the
/// re-quantisation applied to its ofmaps before they feed the next stage.
#[derive(Clone)]
pub struct PipelineStage {
    pub layer: ConvLayer,
    pub weights: Arc<Vec<i32>>,
    pub requant: Option<Requant>,
}

/// Result of streaming a batch of images through a layer pipeline.
#[derive(Debug, Clone)]
pub struct PipelineRunResult {
    /// Final activations, one per input image, in input order.
    pub outputs: Vec<Tensor3>,
    /// Aggregate stats under the **deterministic** stage→virtual-engine
    /// model (stage `i` → engine `i mod E`, the static pinning of PR 1):
    /// cycles = max over virtual engines of their sequential stage
    /// totals; accesses/MACs = sum over all jobs. Work stealing only
    /// changes which host thread runs a job — never the simulated
    /// accounting, so two identical runs report identical stats.
    pub stats: SimStats,
    /// Per-engine sequential stats as work-stealing actually scheduled
    /// the jobs (host-timing-dependent observability; `stats` and
    /// `per_stage` are not — they are derived from the deterministic
    /// model above).
    pub per_engine: Vec<SimStats>,
    /// Per-stage sequential stats: stage `i` over the whole batch — the
    /// per-layer cost breakdown the serving path reports.
    pub per_stage: Vec<SimStats>,
}

/// EWMA smoothing factor of the health map (matches the coordinator's
/// admission EWMA).
const HEALTH_ALPHA: f64 = 0.25;

/// Slowdown ratio past which the planner switches from equal-split to
/// cost-proportional ([`plan_shards_weighted`]) shard sizing, and past
/// which an engine is soft-banned from above-median shards. Below the
/// gate, plans are byte-identical to the unweighted planner — organic
/// scheduling noise on a homogeneous farm never perturbs them.
const SKEW_GATE: f64 = 1.5;

/// Floor of the per-shard hedge budget (µs): protects a cold farm (no
/// fleet EWMA yet) and tiny shards from hedging on scheduler jitter.
const HEDGE_FLOOR_US: f64 = 500.0;

/// Hedge attempts per shard before the valve is the only recourse
/// (each successive hedge doubles the wait first).
const MAX_HEDGES_PER_SHARD: u32 = 6;

/// Per-engine latency-vs-analytic health: an EWMA of observed
/// wall-µs-per-analytic-cycle, per engine and fleet-wide, fed at every
/// shard completion. The fleet ratio prices service budgets (hedging
/// and the safety valve); per-engine ÷ fleet is an engine's *slowdown*,
/// which drives cost-proportional shard sizing once the skew passes
/// [`SKEW_GATE`] — the heterogeneous-farm hook: a 2×-slower engine gets
/// a 2×-smaller filter-group/row-band share.
pub struct EngineHealthMap {
    state: Mutex<HealthEwma>,
}

struct HealthEwma {
    per_engine: Vec<Option<f64>>,
    fleet: Option<f64>,
}

impl EngineHealthMap {
    fn new(engines: usize) -> Self {
        Self { state: Mutex::new(HealthEwma { per_engine: vec![None; engines], fleet: None }) }
    }

    /// Feed one shard completion: `analytic_cycles` from the closed-form
    /// model, `elapsed` as observed at the merge point.
    pub fn observe(&self, engine: usize, analytic_cycles: u64, elapsed: Duration) {
        let ratio = (elapsed.as_micros() as f64 / analytic_cycles.max(1) as f64).max(1e-9);
        let mut st = lock_unpoisoned(&self.state);
        st.fleet = Some(match st.fleet {
            Some(prev) => prev + HEALTH_ALPHA * (ratio - prev),
            None => ratio,
        });
        if let Some(slot) = st.per_engine.get_mut(engine) {
            *slot = Some(match *slot {
                Some(prev) => prev + HEALTH_ALPHA * (ratio - prev),
                None => ratio,
            });
        }
    }

    /// Fleet-wide wall-µs-per-analytic-cycle (None until the first
    /// observation).
    pub fn us_per_cycle(&self) -> Option<f64> {
        lock_unpoisoned(&self.state).fleet
    }

    /// `engine`'s latency ratio relative to the fleet (1.0 = average or
    /// unobserved; 2.0 = twice as slow per analytic cycle).
    pub fn slowdown(&self, engine: usize) -> f64 {
        let st = lock_unpoisoned(&self.state);
        match (st.fleet, st.per_engine.get(engine).copied().flatten()) {
            (Some(fleet), Some(own)) if fleet > 0.0 => own / fleet,
            _ => 1.0,
        }
    }

    /// Probation restore: forget an engine's history so a recovered
    /// member is not priced on its quarantine-era latencies.
    fn reset(&self, engine: usize) {
        if let Some(slot) = lock_unpoisoned(&self.state).per_engine.get_mut(engine) {
            *slot = None;
        }
    }

    /// Cost-proportional plan weights for `live` engines (1/slowdown
    /// each, clamped), or `None` while the fleet is cold or its
    /// max/min slowdown skew is below [`SKEW_GATE`] — equal-split plans
    /// stay byte-identical until heterogeneity is real.
    pub fn plan_weights(&self, live: &[usize]) -> Option<Vec<f64>> {
        let st = lock_unpoisoned(&self.state);
        let fleet = st.fleet?;
        if fleet <= 0.0 || live.len() < 2 {
            return None;
        }
        let slowdowns: Vec<f64> = live
            .iter()
            .map(|&e| match st.per_engine.get(e).copied().flatten() {
                Some(own) => (own / fleet).clamp(0.05, 20.0),
                None => 1.0,
            })
            .collect();
        let hi = slowdowns.iter().copied().fold(0.0f64, f64::max);
        let lo = slowdowns.iter().copied().fold(f64::INFINITY, f64::min);
        if hi / lo.max(1e-12) < SKEW_GATE {
            return None;
        }
        Some(slowdowns.iter().map(|s| 1.0 / s).collect())
    }
}

/// A pool of simulated TrIM engines stealing work from one shared
/// injector queue.
pub struct EngineFarm {
    cfg: FarmConfig,
    injector: Arc<Injector<Job>>,
    workers: Vec<JoinHandle<()>>,
    registry: Arc<Registry>,
    canary: Option<Canary>,
    /// Self-healing state: per-engine attributed fault counts plus the
    /// quarantine mask. One mutex — health transitions happen only on
    /// detected faults, never on the fault-free hot path.
    health: Mutex<HealthState>,
    /// Per-engine latency-vs-analytic EWMAs (hedging budgets +
    /// cost-proportional planning).
    health_map: EngineHealthMap,
    /// Self-healing counters, resolved once (the registry map is not on
    /// the merge hot path).
    heal: HealCounters,
}

struct HealthState {
    /// Detected faults attributed per engine (checksum mismatches and
    /// worker panics observed at the merge point).
    faults: Vec<u32>,
    /// Timing strikes attributed per engine (late arrivals past the
    /// hedge budget) — the gray-failure analogue of `faults`.
    slow_faults: Vec<u32>,
    /// Bit mask of quarantined engines.
    quarantined: u64,
    /// Bit mask of engines released from quarantine on probation: one
    /// clean shard restores them, one fault re-quarantines with the
    /// cooldown doubled.
    probation: u64,
    /// When each quarantined engine's cooldown expires (None = not
    /// quarantined or pre-probation).
    cooldown_until: Vec<Option<Instant>>,
    /// Current cooldown per engine (doubles on every failed probation).
    cooldown: Vec<Duration>,
}

struct HealCounters {
    detected: Arc<Counter>,
    corrected: Arc<Counter>,
    reexecuted: Arc<Counter>,
    quarantined: Arc<Counter>,
    hedged: Arc<Counter>,
    hedge_wasted: Arc<Counter>,
    hedge_won: Arc<Counter>,
    stragglers: Arc<Counter>,
    timing_quarantined: Arc<Counter>,
}

impl EngineFarm {
    /// Spawn `cfg.engines` worker threads, each owning one [`EngineSim`],
    /// all stealing from one shared injector queue; plus, when the
    /// canary is enabled, one `Register`-fidelity shadow engine on its
    /// own thread.
    pub fn new(cfg: FarmConfig) -> Self {
        assert!(cfg.engines >= 1, "farm needs at least one engine");
        let registry = Arc::new(Registry::new());
        let injector = Arc::new(Injector::new(registry.gauge("injector.depth")));
        let mut workers = Vec::with_capacity(cfg.engines);
        for i in 0..cfg.engines {
            let mut engine = EngineSim::with_fidelity(cfg.arch, cfg.fidelity);
            if cfg.chaos.enabled() {
                engine = engine
                    .with_fault(FaultInjector::new(cfg.chaos, i, registry.counter("fault.injected")));
            }
            let inj = Arc::clone(&injector);
            let tel = WorkerTelemetry {
                jobs: registry.counter(&format!("engine{i}.jobs")),
                busy_us: registry.counter(&format!("engine{i}.busy_us")),
                idle_us: registry.counter(&format!("engine{i}.idle_us")),
                steals: registry.counter(&format!("engine{i}.steals")),
                scratch_fills: registry.counter("scratch.fills"),
                scratch_hits: registry.counter("scratch.hits"),
                mk_k3: registry.counter("microkernel.k3"),
                mk_unit: registry.counter("microkernel.unit"),
                mk_strided: registry.counter("microkernel.strided"),
            };
            let chaos = cfg.chaos;
            // Spawn failure (fd/memory exhaustion) degrades the pool
            // instead of panicking: the farm runs on whatever workers
            // came up, the same shape quarantine already handles.
            match std::thread::Builder::new()
                .name(format!("trim-farm-{i}"))
                .spawn(move || worker_loop(i, engine, inj, tel, chaos))
            {
                Ok(handle) => workers.push(handle),
                Err(_) => registry.counter("farm.spawn_failures").inc(),
            }
        }
        assert!(!workers.is_empty(), "farm could not spawn any worker thread");
        let canary = if cfg.canary.enabled() {
            let (tx, rx) = mpsc::channel::<CanaryJob>();
            let counters = CanaryCounters {
                sampled: registry.counter("canary.sampled"),
                bit_divergence: registry.counter("canary.bit_divergence"),
                counter_divergence: registry.counter("canary.counter_divergence"),
                pending: Arc::new(AtomicU64::new(0)),
            };
            let oracle = EngineSim::with_fidelity(cfg.arch, ExecFidelity::Register);
            let loop_counters = counters.clone();
            // A canary that fails to spawn disables itself (served
            // results were never gated on it).
            match std::thread::Builder::new()
                .name("trim-canary".to_string())
                .spawn(move || canary_loop(oracle, rx, loop_counters))
            {
                Ok(worker) => Some(Canary {
                    cfg: cfg.canary,
                    tx,
                    rng: Mutex::new(SplitMix64::new(cfg.canary.seed)),
                    counters,
                    worker: Some(worker),
                }),
                Err(_) => {
                    registry.counter("farm.spawn_failures").inc();
                    None
                }
            }
        } else {
            None
        };
        let health = Mutex::new(HealthState {
            faults: vec![0; cfg.engines],
            slow_faults: vec![0; cfg.engines],
            quarantined: 0,
            probation: 0,
            cooldown_until: vec![None; cfg.engines],
            cooldown: vec![cfg.probation_cooldown; cfg.engines],
        });
        let health_map = EngineHealthMap::new(cfg.engines);
        let heal = HealCounters {
            detected: registry.counter("fault.detected"),
            corrected: registry.counter("fault.corrected"),
            reexecuted: registry.counter("fault.reexecuted"),
            quarantined: registry.counter("fault.quarantined"),
            hedged: registry.counter("fault.hedged"),
            hedge_wasted: registry.counter("fault.hedge_wasted"),
            hedge_won: registry.counter("fault.hedge_won"),
            stragglers: registry.counter("fault.stragglers"),
            timing_quarantined: registry.counter("fault.timing_quarantined"),
        };
        Self { cfg, injector, workers, registry, canary, health, health_map, heal }
    }

    pub fn engines(&self) -> usize {
        self.cfg.engines
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.cfg.arch
    }

    pub fn fidelity(&self) -> ExecFidelity {
        self.cfg.fidelity
    }

    /// The farm's metric registry: per-engine `engine{i}.jobs` /
    /// `engine{i}.busy_us` / `engine{i}.idle_us` / `engine{i}.steals`
    /// counters, the `injector.depth` gauge, farm-wide `scratch.fills` /
    /// `scratch.hits` and `microkernel.{k3,unit,strided}` totals, and —
    /// when enabled — the `canary.*` divergence counters.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Whether the shadow-execution canary is running.
    pub fn canary_enabled(&self) -> bool {
        self.canary.is_some()
    }

    /// Cumulative canary totals (all zero when the canary is disabled).
    /// The canary judges asynchronously; call [`EngineFarm::canary_drain`]
    /// first if the report must cover every submitted sample.
    pub fn canary_report(&self) -> CanaryReport {
        match &self.canary {
            Some(c) => CanaryReport {
                sampled: c.counters.sampled.get(),
                bit_divergence: c.counters.bit_divergence.get(),
                counter_divergence: c.counters.counter_divergence.get(),
            },
            None => CanaryReport::default(),
        }
    }

    /// Block until the canary has judged every submitted sample (no-op
    /// when disabled; bounded at 60 s as a safety valve).
    pub fn canary_drain(&self) {
        if let Some(c) = &self.canary {
            let deadline = Instant::now() + Duration::from_secs(60);
            while c.counters.pending.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }

    /// Whether seeded fault injection is active on the worker engines.
    pub fn chaos_enabled(&self) -> bool {
        self.cfg.chaos.enabled()
    }

    /// Cumulative fault-tolerance totals: faults injected (chaos mode),
    /// detected at merge (ABFT mismatch or worker panic), shards healed
    /// by re-execution, re-execution attempts, engines quarantined, and
    /// the gray-failure side — shards hedged, duplicate completions
    /// discarded, hedges that won, distinct stragglers detected, and
    /// engines quarantined for straggling. All zero on a farm that has
    /// never seen a fault.
    pub fn fault_report(&self) -> FaultReport {
        FaultReport {
            injected: self.registry.counter_value("fault.injected"),
            detected: self.registry.counter_value("fault.detected"),
            corrected: self.registry.counter_value("fault.corrected"),
            reexecuted: self.registry.counter_value("fault.reexecuted"),
            quarantined: self.registry.counter_value("fault.quarantined"),
            hedged: self.registry.counter_value("fault.hedged"),
            hedge_wasted: self.registry.counter_value("fault.hedge_wasted"),
            hedge_won: self.registry.counter_value("fault.hedge_won"),
            stragglers_detected: self.registry.counter_value("fault.stragglers"),
            timing_quarantined: self.registry.counter_value("fault.timing_quarantined"),
        }
    }

    /// The farm's latency-vs-analytic health map (hedge budgets,
    /// cost-proportional planning). Exposed so serving layers and tests
    /// can read — or pre-seed — engine slowdowns.
    pub fn health_map(&self) -> &EngineHealthMap {
        &self.health_map
    }

    /// Health of every engine: `Healthy` (no attributed faults),
    /// `Suspect` (value faults below the quarantine threshold), `Slow`
    /// (timing strikes dominate), `Quarantined`.
    pub fn engine_health(&self) -> Vec<EngineHealth> {
        let h = lock_unpoisoned(&self.health);
        (0..self.cfg.engines)
            .map(|i| {
                if h.quarantined & engine_bit(i) != 0 {
                    EngineHealth::Quarantined
                } else if h.slow_faults[i] > 0 && h.slow_faults[i] >= h.faults[i] {
                    EngineHealth::Slow
                } else if h.faults[i] > 0 {
                    EngineHealth::Suspect
                } else {
                    EngineHealth::Healthy
                }
            })
            .collect()
    }

    /// Engines still receiving work (total minus quarantined, never
    /// below one). Shard plans for subsequent layers are drawn over this
    /// count — the degraded-capacity replan.
    pub fn live_engines(&self) -> usize {
        let h = lock_unpoisoned(&self.health);
        (self.cfg.engines - h.quarantined.count_ones() as usize).max(1)
    }

    /// Current quarantine mask (for job banning).
    fn quarantine_mask(&self) -> u64 {
        lock_unpoisoned(&self.health).quarantined
    }

    /// Attribute one detected *value* fault (ABFT mismatch or panic) to
    /// `engine`; quarantine it when it crosses the threshold (unless it
    /// is the last live engine). Returns true when this call
    /// quarantined the engine.
    fn note_engine_fault(&self, engine: usize) -> bool {
        self.heal.detected.inc();
        let q = self.strike(engine, false);
        self.registry.counter(&format!("engine{engine}.faults")).inc();
        q
    }

    /// Attribute one *timing* strike (arrival past the hedge budget) to
    /// `engine`; quarantine with the [`EngineHealth::Slow`] cause at
    /// [`FarmConfig::straggler_threshold`]. Returns true when this call
    /// quarantined the engine.
    fn note_timing_fault(&self, engine: usize) -> bool {
        let q = self.strike(engine, true);
        self.registry.counter(&format!("engine{engine}.slow_faults")).inc();
        q
    }

    /// Shared quarantine transition of both fault families. An engine
    /// on probation re-quarantines on its first strike of either kind,
    /// with its cooldown doubled (flapper containment); otherwise the
    /// per-family threshold applies. The last live engine is never
    /// quarantined.
    fn strike(&self, engine: usize, timing: bool) -> bool {
        let mut h = lock_unpoisoned(&self.health);
        if engine >= h.faults.len() {
            return false;
        }
        if timing {
            h.slow_faults[engine] += 1;
        } else {
            h.faults[engine] += 1;
        }
        let count = if timing { h.slow_faults[engine] } else { h.faults[engine] };
        let threshold = if timing { self.cfg.straggler_threshold } else { self.cfg.quarantine_after };
        let bit = engine_bit(engine);
        let on_probation = h.probation & bit != 0;
        let crossed = count >= threshold.max(1) || on_probation;
        let already = h.quarantined & bit != 0;
        let survivors = self.cfg.engines - (h.quarantined | bit).count_ones() as usize;
        if crossed && !already && bit != 0 && survivors >= 1 {
            h.quarantined |= bit;
            h.probation &= !bit;
            if on_probation {
                // Failed probe: double the cooldown (capped) before the
                // next probation so a permanent flapper converges to
                // near-zero probe traffic.
                h.cooldown[engine] =
                    (h.cooldown[engine] * 2).min(Duration::from_secs(3600));
            }
            h.cooldown_until[engine] = Some(Instant::now() + h.cooldown[engine]);
            drop(h);
            if timing {
                self.heal.timing_quarantined.inc();
            } else {
                self.heal.quarantined.inc();
            }
            return true;
        }
        false
    }

    /// Release quarantined engines whose cooldown expired onto
    /// probation: they re-enter planning and receive shards again; the
    /// first clean completion restores them fully
    /// ([`EngineFarm::note_engine_recovered`]), the first fault
    /// re-quarantines with the cooldown doubled. Called at the top of
    /// every layer run.
    fn probation_tick(&self) {
        let now = Instant::now();
        let mut h = lock_unpoisoned(&self.health);
        for e in 0..self.cfg.engines.min(h.cooldown_until.len()) {
            let bit = engine_bit(e);
            if h.quarantined & bit == 0 {
                continue;
            }
            if let Some(at) = h.cooldown_until[e] {
                if now >= at {
                    h.quarantined &= !bit;
                    h.probation |= bit;
                    h.cooldown_until[e] = None;
                }
            }
        }
    }

    /// A probation engine completed a shard cleanly: restore it — fault
    /// counters cleared, cooldown back to base, stale latency history
    /// forgotten.
    fn note_engine_recovered(&self, engine: usize) {
        let bit = engine_bit(engine);
        if bit == 0 {
            return;
        }
        let mut h = lock_unpoisoned(&self.health);
        if h.probation & bit != 0 && engine < h.faults.len() {
            h.probation &= !bit;
            h.faults[engine] = 0;
            h.slow_faults[engine] = 0;
            h.cooldown[engine] = self.cfg.probation_cooldown;
            drop(h);
            self.health_map.reset(engine);
        }
    }

    /// Run one layer sharded across the farm in filter-shard mode and
    /// merge the results (the PR-1 entry point, kept for the existing
    /// callers/tests). See [`EngineFarm::run_layer_mode`].
    pub fn run_layer(&self, layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> Result<FarmRunResult> {
        self.run_layer_mode(layer, input, weights, ShardMode::FilterShards)
    }

    /// Run one layer sharded across the farm under `mode` (filter,
    /// spatial, hybrid or auto) and merge the results. Blocks until every
    /// shard has completed; errs (naming the engine) if a worker panicked
    /// on a shard. Copies `input` and `weights` into shared buffers —
    /// callers that already hold `Arc`s (the serving hot path) should use
    /// [`EngineFarm::run_layer_shared`] to avoid the copies.
    pub fn run_layer_mode(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        mode: ShardMode,
    ) -> Result<FarmRunResult> {
        self.run_layer_shared(layer, Arc::new(input.clone()), Arc::new(weights.to_vec()), mode)
    }

    /// Zero-copy variant of [`EngineFarm::run_layer_mode`]: shards
    /// reference the caller's buffers through `Arc` clones. `mode` picks
    /// the shard axis ([`ShardMode::FilterShards`], [`ShardMode::Spatial`],
    /// [`ShardMode::Hybrid`] or the per-layer [`ShardMode::Auto`]);
    /// [`ShardMode::LayerPipeline`] is a cross-layer mode served by
    /// [`EngineFarm::run_pipeline`] instead.
    ///
    /// Jobs go through the shared work-stealing injector, so which engine
    /// runs which shard depends on timing — the result does not: shards
    /// are self-contained, the ofmap stitch writes disjoint ranges keyed
    /// by the shard's (filters × rows) tile, and `per_shard` is indexed
    /// by shard (not worker).
    pub fn run_layer_shared(
        &self,
        layer: &ConvLayer,
        input: Arc<Tensor3>,
        weights: Arc<Vec<i32>>,
        mode: ShardMode,
    ) -> Result<FarmRunResult> {
        assert!(mode != ShardMode::LayerPipeline, "pipeline mode goes through run_pipeline");
        // Probation: release quarantined engines whose cooldown expired
        // before planning — they rejoin the live set, and the next shard
        // they complete (or fault) decides their fate.
        self.probation_tick();
        // Degraded-capacity replanning: quarantined engines no longer
        // count — the plan (and its speedup bound) shrinks to the
        // survivors instead of leaving shards parked on banned engines.
        let quarantined = self.quarantine_mask();
        let live_ids: Vec<usize> = (0..self.cfg.engines)
            .filter(|&i| quarantined & engine_bit(i) == 0)
            .collect();
        let live = live_ids.len().max(1);
        // Cost-proportional sizing (the heterogeneous-farm hook): once
        // the health map shows real slowdown skew, shares go 1/slowdown
        // (sorted descending so the shard-index → share mapping is
        // deterministic) and engines past the gate are soft-banned from
        // above-median shards, so slow engines only steal small work.
        let plan_weights = self.health_map.plan_weights(&live_ids);
        let plan = match &plan_weights {
            Some(w) => {
                let mut w = w.clone();
                w.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
                plan_shards_weighted(&self.cfg.arch, layer, &w, mode)
            }
            None => plan_shards(&self.cfg.arch, layer, live, mode),
        };
        let soft_ban: u64 = if plan_weights.is_some() {
            let mask: u64 = live_ids
                .iter()
                .filter(|&&e| self.health_map.slowdown(e) >= SKEW_GATE)
                .fold(0u64, |m, &e| m | engine_bit(e));
            let live_mask: u64 = live_ids.iter().fold(0u64, |m, &e| m | engine_bit(e));
            // Never ban the whole live set — someone must run the shard.
            if mask != 0 && mask & live_mask != live_mask {
                mask
            } else {
                0
            }
        } else {
            0
        };
        // Per-shard a-priori service estimate from the closed-form
        // eq. (2) model — the denominator of every budget below.
        let analytic: Vec<u64> = plan
            .shards
            .iter()
            .map(|s| crate::verify::analytic_shard_stats(&self.cfg.arch, layer, s).cycles.max(1))
            .collect();
        let median_cycles = {
            let mut sorted = analytic.clone();
            sorted.sort_unstable();
            sorted.get(sorted.len() / 2).copied().unwrap_or(1)
        };
        let span = obs::tracer().begin("farm.layer", 0);
        let trace_parent = span.id();
        let (reply, done_rx) = mpsc::channel::<JobDone>();
        let cancels: Vec<Arc<FirstWins>> =
            (0..plan.shards.len()).map(|_| Arc::new(FirstWins::new())).collect();
        let jobs: Vec<Job> = plan
            .shards
            .iter()
            .map(|shard| Job {
                layer: layer.clone(),
                input: Arc::clone(&input),
                weights: Arc::clone(&weights),
                filters: shard.filters.clone(),
                rows: shard.rows.clone(),
                requant: None,
                tag: shard.index as u64,
                trace_parent,
                banned: quarantined
                    | if analytic[shard.index] > median_cycles { soft_ban } else { 0 },
                cancel: Arc::clone(&cancels[shard.index]),
                hedge: false,
                reply: reply.clone(),
            })
            .collect();
        self.injector.push(jobs);

        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        let mut ofmaps = Tensor3::zeros(layer.n, h_o, w_o);
        let mut stats = SimStats::default();
        let n_shards = plan.shards.len();
        let mut per_shard = vec![SimStats::default(); n_shards];
        // ABFT: every merged shard is checksum-verified — not sampled.
        // The checker (O(input) summed-area tables) is built on the first
        // result so a layer that fails outright never pays for it.
        let mut checker: Option<AbftChecker> = None;
        let mut attempts: Vec<u32> = vec![0; n_shards];
        let mut banned: Vec<u64> = vec![quarantined; n_shards];
        let all_engines: u64 = if self.cfg.engines >= 64 { u64::MAX } else { (1u64 << self.cfg.engines) - 1 };
        let mut completed = 0usize;
        let mut received = 0usize;
        let mut failure: Option<anyhow::Error> = None;
        // Service budgets: analytic cycles × the fleet's observed
        // µs-per-cycle EWMA, floored while the fleet is cold. A shard
        // outstanding past hedge_factor × budget is re-injected (first
        // result wins); the whole layer is bounded by the valve —
        // valve_multiplier × the summed budget (with valve_floor), fired
        // as a typed ServeError::EngineFailed. This replaces the old
        // hard-coded 300 s recv_timeout with an analytically derived
        // budget.
        let started = Instant::now();
        let upc = self.health_map.us_per_cycle();
        let budget: Vec<Duration> = analytic
            .iter()
            .map(|&c| {
                let us = upc.map(|r| c as f64 * r).unwrap_or(0.0).max(HEDGE_FLOOR_US);
                Duration::from_micros(us.min(3.6e9) as u64)
            })
            .collect();
        let hedge_on = self.cfg.hedge_factor > 0.0 && live > 1;
        let factor = if self.cfg.hedge_factor > 0.0 { self.cfg.hedge_factor } else { 1.0 };
        let hedge_wait: Vec<Duration> = budget
            .iter()
            .map(|b| Duration::from_micros((b.as_micros() as f64 * factor).min(3.6e9) as u64))
            .collect();
        let total_budget_us: f64 = budget.iter().map(|b| b.as_micros() as f64).sum();
        let valve_at = started
            + self.cfg.valve_floor.max(Duration::from_micros(
                (total_budget_us * self.cfg.valve_multiplier.max(1.0)).min(3.6e9) as u64,
            ));
        let mut next_hedge: Vec<Instant> = hedge_wait.iter().map(|w| started + *w).collect();
        let mut hedges: Vec<u32> = vec![0; n_shards];
        let mut hedged_at: Vec<Option<Instant>> = vec![None; n_shards];
        // We hold `reply` so re-executions and hedges can be dispatched
        // mid-merge; the loop therefore counts completions instead of
        // waiting for the channel to close, waking at the earliest
        // pending hedge deadline (or the valve).
        while completed < n_shards && failure.is_none() {
            let now = Instant::now();
            if now >= valve_at {
                failure = Some(
                    ServeError::EngineFailed {
                        reason: format!(
                            "farm service budget exhausted on {}: {completed} of {n_shards} shards \
                             completed after {:?} (analytic budget {:.0} µs, valve ×{})",
                            layer.name,
                            started.elapsed(),
                            total_budget_us,
                            self.cfg.valve_multiplier,
                        ),
                    }
                    .into(),
                );
                break;
            }
            let mut wake = valve_at;
            if hedge_on {
                for t in 0..n_shards {
                    if !cancels[t].is_cancelled() && hedges[t] < MAX_HEDGES_PER_SHARD {
                        wake = wake.min(next_hedge[t]);
                    }
                }
            }
            let done = match done_rx.recv_timeout(wake.saturating_duration_since(now)) {
                Ok(done) => done,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Hedge pass: every unresolved shard past its
                    // deadline is re-injected for the pool; each
                    // successive hedge of one shard doubles its wait so
                    // a pathological layer cannot flood the queue.
                    if hedge_on {
                        let now = Instant::now();
                        for (t, shard) in plan.shards.iter().enumerate() {
                            if cancels[t].is_cancelled()
                                || hedges[t] >= MAX_HEDGES_PER_SHARD
                                || now < next_hedge[t]
                            {
                                continue;
                            }
                            if hedges[t] == 0 {
                                self.heal.stragglers.inc();
                            }
                            hedges[t] += 1;
                            self.heal.hedged.inc();
                            hedged_at[t] = Some(now);
                            next_hedge[t] = now + hedge_wait[t] * 2u32.saturating_pow(hedges[t].min(16));
                            self.injector.push([Job {
                                layer: layer.clone(),
                                input: Arc::clone(&input),
                                weights: Arc::clone(&weights),
                                filters: shard.filters.clone(),
                                rows: shard.rows.clone(),
                                requant: None,
                                tag: t as u64,
                                trace_parent,
                                banned: self.quarantine_mask(),
                                cancel: Arc::clone(&cancels[t]),
                                hedge: true,
                                reply: reply.clone(),
                            }]);
                        }
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    failure = Some(
                        ServeError::EngineFailed {
                            reason: format!("farm workers gone mid-layer on {}", layer.name),
                        }
                        .into(),
                    );
                    break;
                }
            };
            received += 1;
            let tag = done.tag as usize;
            if tag >= n_shards {
                continue;
            }
            // Service time is measured from the submission that produced
            // this reply: layer start for originals (and retries — close
            // enough), the hedge push for duplicates.
            let since = if done.hedged { hedged_at[tag].unwrap_or(started) } else { started };
            if cancels[tag].is_cancelled() {
                // A twin of an already-merged shard: discard the
                // duplicate work, and if this arrival was late past its
                // own hedge budget, attribute a timing strike to the
                // engine (threshold-crossing stragglers quarantine with
                // the Slow cause).
                self.heal.hedge_wasted.inc();
                if since.elapsed() > hedge_wait[tag] {
                    self.note_timing_fault(done.engine);
                }
                continue;
            }
            // A result only merges if its ABFT filter checksums hold;
            // a mismatch (or a worker panic) is a detected fault.
            let verdict = match done.result {
                Ok(result) => {
                    let ck = checker.get_or_insert_with(|| AbftChecker::new(layer, &input));
                    match ck.check(&weights, &done.filters, &done.rows, &result.ofmaps) {
                        None => Ok(result),
                        Some(m) => Err(format!(
                            "ABFT checksum mismatch on filter {} (expected {}, actual {})",
                            m.filter, m.expected, m.actual
                        )),
                    }
                }
                Err(msg) => Err(format!("panicked: {msg}")),
            };
            match verdict {
                Ok(result) => {
                    // First result wins: the claim is also the cancel
                    // signal every remaining twin of this tag observes.
                    cancels[tag].claim();
                    if done.hedged {
                        self.heal.hedge_won.inc();
                    }
                    self.health_map.observe(done.engine, analytic[tag], since.elapsed());
                    self.note_engine_recovered(done.engine);
                    if attempts[tag] > 0 {
                        self.heal.corrected.inc();
                    }
                    // Shadow-execution canary: off the hot path, the only
                    // per-shard cost when sampled is cloning the fast
                    // result for the oracle comparison.
                    if let Some(c) = self.canary.as_ref().filter(|c| c.should_sample()) {
                        let mut fast_ofmaps = result.ofmaps.clone();
                        if c.cfg.perturb && !fast_ofmaps.data.is_empty() {
                            fast_ofmaps.data[0] = fast_ofmaps.data[0].wrapping_add(1);
                        }
                        c.submit(CanaryJob {
                            layer: layer.clone(),
                            input: Arc::clone(&input),
                            weights: Arc::clone(&weights),
                            filters: done.filters.clone(),
                            rows: done.rows.clone(),
                            fast_ofmaps,
                            fast_stats: result.stats,
                        });
                    }
                    stitch(&mut ofmaps.data, &result.ofmaps.data, &done.filters, &done.rows, h_o, w_o);
                    stats.merge(&result.stats); // parallel: cycles max, counters sum
                    per_shard[tag] = result.stats;
                    completed += 1;
                }
                Err(why) => {
                    self.note_engine_fault(done.engine);
                    if attempts[tag] < self.cfg.max_retries {
                        // Re-execute on a different engine: ban every
                        // engine that already faulted on this shard plus
                        // the current quarantine set — unless that would
                        // ban the whole pool (single-engine farms retry
                        // in place and exhaust deterministically).
                        attempts[tag] += 1;
                        self.heal.reexecuted.inc();
                        let mut ban = banned[tag] | engine_bit(done.engine) | self.quarantine_mask();
                        if ban & all_engines == all_engines {
                            ban = 0;
                        }
                        banned[tag] = ban;
                        // The retry gets a fresh hedge deadline: hedging
                        // bounds service time per attempt, not the
                        // shard's cumulative bad luck.
                        next_hedge[tag] = Instant::now() + hedge_wait[tag];
                        self.injector.push([Job {
                            layer: layer.clone(),
                            input: Arc::clone(&input),
                            weights: Arc::clone(&weights),
                            filters: done.filters.clone(),
                            rows: done.rows.clone(),
                            requant: None,
                            tag: done.tag,
                            trace_parent,
                            banned: ban,
                            cancel: Arc::clone(&cancels[tag]),
                            hedge: done.hedged,
                            reply: reply.clone(),
                        }]);
                    } else {
                        failure = Some(anyhow!(
                            "engine trim-farm-{} {why} on shard {} (filters {:?}, rows {:?}) of layer {} \
                             after {} attempts",
                            done.engine,
                            done.tag,
                            done.filters,
                            done.rows,
                            layer.name,
                            attempts[tag] + 1
                        ));
                    }
                }
            }
        }
        // Unstick any parked straggler (hung chaos, racing duplicates):
        // claiming every outstanding tag sets the cancel flag their
        // workers poll, so a failed layer never leaves a worker wedged.
        // On the success path every tag is already claimed — a no-op.
        for c in &cancels {
            c.claim();
        }
        // Dropping our sender lets any straggler replies (a fatal bail
        // with other shards still in flight) fail harmlessly in the
        // workers instead of accumulating.
        drop(reply);
        obs::tracer().finish_with(
            span,
            format!(
                "layer={} axis={:?} shards={} received={received} completed={completed} ok={}",
                layer.name,
                plan.axis,
                plan.shards.len(),
                failure.is_none()
            ),
        );
        if let Some(e) = failure {
            return Err(e);
        }
        ensure!(
            completed == plan.shards.len(),
            "farm worker(s) died mid-layer on {}: {completed} of {} shards completed",
            layer.name,
            plan.shards.len()
        );
        // Merge-time conservation checks (debug builds only — release
        // stays free): the plan must partition the layer and the merged
        // per-shard counters must obey the same coverage / halo /
        // counter-conservation laws `trim check` proves statically. Only
        // ABFT-verified results merged, so healed runs satisfy the same
        // laws as fault-free ones.
        #[cfg(debug_assertions)]
        {
            let vp = crate::verify::check_plan(&self.cfg.arch, layer, live, &plan);
            debug_assert!(
                vp.is_empty(),
                "shard plan violates coverage laws at merge: {}",
                vp.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("; ")
            );
            let vs = crate::verify::check_stats(&self.cfg.arch, layer, &plan, &per_shard);
            debug_assert!(
                vs.is_empty(),
                "merged shard stats violate conservation laws: {}",
                vs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("; ")
            );
        }
        Ok(FarmRunResult { ofmaps, stats, per_shard, plan })
    }

    /// Stream `inputs` through a chain of layers: every (image, stage)
    /// pair is an independent job on the work-stealing injector, so an
    /// image's stages run in order while across images the stages overlap
    /// on whichever engines are idle — which is where the speedup comes
    /// from. Outputs are returned in input order. Blocks until the last
    /// image leaves the last stage; errs (naming the engine and stage) if
    /// a worker panicked on a job.
    pub fn run_pipeline(&self, stages: &[PipelineStage], inputs: Vec<Tensor3>) -> Result<PipelineRunResult> {
        assert!(!stages.is_empty(), "pipeline needs at least one stage");
        for (a, b) in stages.iter().zip(stages.iter().skip(1)) {
            assert_eq!(a.layer.n, b.layer.m, "stage channel mismatch: {} → {}", a.layer.name, b.layer.name);
            assert_eq!((a.layer.h_o(), a.layer.w_o()), (b.layer.h_i, b.layer.w_i),
                "stage shape mismatch: {} → {}", a.layer.name, b.layer.name);
        }
        let n_img = inputs.len();
        let n_stage = stages.len();
        let span = obs::tracer().begin("farm.pipeline", 0);
        let trace_parent = span.id();
        let (reply, done_rx) = mpsc::channel::<JobDone>();
        let submit = |img: usize, stage: usize, input: Arc<Tensor3>| {
            let s = &stages[stage];
            self.injector.push([Job {
                layer: s.layer.clone(),
                input,
                weights: Arc::clone(&s.weights),
                filters: 0..s.layer.n,
                rows: 0..s.layer.h_o(),
                requant: s.requant,
                tag: (img * n_stage + stage) as u64,
                trace_parent,
                banned: self.quarantine_mask(),
                cancel: Arc::new(FirstWins::new()),
                hedge: false,
                reply: reply.clone(),
            }]);
        };

        for (img, t) in inputs.into_iter().enumerate() {
            submit(img, 0, Arc::new(t));
        }
        let mut outputs: Vec<Option<Tensor3>> = (0..n_img).map(|_| None).collect();
        let mut per_engine = vec![SimStats::default(); self.engines()];
        let mut per_stage = vec![SimStats::default(); n_stage];
        let mut finished = 0usize;
        while finished < n_img {
            // We hold `reply` (for follow-on submissions), so the channel
            // cannot disconnect; every job replies even on panic.
            let done = done_rx.recv().map_err(|_| anyhow!("farm workers gone mid-pipeline"))?;
            let tag = done.tag as usize;
            let (img, stage) = (tag / n_stage, tag % n_stage);
            let result = match done.result {
                Ok(r) => r,
                Err(msg) => bail!(
                    "engine trim-farm-{} panicked on pipeline stage {stage} ({}) of image {img}: {msg}",
                    done.engine,
                    stages[stage].layer.name
                ),
            };
            per_engine[done.engine].merge_sequential(&result.stats);
            per_stage[stage].merge_sequential(&result.stats);
            if stage + 1 < n_stage {
                submit(img, stage + 1, Arc::new(result.ofmaps));
            } else {
                outputs[img] = Some(result.ofmaps);
                finished += 1;
            }
        }
        // Deterministic cycle model: attribute stage i to *virtual*
        // engine i mod E (the static pinning of PR 1) and reduce over
        // those — cycles add within a virtual engine, max across them.
        // Reducing over the observed `per_engine` instead would make the
        // reported wall-clock depend on which worker happened to steal
        // which job, i.e. on host thread timing.
        let mut stats = SimStats::default();
        let mut virt = vec![SimStats::default(); self.engines()];
        for (i, s) in per_stage.iter().enumerate() {
            virt[i % self.engines()].merge_sequential(s);
        }
        for e in &virt {
            stats.merge(e); // virtual engines run in parallel: cycles max, counters sum
        }
        let outputs: Vec<Tensor3> = outputs.into_iter().flatten().collect();
        ensure!(outputs.len() == n_img, "pipeline lost {} of {n_img} images", n_img - outputs.len());
        obs::tracer().finish_with(span, format!("images={n_img} stages={n_stage}"));
        Ok(PipelineRunResult { outputs, stats, per_engine, per_stage })
    }
}

impl Drop for EngineFarm {
    fn drop(&mut self) {
        // Wake every parked worker with the shutdown flag (the queue
        // drains first, so pending replies still go out); then join.
        self.injector.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Closing the canary's sender ends its recv loop after the
        // channel drains, so every submitted sample still gets judged.
        if let Some(mut canary) = self.canary.take() {
            let worker = canary.worker.take();
            drop(canary);
            if let Some(h) = worker {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv3d_i32;
    use crate::scheduler::shard::ShardAxis;
    use crate::util::SplitMix64;

    fn rand_tensor(rng: &mut SplitMix64, c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3 { c, h, w, data: rng.vec_i32(c * h * w, -64, 64) }
    }

    #[test]
    fn farm_matches_golden_and_aggregates_stats() {
        let mut rng = SplitMix64::new(11);
        let layer = ConvLayer::new("f", 10, 3, 5, 9, 1, 1);
        let input = rand_tensor(&mut rng, 5, 10, 10);
        let weights = rng.vec_i32(9 * 5 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(3, arch));
        let r = farm.run_layer(&layer, &input, &weights).unwrap();
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 9, 3, 1, 1));
        assert_eq!(r.plan.shards.len(), 3);
        // cycles = max over shards, counters = sum over shards
        assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
        assert_eq!(r.stats.macs, r.per_shard.iter().map(|s| s.macs).sum::<u64>());
        // … and the counters partition a single-engine run exactly.
        let single = EngineSim::new(arch).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, single.ofmaps);
        assert_eq!(r.stats.ext_input_reads, single.stats.ext_input_reads);
        assert_eq!(r.stats.macs, single.stats.macs);
        assert_eq!(r.stats.output_writes, single.stats.output_writes);
        assert!(r.stats.cycles < single.stats.cycles, "sharding must cut parallel cycles");
    }

    #[test]
    fn pipeline_matches_serial_golden_chain() {
        let mut rng = SplitMix64::new(23);
        // 2-stage chain: 3→4 then 4→2, both 3×3 pad 1 on 8×8.
        let l1 = ConvLayer::new("p1", 8, 3, 3, 4, 1, 1);
        let l2 = ConvLayer::new("p2", 8, 3, 4, 2, 1, 1);
        let w1 = Arc::new(rng.vec_i32(4 * 3 * 9, -6, 6));
        let w2 = Arc::new(rng.vec_i32(2 * 4 * 9, -6, 6));
        let q = Requant::new(4, 8);
        let stages = vec![
            PipelineStage { layer: l1.clone(), weights: Arc::clone(&w1), requant: Some(q) },
            PipelineStage { layer: l2.clone(), weights: Arc::clone(&w2), requant: Some(q) },
        ];
        let images: Vec<Tensor3> = (0..5).map(|_| rand_tensor(&mut rng, 3, 8, 8)).collect();
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        let r = farm.run_pipeline(&stages, images.clone()).unwrap();
        assert_eq!(r.outputs.len(), 5);
        for (img, out) in images.iter().zip(&r.outputs) {
            let mut a1 = conv3d_i32(img, &w1, 4, 3, 1, 1);
            for v in a1.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
            let mut a2 = conv3d_i32(&a1, &w2, 2, 3, 1, 1);
            for v in a2.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
            assert_eq!(out, &a2);
        }
        // Work-stealing schedules stages onto whichever engine is idle,
        // so per-engine shares are host-timing-dependent — the aggregate
        // is not: cycles come from the deterministic stage→virtual-engine
        // model (stage i → engine i mod E; with 2 stages on 2 engines,
        // max over the two per-stage totals), and the per-stage breakdown
        // accounts every job exactly once.
        assert_eq!(r.per_engine.len(), 2);
        assert!(r.per_engine.iter().map(|s| s.cycles).sum::<u64>() > 0);
        assert_eq!(r.per_stage.len(), 2);
        assert!(r.per_stage.iter().all(|s| s.cycles > 0 && s.macs > 0), "every stage ran");
        assert_eq!(
            r.stats.cycles,
            r.per_stage.iter().map(|s| s.cycles).max().unwrap(),
            "deterministic cycle model, independent of the steal schedule"
        );
        assert_eq!(
            r.per_stage.iter().map(|s| s.macs).sum::<u64>(),
            r.per_engine.iter().map(|s| s.macs).sum::<u64>(),
            "stage and engine breakdowns account the same jobs"
        );
        assert_eq!(r.stats.macs, r.per_stage.iter().map(|s| s.macs).sum::<u64>());
    }

    #[test]
    fn single_engine_farm_is_degenerate_but_exact() {
        let mut rng = SplitMix64::new(31);
        let layer = ConvLayer::new("d", 7, 3, 2, 3, 1, 0);
        let input = rand_tensor(&mut rng, 2, 7, 7);
        let weights = rng.vec_i32(3 * 2 * 9, -8, 8);
        let farm = EngineFarm::new(FarmConfig::new(1, ArchConfig::small(3, 2, 2)));
        let r = farm.run_layer(&layer, &input, &weights).unwrap();
        let single = EngineSim::new(ArchConfig::small(3, 2, 2)).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, single.ofmaps);
        assert_eq!(r.stats, single.stats);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let farm = EngineFarm::new(FarmConfig::new(3, ArchConfig::small(3, 2, 2)));
        drop(farm); // must not hang or panic
    }

    #[test]
    fn row_shards_stitch_bit_exact() {
        // Spatial mode must reassemble the interleaved row bands into the
        // same ofmaps a single engine produces, on a strided layer too.
        let mut rng = SplitMix64::new(41);
        for (hw, k, stride, pad) in [(10usize, 3usize, 1usize, 1usize), (13, 3, 2, 1)] {
            let layer = ConvLayer::new("rs", hw, k, 4, 5, stride, pad);
            let input = rand_tensor(&mut rng, 4, hw, hw);
            let weights = rng.vec_i32(5 * 4 * k * k, -8, 8);
            let arch = ArchConfig::small(3, 2, 2);
            let farm = EngineFarm::new(FarmConfig::new(3, arch));
            let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Spatial).unwrap();
            assert_eq!(r.plan.axis, ShardAxis::Rows);
            assert_eq!(r.plan.shards.len(), 3);
            let single = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
            assert_eq!(r.ofmaps, single.ofmaps, "s={stride}: row stitch vs single engine");
            assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 5, k, stride, pad));
            // work counters that are proportional to ofmap rows partition
            assert_eq!(r.stats.output_writes, single.stats.output_writes);
            assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
            assert!(r.stats.cycles < single.stats.cycles, "bands must cut parallel cycles");
            // halo accounting: bands read at least the single-engine slab
            assert!(r.stats.ext_input_reads >= single.stats.ext_input_reads);
        }
    }

    #[test]
    fn auto_mode_picks_rows_on_narrow_wide_layers() {
        // CL1-class shape: few filters (1 group on P_N=2), wide spatial.
        let mut rng = SplitMix64::new(43);
        let layer = ConvLayer::new("cl1ish", 16, 3, 3, 2, 1, 1);
        let input = rand_tensor(&mut rng, 3, 16, 16);
        let weights = rng.vec_i32(2 * 3 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(4, arch));
        let auto = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
        let filt = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
        assert_eq!(auto.plan.axis, ShardAxis::Rows, "auto must pick the spatial axis here");
        assert_eq!(filt.plan.shards.len(), 1, "filter axis is starved (1 group)");
        assert_eq!(auto.ofmaps, filt.ofmaps, "both modes serve identical ofmaps");
        assert!(
            auto.stats.cycles < filt.stats.cycles,
            "spatial sharding must beat starved filter sharding: {} vs {}",
            auto.stats.cycles,
            filt.stats.cycles
        );
    }

    #[test]
    fn farm_fidelities_agree_exactly() {
        // A fast farm and a register farm must return identical
        // FarmRunResults (ofmaps, merged stats, per-shard stats).
        let mut rng = SplitMix64::new(77);
        let layer = ConvLayer::new("fid", 9, 3, 5, 7, 1, 1);
        let input = rand_tensor(&mut rng, 5, 9, 9);
        let weights = rng.vec_i32(7 * 5 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        assert_eq!(FarmConfig::new(2, arch).fidelity, ExecFidelity::Fast);
        let fast = EngineFarm::new(FarmConfig::new(2, arch));
        let reg = EngineFarm::new(FarmConfig::with_fidelity(2, arch, ExecFidelity::Register));
        assert_eq!(reg.fidelity(), ExecFidelity::Register);
        let rf = fast.run_layer(&layer, &input, &weights).unwrap();
        let rr = reg.run_layer(&layer, &input, &weights).unwrap();
        assert_eq!(rf.ofmaps, rr.ofmaps);
        assert_eq!(rf.stats, rr.stats);
        assert_eq!(rf.per_shard, rr.per_shard);
    }

    #[test]
    fn hybrid_shards_stitch_bit_exact() {
        // Explicit hybrid mode: a 2×2 grid of filter-split × row-band
        // tiles reassembles bit-exactly against the golden conv and a
        // single engine, with the grid recorded on the plan.
        // 4 filter groups (P_N = 1) × H_O = 6 on 4 engines: neither pure
        // axis reaches 4× (filters 4 needs 4 shards of 1 group — bound 4,
        // tied — but rows cap at 6/2 = 3×), and the planner lands on the
        // 2×2 grid (bound 2·2 = 4 with every tile equal).
        let mut rng = SplitMix64::new(47);
        let layer = ConvLayer::new("hy", 6, 3, 2, 4, 1, 1); // 4 filters, H_O = 6
        let input = rand_tensor(&mut rng, 2, 6, 6);
        let weights = rng.vec_i32(4 * 2 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 1); // P_N = 1 → 4 filter groups
        let farm = EngineFarm::new(FarmConfig::new(4, arch));
        let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Hybrid).unwrap();
        assert_eq!(r.plan.axis, ShardAxis::Hybrid);
        assert_eq!(r.plan.shards.len(), r.plan.grid.0 * r.plan.grid.1);
        assert!(r.plan.grid.0 > 1 && r.plan.grid.1 > 1, "a true 2-D grid: {:?}", r.plan.grid);
        let single = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 4, 3, 1, 1), "hybrid vs golden");
        assert_eq!(r.ofmaps, single.ofmaps, "hybrid stitch vs single engine");
        assert_eq!(r.stats.output_writes, single.stats.output_writes);
        assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
        assert!(r.stats.cycles < single.stats.cycles, "the grid must cut parallel cycles");
    }

    #[test]
    fn poisoned_job_surfaces_named_engine_error_and_farm_survives() {
        // The PR-5 farm-robustness regression: a job that panics inside a
        // worker (here: corrupt weights tripping the engine's length
        // assert) must come back as a named-engine error — not a deadlock
        // on the reply channel, not a worker-thread loss — and the farm
        // must keep serving afterwards.
        let mut rng = SplitMix64::new(53);
        let layer = ConvLayer::new("poison", 8, 3, 2, 4, 1, 1);
        let input = rand_tensor(&mut rng, 2, 8, 8);
        let good = rng.vec_i32(4 * 2 * 9, -8, 8);
        let bad = vec![1i32; 7]; // wrong length → assert in run_shard_shared
        let farm = EngineFarm::new(FarmConfig::new(3, ArchConfig::small(3, 2, 2)));
        let err = farm
            .run_layer_mode(&layer, &input, &bad, ShardMode::FilterShards)
            .expect_err("poisoned job must error, not hang or succeed");
        let msg = format!("{err:#}");
        assert!(msg.contains("trim-farm-"), "error names the engine: {msg}");
        assert!(msg.contains("poison"), "error names the layer: {msg}");
        // The workers caught the panic: the same farm still serves.
        let r = farm.run_layer_mode(&layer, &input, &good, ShardMode::Auto).unwrap();
        assert_eq!(r.ofmaps, conv3d_i32(&input, &good, 4, 3, 1, 1), "farm survives the poison");
    }

    #[test]
    fn poisoned_pipeline_job_errors_instead_of_hanging() {
        // run_pipeline holds its reply sender for follow-on stage
        // submissions, which is exactly the shape that used to deadlock
        // when a worker died: the channel never closed. The catch_unwind
        // reply path turns it into a named-engine error.
        let l1 = ConvLayer::new("p1", 8, 3, 2, 3, 1, 1);
        let mut rng = SplitMix64::new(59);
        let stages = vec![PipelineStage {
            layer: l1.clone(),
            weights: Arc::new(vec![0i32; 3]), // wrong length → panic in worker
            requant: None,
        }];
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        let images = vec![rand_tensor(&mut rng, 2, 8, 8)];
        let err = farm.run_pipeline(&stages, images).expect_err("must error, not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("trim-farm-") && msg.contains("stage 0"), "named error: {msg}");
    }

    #[test]
    fn canary_full_sample_reads_zero_divergence() {
        // Fast tier ≡ register oracle, so a rate-1.0 canary must judge
        // every shard and count no divergence of either kind.
        let mut rng = SplitMix64::new(61);
        let layer = ConvLayer::new("cny", 10, 3, 4, 6, 1, 1);
        let input = rand_tensor(&mut rng, 4, 10, 10);
        let weights = rng.vec_i32(6 * 4 * 9, -8, 8);
        let farm = EngineFarm::new(
            FarmConfig::new(2, ArchConfig::small(3, 2, 2)).with_canary(CanaryConfig::sampled(1.0)),
        );
        assert!(farm.canary_enabled());
        let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
        farm.canary_drain();
        let rep = farm.canary_report();
        assert_eq!(rep.sampled, r.plan.shards.len() as u64, "rate 1.0 samples every shard");
        assert_eq!(rep.bit_divergence, 0, "fast ofmaps are bit-exact vs the oracle");
        assert_eq!(rep.counter_divergence, 0, "fast stats are counter-exact vs the oracle");
        assert!(rep.is_clean());
        // ... and the same totals are visible through the farm registry.
        assert_eq!(farm.registry().counter_value("canary.sampled"), rep.sampled);
    }

    #[test]
    fn canary_catches_perturbed_fast_results() {
        // The perturb hook corrupts only the copy fed to the canary —
        // served ofmaps stay correct — and every perturbed sample must
        // be caught as bit divergence (stats are untouched).
        let mut rng = SplitMix64::new(67);
        let layer = ConvLayer::new("prt", 9, 3, 3, 4, 1, 1);
        let input = rand_tensor(&mut rng, 3, 9, 9);
        let weights = rng.vec_i32(4 * 3 * 9, -8, 8);
        let canary = CanaryConfig { perturb: true, ..CanaryConfig::sampled(1.0) };
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)).with_canary(canary));
        let r = farm.run_layer(&layer, &input, &weights).unwrap();
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 4, 3, 1, 1), "serving is unaffected");
        farm.canary_drain();
        let rep = farm.canary_report();
        assert!(rep.sampled > 0);
        assert_eq!(rep.bit_divergence, rep.sampled, "every perturbed sample is caught");
        assert_eq!(rep.counter_divergence, 0, "stats were not perturbed");
        assert!(!rep.is_clean());
    }

    #[test]
    fn canary_disabled_is_free_and_reports_zero() {
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        assert!(!farm.canary_enabled());
        farm.canary_drain(); // no-op
        assert_eq!(farm.canary_report(), CanaryReport::default());
    }

    #[test]
    fn canary_report_merge_and_delta() {
        let mut a = CanaryReport { sampled: 10, bit_divergence: 1, counter_divergence: 0 };
        let b = CanaryReport { sampled: u64::MAX, bit_divergence: 2, counter_divergence: 3 };
        a.merge(&b);
        assert_eq!(a.sampled, u64::MAX, "merge saturates");
        assert_eq!(a.bit_divergence, 3);
        let d = b.delta_since(&CanaryReport { sampled: 5, bit_divergence: 2, counter_divergence: 9 });
        assert_eq!(d.bit_divergence, 0);
        assert_eq!(d.counter_divergence, 0, "delta saturates at zero");
    }

    #[test]
    fn farm_registry_tracks_jobs_depth_and_microkernels() {
        let mut rng = SplitMix64::new(71);
        let layer = ConvLayer::new("tel", 10, 3, 4, 6, 1, 1);
        let input = rand_tensor(&mut rng, 4, 10, 10);
        let weights = rng.vec_i32(6 * 4 * 9, -8, 8);
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        let r = farm.run_layer(&layer, &input, &weights).unwrap();
        let reg = farm.registry();
        let jobs: u64 = (0..farm.engines())
            .map(|i| reg.counter_value(&format!("engine{i}.jobs")))
            .sum();
        assert_eq!(jobs, r.plan.shards.len() as u64, "every shard is counted on some engine");
        assert_eq!(reg.gauge_value("injector.depth"), 0, "queue drained");
        assert!(reg.counter_value("scratch.fills") > 0, "fast tier padded at least once");
        assert!(
            reg.counter_value("microkernel.k3") > 0,
            "3×3 stride-1 layer dispatches the fused K=3 arm"
        );
        assert_eq!(reg.counter_value("microkernel.strided"), 0);
        let prom = reg.render_prometheus();
        assert!(prom.contains("# TYPE injector_depth gauge"));
        assert!(prom.contains("engine0_jobs"));
    }

    #[test]
    fn chaos_faults_are_detected_and_healed_bit_exact() {
        // Seeded chaos on a 4-engine farm, all three fault models: every
        // injected corruption must be caught by the ABFT merge check
        // (detected == injected — 100% coverage) and every affected
        // shard re-executed until the final ofmaps equal the fault-free
        // run bit for bit. A run may legitimately *fail* instead (the
        // deterministic plan can fault one shard on every engine, which
        // exhausts the bounded retries) — but it may never serve a wrong
        // answer.
        let mut rng = SplitMix64::new(73);
        let layer = ConvLayer::new("chaos", 12, 3, 3, 8, 1, 1);
        let input = rand_tensor(&mut rng, 3, 12, 12);
        let weights = rng.vec_i32(8 * 3 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let clean = EngineFarm::new(FarmConfig::new(4, arch));
        let want = clean.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
        use crate::fault::FaultModel;
        for model in [FaultModel::Pe, FaultModel::Rsrb, FaultModel::Mem] {
            let mut injected_total = 0u64;
            let mut healed_runs = 0usize;
            let mut failed_runs = 0usize;
            for seed in 1..=8u64 {
                let farm = EngineFarm::new(
                    FarmConfig::new(4, arch)
                        .with_chaos(FaultConfig::new(0.3, seed, model))
                        .with_heal(8, u32::MAX), // isolate healing from quarantine
                );
                assert!(farm.chaos_enabled());
                match farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto) {
                    Ok(r) => {
                        assert_eq!(
                            r.ofmaps, want.ofmaps,
                            "{model} seed {seed}: healed run must be bit-exact"
                        );
                        assert_eq!(r.stats, want.stats, "{model} seed {seed}: stats from verified shards only");
                        let rep = farm.fault_report();
                        // A completed run received every dispatched job:
                        // exactly the injected faults were detected, each
                        // triggered one re-execution, and every faulted
                        // shard eventually healed.
                        assert_eq!(rep.detected, rep.injected, "{model} seed {seed}: 100% detection");
                        assert_eq!(rep.reexecuted, rep.detected, "{model} seed {seed}: every detection retried");
                        if rep.detected > 0 {
                            assert!(rep.corrected > 0, "{model} seed {seed}: faulted shards healed");
                        }
                        healed_runs += 1;
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(
                            msg.contains("ABFT checksum mismatch"),
                            "{model} seed {seed}: failure must be the typed detection error: {msg}"
                        );
                        let rep = farm.fault_report();
                        // The exhausted shard's final fault retries no
                        // further; in-flight shards may have injected
                        // without being merged (the run bailed first).
                        assert!(rep.detected >= 1, "{model} seed {seed}: failure implies detection");
                        assert!(rep.injected >= rep.detected, "{model} seed {seed}: no phantom detections");
                        assert_eq!(rep.reexecuted, rep.detected - 1, "{model} seed {seed}: bounded retries");
                        failed_runs += 1;
                    }
                }
                injected_total += farm.fault_report().injected;
            }
            assert!(
                injected_total > 0,
                "{model}: rate 0.3 over 8 seeds × shards must inject at least once"
            );
            assert!(
                healed_runs >= failed_runs,
                "{model}: bounded-retry exhaustion should be the exception ({healed_runs} ok, {failed_runs} failed)"
            );
        }
    }

    #[test]
    fn single_engine_chaos_exhausts_retries_into_typed_error() {
        // One engine, rate 1.0: the fault is deterministic per (engine,
        // shard), so every re-execution reproduces it and the bounded
        // retries exhaust into a typed error — never a wrong answer.
        let mut rng = SplitMix64::new(79);
        let layer = ConvLayer::new("lonely", 8, 3, 2, 2, 1, 1);
        let input = rand_tensor(&mut rng, 2, 8, 8);
        let weights = rng.vec_i32(2 * 2 * 9, -8, 8);
        let farm = EngineFarm::new(
            FarmConfig::new(1, ArchConfig::small(3, 2, 2))
                .with_chaos(FaultConfig::new(1.0, 7, crate::fault::FaultModel::Pe))
                .with_heal(2, 3),
        );
        let err = farm
            .run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards)
            .expect_err("a deterministic fault on the only engine cannot heal");
        let msg = format!("{err:#}");
        assert!(msg.contains("ABFT checksum mismatch"), "typed detection error: {msg}");
        assert!(msg.contains("after 3 attempts"), "bounded retries: {msg}");
        let rep = farm.fault_report();
        assert_eq!(
            rep,
            FaultReport { injected: 3, detected: 3, corrected: 0, reexecuted: 2, ..FaultReport::default() }
        );
        // Threshold crossed but the last live engine is protected.
        assert_eq!(farm.engine_health(), vec![EngineHealth::Suspect]);
        assert_eq!(farm.live_engines(), 1);
    }

    #[test]
    fn quarantine_replans_over_survivors() {
        // Quarantine is driven through the attribution path directly so
        // the test is independent of hash luck: two faults cross the
        // threshold, the engine stops receiving work, and the next layer
        // is replanned over the three survivors.
        let mut rng = SplitMix64::new(83);
        let layer = ConvLayer::new("replan", 10, 3, 2, 16, 1, 1); // 8 filter groups on P_N=2
        let input = rand_tensor(&mut rng, 2, 10, 10);
        let weights = rng.vec_i32(16 * 2 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(4, arch).with_heal(3, 2));
        assert!(!farm.note_engine_fault(3), "first fault: suspect, not quarantined");
        assert_eq!(farm.engine_health()[3], EngineHealth::Suspect);
        assert!(farm.note_engine_fault(3), "second fault crosses the threshold");
        assert_eq!(farm.engine_health()[3], EngineHealth::Quarantined);
        assert_eq!(farm.live_engines(), 3);
        assert_eq!(farm.fault_report().quarantined, 1);
        let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
        assert_eq!(r.plan.shards.len(), 3, "plan shrinks to the survivors");
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 16, 3, 1, 1), "degraded, never wrong");
        assert_eq!(
            farm.registry().counter_value("engine3.jobs"),
            0,
            "a quarantined engine receives no work"
        );
        // The last live engine can never be quarantined.
        for e in 0..3 {
            farm.note_engine_fault(e);
            farm.note_engine_fault(e);
        }
        assert!(farm.live_engines() >= 1);
        let health = farm.engine_health();
        assert_eq!(
            health.iter().filter(|h| **h == EngineHealth::Quarantined).count(),
            3,
            "exactly one engine survives: {health:?}"
        );
        let r2 = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
        assert_eq!(r2.plan.shards.len(), 1, "degenerate single-survivor plan");
        assert_eq!(r2.ofmaps, r.ofmaps);
    }

    #[test]
    fn zero_rate_chaos_reports_nothing_and_serves_exactly() {
        // Injection disabled: no fault counters move, yet the ABFT check
        // still verified every merged shard (it simply found nothing).
        let mut rng = SplitMix64::new(89);
        let layer = ConvLayer::new("calm", 9, 3, 3, 4, 1, 1);
        let input = rand_tensor(&mut rng, 3, 9, 9);
        let weights = rng.vec_i32(4 * 3 * 9, -8, 8);
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        assert!(!farm.chaos_enabled());
        let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto).unwrap();
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 4, 3, 1, 1));
        assert_eq!(farm.fault_report(), FaultReport::default());
        assert!(farm.engine_health().iter().all(|h| *h == EngineHealth::Healthy));
    }

    #[test]
    fn hedged_slow_chaos_stays_bit_exact() {
        // Slow chaos delays seeded (engine, shard) pairs by 2–8 ms;
        // with hedging on, a duplicate dispatched past the budget races
        // the sleeper and the first result wins the FirstWins
        // rendezvous — the merge is bit-exact either way, duplicates
        // are discarded, never double-merged.
        let mut rng = SplitMix64::new(97);
        let layer = ConvLayer::new("slowpoke", 10, 3, 2, 16, 1, 1);
        let input = rand_tensor(&mut rng, 2, 10, 10);
        let weights = rng.vec_i32(16 * 2 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let want = conv3d_i32(&input, &weights, 16, 3, 1, 1);
        let mut hedged_total = 0u64;
        for seed in 1..=6u64 {
            let farm = EngineFarm::new(
                FarmConfig::new(4, arch)
                    .with_chaos(FaultConfig::new(0.5, seed, crate::fault::FaultModel::Slow))
                    .with_hedge(2.0, u32::MAX), // isolate hedging from quarantine
            );
            let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
            assert_eq!(r.ofmaps, want, "seed {seed}: hedged slow run must be bit-exact");
            let rep = farm.fault_report();
            assert_eq!(rep.injected, 0, "seed {seed}: timing chaos corrupts nothing");
            assert_eq!(rep.timing_quarantined, 0, "seed {seed}: threshold maxed out");
            hedged_total += rep.hedged;
        }
        assert!(hedged_total > 0, "slow rate 0.5 over 6 seeds must trip the hedge budget");
    }

    #[test]
    fn hang_chaos_with_hedging_resolves_or_fails_typed() {
        // Hang chaos parks the worker until cancelled: the shard only
        // resolves through a hedge duplicate on another engine. Every
        // completed run must be bit-exact; a run where every engine
        // hangs on the same shard may fail — but only through the
        // typed analytic valve, never a wrong answer or a deadlock.
        let mut rng = SplitMix64::new(103);
        let layer = ConvLayer::new("hangover", 10, 3, 2, 16, 1, 1);
        let input = rand_tensor(&mut rng, 2, 10, 10);
        let weights = rng.vec_i32(16 * 2 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let want = conv3d_i32(&input, &weights, 16, 3, 1, 1);
        let mut hedged_total = 0u64;
        let mut ok_runs = 0usize;
        for seed in 1..=12u64 {
            let farm = EngineFarm::new(
                FarmConfig::new(4, arch)
                    .with_chaos(FaultConfig::new(0.3, seed, crate::fault::FaultModel::Hang))
                    .with_hedge(4.0, 3)
                    .with_valve(Duration::from_secs(5), 8.0),
            );
            match farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards) {
                Ok(r) => {
                    assert_eq!(r.ofmaps, want, "seed {seed}: hedged hang run must be bit-exact");
                    ok_runs += 1;
                }
                Err(e) => {
                    assert!(
                        matches!(e.downcast_ref::<ServeError>(), Some(ServeError::EngineFailed { .. })),
                        "seed {seed}: the only allowed failure is the typed valve: {e:#}"
                    );
                }
            }
            hedged_total += farm.fault_report().hedged;
        }
        assert!(hedged_total > 0, "hang rate 0.3 over 12 seeds must hedge");
        assert!(ok_runs >= 9, "an unresolvable hang must be the rare exception ({ok_runs}/12 ok)");
    }

    #[test]
    fn hang_on_sole_engine_fires_analytic_valve_typed() {
        // Single-engine farms cannot hedge; the whole-layer valve
        // (analytic budget × multiplier, floored) is the backstop and
        // must fire as a typed, retryable EngineFailed — not block for
        // the legacy 300 s, not return garbage.
        let mut rng = SplitMix64::new(107);
        let layer = ConvLayer::new("stuck", 8, 3, 2, 2, 1, 1);
        let input = rand_tensor(&mut rng, 2, 8, 8);
        let weights = rng.vec_i32(2 * 2 * 9, -8, 8);
        let farm = EngineFarm::new(
            FarmConfig::new(1, ArchConfig::small(3, 2, 2))
                .with_chaos(FaultConfig::new(1.0, 11, crate::fault::FaultModel::Hang))
                .with_valve(Duration::from_millis(200), 1.0),
        );
        let started = Instant::now();
        let err = farm
            .run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards)
            .expect_err("a hang on the only engine cannot resolve");
        assert!(started.elapsed() < Duration::from_secs(30), "valve fires at the floor, not 300 s");
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::EngineFailed { reason }) => {
                assert!(reason.contains("service budget exhausted"), "valve reason: {reason}");
            }
            other => panic!("expected the typed valve cause, got {other:?}: {err:#}"),
        }
    }

    #[test]
    fn probation_restores_engines_and_contains_flappers() {
        // Quarantine is no longer forever: after the cooldown the
        // engine re-enters planning on probation. A clean probe
        // restores it fully; a faulting probe re-quarantines it with
        // the cooldown doubled, so a permanent flapper converges to
        // near-zero probe traffic instead of oscillating.
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(
            FarmConfig::new(4, arch).with_heal(3, 2).with_probation(Duration::from_millis(200)),
        );
        farm.note_engine_fault(2);
        assert!(farm.note_engine_fault(2), "second fault crosses the threshold");
        assert_eq!(farm.engine_health()[2], EngineHealth::Quarantined);
        assert_eq!(farm.live_engines(), 3);
        farm.probation_tick();
        assert_eq!(farm.engine_health()[2], EngineHealth::Quarantined, "cooldown not yet expired");
        std::thread::sleep(Duration::from_millis(250));
        farm.probation_tick();
        assert_ne!(farm.engine_health()[2], EngineHealth::Quarantined, "released on probation");
        assert_eq!(farm.live_engines(), 4, "probation engine is back in the plan");
        farm.note_engine_recovered(2);
        assert_eq!(farm.engine_health()[2], EngineHealth::Healthy, "clean probe restores fully");
        // The flapper: re-quarantine, probe, fault on probation.
        farm.note_engine_fault(2);
        assert!(farm.note_engine_fault(2));
        std::thread::sleep(Duration::from_millis(250));
        farm.probation_tick();
        assert_ne!(farm.engine_health()[2], EngineHealth::Quarantined);
        assert!(farm.note_engine_fault(2), "one strike on probation re-quarantines immediately");
        assert_eq!(farm.engine_health()[2], EngineHealth::Quarantined);
        // Doubled cooldown: the base expiry no longer releases it.
        std::thread::sleep(Duration::from_millis(250));
        farm.probation_tick();
        assert_eq!(
            farm.engine_health()[2],
            EngineHealth::Quarantined,
            "flapper containment: cooldown doubled to 400 ms"
        );
        std::thread::sleep(Duration::from_millis(200));
        farm.probation_tick();
        assert_ne!(farm.engine_health()[2], EngineHealth::Quarantined, "released after the doubled cooldown");
    }

    #[test]
    fn health_map_skew_shrinks_slow_engine_share() {
        // Seed the latency EWMA directly: three engines at 1 µs/cycle,
        // one at 8 µs/cycle. Past the skew gate the planner goes
        // cost-proportional — the slow engine's shard gets fewer filter
        // groups — and the merged output stays exact (the heterogeneity
        // hook of the ROADMAP item).
        let mut rng = SplitMix64::new(101);
        let layer = ConvLayer::new("skewed", 10, 3, 2, 32, 1, 1); // 16 filter groups on P_N=2
        let input = rand_tensor(&mut rng, 2, 10, 10);
        let weights = rng.vec_i32(32 * 2 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(4, arch));
        for _ in 0..32 {
            for e in 0..3 {
                farm.health_map().observe(e, 1_000, Duration::from_micros(1_000));
            }
            farm.health_map().observe(3, 1_000, Duration::from_micros(8_000));
        }
        assert!(farm.health_map().slowdown(3) > 1.0, "EWMA sees the slow engine");
        assert!(
            farm.health_map().plan_weights(&[0, 1, 2, 3]).is_some(),
            "skew past the gate enables weighted planning"
        );
        let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards).unwrap();
        assert_eq!(r.plan.shards.len(), 4, "one shard per live engine");
        let sizes: Vec<usize> = r.plan.shards.iter().map(|s| s.filters.len()).collect();
        let (lo, hi) = (sizes.iter().min().copied(), sizes.iter().max().copied());
        assert!(lo < hi, "cost-proportional sizing: shares must be unequal, got {sizes:?}");
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 32, 3, 1, 1), "weighted plan merges exactly");
    }
}
