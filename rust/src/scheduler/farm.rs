//! The engine farm: a pool of worker threads, each wrapping one
//! cycle-accurate [`EngineSim`], plus the dispatch/merge logic that makes
//! the pool behave like one big accelerator.
//!
//! Distribution strategies (see [`super::shard::ShardMode`]):
//!
//! * **filter shards** — [`EngineFarm::run_layer`] splits a layer's
//!   filters across engines on `P_N`-group boundaries (the planner of
//!   [`super::shard`]) and reassembles the ofmaps bit-exactly. This is the
//!   multi-fabric scaling of the 3D-TrIM follow-up: every fabric sees the
//!   same broadcast inputs and owns a disjoint set of filters.
//! * **spatial (row) shards** — split the layer's *output rows* instead:
//!   each engine runs all `N` filters over a contiguous row band
//!   ([`super::shard::plan_row_shards`]), reading its input slab including
//!   the halo rows shared with neighbouring bands. This is the axis that
//!   saturates the farm on CL1-class layers whose few filter groups leave
//!   filter sharding starved; `Auto` picks the better axis per layer.
//! * **layer pipeline** — [`EngineFarm::run_pipeline`] pins each layer of
//!   a chain to an engine (`layer i → engine i mod E`) and streams images
//!   through, so engine 0 convolves image 1's first layer while engine 1
//!   works on image 0's second layer (contrast with Chain-NN's serial
//!   chain, where one fabric owns the whole network).
//!
//! Stats follow the Tables I–II accounting: counters of parallel shards
//! **sum** (every access really happens — a row band's off-chip input
//! reads count its whole slab, halo rows included) while cycles take the
//! **max** (shards run concurrently); within one engine, sequential jobs
//! add their cycles. Both reductions reuse [`SimStats::merge`] /
//! [`SimStats::merge_sequential`].

use super::shard::{plan_shards, ShardAxis, ShardMode, ShardPlan};
use crate::arch::engine::EngineRunResult;
use crate::arch::{ArchConfig, EngineSim, ExecFidelity, SimStats};
use crate::golden::Tensor3;
use crate::model::quant::Requant;
use crate::model::ConvLayer;
use std::ops::Range;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Farm-level configuration.
#[derive(Debug, Clone, Copy)]
pub struct FarmConfig {
    /// Number of simulated TrIM engines (worker threads).
    pub engines: usize,
    /// Architecture of every engine in the pool (homogeneous farm).
    pub arch: ArchConfig,
    /// Execution tier of every engine. The farm defaults to
    /// [`ExecFidelity::Fast`] — identical results (bit-exact ofmaps,
    /// counter-exact stats), orders of magnitude more layer throughput;
    /// pick [`ExecFidelity::Register`] to run the cycle-accurate oracle.
    pub fidelity: ExecFidelity,
}

impl FarmConfig {
    pub fn new(engines: usize, arch: ArchConfig) -> Self {
        Self { engines, arch, fidelity: ExecFidelity::Fast }
    }

    pub fn with_fidelity(engines: usize, arch: ArchConfig, fidelity: ExecFidelity) -> Self {
        Self { engines, arch, fidelity }
    }
}

impl Default for FarmConfig {
    fn default() -> Self {
        Self::new(4, ArchConfig::paper_engine())
    }
}

/// The slice of a layer one worker computes: a contiguous filter range
/// (over all output rows) or a contiguous output-row band (over all
/// filters) — the two shard axes of [`super::shard`].
#[derive(Debug, Clone)]
enum ShardWork {
    Filters(Range<usize>),
    Rows(Range<usize>),
}

/// One unit of work for a worker: a piece of one layer, plus an optional
/// output re-quantisation (used between pipeline stages).
struct Job {
    layer: ConvLayer,
    input: Arc<Tensor3>,
    weights: Arc<Vec<i32>>,
    work: ShardWork,
    requant: Option<Requant>,
    tag: u64,
    reply: Sender<JobDone>,
}

struct JobDone {
    tag: u64,
    work: ShardWork,
    result: EngineRunResult,
}

fn worker_loop(engine: EngineSim, rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        // The `_shared` entry points let the engine's fast tier key its
        // padded-input materialisation on the Arc'd input identity.
        let mut result = match &job.work {
            ShardWork::Filters(r) => {
                engine.run_filter_range_shared(&job.layer, &job.input, &job.weights, r.clone())
            }
            ShardWork::Rows(r) => {
                engine.run_row_range_shared(&job.layer, &job.input, &job.weights, r.clone())
            }
        };
        if let Some(q) = job.requant {
            for v in result.ofmaps.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
        }
        // Receiver may have given up (farm dropped mid-run) — ignore.
        let _ = job.reply.send(JobDone { tag: job.tag, work: job.work, result });
    }
}

/// Result of one farmed layer run (filter- or row-shard mode).
#[derive(Debug, Clone)]
pub struct FarmRunResult {
    /// Reassembled ofmaps `[N][H_O][W_O]` — bit-identical to a
    /// single-engine [`EngineSim::run_layer`] of the same layer.
    pub ofmaps: Tensor3,
    /// Aggregate stats: cycles = max over shards, accesses/MACs = sum.
    /// Filter shards partition the single-engine counters exactly; row
    /// bands additionally count their halo input rows (each band reads its
    /// whole slab), so summed off-chip input reads exceed the
    /// single-engine count by exactly the inter-band halo duplication.
    pub stats: SimStats,
    /// Per-shard stats, indexed like `plan.shards`.
    pub per_shard: Vec<SimStats>,
    /// The shard assignment that produced this result.
    pub plan: ShardPlan,
}

/// One stage of a layer pipeline: a layer, its weights, and the
/// re-quantisation applied to its ofmaps before they feed the next stage.
#[derive(Clone)]
pub struct PipelineStage {
    pub layer: ConvLayer,
    pub weights: Arc<Vec<i32>>,
    pub requant: Option<Requant>,
}

/// Result of streaming a batch of images through a layer pipeline.
#[derive(Debug, Clone)]
pub struct PipelineRunResult {
    /// Final activations, one per input image, in input order.
    pub outputs: Vec<Tensor3>,
    /// Aggregate stats: cycles = max over engines of that engine's total
    /// (sequential) cycles; accesses/MACs = sum over all jobs.
    pub stats: SimStats,
    /// Per-engine sequential stats.
    pub per_engine: Vec<SimStats>,
}

/// A pool of simulated TrIM engines behind per-worker job queues.
pub struct EngineFarm {
    cfg: FarmConfig,
    senders: Vec<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl EngineFarm {
    /// Spawn `cfg.engines` worker threads, each owning one [`EngineSim`].
    pub fn new(cfg: FarmConfig) -> Self {
        assert!(cfg.engines >= 1, "farm needs at least one engine");
        let mut senders = Vec::with_capacity(cfg.engines);
        let mut workers = Vec::with_capacity(cfg.engines);
        for i in 0..cfg.engines {
            let (tx, rx) = mpsc::channel::<Job>();
            let engine = EngineSim::with_fidelity(cfg.arch, cfg.fidelity);
            let handle = std::thread::Builder::new()
                .name(format!("trim-farm-{i}"))
                .spawn(move || worker_loop(engine, rx))
                .expect("spawning farm worker");
            senders.push(tx);
            workers.push(handle);
        }
        Self { cfg, senders, workers }
    }

    pub fn engines(&self) -> usize {
        self.senders.len()
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.cfg.arch
    }

    pub fn fidelity(&self) -> ExecFidelity {
        self.cfg.fidelity
    }

    /// Run one layer sharded across the farm in filter-shard mode and
    /// merge the results (the PR-1 entry point, kept for the existing
    /// callers/tests). See [`EngineFarm::run_layer_mode`].
    pub fn run_layer(&self, layer: &ConvLayer, input: &Tensor3, weights: &[i32]) -> FarmRunResult {
        self.run_layer_mode(layer, input, weights, ShardMode::FilterShards)
    }

    /// Run one layer sharded across the farm under `mode` (filter, spatial
    /// or auto) and merge the results. Blocks until every shard has
    /// completed. Copies `input` and `weights` into shared buffers —
    /// callers that already hold `Arc`s (the serving hot path) should use
    /// [`EngineFarm::run_layer_shared`] to avoid the copies.
    pub fn run_layer_mode(
        &self,
        layer: &ConvLayer,
        input: &Tensor3,
        weights: &[i32],
        mode: ShardMode,
    ) -> FarmRunResult {
        self.run_layer_shared(layer, Arc::new(input.clone()), Arc::new(weights.to_vec()), mode)
    }

    /// Zero-copy variant of [`EngineFarm::run_layer_mode`]: shards
    /// reference the caller's buffers through `Arc` clones. `mode` picks
    /// the shard axis ([`ShardMode::FilterShards`], [`ShardMode::Spatial`]
    /// or the per-layer [`ShardMode::Auto`]);
    /// [`ShardMode::LayerPipeline`] is a cross-layer mode served by
    /// [`EngineFarm::run_pipeline`] instead.
    pub fn run_layer_shared(
        &self,
        layer: &ConvLayer,
        input: Arc<Tensor3>,
        weights: Arc<Vec<i32>>,
        mode: ShardMode,
    ) -> FarmRunResult {
        assert!(mode != ShardMode::LayerPipeline, "pipeline mode goes through run_pipeline");
        let plan = plan_shards(&self.cfg.arch, layer, self.engines(), mode);
        let (reply, done_rx) = mpsc::channel::<JobDone>();
        for shard in &plan.shards {
            let work = match plan.axis {
                ShardAxis::Filters => ShardWork::Filters(shard.filters.clone()),
                ShardAxis::Rows => ShardWork::Rows(shard.rows.clone()),
            };
            let job = Job {
                layer: layer.clone(),
                input: Arc::clone(&input),
                weights: Arc::clone(&weights),
                work,
                requant: None,
                tag: shard.index as u64,
                reply: reply.clone(),
            };
            self.senders[shard.index].send(job).expect("farm worker gone");
        }
        drop(reply);

        let (h_o, w_o) = (layer.h_o(), layer.w_o());
        let mut ofmaps = Tensor3::zeros(layer.n, h_o, w_o);
        let mut stats = SimStats::default();
        let mut per_shard = vec![SimStats::default(); plan.shards.len()];
        let mut received = 0usize;
        while let Ok(done) = done_rx.recv() {
            let data = &done.result.ofmaps.data;
            match &done.work {
                // A filter shard is a contiguous channel block of the ofmap.
                ShardWork::Filters(filters) => {
                    let at = filters.start * h_o * w_o;
                    ofmaps.data[at..at + data.len()].copy_from_slice(data);
                }
                // A row band interleaves: rows `rows` of every filter.
                ShardWork::Rows(rows) => {
                    let b_h = rows.len();
                    for f in 0..layer.n {
                        let src = &data[f * b_h * w_o..(f + 1) * b_h * w_o];
                        let at = (f * h_o + rows.start) * w_o;
                        ofmaps.data[at..at + b_h * w_o].copy_from_slice(src);
                    }
                }
            }
            stats.merge(&done.result.stats); // parallel: cycles max, counters sum
            per_shard[done.tag as usize] = done.result.stats;
            received += 1;
        }
        assert_eq!(received, plan.shards.len(), "a farm worker died mid-layer");
        FarmRunResult { ofmaps, stats, per_shard, plan }
    }

    /// Stream `inputs` through a chain of layers, one engine per stage
    /// (`stage i → engine i mod E`). An image's stages run in order; across
    /// images the stages overlap, which is where the speedup comes from.
    /// Outputs are returned in input order. Blocks until the last image
    /// leaves the last stage.
    pub fn run_pipeline(&self, stages: &[PipelineStage], inputs: Vec<Tensor3>) -> PipelineRunResult {
        assert!(!stages.is_empty(), "pipeline needs at least one stage");
        for (a, b) in stages.iter().zip(stages.iter().skip(1)) {
            assert_eq!(a.layer.n, b.layer.m, "stage channel mismatch: {} → {}", a.layer.name, b.layer.name);
            assert_eq!((a.layer.h_o(), a.layer.w_o()), (b.layer.h_i, b.layer.w_i),
                "stage shape mismatch: {} → {}", a.layer.name, b.layer.name);
        }
        let n_img = inputs.len();
        let n_stage = stages.len();
        let (reply, done_rx) = mpsc::channel::<JobDone>();
        let submit = |img: usize, stage: usize, input: Arc<Tensor3>| {
            let s = &stages[stage];
            let job = Job {
                layer: s.layer.clone(),
                input,
                weights: Arc::clone(&s.weights),
                work: ShardWork::Filters(0..s.layer.n),
                requant: s.requant,
                tag: (img * n_stage + stage) as u64,
                reply: reply.clone(),
            };
            self.senders[stage % self.senders.len()].send(job).expect("farm worker gone");
        };

        for (img, t) in inputs.into_iter().enumerate() {
            submit(img, 0, Arc::new(t));
        }
        let mut outputs: Vec<Option<Tensor3>> = (0..n_img).map(|_| None).collect();
        let mut per_engine = vec![SimStats::default(); self.senders.len()];
        let mut finished = 0usize;
        while finished < n_img {
            let done = done_rx.recv().expect("farm workers gone mid-pipeline");
            let tag = done.tag as usize;
            let (img, stage) = (tag / n_stage, tag % n_stage);
            per_engine[stage % self.senders.len()].merge_sequential(&done.result.stats);
            if stage + 1 < n_stage {
                submit(img, stage + 1, Arc::new(done.result.ofmaps));
            } else {
                outputs[img] = Some(done.result.ofmaps);
                finished += 1;
            }
        }
        let mut stats = SimStats::default();
        for e in &per_engine {
            stats.merge(e); // engines run in parallel: cycles max, counters sum
        }
        let outputs = outputs.into_iter().map(|o| o.expect("image lost in pipeline")).collect();
        PipelineRunResult { outputs, stats, per_engine }
    }
}

impl Drop for EngineFarm {
    fn drop(&mut self) {
        // Closing every job queue ends the worker loops; then join.
        self.senders.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::conv3d_i32;
    use crate::util::SplitMix64;

    fn rand_tensor(rng: &mut SplitMix64, c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3 { c, h, w, data: rng.vec_i32(c * h * w, -64, 64) }
    }

    #[test]
    fn farm_matches_golden_and_aggregates_stats() {
        let mut rng = SplitMix64::new(11);
        let layer = ConvLayer::new("f", 10, 3, 5, 9, 1, 1);
        let input = rand_tensor(&mut rng, 5, 10, 10);
        let weights = rng.vec_i32(9 * 5 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(3, arch));
        let r = farm.run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 9, 3, 1, 1));
        assert_eq!(r.plan.shards.len(), 3);
        // cycles = max over shards, counters = sum over shards
        assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
        assert_eq!(r.stats.macs, r.per_shard.iter().map(|s| s.macs).sum::<u64>());
        // … and the counters partition a single-engine run exactly.
        let single = EngineSim::new(arch).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, single.ofmaps);
        assert_eq!(r.stats.ext_input_reads, single.stats.ext_input_reads);
        assert_eq!(r.stats.macs, single.stats.macs);
        assert_eq!(r.stats.output_writes, single.stats.output_writes);
        assert!(r.stats.cycles < single.stats.cycles, "sharding must cut parallel cycles");
    }

    #[test]
    fn pipeline_matches_serial_golden_chain() {
        let mut rng = SplitMix64::new(23);
        // 2-stage chain: 3→4 then 4→2, both 3×3 pad 1 on 8×8.
        let l1 = ConvLayer::new("p1", 8, 3, 3, 4, 1, 1);
        let l2 = ConvLayer::new("p2", 8, 3, 4, 2, 1, 1);
        let w1 = Arc::new(rng.vec_i32(4 * 3 * 9, -6, 6));
        let w2 = Arc::new(rng.vec_i32(2 * 4 * 9, -6, 6));
        let q = Requant::new(4, 8);
        let stages = vec![
            PipelineStage { layer: l1.clone(), weights: Arc::clone(&w1), requant: Some(q) },
            PipelineStage { layer: l2.clone(), weights: Arc::clone(&w2), requant: Some(q) },
        ];
        let images: Vec<Tensor3> = (0..5).map(|_| rand_tensor(&mut rng, 3, 8, 8)).collect();
        let farm = EngineFarm::new(FarmConfig::new(2, ArchConfig::small(3, 2, 2)));
        let r = farm.run_pipeline(&stages, images.clone());
        assert_eq!(r.outputs.len(), 5);
        for (img, out) in images.iter().zip(&r.outputs) {
            let mut a1 = conv3d_i32(img, &w1, 4, 3, 1, 1);
            for v in a1.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
            let mut a2 = conv3d_i32(&a1, &w2, 2, 3, 1, 1);
            for v in a2.data.iter_mut() {
                *v = q.apply(*v as i64) as i32;
            }
            assert_eq!(out, &a2);
        }
        // Both engines must have done work, and parallel cycles = max.
        assert!(r.per_engine.iter().all(|s| s.cycles > 0));
        assert_eq!(r.stats.cycles, r.per_engine.iter().map(|s| s.cycles).max().unwrap());
    }

    #[test]
    fn single_engine_farm_is_degenerate_but_exact() {
        let mut rng = SplitMix64::new(31);
        let layer = ConvLayer::new("d", 7, 3, 2, 3, 1, 0);
        let input = rand_tensor(&mut rng, 2, 7, 7);
        let weights = rng.vec_i32(3 * 2 * 9, -8, 8);
        let farm = EngineFarm::new(FarmConfig::new(1, ArchConfig::small(3, 2, 2)));
        let r = farm.run_layer(&layer, &input, &weights);
        let single = EngineSim::new(ArchConfig::small(3, 2, 2)).run_layer(&layer, &input, &weights);
        assert_eq!(r.ofmaps, single.ofmaps);
        assert_eq!(r.stats, single.stats);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let farm = EngineFarm::new(FarmConfig::new(3, ArchConfig::small(3, 2, 2)));
        drop(farm); // must not hang or panic
    }

    #[test]
    fn row_shards_stitch_bit_exact() {
        // Spatial mode must reassemble the interleaved row bands into the
        // same ofmaps a single engine produces, on a strided layer too.
        let mut rng = SplitMix64::new(41);
        for (hw, k, stride, pad) in [(10usize, 3usize, 1usize, 1usize), (13, 3, 2, 1)] {
            let layer = ConvLayer::new("rs", hw, k, 4, 5, stride, pad);
            let input = rand_tensor(&mut rng, 4, hw, hw);
            let weights = rng.vec_i32(5 * 4 * k * k, -8, 8);
            let arch = ArchConfig::small(3, 2, 2);
            let farm = EngineFarm::new(FarmConfig::new(3, arch));
            let r = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Spatial);
            assert_eq!(r.plan.axis, ShardAxis::Rows);
            assert_eq!(r.plan.shards.len(), 3);
            let single = EngineSim::fast(arch).run_layer(&layer, &input, &weights);
            assert_eq!(r.ofmaps, single.ofmaps, "s={stride}: row stitch vs single engine");
            assert_eq!(r.ofmaps, conv3d_i32(&input, &weights, 5, k, stride, pad));
            // work counters that are proportional to ofmap rows partition
            assert_eq!(r.stats.output_writes, single.stats.output_writes);
            assert_eq!(r.stats.cycles, r.per_shard.iter().map(|s| s.cycles).max().unwrap());
            assert!(r.stats.cycles < single.stats.cycles, "bands must cut parallel cycles");
            // halo accounting: bands read at least the single-engine slab
            assert!(r.stats.ext_input_reads >= single.stats.ext_input_reads);
        }
    }

    #[test]
    fn auto_mode_picks_rows_on_narrow_wide_layers() {
        // CL1-class shape: few filters (1 group on P_N=2), wide spatial.
        let mut rng = SplitMix64::new(43);
        let layer = ConvLayer::new("cl1ish", 16, 3, 3, 2, 1, 1);
        let input = rand_tensor(&mut rng, 3, 16, 16);
        let weights = rng.vec_i32(2 * 3 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        let farm = EngineFarm::new(FarmConfig::new(4, arch));
        let auto = farm.run_layer_mode(&layer, &input, &weights, ShardMode::Auto);
        let filt = farm.run_layer_mode(&layer, &input, &weights, ShardMode::FilterShards);
        assert_eq!(auto.plan.axis, ShardAxis::Rows, "auto must pick the spatial axis here");
        assert_eq!(filt.plan.shards.len(), 1, "filter axis is starved (1 group)");
        assert_eq!(auto.ofmaps, filt.ofmaps, "both modes serve identical ofmaps");
        assert!(
            auto.stats.cycles < filt.stats.cycles,
            "spatial sharding must beat starved filter sharding: {} vs {}",
            auto.stats.cycles,
            filt.stats.cycles
        );
    }

    #[test]
    fn farm_fidelities_agree_exactly() {
        // A fast farm and a register farm must return identical
        // FarmRunResults (ofmaps, merged stats, per-shard stats).
        let mut rng = SplitMix64::new(77);
        let layer = ConvLayer::new("fid", 9, 3, 5, 7, 1, 1);
        let input = rand_tensor(&mut rng, 5, 9, 9);
        let weights = rng.vec_i32(7 * 5 * 9, -8, 8);
        let arch = ArchConfig::small(3, 2, 2);
        assert_eq!(FarmConfig::new(2, arch).fidelity, ExecFidelity::Fast);
        let fast = EngineFarm::new(FarmConfig::new(2, arch));
        let reg = EngineFarm::new(FarmConfig::with_fidelity(2, arch, ExecFidelity::Register));
        assert_eq!(reg.fidelity(), ExecFidelity::Register);
        let rf = fast.run_layer(&layer, &input, &weights);
        let rr = reg.run_layer(&layer, &input, &weights);
        assert_eq!(rf.ofmaps, rr.ofmaps);
        assert_eq!(rf.stats, rr.stats);
        assert_eq!(rf.per_shard, rr.per_shard);
    }
}
