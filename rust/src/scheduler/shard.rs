//! Sharding planner: split one [`ConvLayer`] into independent pieces of
//! work along the paper's own step structure.
//!
//! Two per-layer shard axes (plus the cross-layer pipeline mode):
//!
//! * **Filters** — the TrIM engine executes a layer as `⌈N/P_N⌉ × ⌈M/P_M⌉`
//!   computational steps (eq. (2)): the outer loop walks *filter groups* of
//!   `P_N` filters, and filters never share state — each core owns one
//!   filter and one psum buffer (Fig. 6). Filter groups are therefore the
//!   natural shard unit for a farm of engines (the multi-fabric scaling of
//!   the 3D-TrIM follow-up): give each engine a contiguous run of whole
//!   filter groups and the union of the shard ofmaps is bit-identical to a
//!   single-engine run, while the shard access counters partition the
//!   single-engine counters exactly.
//! * **Rows** ([`plan_row_shards`]) — split the *spatial* dimension
//!   instead: contiguous bands of output rows, each engine computing all
//!   `N` filters over its band (the multi-fabric spatial split the 3D-TrIM
//!   follow-up motivates for wide early layers). This is the axis that
//!   saturates a farm on CL1-class layers, where `⌈N/P_N⌉` filter groups
//!   cap filter-shard parallelism below the engine count (VGG-16 CL1 on
//!   the paper engine: 10 groups — an 8+-engine farm is starved on the
//!   filter axis but `H_O = 224` rows split 8 ways evenly). Each band
//!   reads its input slab *including halo rows* shared with the adjacent
//!   band ([`ConvLayer::band_input_rows`]), so band off-chip input reads
//!   sum to the single-engine count plus exactly the halo duplication.
//!
//! Tiled layers (K > K_nat, §V) keep a different *intra*-engine schedule,
//! but filters remain independent there too and a row band is just a
//! shorter layer, so both splits stay exact.
//!
//! [`ShardMode::Auto`] picks per layer: whichever axis has the better
//! [`ShardPlan::speedup_bound`], rows winning ties on layers whose filter
//! count cannot occupy the farm (`N < engines·P_N`).

use crate::arch::ArchConfig;
use crate::model::ConvLayer;
use std::ops::Range;

/// How the farm distributes work (see [`crate::scheduler::EngineFarm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Split each layer's filters across engines (data-parallel within a
    /// layer); every engine sees every input activation.
    FilterShards,
    /// Pin each layer of a network to an engine and stream images through
    /// (pipeline-parallel across layers); engine `i` runs layers
    /// `i, i+E, …` of the chain.
    LayerPipeline,
    /// Split each layer's output rows across engines (spatial-parallel
    /// within a layer); every engine runs all `N` filters over its band.
    Spatial,
    /// Per layer, pick the better of [`ShardMode::FilterShards`] and
    /// [`ShardMode::Spatial`] by [`ShardPlan::speedup_bound`] (rows win
    /// ties on `N < engines·P_N` layers).
    Auto,
}

impl ShardMode {
    /// CLI-facing name (`--shard filter|pipeline|spatial|auto`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::FilterShards => "filter",
            Self::LayerPipeline => "pipeline",
            Self::Spatial => "spatial",
            Self::Auto => "auto",
        }
    }
}

impl std::fmt::Display for ShardMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

impl std::str::FromStr for ShardMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "filter" | "filters" | "shards" => Ok(Self::FilterShards),
            "pipeline" | "layers" => Ok(Self::LayerPipeline),
            "spatial" | "rows" => Ok(Self::Spatial),
            "auto" => Ok(Self::Auto),
            other => Err(anyhow::anyhow!(
                "unknown shard mode {other:?} (expected filter|pipeline|spatial|auto)"
            )),
        }
    }
}

/// Which dimension a [`ShardPlan`] cuts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAxis {
    /// Shards are contiguous filter ranges (each over all output rows).
    Filters,
    /// Shards are contiguous output-row bands (each over all filters).
    Rows,
}

/// One engine's piece of a layer: a filter range × an output-row range.
/// Filter-axis shards cover all rows; row-axis shards cover all filters.
/// Filter boundaries are aligned to `P_N`-filter group boundaries (except
/// for the tail of the layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Shard index (== the engine it is dispatched to).
    pub index: usize,
    /// Filters `[start, end)` of the layer this shard computes.
    pub filters: Range<usize>,
    /// Whole filter groups of `P_N` covered by this shard.
    pub groups: usize,
    /// Output rows `[start, end)` of the layer this shard computes.
    pub rows: Range<usize>,
}

/// The per-layer shard assignment.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// The dimension this plan cuts.
    pub axis: ShardAxis,
    /// One entry per engine that received work (`len() ≤ engines`).
    pub shards: Vec<Shard>,
    /// Total filter groups in the layer: `⌈N/P_N⌉`.
    pub filter_groups: usize,
    /// The group size filter splits are aligned to (`P_N` of the engine).
    pub p_n: usize,
    /// Total output rows in the layer (`H_O`).
    pub rows: usize,
}

impl ShardPlan {
    /// Upper bound on the parallel speedup this split can deliver, in the
    /// plan's own work unit: whole-layer filter groups over the largest
    /// shard's groups (filter axis), or whole-layer output rows over the
    /// largest band (row axis). One metric across both axes, so
    /// [`ShardMode::Auto`] can compare them directly.
    pub fn speedup_bound(&self) -> f64 {
        match self.axis {
            ShardAxis::Filters => {
                let largest = self.shards.iter().map(|s| s.groups).max().unwrap_or(1);
                self.filter_groups as f64 / largest as f64
            }
            ShardAxis::Rows => {
                let largest = self.shards.iter().map(|s| s.rows.len()).max().unwrap_or(1);
                self.rows as f64 / largest as f64
            }
        }
    }
}

/// Split `n_units` contiguous work units across at most `engines` shards,
/// as evenly as possible (counts differ by at most one).
fn balanced_split(n_units: usize, engines: usize) -> Vec<Range<usize>> {
    let n_shards = engines.min(n_units);
    let base = n_units / n_shards;
    let extra = n_units % n_shards;
    let mut out = Vec::with_capacity(n_shards);
    let mut at = 0usize;
    for index in 0..n_shards {
        let take = base + usize::from(index < extra);
        out.push(at..at + take);
        at += take;
    }
    out
}

/// Split `layer` into at most `engines` filter shards on `P_N`-group
/// boundaries, balancing whole groups as evenly as possible.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * shards are non-empty, disjoint, contiguous and cover `0..N`;
/// * every shard boundary except the layer end is a multiple of `P_N`;
/// * shard group counts differ by at most one;
/// * `shards.len() == min(engines, ⌈N/P_N⌉)`.
pub fn plan_filter_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    assert!(layer.n >= 1, "layer has no filters");
    let p_n = arch.p_n;
    let h_o = layer.h_o();
    let filter_groups = layer.n.div_ceil(p_n);
    let shards = balanced_split(filter_groups, engines)
        .into_iter()
        .enumerate()
        .map(|(index, g)| Shard {
            index,
            filters: g.start * p_n..(g.end * p_n).min(layer.n),
            groups: g.len(),
            rows: 0..h_o,
        })
        .collect();
    ShardPlan { axis: ShardAxis::Filters, shards, filter_groups, p_n, rows: h_o }
}

/// Split `layer` into at most `engines` contiguous output-row bands; each
/// shard computes all `N` filters over its band.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * bands are non-empty, disjoint, contiguous and cover `0..H_O`;
/// * band heights differ by at most one;
/// * `shards.len() == min(engines, H_O)`.
pub fn plan_row_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    let h_o = layer.h_o();
    assert!(h_o >= 1, "layer has no output rows");
    let filter_groups = layer.n.div_ceil(arch.p_n);
    let shards = balanced_split(h_o, engines)
        .into_iter()
        .enumerate()
        .map(|(index, rows)| Shard {
            index,
            filters: 0..layer.n,
            groups: filter_groups,
            rows,
        })
        .collect();
    ShardPlan { axis: ShardAxis::Rows, shards, filter_groups, p_n: arch.p_n, rows: h_o }
}

/// Plan one layer under `mode`. `Auto` compares the two per-layer axes on
/// [`ShardPlan::speedup_bound`]; ties go to rows exactly when the layer's
/// filters cannot occupy the farm (`N < engines·P_N` — the CL1-class
/// shape spatial sharding exists for). [`ShardMode::LayerPipeline`] is a
/// cross-layer mode and has no per-layer plan.
pub fn plan_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize, mode: ShardMode) -> ShardPlan {
    match mode {
        ShardMode::FilterShards => plan_filter_shards(arch, layer, engines),
        ShardMode::Spatial => plan_row_shards(arch, layer, engines),
        ShardMode::Auto => {
            let by_filters = plan_filter_shards(arch, layer, engines);
            let by_rows = plan_row_shards(arch, layer, engines);
            let (bf, br) = (by_filters.speedup_bound(), by_rows.speedup_bound());
            if br > bf || (br == bf && layer.n < engines * arch.p_n) {
                by_rows
            } else {
                by_filters
            }
        }
        ShardMode::LayerPipeline => {
            panic!("LayerPipeline is a cross-layer mode; it has no per-layer shard plan")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(n: usize) -> ConvLayer {
        ConvLayer::new("s", 8, 3, 2, n, 1, 1)
    }

    fn check_invariants(plan: &ShardPlan, n: usize, engines: usize) {
        assert_eq!(plan.axis, ShardAxis::Filters);
        assert_eq!(plan.shards.len(), engines.min(plan.filter_groups));
        let mut next = 0usize;
        for (i, s) in plan.shards.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.filters.start, next, "contiguous");
            assert!(s.filters.start < s.filters.end, "non-empty");
            if s.filters.end != n {
                assert_eq!(s.filters.end % plan.p_n, 0, "group-aligned");
            }
            assert_eq!(s.rows, 0..plan.rows, "filter shards cover all rows");
            next = s.filters.end;
        }
        assert_eq!(next, n, "covers all filters");
        let gmin = plan.shards.iter().map(|s| s.groups).min().unwrap();
        let gmax = plan.shards.iter().map(|s| s.groups).max().unwrap();
        assert!(gmax - gmin <= 1, "balanced");
    }

    #[test]
    fn splits_on_group_boundaries() {
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        for n in [1, 2, 3, 5, 7, 8, 64] {
            for engines in [1, 2, 3, 4, 9] {
                let plan = plan_filter_shards(&cfg, &layer(n), engines);
                check_invariants(&plan, n, engines);
            }
        }
    }

    #[test]
    fn paper_engine_vgg_cl2_split() {
        // VGG-16 CL2: N = 64 on P_N = 7 → 10 filter groups; 4 engines get
        // 3+3+2+2 groups.
        let cfg = ArchConfig::paper_engine();
        let l = ConvLayer::new("CL2", 224, 3, 64, 64, 1, 1);
        let plan = plan_filter_shards(&cfg, &l, 4);
        assert_eq!(plan.filter_groups, 10);
        let groups: Vec<usize> = plan.shards.iter().map(|s| s.groups).collect();
        assert_eq!(groups, vec![3, 3, 2, 2]);
        assert_eq!(plan.shards[0].filters, 0..21);
        assert_eq!(plan.shards[3].filters, 56..64);
        assert!((plan.speedup_bound() - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn more_engines_than_groups_caps_shards() {
        let cfg = ArchConfig::small(3, 2, 4); // P_N = 4
        let plan = plan_filter_shards(&cfg, &layer(6), 8);
        assert_eq!(plan.filter_groups, 2);
        assert_eq!(plan.shards.len(), 2);
        assert_eq!(plan.shards[0].filters, 0..4);
        assert_eq!(plan.shards[1].filters, 4..6);
    }

    #[test]
    fn row_shards_cover_and_balance() {
        let cfg = ArchConfig::small(3, 2, 2);
        for h_w in [8usize, 9, 10, 13] {
            let l = ConvLayer::new("r", h_w, 3, 2, 5, 1, 1);
            for engines in [1usize, 2, 3, 4, 64] {
                let plan = plan_row_shards(&cfg, &l, engines);
                assert_eq!(plan.axis, ShardAxis::Rows);
                assert_eq!(plan.rows, l.h_o());
                assert_eq!(plan.shards.len(), engines.min(l.h_o()));
                let mut next = 0usize;
                for (i, s) in plan.shards.iter().enumerate() {
                    assert_eq!(s.index, i);
                    assert_eq!(s.rows.start, next, "contiguous");
                    assert!(!s.rows.is_empty(), "non-empty");
                    assert_eq!(s.filters, 0..l.n, "row shards cover all filters");
                    next = s.rows.end;
                }
                assert_eq!(next, l.h_o(), "covers all rows");
                let bmin = plan.shards.iter().map(|s| s.rows.len()).min().unwrap();
                let bmax = plan.shards.iter().map(|s| s.rows.len()).max().unwrap();
                assert!(bmax - bmin <= 1, "balanced");
                assert!((plan.speedup_bound() - plan.rows as f64 / bmax as f64).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn paper_engine_vgg_cl1_rows_beat_filters() {
        // VGG-16 CL1 (N = 64, H_O = 224) on the paper engine: only 10
        // filter groups, so an 8-engine farm is capped at 10/2 = 5× on the
        // filter axis while 224 rows split 8 ways bound 8×. Auto must pick
        // rows.
        let cfg = ArchConfig::paper_engine();
        let cl1 = ConvLayer::new("CL1", 224, 3, 3, 64, 1, 1);
        let f = plan_filter_shards(&cfg, &cl1, 8);
        let r = plan_row_shards(&cfg, &cl1, 8);
        assert!((f.speedup_bound() - 5.0).abs() < 1e-9);
        assert!((r.speedup_bound() - 8.0).abs() < 1e-9);
        let auto = plan_shards(&cfg, &cl1, 8, ShardMode::Auto);
        assert_eq!(auto.axis, ShardAxis::Rows);
        assert!((auto.speedup_bound() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn auto_tie_breaks_toward_rows_only_on_narrow_layers() {
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        // N = 4 → 2 groups; H_O = 8. Two engines: both axes bound 2×, and
        // N = 4 == engines·P_N, so the tie goes to the filter axis.
        let wide = ConvLayer::new("w", 8, 3, 2, 4, 1, 1);
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::Auto).axis, ShardAxis::Filters);
        // N = 2 → 1 group; a 1-engine farm ties at 1× on both axes, and
        // N = 2 < 1·2 is false → filters; with 2 engines rows bound 2× > 1×.
        let narrow = ConvLayer::new("n", 8, 3, 2, 2, 1, 1);
        assert_eq!(plan_shards(&cfg, &narrow, 2, ShardMode::Auto).axis, ShardAxis::Rows);
        // Explicit modes pass through.
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::Spatial).axis, ShardAxis::Rows);
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::FilterShards).axis, ShardAxis::Filters);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!("filter".parse::<ShardMode>().unwrap(), ShardMode::FilterShards);
        assert_eq!("pipeline".parse::<ShardMode>().unwrap(), ShardMode::LayerPipeline);
        assert_eq!("spatial".parse::<ShardMode>().unwrap(), ShardMode::Spatial);
        assert_eq!("rows".parse::<ShardMode>().unwrap(), ShardMode::Spatial);
        assert_eq!("auto".parse::<ShardMode>().unwrap(), ShardMode::Auto);
        let err = "bogus".parse::<ShardMode>().unwrap_err().to_string();
        assert!(err.contains("filter|pipeline|spatial|auto"), "error lists every mode: {err}");
        assert_eq!(ShardMode::Spatial.to_string(), "spatial");
        assert_eq!(ShardMode::Auto.as_str(), "auto");
    }
}
